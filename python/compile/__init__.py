"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT lowering."""
