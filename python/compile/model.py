"""L2 — the JAX compute graphs of the BSF applications (build-time only).

Each function here is the *model layer* of one BSF application: it composes
the L1 Pallas kernels (``kernels/``) into the per-iteration computation that
the paper's Algorithm 2 distributes between master and workers. They are
lowered once by ``aot.py`` to HLO text and executed from Rust via PJRT;
Python never runs on the request path.

Artifact granularity (see DESIGN.md §7):

* ``*_map_block`` — a worker-side block call. A worker's sublist of any
  length is processed as ``ceil(len/B)`` zero-padded fixed-shape block calls,
  so the artifact set stays finite (no per-K recompiles).
* ``*_post`` — the master-side post-processing (Compute + StopCond
  quantities, Algorithm 1 steps 5/7).
* ``jacobi_step`` — the fused single-node iteration (used by the calibration
  path and as the L2 fusion showcase).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cimmino, gravity, jacobi
from .kernels.ref import jacobi_post_ref


# --------------------------------------------------------------------------
# BSF-Jacobi (paper §5, Algorithms 3 & 4)
# --------------------------------------------------------------------------

def jacobi_map_block(c_blk, x_blk):
    """Worker Map+local-Reduce over one column block: ``C[:,blk] @ x[blk]``."""
    return (jacobi.jacobi_map_block(c_blk, x_blk),)


def jacobi_post(s, d, x_old):
    """Master post-processing: ``x_new = s + d``, ``||x_new - x_old||^2``.

    Algorithm 4 steps 8 and 10. Returns ``(x_new, sqnorm)``.
    """
    return jacobi_post_ref(s, d, x_old)


def jacobi_step(c, d, x):
    """Fused single-node Jacobi iteration (Pallas matvec + post).

    Returns ``(x_new, sqnorm)``. Used for calibration runs where the whole
    list lives on one node, and as the fused-L2 artifact.
    """
    s = jacobi.jacobi_full_matvec(c, x)
    return jacobi_post_ref(s, d, x)


# --------------------------------------------------------------------------
# BSF-Gravity (paper §6, Algorithms 5 & 6)
# --------------------------------------------------------------------------

def gravity_map_block(y_blk, m_blk, x):
    """Worker Map+local-Reduce over one body block: partial acceleration."""
    return (gravity.gravity_map_block(y_blk, m_blk, x),)


def gravity_post(v, alpha, x, eta):
    """Master post-processing: Algorithm 6 steps 8–10.

    ``delta_t = eta / (||V||^2 ||alpha||^4)`` (13 arithmetic ops in the
    paper's accounting), then the velocity/position updates.
    Returns ``(v_new, x_new, delta_t)``.
    """
    v2 = jnp.dot(v, v)
    a2 = jnp.dot(alpha, alpha)
    delta_t = eta / (v2 * a2 * a2)
    v_new = v + alpha * delta_t
    x_new = x + v_new * delta_t
    return v_new, x_new, delta_t


# --------------------------------------------------------------------------
# BSF-Cimmino (linear inequalities, paper ref [31])
# --------------------------------------------------------------------------

def cimmino_map_block(a_blk, b_blk, x):
    """Worker Map+local-Reduce over one row block: partial correction."""
    return (cimmino.cimmino_map_block(a_blk, b_blk, x),)


def cimmino_post(s, x_old, lam):
    """Master post-processing: relaxed update ``x_new = x_old + lam * s``.

    Returns ``(x_new, sqnorm)`` where sqnorm is ``||x_new - x_old||^2``
    (the termination quantity).
    """
    x_new = x_old + lam * s
    diff = x_new - x_old
    return x_new, jnp.dot(diff, diff)


# --------------------------------------------------------------------------
# Shape specs for AOT lowering (shared with aot.py and the pytest suite)
# --------------------------------------------------------------------------

def f64(*shape):
    """ShapeDtypeStruct helper (the whole stack is f64, like the paper's C++)."""
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def artifact_specs(sizes=(256, 512, 1024, 2048), block=256):
    """The full AOT artifact set: name -> (fn, example_args).

    ``sizes`` are the n values compiled for; ``block`` is the worker block
    width B (must match ``kernels.jacobi.BLOCK_B`` etc.).
    """
    specs = {}
    for n in sizes:
        # AOT map kernels use a single grid step (tile = full extent):
        # interpret-mode Pallas lowers each grid step into a while-loop
        # body with dynamic slices, which XLA-CPU executes ~25x slower
        # than a plain dot. On a real TPU target the multi-step BlockSpec
        # (TILE_N x B streaming through VMEM) is the right shape — see
        # DESIGN.md "Hardware adaptation"; the tiled variants remain
        # exercised by the pytest suite.
        specs[f"jacobi_map_n{n}"] = (
            lambda c, x, _n=n: (jacobi.jacobi_map_block(c, x, tile_n=_n),),
            (f64(n, block), f64(block)),
        )
        specs[f"jacobi_post_n{n}"] = (
            lambda s, d, x: jacobi_post(s, d, x),
            (f64(n), f64(n), f64(n)),
        )
        specs[f"jacobi_step_n{n}"] = (
            lambda c, d, x: jacobi_step(c, d, x),
            (f64(n, n), f64(n), f64(n)),
        )
        specs[f"cimmino_map_n{n}"] = (
            lambda a, b, x, _blk=block: (cimmino.cimmino_map_block(a, b, x, tile=_blk),),
            (f64(block, n), f64(block), f64(n)),
        )
        specs[f"cimmino_post_n{n}"] = (
            lambda s, x, lam: cimmino_post(s, x, lam),
            (f64(n), f64(n), f64()),
        )
    specs[f"gravity_map_b{block}"] = (
        gravity_map_block,
        (f64(block, 3), f64(block), f64(3)),
    )
    specs["gravity_post"] = (
        lambda v, a, x, eta: gravity_post(v, a, x, eta),
        (f64(3), f64(3), f64(3), f64()),
    )
    return specs
