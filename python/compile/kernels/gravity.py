"""Pallas kernel for BSF-Gravity (L1, the worker hot spot).

The BSF-Gravity Map (paper eq. 35) over a worker's block of bodies computes

    f_X(Y_i, m_i) = G * m_i / ||Y_i - X||^2 * (Y_i - X)

folded with 3-vector addition. The kernel tiles the body block into
``TILE_BODIES`` rows per grid step and accumulates the 3-vector folding
in the VMEM-resident output; positions/masses stream through one tile at a
time, so arbitrarily large body blocks have a constant VMEM footprint
(``TILE_BODIES*(3+1)*8`` bytes ≈ 8 KB at 256 bodies, f64).

Padded slots carry mass 0 and therefore contribute exactly 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GRAVITY_G, _R2_FLOOR

#: Body-block size processed per worker call (AOT artifact granularity).
BLOCK_BODIES = 256

#: Bodies per grid step inside the kernel.
TILE_BODIES = 256


def _gravity_kernel(y_ref, m_ref, x_ref, o_ref):
    """One body-tile of the acceleration folding, accumulated over the grid."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = y_ref[...] - x_ref[...][None, :]
    r2 = jnp.maximum(jnp.sum(d * d, axis=1), _R2_FLOOR)
    w = GRAVITY_G * m_ref[...] / r2
    o_ref[...] += jnp.sum(w[:, None] * d, axis=0)


@functools.partial(jax.jit, static_argnames=("tile",))
def gravity_map_block(
    y_blk: jax.Array, m_blk: jax.Array, x: jax.Array, *, tile: int | None = None
):
    """Partial acceleration over one block of motionless bodies (Pallas).

    Args:
      y_blk: ``(B, 3)`` body positions, ``B`` a multiple of ``tile``.
      m_blk: ``(B,)`` body masses (0 in padded slots).
      x: ``(3,)`` probe position.
      tile: bodies per grid step.

    Returns:
      ``(3,)`` partial acceleration (the block's folding).
    """
    b = y_blk.shape[0]
    if tile is None:
        from .jacobi import _fit_tile

        tile = _fit_tile(b, TILE_BODIES)
    if b % tile != 0:
        raise ValueError(f"block={b} not a multiple of tile={tile}")
    grid = (b // tile,)
    return pl.pallas_call(
        _gravity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 3), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((3,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((3,), y_blk.dtype),
        interpret=True,
    )(y_blk, m_blk, x)
