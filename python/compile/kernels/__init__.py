"""L1 Pallas kernels (worker hot spots) and their pure-jnp oracles."""
