"""Pallas kernel for BSF-Cimmino (linear inequalities, paper ref [31]).

The Map over a worker's block of inequality rows computes, per violated row
``a_i . x > b_i``, the projection correction ``-(max(0, a_i.x - b_i) /
||a_i||^2) a_i``; the fold is n-vector addition. Zero rows (padding)
contribute exactly zero.

Tiling: the row block streams through VMEM ``TILE_ROWS`` rows at a time while
the ``(n,)`` x-vector and the ``(n,)`` accumulator stay resident. VMEM per
step (f64): ``TILE_ROWS*n*8 + 2*n*8 + TILE_ROWS*8`` — with TILE_ROWS = 64 and
n = 2048 that is ~1.1 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _R2_FLOOR

#: Row-block size processed per worker call (AOT artifact granularity).
BLOCK_ROWS = 256

#: Rows per grid step inside the kernel.
TILE_ROWS = 64


def _cimmino_kernel(a_ref, b_ref, x_ref, o_ref):
    """One row-tile of the Cimmino correction folding."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    resid = a @ x_ref[...] - b_ref[...]
    viol = jnp.maximum(resid, 0.0)
    nrm2 = jnp.sum(a * a, axis=1)
    w = jnp.where(nrm2 > 0.0, viol / jnp.maximum(nrm2, _R2_FLOOR), 0.0)
    o_ref[...] += -(w @ a)


@functools.partial(jax.jit, static_argnames=("tile",))
def cimmino_map_block(
    a_blk: jax.Array, b_blk: jax.Array, x: jax.Array, *, tile: int | None = None
):
    """Partial Cimmino correction over one block of inequality rows (Pallas).

    Args:
      a_blk: ``(B, n)`` constraint rows, ``B`` a multiple of ``tile``.
      b_blk: ``(B,)`` right-hand sides.
      x: ``(n,)`` current approximation.
      tile: rows per grid step.

    Returns:
      ``(n,)`` partial correction (the block's folding).
    """
    b, n = a_blk.shape
    if tile is None:
        from .jacobi import _fit_tile

        tile = _fit_tile(b, TILE_ROWS)
    if b % tile != 0:
        raise ValueError(f"block={b} not a multiple of tile={tile}")
    grid = (b // tile,)
    return pl.pallas_call(
        _cimmino_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a_blk.dtype),
        interpret=True,
    )(a_blk, b_blk, x)
