"""Pallas kernels for BSF-Jacobi (L1, the worker hot spot).

The BSF-Jacobi Map (paper eq. 16) over a worker's column block is a
column-block matvec ``s_blk = C[:, block] @ x[block]``. The kernel tiles the
output vector into ``TILE_N`` rows per grid step so that one
``(TILE_N, B)`` tile of C plus the ``(B,)`` x-block and the ``(TILE_N,)``
accumulator stream through VMEM; the 2-D tile shape is MXU-friendly
(``(TILE_N, B) @ (B, 1)``).

VMEM budget per grid step (f64): ``TILE_N*B*8 + B*8 + TILE_N*8`` bytes.
With TILE_N = 256, B = 256 that is ~0.53 MB — comfortably under the ~16 MB
VMEM of a TPU core, leaving room for double-buffering (see DESIGN.md §9).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs on the Rust CPU client. Real-TPU performance is *estimated*
from the BlockSpec in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Column-block width processed per worker call. Fixed so the AOT artifact
#: set stays finite: a worker's sublist of any length is processed as
#: ceil(len/B) calls on zero-padded blocks.
BLOCK_B = 256

#: Output-vector tile height per grid step.
TILE_N = 256


def _fit_tile(n: int, preferred: int) -> int:
    """Largest divisor of ``n`` that does not exceed ``preferred``.

    AOT sizes are powers of two so this returns ``preferred`` there; the
    pytest/hypothesis sweep exercises irregular sizes too.
    """
    t = min(n, preferred)
    while n % t != 0:
        t -= 1
    return t


def _matvec_kernel(c_ref, x_ref, o_ref):
    """One row-tile of the column-block matvec: ``o = C_tile @ x_blk``."""
    o_ref[...] = c_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_n",))
def jacobi_map_block(
    c_blk: jax.Array, x_blk: jax.Array, *, tile_n: int | None = None
):
    """Partial folding of the Jacobi Map over one column block (Pallas).

    Args:
      c_blk: ``(n, B)`` column block of C; ``n`` must be a multiple of
        ``tile_n`` (all AOT sizes are powers of two ≥ 256).
      x_blk: ``(B,)`` slice of the current approximation (zero-padded tail).
      tile_n: row-tile height (grid dimension); defaults to the largest
        divisor of ``n`` not exceeding ``TILE_N``.

    Returns:
      ``(n,)`` partial folding, exactly ``c_blk @ x_blk``.
    """
    n, b = c_blk.shape
    if tile_n is None:
        tile_n = _fit_tile(n, TILE_N)
    if n % tile_n != 0:
        raise ValueError(f"n={n} not a multiple of tile_n={tile_n}")
    grid = (n // tile_n,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, b), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), c_blk.dtype),
        interpret=True,
    )(c_blk, x_blk)


def _full_matvec_kernel(c_ref, x_ref, o_ref):
    """Row-tile × column-block step of the full matvec with accumulation.

    Grid is ``(row_tiles, col_blocks)``; the column dimension is the reduction
    axis, so the output tile is revisited once per column block and
    accumulated in place (initialised on the first visit).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += c_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("tile_n", "block_b"))
def jacobi_full_matvec(
    c: jax.Array,
    x: jax.Array,
    *,
    tile_n: int | None = None,
    block_b: int | None = None,
):
    """Full ``C @ x`` as a 2-D-grid Pallas kernel (used by the fused step).

    The output tile stays VMEM-resident across the reduction axis; C streams
    through one ``(tile_n, block_b)`` tile at a time.
    """
    n, m = c.shape
    if tile_n is None:
        tile_n = _fit_tile(n, TILE_N)
    if block_b is None:
        block_b = _fit_tile(m, BLOCK_B)
    if n % tile_n != 0 or m % block_b != 0:
        raise ValueError(f"shape ({n},{m}) not tiled by ({tile_n},{block_b})")
    grid = (n // tile_n, m // block_b)
    return pl.pallas_call(
        _full_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, block_b), lambda i, j: (i, j)),
            pl.BlockSpec((block_b,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), c.dtype),
        interpret=True,
    )(c, x)
