"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact ``*_ref`` counterpart here,
written with plain ``jax.numpy`` only. ``python/tests/`` asserts allclose
between the two across hypothesis-generated shapes; the Rust integration tests
check the AOT artifacts against values produced by these functions.

All reference functions operate on the *block* granularity used by the BSF
workers: a worker's sublist is processed as a sequence of fixed-shape blocks,
the last block zero-padded. Padding exactness (zero columns / zero masses /
zero rows contribute the identity of the fold operation) is part of the
contract and is tested explicitly.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Gravitational constant used by the simplified n-body problem (paper §6).
#: The paper leaves G symbolic; we fix G = 1 (units absorbed into masses),
#: which preserves the algorithm's arithmetic-operation counts exactly.
GRAVITY_G = 1.0

#: Guard for padded bodies that coincide with the probe point. Any padded
#: entry has mass 0, so its contribution is exactly 0 regardless of the guard.
_R2_FLOOR = 1e-30


def jacobi_map_block_ref(c_blk, x_blk):
    """Partial folding of BSF-Jacobi's Map over one column block.

    Paper eq. (16): ``F_x(j) = x_j * c_j`` (j-th column of C scaled by the
    j-th coordinate of x); the local Reduce is vector addition, so a block's
    folding is ``sum_j x_j c_j == C[:, block] @ x[block]``.

    Args:
      c_blk: ``(n, B)`` column block of the iteration matrix C.
      x_blk: ``(B,)`` matching slice of the current approximation.

    Returns:
      ``(n,)`` partial folding s_blk.
    """
    return c_blk @ x_blk


def jacobi_post_ref(s, d, x_old):
    """Master-side post-processing of one Jacobi iteration.

    Algorithm 4 steps 8 and 10: ``x_new = s + d`` and the squared-norm
    termination quantity ``||x_new - x_old||^2``. Returns ``(x_new, sqnorm)``.
    """
    x_new = s + d
    diff = x_new - x_old
    return x_new, jnp.dot(diff, diff)


def gravity_map_block_ref(y_blk, m_blk, x):
    """Partial acceleration over one block of motionless bodies.

    Paper eq. (35): ``f_X(Y_i, m_i) = G * m_i / ||Y_i - X||^2 * (Y_i - X)``,
    folded with 3-vector addition. Bodies with zero mass (padding) contribute
    exactly zero.

    Args:
      y_blk: ``(B, 3)`` body positions.
      m_blk: ``(B,)`` body masses (0 for padded slots).
      x: ``(3,)`` current position of the probe body.

    Returns:
      ``(3,)`` partial acceleration.
    """
    d = y_blk - x[None, :]
    r2 = jnp.maximum(jnp.sum(d * d, axis=1), _R2_FLOOR)
    w = GRAVITY_G * m_blk / r2
    return jnp.sum(w[:, None] * d, axis=0)


def gravity_post_ref(v, alpha, x, eta):
    """Master-side post-processing of one BSF-Gravity iteration.

    Algorithm 6 steps 8–10 with the paper's time-slot rule
    ``Delta_t(V, alpha) = eta / (||V||^2 * ||alpha||^4)``.

    Returns ``(v_new, x_new, delta_t)``.
    """
    v2 = jnp.dot(v, v)
    a2 = jnp.dot(alpha, alpha)
    delta_t = eta / (v2 * a2 * a2)
    v_new = v + alpha * delta_t
    x_new = x + v_new * delta_t
    return v_new, x_new, delta_t


def cimmino_map_block_ref(a_blk, b_blk, x):
    """Partial Cimmino correction over one block of inequality rows.

    For the system ``A x <= b`` (ref [31]), each violated row contributes the
    projection step ``-(max(0, a_i.x - b_i)/||a_i||^2) a_i``; the fold is
    vector addition. Zero rows (padding) contribute exactly zero.

    Args:
      a_blk: ``(B, n)`` block of constraint rows.
      b_blk: ``(B,)`` right-hand sides.
      x: ``(n,)`` current approximation.

    Returns:
      ``(n,)`` partial correction vector.
    """
    resid = a_blk @ x - b_blk
    viol = jnp.maximum(resid, 0.0)
    nrm2 = jnp.sum(a_blk * a_blk, axis=1)
    w = jnp.where(nrm2 > 0.0, viol / jnp.maximum(nrm2, _R2_FLOOR), 0.0)
    return -(w @ a_blk)


def jacobi_step_ref(c, d, x):
    """One full Jacobi iteration ``x' = C x + d`` with termination quantity.

    This is the L2 (whole-model) oracle: the fused artifact
    ``jacobi_step_n{N}`` must match it. Returns ``(x_new, sqnorm)``.
    """
    s = c @ x
    return jacobi_post_ref(s, d, x)
