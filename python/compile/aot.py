"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust/PJRT.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every lowering uses ``return_tuple=True``; the Rust runtime unwraps with
``to_tuple()``. A ``manifest.json`` records, per artifact, the argument and
result shapes/dtypes so the Rust artifact registry can validate calls.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts [--sizes 256,512]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """Convert a jax ``Lowered`` to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_meta(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_one(name: str, fn, example_args) -> tuple[str, dict]:
    """Lower one artifact; returns (hlo_text, manifest_entry)."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_specs = jax.eval_shape(fn, *example_args)
    if not isinstance(out_specs, tuple):
        out_specs = (out_specs,)
    entry = {
        "inputs": [_spec_meta(a) for a in example_args],
        "outputs": [_spec_meta(o) for o in out_specs],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, entry


def build(out_dir: pathlib.Path, sizes, block: int) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"block": block, "sizes": list(sizes), "artifacts": {}}
    for name, (fn, args) in model.artifact_specs(sizes, block).items():
        text, entry = lower_one(name, fn, args)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        entry["file"] = path.name
        manifest["artifacts"][name] = entry
        print(f"  {name}: {len(text)} chars -> {path.name}")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="256,512,1024,2048")
    ap.add_argument("--block", type=int, default=256)
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    manifest = build(pathlib.Path(args.out_dir), sizes, args.block)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
