"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (n, block widths, tile factors) and dtypes; the
kernels must match ``ref.py`` to tight f64 tolerances and exact f32-relative
tolerances. Padding exactness — a zero-padded tail must contribute the fold
identity — is tested explicitly because the Rust workers rely on it.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cimmino, gravity, jacobi, ref

F64 = np.float64
F32 = np.float32

# Valid (tile | size) pairs: tile divides size.
_TILES = [32, 64, 128, 256]


def _mk_rng(seed):
    return np.random.default_rng(seed)


def _allclose(got, want, dtype):
    rtol = 1e-12 if dtype == F64 else 1e-5
    atol = 1e-12 if dtype == F64 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


# --------------------------------------------------------------------------
# jacobi_map_block
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 6),
    tile=st.sampled_from(_TILES),
    b=st.sampled_from([32, 64, 256]),
    dtype=st.sampled_from([F64, F32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_jacobi_map_block_matches_ref(n_tiles, tile, b, dtype, seed):
    rng = _mk_rng(seed)
    n = n_tiles * tile
    c = jnp.asarray(rng.standard_normal((n, b)), dtype=dtype)
    x = jnp.asarray(rng.standard_normal(b), dtype=dtype)
    got = jacobi.jacobi_map_block(c, x, tile_n=tile)
    _allclose(got, ref.jacobi_map_block_ref(c, x), dtype)


def test_jacobi_map_block_rejects_untiled_n():
    c = jnp.zeros((100, 32))
    x = jnp.zeros(32)
    with pytest.raises(ValueError, match="not a multiple"):
        jacobi.jacobi_map_block(c, x, tile_n=64)


def test_jacobi_map_padding_exact(rng):
    """A zero-padded column tail contributes exactly nothing."""
    n, b, used = 256, 256, 100
    c = np.zeros((n, b))
    x = np.zeros(b)
    c[:, :used] = rng.standard_normal((n, used))
    x[:used] = rng.standard_normal(used)
    got = jacobi.jacobi_map_block(jnp.asarray(c), jnp.asarray(x))
    want = c[:, :used] @ x[:used]
    np.testing.assert_array_equal(np.asarray(got), want)


# --------------------------------------------------------------------------
# jacobi_full_matvec (fused step's hot spot)
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n_tiles=st.integers(1, 4),
    m_tiles=st.integers(1, 4),
    tile=st.sampled_from([32, 64]),
    dtype=st.sampled_from([F64, F32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_jacobi_full_matvec_matches_ref(n_tiles, m_tiles, tile, dtype, seed):
    rng = _mk_rng(seed)
    n, m = n_tiles * tile, m_tiles * tile
    c = jnp.asarray(rng.standard_normal((n, m)), dtype=dtype)
    x = jnp.asarray(rng.standard_normal(m), dtype=dtype)
    got = jacobi.jacobi_full_matvec(c, x, tile_n=tile, block_b=tile)
    _allclose(got, c @ x, dtype)


def test_jacobi_step_matches_ref(rng):
    n = 128
    c = jnp.asarray(rng.standard_normal((n, n)))
    d = jnp.asarray(rng.standard_normal(n))
    x = jnp.asarray(rng.standard_normal(n))
    from compile import model

    x_new, sqnorm = model.jacobi_step(c, d, x)
    want_x, want_sq = ref.jacobi_step_ref(c, d, x)
    _allclose(x_new, want_x, F64)
    _allclose(sqnorm, want_sq, F64)


# --------------------------------------------------------------------------
# gravity_map_block
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 4),
    tile=st.sampled_from([32, 64, 256]),
    dtype=st.sampled_from([F64, F32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gravity_map_block_matches_ref(tiles, tile, dtype, seed):
    rng = _mk_rng(seed)
    b = tiles * tile
    y = jnp.asarray(rng.standard_normal((b, 3)) * 10.0, dtype=dtype)
    m = jnp.asarray(np.abs(rng.standard_normal(b)) + 0.1, dtype=dtype)
    x = jnp.asarray(rng.standard_normal(3), dtype=dtype)
    got = gravity.gravity_map_block(y, m, x, tile=tile)
    want = ref.gravity_map_block_ref(y, m, x)
    rtol = 1e-10 if dtype == F64 else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=rtol)


def test_gravity_padding_exact(rng):
    """Zero-mass padded bodies contribute exactly zero, even at the probe."""
    b, used = 256, 77
    y = np.zeros((b, 3))
    m = np.zeros(b)
    y[:used] = rng.standard_normal((used, 3)) * 5.0
    m[:used] = np.abs(rng.standard_normal(used)) + 0.1
    x = rng.standard_normal(3)
    # Padded bodies sit exactly at the probe position: worst case for the
    # r^2 guard. Mass 0 must still kill the contribution.
    y[used:] = x
    got = gravity.gravity_map_block(jnp.asarray(y), jnp.asarray(m), jnp.asarray(x))
    want = ref.gravity_map_block_ref(
        jnp.asarray(y[:used]), jnp.asarray(m[:used]), jnp.asarray(x)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_gravity_two_body_analytic():
    """Single unit-mass body at distance r: |alpha| = G/r^2 * r = G/r."""
    y = np.zeros((32, 3))
    m = np.zeros(32)
    y[0] = [2.0, 0.0, 0.0]
    m[0] = 1.0
    x = jnp.zeros(3)
    got = np.asarray(
        gravity.gravity_map_block(jnp.asarray(y), jnp.asarray(m), x, tile=32)
    )
    # d = (2,0,0), r^2 = 4 -> alpha = 1/4 * (2,0,0) = (0.5, 0, 0)
    np.testing.assert_allclose(got, [0.5, 0.0, 0.0], atol=1e-15)


# --------------------------------------------------------------------------
# cimmino_map_block
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 4),
    tile=st.sampled_from([32, 64]),
    n=st.sampled_from([16, 64, 256]),
    dtype=st.sampled_from([F64, F32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cimmino_map_block_matches_ref(tiles, tile, n, dtype, seed):
    rng = _mk_rng(seed)
    b = tiles * tile
    a = jnp.asarray(rng.standard_normal((b, n)), dtype=dtype)
    rhs = jnp.asarray(rng.standard_normal(b), dtype=dtype)
    x = jnp.asarray(rng.standard_normal(n), dtype=dtype)
    got = cimmino.cimmino_map_block(a, rhs, x, tile=tile)
    _allclose(got, ref.cimmino_map_block_ref(a, rhs, x), dtype)


def test_cimmino_satisfied_rows_contribute_zero(rng):
    """Rows with a_i.x <= b_i must contribute nothing."""
    n = 64
    a = rng.standard_normal((32, n))
    x = rng.standard_normal(n)
    rhs = a @ x + 1.0  # all satisfied with slack 1
    got = cimmino.cimmino_map_block(
        jnp.asarray(a), jnp.asarray(rhs), jnp.asarray(x), tile=32
    )
    np.testing.assert_array_equal(np.asarray(got), np.zeros(n))


def test_cimmino_padding_exact(rng):
    """Zero rows (padding) contribute exactly zero."""
    n, b, used = 64, 64, 20
    a = np.zeros((b, n))
    rhs = np.zeros(b)
    a[:used] = rng.standard_normal((used, n))
    rhs[:used] = rng.standard_normal(used)
    x = rng.standard_normal(n)
    got = cimmino.cimmino_map_block(
        jnp.asarray(a), jnp.asarray(rhs), jnp.asarray(x), tile=64
    )
    want = ref.cimmino_map_block_ref(
        jnp.asarray(a[:used]), jnp.asarray(rhs[:used]), jnp.asarray(x)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


def test_cimmino_single_violated_row_projects_onto_halfspace(rng):
    """One violated row: x + correction must land on the hyperplane a.x = b."""
    n = 16
    a = np.zeros((32, n))
    rhs = np.zeros(32)
    a[0] = rng.standard_normal(n)
    x = rng.standard_normal(n)
    rhs[0] = a[0] @ x - 3.0  # violated by 3
    corr = np.asarray(
        cimmino.cimmino_map_block(jnp.asarray(a), jnp.asarray(rhs), jnp.asarray(x), tile=32)
    )
    np.testing.assert_allclose(a[0] @ (x + corr), rhs[0], atol=1e-10)
