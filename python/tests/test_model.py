"""L2 correctness: model-layer iteration bodies and the promotion theorem.

The distributed identity the whole BSF parallelization rests on (paper
eq. 5, the promotion theorem): folding block partials equals the full fold.
We verify it at the model layer — block map calls + master reduce must equal
the fused single-node step bit-for-bit up to f64 roundoff.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@settings(max_examples=10, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_jacobi_promotion_blocks_equal_full(n_blocks, seed):
    """sum_k (C[:,blk_k] @ x[blk_k]) == C @ x  (eq. 5 for BSF-Jacobi)."""
    rng = np.random.default_rng(seed)
    b = 64
    n = n_blocks * b
    c = rng.standard_normal((n, n))
    x = rng.standard_normal(n)
    partial = np.zeros(n)
    for k in range(n_blocks):
        blk = slice(k * b, (k + 1) * b)
        (s_k,) = model.jacobi_map_block(jnp.asarray(c[:, blk]), jnp.asarray(x[blk]))
        partial += np.asarray(s_k)
    np.testing.assert_allclose(partial, c @ x, rtol=1e-10, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(n_blocks=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_gravity_promotion_blocks_equal_full(n_blocks, seed):
    rng = np.random.default_rng(seed)
    b = 64
    nb = n_blocks * b
    y = rng.standard_normal((nb, 3)) * 10.0
    m = np.abs(rng.standard_normal(nb)) + 0.1
    x = rng.standard_normal(3)
    acc = np.zeros(3)
    for k in range(n_blocks):
        blk = slice(k * b, (k + 1) * b)
        (a_k,) = model.gravity_map_block(
            jnp.asarray(y[blk]), jnp.asarray(m[blk]), jnp.asarray(x)
        )
        acc += np.asarray(a_k)
    want = np.asarray(ref.gravity_map_block_ref(jnp.asarray(y), jnp.asarray(m), jnp.asarray(x)))
    np.testing.assert_allclose(acc, want, rtol=1e-9, atol=1e-9)


def test_jacobi_post_matches_ref(rng):
    n = 128
    s = jnp.asarray(rng.standard_normal(n))
    d = jnp.asarray(rng.standard_normal(n))
    x = jnp.asarray(rng.standard_normal(n))
    x_new, sq = model.jacobi_post(s, d, x)
    want_x, want_sq = ref.jacobi_post_ref(s, d, x)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(want_x))
    np.testing.assert_allclose(float(sq), float(want_sq))


def test_gravity_post_matches_ref(rng):
    v = jnp.asarray(rng.standard_normal(3))
    a = jnp.asarray(rng.standard_normal(3))
    x = jnp.asarray(rng.standard_normal(3))
    eta = jnp.asarray(0.01)
    got = model.gravity_post(v, a, x, eta)
    want = ref.gravity_post_ref(v, a, x, eta)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-14)


def test_gravity_post_delta_t_rule(rng):
    """delta_t == eta / (||V||^2 ||alpha||^4) exactly."""
    v = jnp.asarray([1.0, 2.0, 2.0])  # ||v||^2 = 9
    a = jnp.asarray([0.0, 1.0, 0.0])  # ||a||^2 = 1
    eta = jnp.asarray(4.5)
    _, _, dt = model.gravity_post(v, a, jnp.zeros(3), eta)
    np.testing.assert_allclose(float(dt), 0.5)


def test_cimmino_post_relaxation(rng):
    n = 64
    s = jnp.asarray(rng.standard_normal(n))
    x = jnp.asarray(rng.standard_normal(n))
    lam = jnp.asarray(1.5)
    x_new, sq = model.cimmino_post(s, x, lam)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(x) + 1.5 * np.asarray(s))
    np.testing.assert_allclose(float(sq), float(np.sum((1.5 * np.asarray(s)) ** 2)))


def test_jacobi_sequential_convergence(rng):
    """End-to-end L2 check: Jacobi on a diagonally dominant system converges.

    System: A = ones + diag(extra), strongly dominant; solution x*=(1..1)
    by construction of b = A @ ones.
    """
    n = 128
    a = np.ones((n, n)) + np.diag(np.arange(1, n + 1) + n)
    b = a @ np.ones(n)
    dinv = 1.0 / np.diag(a)
    c = -a * dinv[:, None]
    np.fill_diagonal(c, 0.0)
    d = b * dinv

    x = jnp.asarray(d)
    cj, dj = jnp.asarray(c), jnp.asarray(d)
    for _ in range(200):
        x, sq = model.jacobi_step(cj, dj, x)
        if float(sq) < 1e-24:
            break
    np.testing.assert_allclose(np.asarray(x), np.ones(n), rtol=1e-10)


def test_artifact_specs_complete():
    """Every expected artifact name is present with consistent shapes."""
    specs = model.artifact_specs(sizes=(256,), block=256)
    names = set(specs)
    assert {
        "jacobi_map_n256",
        "jacobi_post_n256",
        "jacobi_step_n256",
        "cimmino_map_n256",
        "cimmino_post_n256",
        "gravity_map_b256",
        "gravity_post",
    } == names
    fn, args = specs["jacobi_map_n256"]
    assert args[0].shape == (256, 256) and args[1].shape == (256,)
