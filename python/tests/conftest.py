"""Shared pytest fixtures for the L1/L2 test suite."""

from __future__ import annotations

import os
import sys

import jax

# The whole stack is f64 (like the paper's C++ implementation); must be set
# before any tracing happens.
jax.config.update("jax_enable_x64", True)

# Make `compile` importable when pytest is run from python/ or the repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0xB5F)
