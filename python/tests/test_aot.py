"""AOT path: lowering produces parseable HLO text + a consistent manifest.

These tests exercise exactly the path `make artifacts` runs, at small sizes
so they stay fast. Numeric equivalence of the *artifacts* (as opposed to the
traced functions) is re-checked by executing the HLO through the XLA CPU
client — the same engine the Rust runtime drives via PJRT.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(out, sizes=(256,), block=256)
    return out, manifest


def test_manifest_lists_all_artifacts(built):
    out, manifest = built
    assert set(manifest["artifacts"]) == set(model.artifact_specs((256,), 256))
    for name, entry in manifest["artifacts"].items():
        assert (out / entry["file"]).exists(), name
        assert entry["inputs"] and entry["outputs"]


def test_manifest_roundtrips_json(built):
    out, _ = built
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["block"] == 256
    assert manifest["sizes"] == [256]


def test_hlo_text_is_valid_hlo(built):
    out, manifest = built
    for entry in manifest["artifacts"].values():
        text = (out / entry["file"]).read_text()
        assert text.startswith("HloModule"), entry["file"]


def test_hlo_text_reparses_with_cxx_parser(built):
    """The artifacts must round-trip through the C++ HLO text parser.

    This is the exact parser the Rust runtime invokes
    (``HloModuleProto::from_text_file``); numeric execution of the parsed
    module is covered by the Rust integration tests (`rust/tests/`), which
    run it on the PJRT CPU client.
    """
    from jax._src.lib import xla_client as xc

    out, manifest = built
    for name, entry in manifest["artifacts"].items():
        text = (out / entry["file"]).read_text()
        mod = xc._xla.hlo_module_from_text(text)
        # A successful parse is the contract; also sanity-check that the
        # parsed module kept every parameter declaration.
        reparsed = mod.to_string()
        assert reparsed.count("parameter(") >= len(entry["inputs"]), name


def test_gravity_post_artifact_shapes(built):
    out, manifest = built
    entry = manifest["artifacts"]["gravity_post"]
    assert [i["shape"] for i in entry["inputs"]] == [[3], [3], [3], []]
    assert [o["shape"] for o in entry["outputs"]] == [[3], [3], []]
    assert all(i["dtype"] == "float64" for i in entry["inputs"])


def test_lower_one_is_deterministic():
    """Same spec -> same HLO text (sha recorded in manifest must be stable)."""
    specs = model.artifact_specs((256,), 256)
    fn, args = specs["jacobi_post_n256"]
    t1, e1 = aot.lower_one("jacobi_post_n256", fn, args)
    t2, e2 = aot.lower_one("jacobi_post_n256", fn, args)
    assert e1["sha256"] == e2["sha256"]
    assert t1 == t2
