//! End-to-end driver (DESIGN.md experiment E2E): the full BSF pipeline on a
//! real workload, proving all layers compose.
//!
//! 1. **Live execution** — BSF-Jacobi on the paper's scalable system
//!    (n = 2048) through the master/worker skeleton with the AOT Pallas
//!    kernel (L1) inside the L2 step, loaded via PJRT (runtime) under the
//!    Rust coordinator (L3). Convergence is checked against the known
//!    solution x* = (1, …, 1).
//! 2. **Calibration** — cost parameters measured on one master + one worker
//!    (the paper's §6 recipe).
//! 3. **Analytic boundary** — K_BSF from eq. (14), *before* any run at
//!    scale.
//! 4. **Simulated scale-out** — the discrete-event cluster executes
//!    Algorithm 2 for K up to ~2.4·K_BSF using the measured compute times
//!    and the modelled interconnect; the empirical peak K_test is compared
//!    to K_BSF with the paper's error metric (eq. 26). Headline: error
//!    within the paper's ≤ 15 % band.
//!
//! ```text
//! make artifacts && cargo run --release --example jacobi_scalability
//! ```

use std::sync::Arc;

use bsf::coordinator::{BsfProblem, LiveRunner};
use bsf::experiments::{
    calibrate, effective_net_with_latency, k_sweep, sampled_provider, simulated_curve,
    ExperimentCtx,
};
use bsf::linalg::generators::paper_system;
use bsf::model::scalability::peak_smoothed;
use bsf::model::{prediction_error, BsfModel};
use bsf::problems::JacobiProblem;
use bsf::util::{table::sci, Rng, Table};

fn main() -> anyhow::Result<()> {
    let n = 2048;
    let mut ctx = ExperimentCtx::default();
    // This machine's node computes ~10x faster than the paper's 2010-era
    // Xeon; to stay in the model's compute-intensive regime (comp/comm in
    // the hundreds, like Table 2) the modelled interconnect is a
    // proportionally modern fabric (1 µs latency, 10 GB/s).
    ctx.cluster.net = bsf::net::NetworkParams::fast_fabric();
    println!("== BSF end-to-end driver: BSF-Jacobi, n = {n} ==\n");
    if ctx.artifact_dir.is_none() {
        eprintln!("note: artifacts/ missing — run `make artifacts` for the kernel path");
    }

    // -- 1. live execution on this machine (fixed iteration budget: the
    //    paper's matrix is only weakly dominant, so we measure timing and
    //    check the residual direction rather than full convergence).
    let problem: Arc<dyn BsfProblem> = Arc::new(JacobiProblem::new(paper_system(n), 1e-18));
    let mut runner = LiveRunner::new(4, 30);
    runner.artifact_dir = ctx.artifact_dir.clone();
    let live = runner.run(problem.clone())?;
    let m = live.metrics.without_warmup(2);
    println!(
        "live run (K=4): {} iterations, mean iteration {} (map {}, post {})",
        live.iterations,
        sci(m.total_summary().mean),
        sci(m.map_summary().mean),
        sci(m.post_summary().mean),
    );

    // -- 2. calibration (1 master + 1 worker, kernels when available)
    let cal_problem: Arc<dyn BsfProblem> = Arc::new(JacobiProblem::new(paper_system(n), 1e-18));
    let (params, cal) = calibrate(&ctx, cal_problem)?;
    println!("\ncalibrated cost parameters (projected on the modelled cluster):");
    println!(
        "  t_c = {}  t_p = {}  t_a = {}  t_Map = {}  comp/comm = {:.0}",
        sci(params.t_c),
        sci(params.t_p),
        sci(params.t_a),
        sci(params.t_map),
        params.comp_comm_ratio()
    );

    // -- 3. analytic boundary (eq. 14)
    let model = BsfModel::new(params);
    let k_bsf = model.k_bsf();
    println!("\nanalytic boundary (eq. 14): K_BSF = {k_bsf:.1}");

    // -- 4. simulated scale-out with measured compute samples
    let ks = k_sweep(k_bsf, false);
    let mut sim = ctx.sim_params(n, n);
    sim.net = effective_net_with_latency(params.t_c, n, n, ctx.cluster.net.latency);
    let prov = sampled_provider(&cal, &params, ctx.seed);
    let mut rng = Rng::new(ctx.seed);
    let curve = simulated_curve(&ctx, &sim, n, &prov, &ks, 7, &mut rng);
    let pk = peak_smoothed(&curve, 5).expect("curve");
    let err = prediction_error(pk.k as f64, k_bsf);

    let mut t = Table::new(
        "speedup curve (simulated cluster, measured compute)",
        &["K", "T_K", "a_sim", "a_BSF"],
    );
    for p in curve.iter().step_by((curve.len() / 16).max(1)) {
        t.row(&[
            p.k.to_string(),
            sci(p.t_k),
            format!("{:.1}", p.speedup),
            format!("{:.1}", model.speedup(p.k)),
        ]);
    }
    println!("\n{}", t.render());
    println!(
        "RESULT: K_test = {} (peak speedup {:.1}x), K_BSF = {k_bsf:.1}, \
         prediction error = {:.1}% (paper band: <= 15%)",
        pk.k,
        pk.speedup,
        100.0 * err
    );
    ctx.save("e2e_jacobi_curve", &t);
    if err > 0.25 {
        anyhow::bail!("prediction error {err:.2} outside tolerance");
    }
    Ok(())
}
