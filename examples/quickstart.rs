//! Quickstart: solve a linear system with the BSF skeleton and predict its
//! scalability boundary — the library's two core capabilities in ~60 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use bsf::coordinator::{run_sequential, BsfProblem, LiveRunner};
use bsf::linalg::generators::dominant_system;
use bsf::model::BsfModel;
use bsf::net::NetworkParams;
use bsf::problems::JacobiProblem;

fn main() -> anyhow::Result<()> {
    // 1. A diagonally dominant system A x = b with solution x* = (1, …, 1).
    let n = 512;
    let problem = JacobiProblem::new(dominant_system(n), 1e-24);

    // 2. Sequential reference (Algorithm 1).
    let seq = run_sequential(&problem, 500, None);
    println!(
        "sequential: {} iterations, converged = {}, residual = {:.2e}",
        seq.iterations,
        seq.converged,
        problem.system().residual(&seq.final_approx)
    );

    // 3. The same algorithm through the parallel skeleton (Algorithm 2),
    //    4 live workers, PJRT kernels on the hot path when artifacts exist.
    let artifact_dir = std::path::Path::new("artifacts")
        .join("manifest.json")
        .exists()
        .then(|| std::path::PathBuf::from("artifacts"));
    let problem: Arc<dyn BsfProblem> = Arc::new(JacobiProblem::new(dominant_system(n), 1e-24));
    let mut runner = LiveRunner::new(4, 500);
    runner.artifact_dir = artifact_dir;
    let live = runner.run(problem.clone())?;
    println!(
        "live (K=4): {} iterations, converged = {}, wall = {:.3}s",
        live.iterations, live.converged, live.wall
    );
    let max_dev: f64 = live
        .final_approx
        .iter()
        .zip(&seq.final_approx)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("live vs sequential max deviation: {max_dev:.2e}");

    // 4. Predict the scalability boundary on the paper's cluster *before*
    //    running anything at scale (the paper's headline capability).
    //    At n = 512 a cluster wouldn't help (comm-bound — the model says
    //    so!); the boundary becomes meaningful as n grows:
    let tau_op = 9.3e-10; // seconds/arithmetic-op, Tornado-SUSU class node
    for n_pred in [512usize, 4_096, 16_000, 64_000] {
        let mut spec = problem.cost_spec();
        spec.l = n_pred;
        spec.words_down = n_pred;
        spec.words_up = n_pred;
        spec.ops_map_per_elem = n_pred as f64;
        spec.ops_combine = n_pred as f64;
        let params = spec.cost_params(tau_op, &NetworkParams::tornado_susu());
        let model = BsfModel::new(params);
        println!(
            "predicted for a Tornado-SUSU-class cluster, n = {n_pred:>6}: \
             K_BSF = {:>4.0} workers (peak speedup ≈ {:.0}x, comp/comm = {:.0})",
            model.k_bsf(),
            model.speedup((model.k_bsf().round() as usize).max(1)),
            params.comp_comm_ratio(),
        );
    }
    Ok(())
}
