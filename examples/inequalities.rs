//! BSF-Cimmino demo (paper ref [31]): solve a system of linear
//! inequalities `A x ≤ b` by simultaneous projections through the BSF
//! skeleton, verify feasibility of the result, and forecast scalability.
//!
//! ```text
//! cargo run --release --example inequalities
//! ```

use std::sync::Arc;

use bsf::coordinator::{BsfProblem, LiveRunner};
use bsf::linalg::generators::feasible_inequalities;
use bsf::model::BsfModel;
use bsf::net::NetworkParams;
use bsf::problems::CimminoProblem;

fn main() -> anyhow::Result<()> {
    let (m, n) = (2_000usize, 64usize);
    let sys = feasible_inequalities(m, n, 0.1, 2026);
    println!("== BSF-Cimmino: {m} inequalities in R^{n} ==");

    let problem = CimminoProblem::new(sys, 1.5, 1e-18);
    let start_violations = problem.violated(&problem.initial_approx(), 1e-9);
    println!("starting point violates {start_violations}/{m} constraints");

    let artifact_dir = std::path::Path::new("artifacts")
        .join("manifest.json")
        .exists()
        .then(|| std::path::PathBuf::from("artifacts"));
    let spec = problem.cost_spec();
    let p: Arc<dyn BsfProblem> = Arc::new(problem);
    let mut runner = LiveRunner::new(4, 50_000);
    runner.artifact_dir = artifact_dir;
    let report = runner.run(p.clone())?;

    // Feasibility check through a fresh instance (same seed ⇒ same system).
    let checker = CimminoProblem::new(feasible_inequalities(m, n, 0.1, 2026), 1.5, 1e-18);
    let end_violations = checker.violated(&report.final_approx, 1e-6);
    println!(
        "after {} iterations (converged = {}): {} violations remain",
        report.iterations, report.converged, end_violations
    );
    anyhow::ensure!(end_violations == 0, "iterate is not feasible");

    // Scalability forecast from the analytic cost spec (paper §5 style:
    // no large-scale run needed).
    let params = spec.cost_params(9.3e-10, &NetworkParams::tornado_susu());
    let model = BsfModel::new(params);
    println!(
        "forecast on a Tornado-SUSU-class cluster: K_BSF = {:.0} \
         (comp/comm = {:.0})",
        model.k_bsf(),
        params.comp_comm_ratio()
    );
    Ok(())
}
