//! Model comparison: BSF vs BSP vs LogGP on the same iterative workload —
//! the paper's motivating claim is that only BSF yields a *closed-form*
//! scalability boundary; the baselines must be swept numerically.
//!
//! ```text
//! cargo run --release --example model_comparison
//! ```

use bsf::experiments::paper_jacobi_params;
use bsf::model::bsp::{BspModel, BspParams};
use bsf::model::logp::{LogGpModel, LogGpParams};
use bsf::model::BsfModel;
use bsf::net::NetworkParams;
use bsf::util::Table;

fn main() {
    let net = NetworkParams::tornado_susu();
    println!("== parallel computation models on BSF-Jacobi (paper Table 2 params) ==\n");
    for n in [1_500usize, 5_000, 10_000, 16_000] {
        let params = paper_jacobi_params(n).expect("published size");
        let bsf = BsfModel::new(params);
        let bsp = BspModel {
            p: params,
            m: BspParams { g: net.tau_tr, l_sync: 2.0 * net.latency },
            words_down: n,
            words_up: n,
        };
        let loggp = LogGpModel {
            p: params,
            m: LogGpParams { l: net.latency, o: 2e-6, g: 4e-6, big_g: net.tau_tr },
            words_down: n,
            words_up: n,
        };

        let mut t = Table::new(
            format!("n = {n}: predicted speedup by model"),
            &["K", "BSF (eq.9)", "BSP", "LogGP"],
        );
        for k in [1usize, 16, 64, 128, 256] {
            t.row(&[
                k.to_string(),
                format!("{:.1}", bsf.speedup(k)),
                format!("{:.1}", bsp.speedup(k)),
                format!("{:.1}", loggp.speedup(k)),
            ]);
        }
        println!("{}", t.render());
        println!(
            "  boundary: BSF = {:.0} (closed form, eq. 14) | BSP = {} (numeric sweep) | \
             LogGP = {} (numeric sweep)\n",
            bsf.k_bsf(),
            bsp.k_peak(2_000),
            loggp.k_peak(2_000)
        );
    }
}
