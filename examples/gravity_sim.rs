//! BSF-Gravity demo: integrate a probe's trajectory through a cloud of
//! motionless attractors with the parallel skeleton, then predict how far
//! the computation would scale on a cluster.
//!
//! ```text
//! cargo run --release --example gravity_sim
//! ```

use std::sync::Arc;

use bsf::coordinator::{run_sequential, BsfProblem, LiveRunner};
use bsf::experiments::paper_gravity_params;
use bsf::linalg::generators::random_bodies;
use bsf::model::BsfModel;
use bsf::problems::GravityProblem;

fn main() -> anyhow::Result<()> {
    let n = 600;
    let workload = random_bodies(n, 5.0, 2026);
    println!("== BSF-Gravity: {n} attractors, probe from {:?} ==", workload.x0);

    // Sequential trajectory (Algorithm 5).
    let problem = GravityProblem::new(workload.clone(), 1e-3, 2e-6);
    let seq = run_sequential(&problem, 25_000, None);
    let t = seq.final_approx[6];
    println!(
        "sequential: {} steps to t = {:.2e}, final position ({:.3}, {:.3}, {:.3})",
        seq.iterations, t, seq.final_approx[0], seq.final_approx[1], seq.final_approx[2]
    );

    // Parallel (Algorithm 6) with 3 workers — must match bit-for-bit in
    // iteration count and closely in state.
    let artifact_dir = std::path::Path::new("artifacts")
        .join("manifest.json")
        .exists()
        .then(|| std::path::PathBuf::from("artifacts"));
    let p: Arc<dyn BsfProblem> = Arc::new(GravityProblem::new(workload, 1e-3, 2e-6));
    let mut runner = LiveRunner::new(3, 25_000);
    runner.artifact_dir = artifact_dir;
    let live = runner.run(p)?;
    println!(
        "live (K=3):  {} steps, final position ({:.3}, {:.3}, {:.3})",
        live.iterations, live.final_approx[0], live.final_approx[1], live.final_approx[2]
    );
    assert_eq!(live.iterations, seq.iterations, "parallel must track sequential");

    // Scalability forecast on the paper's cluster parameters.
    for n_pred in [300usize, 600, 900, 1_200] {
        let params = paper_gravity_params(n_pred).expect("published");
        let model = BsfModel::new(params);
        println!(
            "paper cluster, n = {n_pred:>5}: K_BSF = {:>6.1} (peak speedup ≈ {:.0}x)",
            model.k_bsf(),
            model.speedup(model.k_bsf().round() as usize)
        );
    }
    Ok(())
}
