//! Sublist partitioning — eq. (4): `A = A₁ ++ … ++ A_K`.
//!
//! The paper assumes for simplicity that `l` is a multiple of `K`; real
//! workloads are not, so [`partition_even`] distributes the remainder one
//! element at a time to the first `l mod K` sublists (the standard MPI block
//! distribution). Invariants — coverage, disjointness, balance within 1 —
//! are enforced by property tests in `rust/tests/`.

use std::ops::Range;

/// A partition of `0..len` into `k` contiguous ranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// Start offsets, length `k+1`; sublist `j` is `offsets[j]..offsets[j+1]`.
    offsets: Vec<usize>,
}

impl Partition {
    /// Number of sublists.
    pub fn k(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total length covered.
    pub fn len(&self) -> usize {
        *self.offsets.last().expect("non-empty offsets")
    }

    /// True when the covered list is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `j`-th sublist's index range.
    pub fn range(&self, j: usize) -> Range<usize> {
        self.offsets[j]..self.offsets[j + 1]
    }

    /// Length of the `j`-th sublist.
    pub fn size(&self, j: usize) -> usize {
        self.offsets[j + 1] - self.offsets[j]
    }

    /// Iterator over all sublist ranges.
    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.k()).map(|j| self.range(j))
    }

    /// The largest sublist length — the straggler bound that determines the
    /// parallel Map time in eq. (8)'s `(t_Map + (l-K) t_a)/K` term.
    pub fn max_size(&self) -> usize {
        (0..self.k()).map(|j| self.size(j)).max().unwrap_or(0)
    }
}

/// Partition `len` items into `k` contiguous near-even sublists.
///
/// Panics if `k == 0`. Sublists may be empty when `len < k` (the model
/// requires `l ≥ K` for meaningful speedup, but the skeleton must not fall
/// over outside that regime).
pub fn partition_even(len: usize, k: usize) -> Partition {
    assert!(k > 0, "partition_even: k must be positive");
    let base = len / k;
    let extra = len % k;
    let mut offsets = Vec::with_capacity(k + 1);
    let mut at = 0usize;
    offsets.push(0);
    for j in 0..k {
        at += base + usize::from(j < extra);
        offsets.push(at);
    }
    Partition { offsets }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_multiple() {
        let p = partition_even(12, 4);
        assert_eq!(p.k(), 4);
        assert!((0..4).all(|j| p.size(j) == 3));
        assert_eq!(p.len(), 12);
    }

    #[test]
    fn remainder_spread_to_front() {
        let p = partition_even(10, 4);
        let sizes: Vec<usize> = (0..4).map(|j| p.size(j)).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn covers_all_contiguously() {
        let p = partition_even(17, 5);
        let mut expect = 0;
        for r in p.ranges() {
            assert_eq!(r.start, expect);
            expect = r.end;
        }
        assert_eq!(expect, 17);
    }

    #[test]
    fn more_workers_than_items() {
        let p = partition_even(3, 7);
        assert_eq!(p.len(), 3);
        let nonempty = p.ranges().filter(|r| !r.is_empty()).count();
        assert_eq!(nonempty, 3);
        assert_eq!(p.max_size(), 1);
    }

    #[test]
    fn single_worker_takes_all() {
        let p = partition_even(100, 1);
        assert_eq!(p.range(0), 0..100);
        assert_eq!(p.max_size(), 100);
    }

    #[test]
    fn empty_list() {
        let p = partition_even(0, 3);
        assert!(p.is_empty());
        assert!(p.ranges().all(|r| r.is_empty()));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_k_panics() {
        partition_even(5, 0);
    }

    #[test]
    fn balance_within_one() {
        for len in [0usize, 1, 13, 100, 1023] {
            for k in [1usize, 2, 3, 10, 64] {
                let p = partition_even(len, k);
                let max = p.max_size();
                let min = (0..k).map(|j| p.size(j)).min().unwrap();
                assert!(max - min <= 1, "len={len} k={k}");
            }
        }
    }
}
