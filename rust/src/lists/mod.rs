//! Bird–Meertens list algebra — the specification language of BSF algorithms.
//!
//! The BSF model (paper §3) requires an algorithm to be expressed as
//! operations over *lists* through the higher-order functions `Map` (eq. 2)
//! and `Reduce` (eq. 3) with an associative fold operation `⊕`. The entire
//! parallelization rests on the **promotion theorem** (eq. 5):
//!
//! ```text
//! Reduce(⊕, Map(F, A₁ ++ … ++ A_K))
//!     = Reduce(⊕, Map(F, A₁)) ⊕ … ⊕ Reduce(⊕, Map(F, A_K))
//! ```
//!
//! which lets K workers fold disjoint sublists independently and the master
//! fold the K partials. This module provides the sequential semantics
//! (ground truth for every parallel runner) and the sublist partitioning of
//! eq. (4).

mod partition;

pub use partition::{partition_even, Partition};

/// An associative binary operation with identity, i.e. a monoid over `B`.
///
/// Associativity is a *requirement* of the BSF model (paper §3); it is what
/// makes the promotion theorem — and thus the whole parallelization — valid.
/// Property tests verify associativity for every monoid shipped in
/// [`crate::problems`].
pub trait Monoid<B> {
    /// The identity element of `⊕` (`combine(identity(), b) == b`).
    fn identity(&self) -> B;
    /// The associative operation `⊕`.
    fn combine(&self, a: B, b: B) -> B;
}

/// Vector addition in `R^n` — the fold of BSF-Jacobi and BSF-Cimmino.
#[derive(Debug, Clone, Copy)]
pub struct VecAdd {
    /// Dimension `n` (the identity is the zero vector of this length).
    pub n: usize,
}

impl Monoid<Vec<f64>> for VecAdd {
    fn identity(&self) -> Vec<f64> {
        vec![0.0; self.n]
    }
    fn combine(&self, mut a: Vec<f64>, b: Vec<f64>) -> Vec<f64> {
        debug_assert_eq!(a.len(), b.len());
        for (x, y) in a.iter_mut().zip(&b) {
            *x += y;
        }
        a
    }
}

/// Scalar addition — the fold of Map-only/Monte-Carlo style algorithms.
#[derive(Debug, Clone, Copy)]
pub struct Add;

impl Monoid<f64> for Add {
    fn identity(&self) -> f64 {
        0.0
    }
    fn combine(&self, a: f64, b: f64) -> f64 {
        a + b
    }
}

/// The higher-order function `Map` (paper eq. 2): applies `f` to each element
/// of the list, preserving order.
pub fn map<A, B>(f: impl Fn(&A) -> B, list: &[A]) -> Vec<B> {
    list.iter().map(f).collect()
}

/// The higher-order function `Reduce` (paper eq. 3): folds the list with the
/// monoid's `⊕`, returning the identity for an empty list.
pub fn reduce<B>(m: &impl Monoid<B>, list: Vec<B>) -> B {
    list.into_iter().fold(m.identity(), |a, b| m.combine(a, b))
}

/// `Reduce(⊕, Map(F, A))` — the fused worker-side step of Algorithm 2
/// (steps 3–4), without materialising the intermediate list `B`.
pub fn map_reduce<A, B>(f: impl Fn(&A) -> B, m: &impl Monoid<B>, list: &[A]) -> B {
    list.iter().fold(m.identity(), |acc, a| m.combine(acc, f(a)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let xs = [1, 2, 3];
        assert_eq!(map(|x| x * 10, &xs), vec![10, 20, 30]);
    }

    #[test]
    fn reduce_empty_is_identity() {
        assert_eq!(reduce(&Add, vec![]), 0.0);
        let v = VecAdd { n: 3 };
        assert_eq!(reduce(&v, vec![]), vec![0.0; 3]);
    }

    #[test]
    fn map_reduce_equals_composition() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let f = |x: &f64| x * 2.0;
        let fused = map_reduce(f, &Add, &xs);
        let composed = reduce(&Add, map(f, &xs));
        assert_eq!(fused, composed);
    }

    #[test]
    fn vec_add_is_elementwise() {
        let m = VecAdd { n: 2 };
        assert_eq!(m.combine(vec![1.0, 2.0], vec![10.0, 20.0]), vec![11.0, 22.0]);
    }

    /// The promotion theorem (paper eq. 5) on a concrete instance.
    #[test]
    fn promotion_theorem_concrete() {
        let xs: Vec<f64> = (0..97).map(|i| (i as f64).sin()).collect();
        let f = |x: &f64| x * x;
        let full = map_reduce(f, &Add, &xs);
        for k in [1, 2, 3, 7, 97] {
            let parts = partition_even(xs.len(), k);
            let partials: Vec<f64> = parts
                .ranges()
                .map(|r| map_reduce(f, &Add, &xs[r]))
                .collect();
            let folded = reduce(&Add, partials);
            assert!((full - folded).abs() < 1e-12, "k={k}");
        }
    }
}
