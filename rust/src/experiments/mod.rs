//! Experiment harnesses — one per table/figure of the paper's evaluation
//! (see DESIGN.md §5 for the index) plus the ablations.
//!
//! Two data sources feed every experiment:
//!
//! * **paper-params mode** — the cost parameters published in the paper
//!   (Table 2 for Jacobi; §6's gravity constants). This checks the
//!   *models and simulator* against the paper's own numbers, independent
//!   of this machine.
//! * **measured mode** — parameters calibrated live on this machine
//!   (1 master + 1 worker, PJRT kernels on the hot path), then projected
//!   onto the modelled cluster network. This is the full-stack
//!   reproduction: L1 kernels → L2 model → L3 skeleton → simulator →
//!   analytic boundary.
//!
//! Every harness returns [`crate::util::Table`]s that the CLI prints and
//! saves as CSV under `results/`.

mod ablations;
mod common;
mod explorer;
pub(crate) mod fig6;
mod fig7;
mod faulty;
mod nonstationary;
mod sqrt_law;
mod tables;

pub use ablations::{ablation_collectives, ablation_masters, baselines};
pub use common::{
    analytic_provider, boundary_row, boundary_rows, calibrate, cell_groups, effective_net,
    effective_net_with_latency, flat_cells, k_sweep, paper_gravity_params, paper_jacobi_params,
    run_cell_bucket, sampled_provider, simulated_curve, simulated_curve_threads, simulated_curves,
    BoundaryRow, BoundarySpec, ExperimentCtx, ProblemKind, SweepJob, SweepScratch,
};
pub use explorer::explorer;
pub use faulty::faulty;
pub use fig6::fig6;
pub use fig7::fig7;
pub use nonstationary::nonstationary;
pub use sqrt_law::sqrt_law;
pub use tables::{table2, table3, table4};
