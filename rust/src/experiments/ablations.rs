//! Ablations over the design choices the BSF model bakes in (DESIGN.md
//! ABL1–ABL3):
//!
//! * **Collectives** — eq. (8) assumes `O(log K)` tree collectives; the
//!   ablation swaps in flat/linear ones and shows the boundary collapse.
//! * **Masters** — §7 Q5: two or more masters admit no closed-form
//!   boundary; the simulator still *runs* such configurations, so we show
//!   what the model cannot predict.
//! * **Baselines** — BSF vs BSP vs LogGP predicted iteration times and
//!   numerically-swept peaks on the same algorithm (no other model yields
//!   eq. (14); each baseline's peak requires a sweep).

use anyhow::Result;

use crate::experiments::common::{
    analytic_provider, k_sweep, paper_jacobi_params, simulated_curves, ExperimentCtx, SweepJob,
};
use crate::model::bsp::{BspModel, BspParams};
use crate::model::logp::{LogGpModel, LogGpParams};
use crate::model::BsfModel;
use crate::net::CollectiveAlgo;
use crate::simulator::ReduceMode;
use crate::util::parallel::default_threads;
use crate::util::{Rng, Table};

/// ABL1: binomial-tree vs linear collectives (and in-tree vs gather
/// reduce) on the n = 5000 Jacobi workload.
pub fn ablation_collectives(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let n = 5_000;
    let params = paper_jacobi_params(n).expect("published");
    let model = BsfModel::new(params);
    let k_bsf = model.k_bsf();
    let ks = k_sweep(k_bsf * 1.2, ctx.quick);
    let iters = if ctx.quick { 3 } else { 7 };

    let mut t = Table::new(
        format!("Ablation ABL1 (Jacobi n={n}): collective algorithm vs boundary"),
        &["collective", "reduce", "K_test (sim)", "peak speedup", "K_BSF (eq.14)"],
    );
    // All six configurations feed one pooled (config × K) work queue;
    // every config keeps its own fresh RNG root, as the serial loop did.
    let prov = analytic_provider(&params);
    let mut labels = Vec::new();
    let mut jobs = Vec::new();
    for (algo, algo_name) in
        [(CollectiveAlgo::BinomialTree, "tree"), (CollectiveAlgo::Linear, "linear")]
    {
        for (mode, mode_name) in [
            (ReduceMode::TreeMasterFold, "paper (tree+master-fold)"),
            (ReduceMode::InTree, "mpi-reduce (in-tree)"),
            (ReduceMode::GatherThenFold, "flat gather+fold"),
        ] {
            let mut cluster = ctx.cluster;
            cluster.algo = algo;
            cluster.reduce_mode = mode;
            let sub = ExperimentCtx { cluster, ..ctx.clone() };
            let sim = sub.sim_params(n, n);
            let mut rng = Rng::new(ctx.seed ^ 0xAB1);
            jobs.push(SweepJob::new(sim, n, &prov, ks.clone(), iters, &mut rng));
            labels.push((algo_name, mode_name));
        }
    }
    let curves = simulated_curves(&jobs, default_threads());
    for ((algo_name, mode_name), curve) in labels.iter().zip(&curves) {
        let w = (ks.len() / 10).max(5);
        let pk = crate::model::scalability::peak_knee(curve, w, 0.99).expect("curve");
        t.row(&[
            (*algo_name).into(),
            (*mode_name).into(),
            pk.k.to_string(),
            format!("{:.1}", pk.speedup),
            format!("{k_bsf:.0}"),
        ]);
    }
    ctx.save("ablation_collectives", &t);
    Ok(vec![t])
}

/// ABL2: master-count ablation (§7 Q5). The model covers `masters = 1`
/// only; the simulator shows what 2/4-master farms would do.
pub fn ablation_masters(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let n = 5_000;
    let params = paper_jacobi_params(n).expect("published");
    let k_bsf = BsfModel::new(params).k_bsf();
    let ks = k_sweep(k_bsf * 1.5, ctx.quick);
    let iters = if ctx.quick { 3 } else { 7 };

    let mut t = Table::new(
        format!("Ablation ABL2 (Jacobi n={n}): master count (§7 Q5)"),
        &["masters", "K_test (sim)", "peak speedup", "closed form?"],
    );
    let prov = analytic_provider(&params);
    let master_counts = [1usize, 2, 4];
    let mut jobs = Vec::new();
    for &masters in &master_counts {
        let mut cluster = ctx.cluster;
        cluster.masters = masters;
        let sub = ExperimentCtx { cluster, ..ctx.clone() };
        let sim = sub.sim_params(n, n);
        let mut rng = Rng::new(ctx.seed ^ 0xAB2);
        jobs.push(SweepJob::new(sim, n, &prov, ks.clone(), iters, &mut rng));
    }
    let curves = simulated_curves(&jobs, default_threads());
    for (&masters, curve) in master_counts.iter().zip(&curves) {
        let w = (ks.len() / 10).max(5);
        let pk = crate::model::scalability::peak_knee(curve, w, 0.99).expect("curve");
        t.row(&[
            masters.to_string(),
            pk.k.to_string(),
            format!("{:.1}", pk.speedup),
            if masters == 1 { format!("yes: K_BSF={k_bsf:.0}") } else { "no (paper §7 Q5)".into() },
        ]);
    }
    ctx.save("ablation_masters", &t);
    Ok(vec![t])
}

/// ABL3: BSF vs BSP vs LogGP on the same Algorithm-2 pattern.
pub fn baselines(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let mut out = Vec::new();
    for n in [5_000usize, 10_000] {
        let params = paper_jacobi_params(n).expect("published");
        let bsf = BsfModel::new(params);
        let bsp = BspModel {
            p: params,
            m: BspParams { g: ctx.cluster.net.tau_tr, l_sync: 2.0 * ctx.cluster.net.latency },
            words_down: n,
            words_up: n,
        };
        let loggp = LogGpModel {
            p: params,
            m: LogGpParams {
                l: ctx.cluster.net.latency,
                o: 2e-6,
                g: 4e-6,
                big_g: ctx.cluster.net.tau_tr,
            },
            words_down: n,
            words_up: n,
        };
        let mut t = Table::new(
            format!("Baselines ABL3 (Jacobi n={n}): predicted iteration time + peak"),
            &["K", "T_K BSF", "T_K BSP", "T_K LogGP"],
        );
        for k in [1usize, 8, 32, 64, 128, 256, 512] {
            t.row(&[
                k.to_string(),
                format!("{:.2e}", bsf.t_k(k)),
                format!("{:.2e}", bsp.t_k(k)),
                format!("{:.2e}", loggp.t_k(k)),
            ]);
        }
        t.row(&[
            "peak K".into(),
            format!("{:.0} (closed form)", bsf.k_bsf()),
            format!("{} (swept)", bsp.k_peak(2_000)),
            format!("{} (swept)", loggp.k_peak(2_000)),
        ]);
        ctx.save(&format!("baselines_n{n}"), &t);
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_collective_collapses_boundary() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let t = ablation_collectives(&ctx).unwrap().remove(0);
        let csv = t.to_csv();
        let rows: Vec<Vec<&str>> =
            csv.lines().skip(1).map(|l| l.split(',').collect()).collect();
        let k_of = |algo: &str, mode: &str| -> f64 {
            rows.iter()
                .find(|r| r[0] == algo && r[1] == mode)
                .map(|r| r[2].parse().unwrap())
                .unwrap()
        };
        assert!(
            k_of("linear", "flat gather+fold") < k_of("tree", "mpi-reduce (in-tree)"),
            "linear should peak earlier: {csv}"
        );
        // mpi-reduce folds in-tree, so it peaks no earlier than the
        // paper's master-fold accounting
        assert!(
            k_of("tree", "mpi-reduce (in-tree)") >= k_of("tree", "paper (tree+master-fold)"),
            "{csv}"
        );
    }

    #[test]
    fn baselines_produce_peaks() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let ts = baselines(&ctx).unwrap();
        assert_eq!(ts.len(), 2);
        assert!(ts[0].to_csv().contains("closed form"));
    }

    #[test]
    fn masters_ablation_runs() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let t = ablation_masters(&ctx).unwrap().remove(0);
        assert_eq!(t.len(), 3);
    }
}
