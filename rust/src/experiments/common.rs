//! Shared experiment machinery: contexts, paper-published parameters,
//! calibration plumbing, and the simulated speedup-curve generator.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::config::ClusterConfig;
use crate::coordinator::{calibrate_problem, BsfProblem};
use crate::linalg::generators;
use crate::model::scalability::SpeedupPoint;
use crate::model::{BsfModel, CostParams};
use crate::problems::{CimminoProblem, GravityProblem, JacobiProblem};
use crate::simulator::{
    group_enabled, run_faulty_into, AnalyticCost, CostFactory, FaultPlan, FaultScratch, FaultSpec,
    GroupCell, IterationTemplate, IterationTiming, SampledCost, ShapeClass, SimParams,
};
use crate::util::parallel::{default_threads, parallel_map_index_groups_with};
use crate::util::{Rng, Table};

/// Which application an experiment drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProblemKind {
    /// BSF-Jacobi (§5).
    Jacobi,
    /// BSF-Gravity (§6).
    Gravity,
    /// BSF-Cimmino (ref [31]).
    Cimmino,
}

impl ProblemKind {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<ProblemKind> {
        match s {
            "jacobi" => Some(ProblemKind::Jacobi),
            "gravity" => Some(ProblemKind::Gravity),
            "cimmino" => Some(ProblemKind::Cimmino),
            _ => None,
        }
    }

    /// Instantiate the problem at size `n` on its standard workload.
    pub fn build(&self, n: usize) -> Arc<dyn BsfProblem> {
        match self {
            ProblemKind::Jacobi => Arc::new(JacobiProblem::new(generators::paper_system(n), 1e-12)),
            ProblemKind::Gravity => {
                Arc::new(GravityProblem::new(generators::random_bodies(n, 5.0, 42), 1e-3, f64::MAX))
            }
            ProblemKind::Cimmino => Arc::new(CimminoProblem::new(
                generators::feasible_inequalities(n, (n / 4).max(8), 0.1, 7),
                1.5,
                1e-20,
            )),
        }
    }
}

/// Shared experiment context (CLI flags + config file).
#[derive(Debug, Clone)]
pub struct ExperimentCtx {
    /// Modelled cluster (network, collectives, jitter, masters).
    pub cluster: ClusterConfig,
    /// Where to save CSVs.
    pub out_dir: PathBuf,
    /// AOT artifact directory for live calibration runs.
    pub artifact_dir: Option<PathBuf>,
    /// Reduced sizes/iterations for CI-speed runs.
    pub quick: bool,
    /// Root seed for all stochastic parts.
    pub seed: u64,
}

impl Default for ExperimentCtx {
    fn default() -> Self {
        let artifact_dir = {
            let p = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
            p.join("manifest.json").exists().then_some(p)
        };
        ExperimentCtx {
            cluster: ClusterConfig::default(),
            out_dir: PathBuf::from("results"),
            artifact_dir,
            quick: false,
            seed: 0xB5F,
        }
    }
}

impl ExperimentCtx {
    /// Save a table as CSV under the out dir (best effort; report errors
    /// but don't fail the experiment).
    pub fn save(&self, name: &str, table: &Table) {
        let path = self.out_dir.join(format!("{name}.csv"));
        if let Err(e) = table.save_csv(&path) {
            eprintln!("warning: could not save {path:?}: {e}");
        }
    }

    /// Simulation parameters for a problem's payload sizes.
    pub fn sim_params(&self, words_down: usize, words_up: usize) -> SimParams {
        SimParams {
            net: self.cluster.net,
            algo: self.cluster.algo,
            reduce_mode: self.cluster.reduce_mode,
            words_down,
            words_up,
            jitter_comp: self.cluster.jitter_comp,
            jitter_comm: self.cluster.jitter_comm,
            masters: self.cluster.masters,
        }
    }
}

/// The paper's published BSF-Jacobi cost parameters (Table 2; L = 1.5e-5).
pub fn paper_jacobi_params(n: usize) -> Option<CostParams> {
    let (t_c, t_p, t_a, t_map) = match n {
        1_500 => (7.20e-5, 5.01e-6, 1.89e-6, 6.23e-3),
        5_000 => (1.06e-3, 1.72e-5, 5.27e-6, 9.28e-2),
        10_000 => (2.17e-3, 3.70e-5, 9.31e-6, 3.73e-1),
        16_000 => (2.95e-3, 5.61e-5, 2.10e-5, 7.73e-1),
        _ => return None,
    };
    Some(CostParams { l: n, t_c, t_p, t_map, t_a })
}

/// The paper's published BSF-Gravity cost parameters (§6: `t_p = 9.5e-7`,
/// `t_a = 4.7e-9`, per-n `t_Map`).
///
/// NOTE on `t_c`: the §6 text prints `t_c = 5·10⁻⁵`, but Table 4's
/// published boundaries (69/141/210/279) are *impossible* under that value
/// — even with `t_a → 0` the peak of eq. (9) is `t_Map·ln2/t_c ≈ 50` at
/// n = 300. Solving Table 4's boundaries for `t_c` gives ≈ 3.6·10⁻⁵
/// consistently across all four sizes, so we use that (reproducing the
/// paper's own table); the discrepancy is recorded in EXPERIMENTS.md.
pub fn paper_gravity_params(n: usize) -> Option<CostParams> {
    let t_map = match n {
        300 => 3.6e-3,
        600 => 7.46e-3,
        900 => 1.12e-2,
        1_200 => 1.5e-2,
        _ => return None,
    };
    Some(CostParams { l: n, t_c: 3.6e-5, t_p: 9.5e-7, t_map, t_a: 4.7e-9 })
}

/// A [`NetworkParams`] consistent with a published `t_c`: keeps the
/// paper's latency `L = 1.5e-5` and solves `t_c = p2p(down) + p2p(up)`
/// for the effective per-word time. Paper-params experiments must charge
/// the simulator with *this* network, not the global default — otherwise
/// the simulated timeline and the analytic metric disagree on `t_c`
/// itself and the comparison is meaningless.
pub fn effective_net(t_c: f64, words_down: usize, words_up: usize) -> crate::net::NetworkParams {
    effective_net_with_latency(t_c, words_down, words_up, 1.5e-5)
}

/// [`effective_net`] with an explicit latency (for clusters other than the
/// paper's testbed).
pub fn effective_net_with_latency(
    t_c: f64,
    words_down: usize,
    words_up: usize,
    latency: f64,
) -> crate::net::NetworkParams {
    let words = (words_down + words_up) as f64;
    let tau_tr = ((t_c - 2.0 * latency) / words).max(0.0);
    crate::net::NetworkParams { latency, tau_tr, link: crate::net::LinkMode::PerEdge }
}

/// K values to sweep for a curve expected to peak near `k_hint`:
/// dense at small K, sparser beyond, up to ~2.4 × the hint.
pub fn k_sweep(k_hint: f64, quick: bool) -> Vec<usize> {
    let k_max = ((k_hint * 2.4).ceil() as usize).max(16);
    let stride = if quick { (k_max / 24).max(1) } else { (k_max / 96).max(1) };
    let mut ks = vec![1usize];
    let mut k = stride.max(2);
    while k <= k_max {
        ks.push(k);
        k += stride;
    }
    ks.dedup();
    ks
}

/// One sweep in a pooled (experiment × size × K) run: everything
/// [`simulated_curve`] needs for one curve, with the RNG root pre-forked
/// so that *job construction order* — not execution order — fixes the
/// per-K streams.
pub struct SweepJob<'a> {
    /// Cluster/timing configuration for this sweep.
    pub params: SimParams,
    /// List length `l`.
    pub l: usize,
    /// Per-K provider factory (`CostFactory::instance(k)` keyed by K).
    pub factory: &'a dyn CostFactory,
    /// Worker counts to evaluate.
    pub ks: Vec<usize>,
    /// Simulated iterations averaged per K-point.
    pub iters: usize,
    /// Sweep-root RNG; the per-K stream is `root.split(k)`.
    pub root: Rng,
    /// Optional fault/heterogeneity injection: when set, each K-point
    /// replays under a [`FaultPlan`] generated from this spec and a per-K
    /// stream split off the sweep root — deterministic at any thread
    /// count, exactly like the clean per-K draws.
    pub fault: Option<FaultSpec>,
    /// Per-job override of the shape-class grouping switch: `Some(true)`
    /// forces this job's cells into shape buckets, `Some(false)` forces
    /// them into singleton groups (the per-cell path), `None` (default)
    /// follows the process-wide [`crate::simulator::group_enabled`]
    /// (`BSF_GROUP`). Grouping is bitwise-neutral either way.
    pub group: Option<bool>,
}

/// Stream tag for per-K fault-plan generation. The clean per-K streams use
/// `root.split(k)` with `k < 2^32`, so the high bit keeps the plan stream
/// disjoint from every timing stream.
const FAULT_PLAN_STREAM: u64 = 1 << 63;

impl<'a> SweepJob<'a> {
    /// Build a job, forking the sweep root off `rng` exactly like the
    /// serial [`simulated_curve`] does. Constructing jobs in the same
    /// order as the serial per-sweep calls keeps every result bitwise
    /// identical to the serial pipeline.
    pub fn new(
        params: SimParams,
        l: usize,
        factory: &'a dyn CostFactory,
        ks: Vec<usize>,
        iters: usize,
        rng: &mut Rng,
    ) -> SweepJob<'a> {
        SweepJob { params, l, factory, ks, iters, root: rng.fork(0x5EED), fault: None, group: None }
    }

    /// Replay this sweep under a fault spec (builder form).
    pub fn with_fault(mut self, spec: FaultSpec) -> SweepJob<'a> {
        self.fault = Some(spec);
        self
    }

    /// Override the shape-class grouping switch for this job (builder
    /// form) — the per-instance mirror of `BSF_GROUP`, like the engine's
    /// per-instance lane overrides.
    pub fn set_group_mode(mut self, mode: Option<bool>) -> SweepJob<'a> {
        self.group = mode;
        self
    }
}

/// Per-worker scratch for pooled sweeps: one engine/template (rebuilt in
/// place per K-point via [`IterationTemplate::reset_to`]) and one timing
/// buffer, reused for every job the worker pulls off the queue. Public so
/// out-of-process executors ([`crate::fleet`] workers) can drive the same
/// bucket runner ([`run_cell_bucket`]) the in-process pool uses.
#[derive(Default)]
pub struct SweepScratch {
    tmpl: Option<IterationTemplate>,
    runs: Vec<IterationTiming>,
    fault_scratch: FaultScratch,
}

/// The old private name, kept for the module's internal prose.
type SweepWorker = SweepScratch;

/// Mean iteration time of `job` at worker count `k` — a pure function of
/// `(job, k)`; the worker scratch only caches buffer capacity.
fn sweep_point(w: &mut SweepWorker, job: &SweepJob, k: usize) -> f64 {
    let mut provider = job.factory.instance(k as u64);
    let mut rng_k = job.root.split(k as u64);
    if let Some(spec) = &job.fault {
        // Faulty replay: the plan is a pure function of (spec, k, sweep
        // root), so pooled execution stays bitwise identical to serial.
        let plan_root = job.root.split(FAULT_PLAN_STREAM | k as u64);
        let plan = FaultPlan::generate(spec, k, job.iters as u64, &plan_root);
        let tmpl = w.tmpl.get_or_insert_with(|| IterationTemplate::new(k, job.l, &job.params));
        run_faulty_into(
            tmpl,
            &plan,
            job.l,
            &job.params,
            job.iters,
            provider.as_mut(),
            &mut rng_k,
            &mut w.runs,
            &mut w.fault_scratch,
        );
        return w.runs.iter().map(|t| t.total).sum::<f64>() / w.runs.len() as f64;
    }
    if let Some(tmpl) = w.tmpl.as_mut() {
        tmpl.reset_to(k, job.l, &job.params);
    }
    let tmpl = w.tmpl.get_or_insert_with(|| IterationTemplate::new(k, job.l, &job.params));
    tmpl.run_into(job.iters, provider.as_mut(), &mut rng_k, &mut w.runs);
    w.runs.iter().map(|t| t.total).sum::<f64>() / w.runs.len() as f64
}

/// Mean iteration times of one shape bucket of flat queue cells — cells
/// whose [`ShapeClass`] keys are equal, so one template serves all of
/// them (per-cell payload binds via [`IterationTemplate::bind_cell`])
/// and their jittered replays ride shared lane batches
/// ([`IterationTemplate::run_group_into`]) even when the cells simulate
/// different sizes, cost params or jitter. Each cell keeps its own
/// provider instance and per-K rng stream, exactly as [`sweep_point`]
/// builds them, so the group result is bitwise identical to calling
/// `sweep_point` per cell in order (pinned in
/// `rust/tests/determinism.rs`). Size-1 groups — faulty cells, opted-out
/// jobs, shapes seen once — take the unchanged [`sweep_point`] path.
fn sweep_group(
    w: &mut SweepWorker,
    jobs: &[SweepJob],
    flat: &[(usize, usize)],
    group: &[usize],
    out: &mut Vec<f64>,
) {
    if group.len() == 1 {
        let (s, i) = flat[group[0]];
        out.push(sweep_point(w, &jobs[s], jobs[s].ks[i]));
        return;
    }
    let (s0, i0) = flat[group[0]];
    let job0 = &jobs[s0];
    let k = job0.ks[i0];
    match w.tmpl.as_mut() {
        Some(tmpl) => {
            tmpl.reset_shape(k, job0.l, &job0.params);
        }
        None => w.tmpl = Some(IterationTemplate::new(k, job0.l, &job0.params)),
    }
    let tmpl = w.tmpl.as_mut().expect("template just ensured");
    let mut cells: Vec<GroupCell> = group
        .iter()
        .map(|&r| {
            let (s, i) = flat[r];
            let (job, kk) = (&jobs[s], jobs[s].ks[i]);
            GroupCell::new(
                job.factory.instance(kk as u64),
                job.root.split(kk as u64),
                job.l,
                &job.params,
            )
        })
        .collect();
    tmpl.run_group_into(&mut cells, job0.iters, &mut w.runs);
    for c in 0..cells.len() {
        let runs = &w.runs[c * job0.iters..(c + 1) * job0.iters];
        out.push(runs.iter().map(|t| t.total).sum::<f64>() / runs.len() as f64);
    }
}

/// Maximum cells per shape bucket: one bucket is one unit of work on one
/// worker thread, so an unbounded bucket would serialise a whole
/// repeated-shape grid (e.g. 4 sizes × every K of a Fig.-6 grid sharing
/// each K's shape) behind a single thread. 32 cells keeps groups long
/// enough to span many lane batches and short enough to load-balance.
const GROUP_CAP: usize = 32;

/// Shape-bucketed partition of the flat queue: cells that may share one
/// engine pass are collected into one group wherever they sit in the
/// flat list — the 4-sizes-per-K structure of the figure grids becomes
/// real multi-cell groups even though equal-shape cells are never
/// adjacent there. Grouping requires an equal [`ShapeClass`] (the
/// [`IterationTemplate::run_group_into`] invariant — sizes, cost params
/// and jitter may differ freely), equal `iters`, no fault injection
/// (faulty replays rebuild the graph per window), and the job opting in
/// ([`SweepJob::group`], defaulting to the process-wide
/// [`crate::simulator::group_enabled`] switch). Non-groupable cells
/// become singleton groups.
///
/// The partition is a pure function of the job list — computed before
/// any work is handed out, buckets in first-occurrence order, members in
/// flat order — so it is identical at every thread count, and pooled
/// results stay bitwise equal to the serial per-cell loop. Buckets close
/// at [`GROUP_CAP`] members.
fn flat_groups(jobs: &[SweepJob], flat: &[(usize, usize)]) -> Vec<Vec<usize>> {
    let default_group = group_enabled();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    // Open buckets: (shape key, iters, index into `groups`). Linear scan
    // — bucket counts stay tiny (distinct shapes currently open).
    let mut open: Vec<(ShapeClass, usize, usize)> = Vec::new();
    for (r, &(s, i)) in flat.iter().enumerate() {
        let job = &jobs[s];
        if !job.group.unwrap_or(default_group) || job.fault.is_some() {
            groups.push(vec![r]);
            continue;
        }
        let shape = ShapeClass::of(job.ks[i], &job.params);
        if let Some(&(_, _, gi)) =
            open.iter().find(|&&(sh, it, _)| sh == shape && it == job.iters)
        {
            groups[gi].push(r);
            if groups[gi].len() >= GROUP_CAP {
                open.retain(|&(_, _, g)| g != gi);
            }
        } else {
            open.push((shape, job.iters, groups.len()));
            groups.push(vec![r]);
        }
    }
    groups
}

/// The flat (experiment × K-point) cell list of a job set, in the
/// job-major order every pooled executor uses: cell `r` is
/// `(sweep index, K index)`. A pure function of the job list — the fleet
/// coordinator and its workers each compute it independently and agree.
pub fn flat_cells(jobs: &[SweepJob]) -> Vec<(usize, usize)> {
    jobs.iter()
        .enumerate()
        .flat_map(|(s, job)| (0..job.ks.len()).map(move |i| (s, i)))
        .collect()
}

/// Public form of the shape-bucketed partition ([`flat_groups`]): the
/// leasable batches of the fleet plane. Each bucket is safe to execute
/// anywhere — results depend only on `(job, k)` via split RNG streams —
/// and executing any sub-slice of a bucket through [`run_cell_bucket`]
/// yields the same per-cell results as the whole bucket (the grouped pass
/// is bitwise equal to the per-cell loop, pinned in
/// `rust/tests/determinism.rs`), so partial re-leases stay exact.
pub fn cell_groups(jobs: &[SweepJob], flat: &[(usize, usize)]) -> Vec<Vec<usize>> {
    flat_groups(jobs, flat)
}

/// Execute one shape bucket (or any sub-slice of one) into `out`, one
/// mean-iteration-time per member cell in order — the public, per-bucket
/// form of the pooled executor's inner loop, shared by the in-process
/// pool and the fleet workers.
pub fn run_cell_bucket(
    scratch: &mut SweepScratch,
    jobs: &[SweepJob],
    flat: &[(usize, usize)],
    bucket: &[usize],
    out: &mut Vec<f64>,
) {
    sweep_group(scratch, jobs, flat, bucket, out)
}

/// Evaluate many sweeps through **one** work queue over every
/// (sweep × K-point) pair: a slow size no longer serialises behind the
/// previous one, and each worker thread reuses a single engine for its
/// whole share of the queue. Cells sharing a [`ShapeClass`] (the same K
/// across sizes, repeated grids) are bucketed onto one worker and ride
/// shared lane batches ([`sweep_group`]). Results are bitwise identical
/// to running the sweeps one [`simulated_curve`] call at a time, at any
/// thread count, grouping on or off.
pub fn simulated_curves(jobs: &[SweepJob], threads: usize) -> Vec<Vec<SpeedupPoint>> {
    let flat = flat_cells(jobs);
    let groups = flat_groups(jobs, &flat);
    let times = parallel_map_index_groups_with(
        &groups,
        flat.len(),
        threads,
        SweepWorker::default,
        |w, group, out| sweep_group(w, jobs, &flat, group, out),
    );
    let mut fallback = SweepWorker::default();
    let mut out = Vec::with_capacity(jobs.len());
    let mut off = 0;
    for job in jobs {
        let tks = &times[off..off + job.ks.len()];
        off += job.ks.len();
        let t1 =
            if job.ks.first() == Some(&1) { tks[0] } else { sweep_point(&mut fallback, job, 1) };
        out.push(
            job.ks
                .iter()
                .zip(tks)
                .map(|(&k, &t_k)| SpeedupPoint { k, t_k, speedup: t1 / t_k })
                .collect(),
        );
    }
    out
}

/// Simulate the "empirical" speedup curve: the discrete-event timeline of
/// Algorithm 2 at each K, with compute times from the provider `factory`
/// and the context's network model. `iters` simulated iterations are
/// averaged per point.
///
/// K points are evaluated in parallel across OS threads
/// ([`default_threads`]; override with `BSF_SWEEP_THREADS`). Each K draws
/// from its own provider instance and RNG stream — both keyed by K, split
/// from the sweep root — so the curve is **bitwise identical** at any
/// thread count (`rust/tests/determinism.rs`). Multi-sweep experiments
/// should batch their sizes through [`simulated_curves`] instead, which
/// shares one work queue across every (size × K) pair.
pub fn simulated_curve(
    ctx: &ExperimentCtx,
    params: &SimParams,
    l: usize,
    factory: &dyn CostFactory,
    ks: &[usize],
    iters: usize,
    rng: &mut Rng,
) -> Vec<SpeedupPoint> {
    simulated_curve_threads(ctx, params, l, factory, ks, iters, rng, default_threads())
}

/// [`simulated_curve`] with an explicit worker-thread count (the
/// determinism suite compares 1 vs N threads).
#[allow(clippy::too_many_arguments)]
pub fn simulated_curve_threads(
    ctx: &ExperimentCtx,
    params: &SimParams,
    l: usize,
    factory: &dyn CostFactory,
    ks: &[usize],
    iters: usize,
    rng: &mut Rng,
    threads: usize,
) -> Vec<SpeedupPoint> {
    let _ = ctx;
    let job = SweepJob::new(params.clone(), l, factory, ks.to_vec(), iters, rng);
    simulated_curves(std::slice::from_ref(&job), threads)
        .pop()
        .expect("one sweep in, one curve out")
}

/// A provider built from published analytic parameters (paper-params mode).
pub fn analytic_provider(p: &CostParams) -> AnalyticCost {
    AnalyticCost { t_map_full: p.t_map, l: p.l, t_a: p.t_a, t_p: p.t_p }
}

/// A provider built from live calibration samples (measured mode).
pub fn sampled_provider(cal: &crate::model::Calibration, p: &CostParams, seed: u64) -> SampledCost {
    SampledCost {
        per_elem: Arc::new(cal.map_samples.iter().map(|s| s / cal.l as f64).collect()),
        t_a: p.t_a,
        t_p: p.t_p,
        rng: Rng::new(seed),
    }
}

/// Calibrate a problem instance live (1 master + 1 worker, kernels when
/// available) and return `(CostParams, Calibration)` on the context's
/// network.
pub fn calibrate(
    ctx: &ExperimentCtx,
    problem: Arc<dyn BsfProblem>,
) -> Result<(CostParams, crate::model::Calibration)> {
    let spec = problem.cost_spec();
    let (warmup, iters, reps) = if ctx.quick { (1, 4, 16) } else { (3, 12, 64) };
    let cal = calibrate_problem(problem, ctx.artifact_dir.clone(), warmup, iters, reps)?;
    let params = cal.params_with_net(&ctx.cluster.net, spec.words_down, spec.words_up);
    Ok((params, cal))
}

/// One row of a boundary-comparison table: analytic K_BSF vs simulated
/// K_test, with eq. (26) error.
#[derive(Debug, Clone, Copy)]
pub struct BoundaryRow {
    /// Problem size.
    pub n: usize,
    /// Closed-form boundary (eq. 14).
    pub k_bsf: f64,
    /// Simulated-peak boundary.
    pub k_test: f64,
    /// eq. (26) error.
    pub error: f64,
    /// Peak speedup observed in the simulated curve.
    pub peak_speedup: f64,
    /// K-range within 1% of the smoothed peak (the plateau): any K inside
    /// is an equally valid "measured boundary".
    pub plateau: (usize, usize),
}

/// Context for measured-mode experiments: this machine's node is ~10x
/// faster than the paper's 2010-era Xeon, so on the default (Tornado)
/// network small-n workloads fall out of the model's compute-intensive
/// regime. When the caller did not override the network, measured mode
/// models a proportionally modern fabric (1 µs latency, 10 GB/s).
pub fn measured_cluster(ctx: &ExperimentCtx) -> ExperimentCtx {
    let mut c = ctx.clone();
    if c.cluster.net == crate::net::NetworkParams::tornado_susu() {
        c.cluster.net = crate::net::NetworkParams::fast_fabric();
    }
    c
}

/// Inputs for one row of a batched boundary comparison (see
/// [`boundary_rows`]).
pub struct BoundarySpec<'a> {
    /// Problem size (display only; the sweep uses `params.l`).
    pub n: usize,
    /// Cost parameters of this size.
    pub params: CostParams,
    /// Downlink payload (f64 words).
    pub words_down: usize,
    /// Uplink payload (f64 words).
    pub words_up: usize,
    /// Per-K provider factory.
    pub factory: &'a dyn CostFactory,
}

/// Compute boundary comparisons for many parameter sets through one
/// (size × K) work queue — all sizes' K-points interleave across the
/// sweep threads instead of each size waiting for the previous one.
/// Bitwise identical to calling [`boundary_row`] per spec in order.
pub fn boundary_rows(
    ctx: &ExperimentCtx,
    specs: &[BoundarySpec],
    rng: &mut Rng,
) -> Vec<BoundaryRow> {
    let iters = if ctx.quick { 3 } else { 7 };
    let mut jobs = Vec::with_capacity(specs.len());
    let mut bounds = Vec::with_capacity(specs.len());
    for s in specs {
        let k_bsf = BsfModel::new(s.params).k_bsf();
        let ks = k_sweep(k_bsf, ctx.quick);
        let mut sim = ctx.sim_params(s.words_down, s.words_up);
        sim.net = effective_net_with_latency(
            s.params.t_c,
            s.words_down,
            s.words_up,
            ctx.cluster.net.latency,
        );
        jobs.push(SweepJob::new(sim, s.params.l, s.factory, ks, iters, rng));
        bounds.push(k_bsf);
    }
    let curves = simulated_curves(&jobs, default_threads());
    specs
        .iter()
        .zip(bounds)
        .zip(&curves)
        .map(|((s, k_bsf), curve)| {
            let w = (curve.len() / 10).max(5);
            let pk =
                crate::model::scalability::peak_knee(curve, w, 0.99).expect("non-empty curve");
            let plateau =
                crate::model::scalability::peak_plateau(curve, w, 0.99).expect("non-empty curve");
            BoundaryRow {
                n: s.n,
                k_bsf,
                k_test: pk.k as f64,
                error: crate::model::prediction_error(pk.k as f64, k_bsf),
                peak_speedup: pk.speedup,
                plateau,
            }
        })
        .collect()
}

/// One cell/size of a planning harness's pooled DES validation: the
/// closed-form parameters plus the payload words the simulator charges.
pub(crate) struct ValidationItem {
    /// Display size.
    pub n: usize,
    /// Closed-form cost parameters of this cell.
    pub params: CostParams,
    /// Downlink payload (f64 words).
    pub words_down: usize,
    /// Uplink payload (f64 words).
    pub words_up: usize,
}

/// Largest closed-form boundary a planning cell may have and still be
/// DES-validated (the K sweep reaches ~2.4×K_BSF; past this the
/// validation costs minutes for cells the analytic table already answers).
pub(crate) const SIM_K_MAX: f64 = 512.0;

/// True when a boundary is worth simulating: at least the model's useful
/// floor, at most [`SIM_K_MAX`].
pub(crate) fn des_tractable(k_bsf: f64) -> bool {
    (1.5..=SIM_K_MAX).contains(&k_bsf)
}

/// Pooled DES validation for the planning harnesses (`explorer`,
/// `sqrt_law`): every item's K-sweep feeds the single
/// `simulated_curves`/[`boundary_rows`] work queue. Policy lives here,
/// once: sweeps always run at **quick** resolution (the validation is a
/// sanity column, not a headline figure — the harnesses must stay
/// interactive at full experiment settings), seeded from `ctx.seed`.
pub(crate) fn validate_boundaries(
    ctx: &ExperimentCtx,
    items: &[ValidationItem],
) -> Vec<BoundaryRow> {
    let provs: Vec<AnalyticCost> =
        items.iter().map(|it| analytic_provider(&it.params)).collect();
    let specs: Vec<BoundarySpec> = items
        .iter()
        .zip(&provs)
        .map(|(it, p)| BoundarySpec {
            n: it.n,
            params: it.params,
            words_down: it.words_down,
            words_up: it.words_up,
            factory: p,
        })
        .collect();
    let sim_ctx = ExperimentCtx { quick: true, ..ctx.clone() };
    boundary_rows(&sim_ctx, &specs, &mut Rng::new(ctx.seed))
}

/// Compute a boundary comparison for one parameter set. The simulator is
/// always charged a network consistent with `params.t_c` (see
/// [`effective_net`]).
pub fn boundary_row(
    ctx: &ExperimentCtx,
    n: usize,
    params: &CostParams,
    words_down: usize,
    words_up: usize,
    factory: &dyn CostFactory,
    rng: &mut Rng,
) -> BoundaryRow {
    let spec = BoundarySpec { n, params: *params, words_down, words_up, factory };
    boundary_rows(ctx, std::slice::from_ref(&spec), rng)
        .pop()
        .expect("one spec in, one row out")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k_sweep_shape() {
        let ks = k_sweep(100.0, false);
        assert_eq!(ks[0], 1);
        assert!(*ks.last().unwrap() >= 200);
        assert!(ks.windows(2).all(|w| w[0] < w[1]));
        let quick = k_sweep(100.0, true);
        assert!(quick.len() < ks.len());
    }

    #[test]
    fn paper_params_present_for_published_sizes() {
        for n in [1_500, 5_000, 10_000, 16_000] {
            assert!(paper_jacobi_params(n).is_some());
        }
        assert!(paper_jacobi_params(123).is_none());
        for n in [300, 600, 900, 1_200] {
            assert!(paper_gravity_params(n).is_some());
        }
        assert!(paper_gravity_params(50).is_none());
    }

    #[test]
    fn problem_kind_parse_and_build() {
        assert_eq!(ProblemKind::parse("jacobi"), Some(ProblemKind::Jacobi));
        assert_eq!(ProblemKind::parse("nope"), None);
        let p = ProblemKind::Jacobi.build(32);
        assert_eq!(p.list_len(), 32);
        let g = ProblemKind::Gravity.build(64);
        assert_eq!(g.list_len(), 64);
        let c = ProblemKind::Cimmino.build(40);
        assert_eq!(c.list_len(), 40);
    }

    /// The headline validation at unit-test scale: simulated peak vs
    /// closed-form boundary on the paper's own n=10000 parameters must
    /// agree within the paper's error band (≤ 15 %).
    #[test]
    fn paper_params_boundary_within_band() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let params = paper_jacobi_params(10_000).unwrap();
        let prov = analytic_provider(&params);
        let mut rng = Rng::new(1);
        let row = boundary_row(&ctx, 10_000, &params, 10_000, 10_000, &prov, &mut rng);
        assert!(
            row.error < 0.20,
            "K_BSF={:.1} K_test={} err={:.2}",
            row.k_bsf,
            row.k_test,
            row.error
        );
        assert!(row.peak_speedup > 10.0);
    }
}
