//! Figure 6 — BSF-Jacobi speedup curves, simulated ("empirical") vs
//! analytic, per problem size.
//!
//! For each `n`, prints the speedup series over K (paper: solid/empirical
//! vs dotted/analytic, with the red boundary line = K_BSF). In
//! paper-params mode the sizes are the paper's {1500, 5000, 10000, 16000}
//! with Table 2's costs; in measured mode the sizes are calibrated live on
//! this machine.

use anyhow::Result;

use crate::experiments::common::{
    analytic_provider, calibrate, k_sweep, paper_jacobi_params, sampled_provider,
    simulated_curves, ExperimentCtx, ProblemKind, SweepJob,
};
use crate::model::BsfModel;
use crate::util::parallel::default_threads;
use crate::util::{table::sci, Rng, Table};

/// Write the Fig.-6/7-style SVG: simulated (solid) vs analytic (dashed)
/// speedup with the red K_BSF boundary line — the paper's plot format.
pub(crate) fn save_curve_svg(
    ctx: &ExperimentCtx,
    name: &str,
    title: &str,
    curve: &[crate::model::SpeedupPoint],
    model: &BsfModel,
    k_bsf: f64,
) {
    use crate::util::svg::{Chart, Series};
    let mut chart = Chart::new(title, "K (worker nodes)", "speedup a(K)");
    chart.push(Series::solid(
        "simulated cluster",
        curve.iter().map(|p| (p.k as f64, p.speedup)).collect(),
        "#1f77b4",
    ));
    chart.push(Series::dashed(
        "BSF model (eq. 9)",
        curve.iter().map(|p| (p.k as f64, model.speedup(p.k))).collect(),
        "#444444",
    ));
    chart.vline(k_bsf, format!("K_BSF = {k_bsf:.0}"));
    let path = ctx.out_dir.join(format!("{name}.svg"));
    if let Err(e) = chart.save(&path) {
        eprintln!("warning: could not save {path:?}: {e}");
    }
}

/// Sizes used in measured mode (kernel artifacts exist for ≤ 2048; larger
/// sizes run the native path — both are the same map semantics).
const MEASURED_SIZES: [usize; 3] = [512, 1024, 2048];

/// Run Figure 6. Returns one table per size (speedup series) plus a peak
/// summary; saves CSVs into `ctx.out_dir`.
pub fn fig6(ctx: &ExperimentCtx, measured: bool) -> Result<Vec<Table>> {
    let mut out = Vec::new();
    let mut summary = Table::new(
        if measured {
            "Fig. 6 summary (measured on this machine, projected on modelled cluster)"
        } else {
            "Fig. 6 summary (paper's Table 2 parameters)"
        },
        &["n", "K_BSF (eq.14)", "K_test (sim peak)", "peak speedup", "err (eq.26)"],
    );
    let measured_ctx = crate::experiments::common::measured_cluster(ctx);
    let ctx = if measured { &measured_ctx } else { ctx };
    let mut rng = Rng::new(ctx.seed);

    let sizes: Vec<usize> = if measured {
        let mut s = MEASURED_SIZES.to_vec();
        if ctx.quick {
            s.truncate(2);
        }
        s
    } else {
        vec![1_500, 5_000, 10_000, 16_000]
    };

    // Phase 1 (serial): per-size cost parameters. Calibration spawns live
    // master/worker threads, so it stays serial; paper mode is table
    // lookups. Order matters for the RNG fork sequence below.
    let mut preps: Vec<(usize, crate::model::CostParams, Box<dyn crate::simulator::CostFactory>)> =
        Vec::with_capacity(sizes.len());
    for n in sizes {
        let (params, factory): (_, Box<dyn crate::simulator::CostFactory>) = if measured {
            let problem = ProblemKind::Jacobi.build(n);
            let (params, cal) = calibrate(ctx, problem)?;
            let prov = sampled_provider(&cal, &params, ctx.seed ^ n as u64);
            (params, Box::new(prov))
        } else {
            let params = paper_jacobi_params(n).expect("published size");
            (params, Box::new(analytic_provider(&params)))
        };
        preps.push((n, params, factory));
    }

    // Phase 2: all sizes' K-points through one pooled work queue.
    let iters = if ctx.quick { 3 } else { 7 };
    let mut jobs = Vec::with_capacity(preps.len());
    for (n, params, factory) in &preps {
        let model = BsfModel::new(*params);
        let ks = k_sweep(model.k_bsf(), ctx.quick);
        let mut sim_params = ctx.sim_params(*n, *n);
        sim_params.net = crate::experiments::common::effective_net_with_latency(
            params.t_c,
            *n,
            *n,
            ctx.cluster.net.latency,
        );
        jobs.push(SweepJob::new(sim_params, *n, factory.as_ref(), ks, iters, &mut rng));
    }
    let curves = simulated_curves(&jobs, default_threads());

    // Phase 3 (serial): render tables/plots per size.
    for ((n, params, _factory), curve) in preps.iter().zip(&curves) {
        let n = *n;
        let model = BsfModel::new(*params);
        let k_bsf = model.k_bsf();
        let ks = k_sweep(k_bsf, ctx.quick);

        let mut t = Table::new(
            format!("Fig. 6, n = {n}: BSF-Jacobi speedup (K_BSF = {k_bsf:.1})"),
            &["K", "a_sim (empirical)", "a_BSF (eq.9)", "T_K sim", "T_K eq.8"],
        );
        for p in curve {
            t.row(&[
                p.k.to_string(),
                format!("{:.2}", p.speedup),
                format!("{:.2}", model.speedup(p.k)),
                sci(p.t_k),
                sci(model.t_k(p.k)),
            ]);
        }
        ctx.save(&format!("fig6_n{n}{}", if measured { "_measured" } else { "" }), &t);
        save_curve_svg(
            ctx,
            &format!("fig6_n{n}{}", if measured { "_measured" } else { "" }),
            &format!("BSF-Jacobi speedup, n = {n}"),
            curve,
            &model,
            k_bsf,
        );

        let w = (ks.len() / 10).max(5);
        let pk = crate::model::scalability::peak_knee(curve, w, 0.99).expect("curve");
        summary.row(&[
            n.to_string(),
            format!("{k_bsf:.1}"),
            pk.k.to_string(),
            format!("{:.1}", pk.speedup),
            format!("{:.3}", crate::model::prediction_error(pk.k as f64, k_bsf)),
        ]);
        out.push(t);
    }
    ctx.save(if measured { "fig6_summary_measured" } else { "fig6_summary" }, &summary);
    out.push(summary);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mode_reproduces_curve_shape() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let tables = fig6(&ctx, false).unwrap();
        // 4 sizes + summary
        assert_eq!(tables.len(), 5);
        let summary = tables.last().unwrap();
        assert_eq!(summary.len(), 4);
    }
}
