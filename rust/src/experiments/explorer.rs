//! Design-space explorer: K_BSF contours over problem size × interconnect.
//!
//! The model's whole purpose is estimating scalability *before* building
//! anything; this harness turns eq. (14) into a planning table — for each
//! (n, τ_tr) cell, the boundary and the peak speedup — so one can read off
//! e.g. "at n = 50k on a 10 GB/s fabric, stop buying nodes past ~600".

use anyhow::Result;

use crate::coordinator::CostSpec;
use crate::experiments::common::{ExperimentCtx, ProblemKind};
use crate::model::BsfModel;
use crate::net::NetworkParams;
use crate::util::Table;

/// Per-word transfer times swept (s/f64): 40 GbE-class down to HDR-IB-class.
const TAUS: [(f64, &str); 4] = [
    (1.6e-9, "40 GB/s"),
    (8.0e-10 * 10.0, "1 GB/s"),
    (9.13e-8, "Tornado (eff.)"),
    (8.0e-7, "10 MB/s"),
];

/// Problem sizes swept.
const NS: [usize; 5] = [1_000, 4_000, 16_000, 64_000, 256_000];

fn spec_for(kind: ProblemKind, n: usize) -> CostSpec {
    // Analytic op counts (same rescaling the CLI `predict` uses).
    match kind {
        ProblemKind::Jacobi => CostSpec {
            l: n,
            words_down: n,
            words_up: n,
            ops_map_per_elem: n as f64,
            ops_combine: n as f64,
            ops_post: 4.0 * n as f64 + 1.0,
        },
        ProblemKind::Gravity => CostSpec {
            l: n,
            words_down: 7,
            words_up: 3,
            ops_map_per_elem: 17.0,
            ops_combine: 3.0,
            ops_post: 26.0,
        },
        ProblemKind::Cimmino => {
            let cols = (n / 4).max(8);
            CostSpec {
                l: n,
                words_down: cols,
                words_up: cols,
                ops_map_per_elem: 6.0 * cols as f64 + 2.0,
                ops_combine: cols as f64,
                ops_post: 5.0 * cols as f64 + 2.0,
            }
        }
    }
}

/// Run the explorer for one problem kind at a given node speed.
pub fn explorer(ctx: &ExperimentCtx, kind: ProblemKind, tau_op: f64) -> Result<Vec<Table>> {
    let mut t = Table::new(
        format!(
            "Design-space explorer: {kind:?}, τ_op = {tau_op:.1e} s/op — \
             K_BSF (peak speedup) per n × interconnect"
        ),
        &{
            let mut h = vec!["n"];
            h.extend(TAUS.iter().map(|(_, name)| *name));
            h
        },
    );
    for &n in &NS {
        let mut row = vec![n.to_string()];
        for &(tau_tr, _) in &TAUS {
            let net = NetworkParams { latency: ctx.cluster.net.latency, tau_tr };
            let params = spec_for(kind, n).cost_params(tau_op, &net);
            let m = BsfModel::new(params);
            let k = m.k_bsf();
            if k < 1.5 {
                row.push("—".into());
            } else {
                let a = m.speedup((k.round() as usize).max(1));
                row.push(format!("{k:.0} ({a:.0}x)"));
            }
        }
        t.row(&row);
    }
    ctx.save(&format!("explorer_{kind:?}").to_lowercase(), &t);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_grows_with_n_and_bandwidth() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let t = explorer(&ctx, ProblemKind::Jacobi, 1e-9).unwrap().remove(0);
        assert_eq!(t.len(), NS.len());
        let csv = t.to_csv();
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        let k_of = |row: usize, col: usize| -> f64 {
            rows[row][col].trim_matches('"').split(' ').next().unwrap().parse().unwrap_or(0.0)
        };
        // fastest fabric, growing n: boundary must grow
        assert!(k_of(4, 1) > k_of(0, 1), "{csv}");
        // fixed n = 64000: faster fabric must not lower the boundary
        assert!(k_of(3, 1) >= k_of(3, 3), "{csv}");
    }

    #[test]
    fn comm_bound_cells_are_dashes() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        // Gravity on a very slow per-op node: boundary exists everywhere;
        // Jacobi at n=1000 on the slowest fabric should be comm-bound.
        let t = explorer(&ctx, ProblemKind::Jacobi, 1e-10).unwrap().remove(0);
        let csv = t.to_csv();
        assert!(csv.contains('—'), "{csv}");
    }

    #[test]
    fn all_kinds_render() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        for kind in [ProblemKind::Jacobi, ProblemKind::Gravity, ProblemKind::Cimmino] {
            let t = explorer(&ctx, kind, 1e-9).unwrap();
            assert_eq!(t.len(), 1);
        }
    }
}
