//! Design-space explorer: K_BSF contours over problem size × interconnect.
//!
//! The model's whole purpose is estimating scalability *before* building
//! anything; this harness turns eq. (14) into a planning table — for each
//! (n, τ_tr) cell, the boundary and the peak speedup — so one can read off
//! e.g. "at n = 50k on a 10 GB/s fabric, stop buying nodes past ~600".
//!
//! Each tractable cell (boundary within `common::SIM_K_MAX`) is
//! additionally **validated against the discrete-event simulator**: every
//! cell's K-sweep is pooled through the one
//! `simulated_curves`/`boundary_rows` work queue shared by the rest of
//! the evaluation (no serial sweeps remain — bitwise-vs-serial is pinned
//! in `rust/tests/determinism.rs`), and a second table reports simulated
//! K_test vs the closed form.

use anyhow::Result;

use crate::coordinator::CostSpec;
use crate::experiments::common::{
    des_tractable, validate_boundaries, ExperimentCtx, ProblemKind, ValidationItem,
};
use crate::model::{BsfModel, CostParams};
use crate::net::NetworkParams;
use crate::util::Table;

/// Per-word transfer times swept (s/f64): 40 GbE-class down to HDR-IB-class.
const TAUS: [(f64, &str); 4] = [
    (1.6e-9, "40 GB/s"),
    (8.0e-10 * 10.0, "1 GB/s"),
    (9.13e-8, "Tornado (eff.)"),
    (8.0e-7, "10 MB/s"),
];

/// Problem sizes swept.
const NS: [usize; 5] = [1_000, 4_000, 16_000, 64_000, 256_000];

fn spec_for(kind: ProblemKind, n: usize) -> CostSpec {
    // Analytic op counts (same rescaling the CLI `predict` uses).
    match kind {
        ProblemKind::Jacobi => CostSpec {
            l: n,
            words_down: n,
            words_up: n,
            ops_map_per_elem: n as f64,
            ops_combine: n as f64,
            ops_post: 4.0 * n as f64 + 1.0,
        },
        ProblemKind::Gravity => CostSpec {
            l: n,
            words_down: 7,
            words_up: 3,
            ops_map_per_elem: 17.0,
            ops_combine: 3.0,
            ops_post: 26.0,
        },
        ProblemKind::Cimmino => {
            let cols = (n / 4).max(8);
            CostSpec {
                l: n,
                words_down: cols,
                words_up: cols,
                ops_map_per_elem: 6.0 * cols as f64 + 2.0,
                ops_combine: cols as f64,
                ops_post: 5.0 * cols as f64 + 2.0,
            }
        }
    }
}

/// One simulatable cell of the contour grid.
struct SimCell {
    n: usize,
    fabric: &'static str,
    params: CostParams,
    words_down: usize,
    words_up: usize,
}

/// Run the explorer for one problem kind at a given node speed. Returns
/// the analytic contour table and the pooled simulated-validation table.
pub fn explorer(ctx: &ExperimentCtx, kind: ProblemKind, tau_op: f64) -> Result<Vec<Table>> {
    let mut t = Table::new(
        format!(
            "Design-space explorer: {kind:?}, τ_op = {tau_op:.1e} s/op — \
             K_BSF (peak speedup) per n × interconnect"
        ),
        &{
            let mut h = vec!["n"];
            h.extend(TAUS.iter().map(|(_, name)| *name));
            h
        },
    );
    let mut cells: Vec<SimCell> = Vec::new();
    for &n in &NS {
        let mut row = vec![n.to_string()];
        for &(tau_tr, fabric) in &TAUS {
            let net = NetworkParams {
                latency: ctx.cluster.net.latency,
                tau_tr,
                link: ctx.cluster.net.link,
            };
            let cs = spec_for(kind, n);
            let params = cs.cost_params(tau_op, &net);
            let m = BsfModel::new(params);
            let k = m.k_bsf();
            if k < 1.5 {
                row.push("—".into());
            } else {
                let a = m.speedup((k.round() as usize).max(1));
                row.push(format!("{k:.0} ({a:.0}x)"));
                if des_tractable(k) {
                    cells.push(SimCell {
                        n,
                        fabric,
                        params,
                        words_down: cs.words_down,
                        words_up: cs.words_up,
                    });
                }
            }
        }
        t.row(&row);
    }
    ctx.save(&format!("explorer_{kind:?}").to_lowercase(), &t);

    // Simulated validation of the tractable cells — all (cell × K) points
    // interleave through the single pooled sweep work queue (policy —
    // quick resolution, seeding — lives in common::validate_boundaries).
    let items: Vec<ValidationItem> = cells
        .iter()
        .map(|c| ValidationItem {
            n: c.n,
            params: c.params,
            words_down: c.words_down,
            words_up: c.words_up,
        })
        .collect();
    let rows = validate_boundaries(ctx, &items);
    let mut sim = Table::new(
        format!("Explorer DES validation: {kind:?} — simulated K_test vs closed-form K_BSF"),
        &["n", "fabric", "K_BSF", "K_test (sim)", "err", "peak speedup"],
    );
    for (c, r) in cells.iter().zip(&rows) {
        sim.row(&[
            c.n.to_string(),
            c.fabric.to_string(),
            format!("{:.1}", r.k_bsf),
            format!("{:.0}", r.k_test),
            format!("{:.3}", r.error),
            format!("{:.1}x", r.peak_speedup),
        ]);
    }
    ctx.save(&format!("explorer_sim_{kind:?}").to_lowercase(), &sim);
    Ok(vec![t, sim])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_grows_with_n_and_bandwidth() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let t = explorer(&ctx, ProblemKind::Jacobi, 1e-9).unwrap().remove(0);
        assert_eq!(t.len(), NS.len());
        let csv = t.to_csv();
        let rows: Vec<Vec<String>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(str::to_string).collect())
            .collect();
        let k_of = |row: usize, col: usize| -> f64 {
            rows[row][col].trim_matches('"').split(' ').next().unwrap().parse().unwrap_or(0.0)
        };
        // fastest fabric, growing n: boundary must grow
        assert!(k_of(4, 1) > k_of(0, 1), "{csv}");
        // fixed n = 64000: faster fabric must not lower the boundary
        assert!(k_of(3, 1) >= k_of(3, 3), "{csv}");
    }

    #[test]
    fn comm_bound_cells_are_dashes() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        // Gravity on a very slow per-op node: boundary exists everywhere;
        // Jacobi at n=1000 on the slowest fabric should be comm-bound.
        let t = explorer(&ctx, ProblemKind::Jacobi, 1e-10).unwrap().remove(0);
        let csv = t.to_csv();
        assert!(csv.contains('—'), "{csv}");
    }

    #[test]
    fn all_kinds_render_with_sim_validation() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        for kind in [ProblemKind::Jacobi, ProblemKind::Gravity, ProblemKind::Cimmino] {
            let ts = explorer(&ctx, kind, 1e-9).unwrap();
            assert_eq!(ts.len(), 2, "{kind:?}: analytic + simulated tables");
            assert!(!ts[1].is_empty(), "{kind:?}: at least one tractable cell simulated");
        }
    }

    /// The pooled DES validation must roughly agree with the closed form
    /// on the tractable cells (the same ≤20 % band the headline
    /// experiments use).
    #[test]
    fn simulated_boundaries_track_closed_form() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let ts = explorer(&ctx, ProblemKind::Jacobi, 1e-9).unwrap();
        let csv = ts[1].to_csv();
        for line in csv.lines().skip(1) {
            let cols: Vec<&str> = line.split(',').collect();
            let k_bsf: f64 = cols[2].trim_matches('"').parse().unwrap();
            let err: f64 = cols[4].trim_matches('"').parse().unwrap();
            // Tiny boundaries quantize hard (±1 worker is a big relative
            // error); hold the band only where the sweep resolves it.
            if k_bsf >= 16.0 {
                assert!(err < 0.35, "cell {line} drifted from the closed form");
            }
        }
    }
}
