//! Faulty-cluster boundary shift (ROADMAP "scenario diversity"): how far
//! the scalability boundary K* moves off the clean model's prediction as
//! worker failure rates and straggler factors grow.
//!
//! Every cell replays the paper's n = 10000 Jacobi workload through the
//! DES under a deterministic [`FaultSpec`] — failures cost recovery tasks
//! + comm edges in the Algorithm-2 graph (per the cell's
//! [`RecoveryPolicy`]), stragglers stretch the slowest Map lane — and the
//! peak of the simulated speedup curve is compared against the clean
//! closed form (eq. 14). The fault draws ride the same split-stream RNG
//! discipline as the clean sweeps, so the whole table is bitwise
//! reproducible at any thread count (`rust/tests/faults.rs`).

use anyhow::Result;

use crate::experiments::common::{
    analytic_provider, effective_net_with_latency, k_sweep, paper_jacobi_params, simulated_curves,
    ExperimentCtx, SweepJob,
};
use crate::model::BsfModel;
use crate::simulator::{FaultSpec, RecoveryPolicy};
use crate::util::parallel::default_threads;
use crate::util::{Rng, Table};

/// One cell of the boundary-shift sweep.
struct Cell {
    fail_prob: f64,
    straggler_factor: f64,
    policy: RecoveryPolicy,
}

fn policy_name(p: RecoveryPolicy) -> &'static str {
    match p {
        RecoveryPolicy::MasterRecompute => "master-recompute",
        RecoveryPolicy::Redistribute => "redistribute",
        RecoveryPolicy::Checkpoint { .. } => "checkpoint",
    }
}

/// The boundary-shift table: peak K* under growing failure rate and
/// straggler factor, vs the clean model. The first (clean) cell doubles as
/// the DES-vs-analytic validation row, like the existing boundary tables.
pub fn faulty(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let n = 10_000;
    let params = paper_jacobi_params(n).expect("published");
    let k_bsf = BsfModel::new(params).k_bsf();
    let ks = k_sweep(k_bsf, ctx.quick);
    let iters = if ctx.quick { 3 } else { 7 };

    // Failure/straggler grid, plus two master-recompute cells at the
    // heaviest rates so the two recovery policies are directly comparable.
    let cells = [
        Cell { fail_prob: 0.00, straggler_factor: 1.0, policy: RecoveryPolicy::Redistribute },
        Cell { fail_prob: 0.01, straggler_factor: 1.0, policy: RecoveryPolicy::Redistribute },
        Cell { fail_prob: 0.05, straggler_factor: 1.0, policy: RecoveryPolicy::Redistribute },
        Cell { fail_prob: 0.00, straggler_factor: 4.0, policy: RecoveryPolicy::Redistribute },
        Cell { fail_prob: 0.01, straggler_factor: 4.0, policy: RecoveryPolicy::Redistribute },
        Cell { fail_prob: 0.05, straggler_factor: 4.0, policy: RecoveryPolicy::Redistribute },
        Cell { fail_prob: 0.05, straggler_factor: 1.0, policy: RecoveryPolicy::MasterRecompute },
        Cell { fail_prob: 0.05, straggler_factor: 4.0, policy: RecoveryPolicy::MasterRecompute },
        Cell {
            fail_prob: 0.05,
            straggler_factor: 1.0,
            policy: RecoveryPolicy::Checkpoint { interval: 4 },
        },
        Cell {
            fail_prob: 0.05,
            straggler_factor: 4.0,
            policy: RecoveryPolicy::Checkpoint { interval: 4 },
        },
    ];

    // Same treatment as `boundary_rows`: charge the simulator a network
    // consistent with the published t_c, and give every cell its own RNG
    // root so pooled execution matches the serial cell order bitwise.
    let prov = analytic_provider(&params);
    let mut sim = ctx.sim_params(n, n);
    sim.net = effective_net_with_latency(params.t_c, n, n, ctx.cluster.net.latency);
    let mut jobs = Vec::with_capacity(cells.len());
    for cell in &cells {
        let spec = FaultSpec {
            speed_sigma: 0.0,
            straggler_prob: if cell.straggler_factor > 1.0 { 0.1 } else { 0.0 },
            straggler_factor: cell.straggler_factor,
            fail_prob: cell.fail_prob,
            downtime: 2,
            policy: cell.policy,
            speed_drift: 0.0,
            hazard_drift: 0.0,
        };
        let mut rng = Rng::new(ctx.seed ^ 0xFA7);
        jobs.push(SweepJob::new(sim.clone(), n, &prov, ks.clone(), iters, &mut rng).with_fault(spec));
    }
    let curves = simulated_curves(&jobs, default_threads());

    let mut t = Table::new(
        format!("Faulty cluster (Jacobi n={n}): boundary shift vs clean model"),
        &[
            "fail rate",
            "straggler ×",
            "recovery",
            "K* (sim)",
            "peak speedup",
            "ΔK* vs clean",
            "K_BSF (clean, eq.14)",
            "error vs eq.14",
        ],
    );
    let w = (ks.len() / 10).max(5);
    let mut clean_peak_k = 0usize;
    for (i, (cell, curve)) in cells.iter().zip(&curves).enumerate() {
        let pk = crate::model::scalability::peak_knee(curve, w, 0.99).expect("non-empty curve");
        if i == 0 {
            clean_peak_k = pk.k;
        }
        let err = crate::model::prediction_error(pk.k as f64, k_bsf);
        t.row(&[
            format!("{:.2}", cell.fail_prob),
            format!("{:.1}", cell.straggler_factor),
            policy_name(cell.policy).into(),
            pk.k.to_string(),
            format!("{:.1}", pk.speedup),
            format!("{}", clean_peak_k as i64 - pk.k as i64),
            format!("{k_bsf:.0}"),
            if i == 0 { format!("{err:.2}") } else { "—".into() },
        ]);
    }
    ctx.save("faulty", &t);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faulty_table_shape_and_clean_validation() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let t = faulty(&ctx).unwrap().remove(0);
        assert_eq!(t.len(), 10);
        let csv = t.to_csv();
        let rows: Vec<Vec<&str>> = csv.lines().skip(1).map(|l| l.split(',').collect()).collect();
        // The clean cell is the DES-vs-analytic validation row: its shift
        // is zero by construction and its error must stay in the paper's
        // band (the same setup as `paper_params_boundary_within_band`).
        assert_eq!(rows[0][0], "0.00");
        assert_eq!(rows[0][5], "0");
        let err: f64 = rows[0][7].parse().unwrap();
        assert!(err < 0.25, "clean-cell DES error too large: {csv}");
        // Every cell produced a real peak.
        for r in &rows {
            assert!(r[3].parse::<usize>().unwrap() >= 1, "{csv}");
        }
        // The heaviest failure cell must not out-peak the clean cell's
        // speedup: faults only add work to the timeline.
        let clean_peak: f64 = rows[0][4].parse().unwrap();
        let heavy_peak: f64 = rows[5][4].parse().unwrap();
        assert!(heavy_peak <= clean_peak * 1.02, "{csv}");
    }
}
