//! The √n growth law (eqs. 24–25 and 36–37): the scalability boundary of
//! both applications grows as `O(√n)`.
//!
//! Sweeps n over a wide range, computes the closed-form boundary from the
//! analytic cost specs (eqs. 20–23 for Jacobi, §6's counts for Gravity),
//! and fits the growth exponent in log-log space — the paper predicts 0.5.
//!
//! Sizes whose boundary is small enough to simulate (within
//! `common::SIM_K_MAX` — gravity's pre-asymptotic sizes past ~1200 run
//! into the hundreds of thousands of workers) are additionally validated
//! against the discrete-event simulator: **both** applications' tractable
//! sizes feed one pooled `simulated_curves`/`boundary_rows` work queue
//! (no serial sweeps remain in the harness; pooled-vs-serial bitwise
//! equality is pinned in `rust/tests/determinism.rs`), and each table
//! gains a "K_test (sim)" column.

use anyhow::Result;

use crate::experiments::common::{
    des_tractable, validate_boundaries, ExperimentCtx, ValidationItem,
};
use crate::model::scalability::growth_exponent;
use crate::model::BsfModel;
use crate::net::NetworkParams;
use crate::util::Table;

/// τ_op matching the paper's testbed (derived from Table 2:
/// `t_a = n·τ_op` at n = 10000 gives ≈ 9.3e-10 s/op).
const TAU_OP: f64 = 9.3e-10;

fn jacobi_params(n: usize, net: &NetworkParams) -> crate::model::CostParams {
    // eqs. (20)-(23): t_c = 2(nτ_tr + L), t_Map = n²τ_op, t_a = nτ_op.
    crate::model::CostParams {
        l: n,
        t_c: 2.0 * (n as f64 * net.tau_tr + net.latency),
        t_p: 4.0 * n as f64 * TAU_OP,
        t_map: (n as f64) * (n as f64) * TAU_OP,
        t_a: n as f64 * TAU_OP,
    }
}

fn gravity_params(n: usize, net: &NetworkParams) -> crate::model::CostParams {
    // §6: t_c = 6τ_tr + 2L, t_Map = 17nτ_op, t_a = 3τ_op.
    crate::model::CostParams {
        l: n,
        t_c: 6.0 * net.tau_tr + 2.0 * net.latency,
        t_p: 26.0 * TAU_OP,
        t_map: 17.0 * n as f64 * TAU_OP,
        t_a: 3.0 * TAU_OP,
    }
}

/// Run the growth-law sweep for both applications.
pub fn sqrt_law(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let net = ctx.cluster.net;
    let jacobi_ns: Vec<usize> =
        [1_000usize, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000, 128_000].to_vec();
    // Gravity's √n regime starts when 29/3·n dominates (t_c/(t_a·ln2))² —
    // around n ≈ 1e7 on these machine constants. The sweep spans the
    // transition: linear growth at the paper's own Table 4 sizes (their
    // boundaries grow ∝ n!), bending to √n asymptotically (eq. 37).
    let gravity_ns: Vec<usize> =
        [300usize, 1_200, 10_000, 100_000, 1_000_000, 10_000_000, 100_000_000, 1_000_000_000]
            .to_vec();

    // Closed-form pass for every size of both apps; (app, words) metadata
    // rides along so the tractable subset can be simulated in one pool.
    struct Entry {
        app: usize,
        n: usize,
        k_bsf: f64,
        params: crate::model::CostParams,
        words: (usize, usize),
        k_test: Option<f64>,
    }
    let apps: [(&str, &[usize], fn(usize, &NetworkParams) -> crate::model::CostParams); 2] = [
        ("jacobi", &jacobi_ns, jacobi_params),
        ("gravity", &gravity_ns, gravity_params),
    ];
    let mut entries: Vec<Entry> = Vec::new();
    for (app, (_, ns, f)) in apps.iter().enumerate() {
        for &n in ns.iter() {
            let params = f(n, &net);
            let k = BsfModel::new(params).k_bsf();
            // Jacobi's payload is the n-vector both ways; gravity's is the
            // paper's 3/3 charge (consistent with its t_c formula above).
            let words = if app == 0 { (n, n) } else { (3, 3) };
            entries.push(Entry { app, n, k_bsf: k, params, words, k_test: None });
        }
    }

    // Pooled DES validation of the tractable sizes — both applications'
    // (size × K) points interleave through the one sweep work queue
    // (policy — quick resolution, seeding — lives in
    // common::validate_boundaries).
    let sim_idx: Vec<usize> =
        (0..entries.len()).filter(|&i| des_tractable(entries[i].k_bsf)).collect();
    let items: Vec<ValidationItem> = sim_idx
        .iter()
        .map(|&i| ValidationItem {
            n: entries[i].n,
            params: entries[i].params,
            words_down: entries[i].words.0,
            words_up: entries[i].words.1,
        })
        .collect();
    let rows = validate_boundaries(ctx, &items);
    for (&i, row) in sim_idx.iter().zip(&rows) {
        entries[i].k_test = Some(row.k_test);
    }

    let mut out = Vec::new();
    for (app, (name, _, _)) in apps.iter().enumerate() {
        let mut t = Table::new(
            format!("√n law ({name}): K_BSF vs n (eqs. 24–25 / 36–37), DES-validated"),
            &["n", "K_BSF", "K_BSF/√n", "K_test (sim)"],
        );
        let mut points = Vec::new();
        for e in entries.iter().filter(|e| e.app == app) {
            points.push((e.n as f64, e.k_bsf));
            t.row(&[
                e.n.to_string(),
                format!("{:.1}", e.k_bsf),
                format!("{:.3}", e.k_bsf / (e.n as f64).sqrt()),
                e.k_test.map_or("—".into(), |k| format!("{k:.0}")),
            ]);
        }
        // Fit the asymptotic tail (largest half of the sweep): the paper's
        // O(√n) claim is asymptotic; gravity is still pre-asymptotic at its
        // published sizes.
        let tail = &points[points.len() / 2..];
        let p = growth_exponent(tail);
        t.row(&[
            "fit exponent (tail)".into(),
            format!("{p:.3}"),
            "(paper: 0.5)".into(),
            "".into(),
        ]);
        ctx.save(&format!("sqrt_law_{name}"), &t);
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponents_near_half_in_asymptotic_regime() {
        let net = NetworkParams::tornado_susu();
        // Jacobi's √n regime needs 2n ≫ (c/2)² ≈ 2e4, i.e. n ≳ 1e6.
        let pts: Vec<(f64, f64)> = [1_000_000usize, 4_000_000, 16_000_000, 64_000_000]
            .iter()
            .map(|&n| (n as f64, BsfModel::new(jacobi_params(n, &net)).k_bsf()))
            .collect();
        let p = growth_exponent(&pts);
        assert!((p - 0.5).abs() < 0.1, "jacobi exponent {p}");
        // Gravity's tiny t_a pushes the regime out to n ~ 1e8.
        let pts: Vec<(f64, f64)> = [100_000_000usize, 400_000_000, 1_600_000_000]
            .iter()
            .map(|&n| (n as f64, BsfModel::new(gravity_params(n, &net)).k_bsf()))
            .collect();
        let p = growth_exponent(&pts);
        assert!((p - 0.5).abs() < 0.1, "gravity exponent {p}");
    }

    #[test]
    fn gravity_preasymptotic_is_linear_like_table4() {
        // The paper's own Table 4 boundaries grow ∝ n (69→279 for
        // 300→1200); the model reproduces that pre-asymptotic behaviour.
        let net = NetworkParams::tornado_susu();
        let pts: Vec<(f64, f64)> = [300usize, 600, 1_200]
            .iter()
            .map(|&n| (n as f64, BsfModel::new(gravity_params(n, &net)).k_bsf()))
            .collect();
        let p = growth_exponent(&pts);
        assert!(p > 0.8, "pre-asymptotic exponent {p} should be near 1");
    }

    #[test]
    fn tables_render_with_simulated_column() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let ts = sqrt_law(&ctx).unwrap();
        assert_eq!(ts.len(), 2);
        assert!(ts[0].to_csv().contains("fit exponent"));
        // Tractable sizes carry a simulated boundary, intractable ones a
        // dash (gravity's giant pre-asymptotic boundaries).
        let jacobi_csv = ts[0].to_csv();
        assert!(jacobi_csv.lines().skip(1).any(|l| !l.contains('—')), "{jacobi_csv}");
        let gravity_csv = ts[1].to_csv();
        assert!(gravity_csv.contains('—'), "{gravity_csv}");
    }
}
