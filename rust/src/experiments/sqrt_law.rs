//! The √n growth law (eqs. 24–25 and 36–37): the scalability boundary of
//! both applications grows as `O(√n)`.
//!
//! Sweeps n over a wide range, computes the closed-form boundary from the
//! analytic cost specs (eqs. 20–23 for Jacobi, §6's counts for Gravity),
//! and fits the growth exponent in log-log space — the paper predicts 0.5.

use anyhow::Result;

use crate::experiments::common::ExperimentCtx;
use crate::model::scalability::growth_exponent;
use crate::model::BsfModel;
use crate::net::NetworkParams;
use crate::util::Table;

/// τ_op matching the paper's testbed (derived from Table 2:
/// `t_a = n·τ_op` at n = 10000 gives ≈ 9.3e-10 s/op).
const TAU_OP: f64 = 9.3e-10;

fn jacobi_params(n: usize, net: &NetworkParams) -> crate::model::CostParams {
    // eqs. (20)-(23): t_c = 2(nτ_tr + L), t_Map = n²τ_op, t_a = nτ_op.
    crate::model::CostParams {
        l: n,
        t_c: 2.0 * (n as f64 * net.tau_tr + net.latency),
        t_p: 4.0 * n as f64 * TAU_OP,
        t_map: (n as f64) * (n as f64) * TAU_OP,
        t_a: n as f64 * TAU_OP,
    }
}

fn gravity_params(n: usize, net: &NetworkParams) -> crate::model::CostParams {
    // §6: t_c = 6τ_tr + 2L, t_Map = 17nτ_op, t_a = 3τ_op.
    crate::model::CostParams {
        l: n,
        t_c: 6.0 * net.tau_tr + 2.0 * net.latency,
        t_p: 26.0 * TAU_OP,
        t_map: 17.0 * n as f64 * TAU_OP,
        t_a: 3.0 * TAU_OP,
    }
}

/// Run the growth-law sweep for both applications.
pub fn sqrt_law(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let net = ctx.cluster.net;
    let jacobi_ns: Vec<usize> =
        [1_000usize, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000, 128_000].to_vec();
    // Gravity's √n regime starts when 29/3·n dominates (t_c/(t_a·ln2))² —
    // around n ≈ 1e7 on these machine constants. The sweep spans the
    // transition: linear growth at the paper's own Table 4 sizes (their
    // boundaries grow ∝ n!), bending to √n asymptotically (eq. 37).
    let gravity_ns: Vec<usize> =
        [300usize, 1_200, 10_000, 100_000, 1_000_000, 10_000_000, 100_000_000, 1_000_000_000]
            .to_vec();

    let mut out = Vec::new();
    for (name, ns, f) in [
        ("jacobi", jacobi_ns, jacobi_params as fn(usize, &NetworkParams) -> _),
        ("gravity", gravity_ns, gravity_params as fn(usize, &NetworkParams) -> _),
    ] {
        let mut t = Table::new(
            format!("√n law ({name}): K_BSF vs n (eqs. 24–25 / 36–37)"),
            &["n", "K_BSF", "K_BSF/√n"],
        );
        let mut points = Vec::new();
        for &n in &ns {
            let k = BsfModel::new(f(n, &net)).k_bsf();
            points.push((n as f64, k));
            t.row(&[n.to_string(), format!("{k:.1}"), format!("{:.3}", k / (n as f64).sqrt())]);
        }
        // Fit the asymptotic tail (largest half of the sweep): the paper's
        // O(√n) claim is asymptotic; gravity is still pre-asymptotic at its
        // published sizes.
        let tail = &points[points.len() / 2..];
        let p = growth_exponent(tail);
        t.row(&["fit exponent (tail)".into(), format!("{p:.3}"), "(paper: 0.5)".into()]);
        ctx.save(&format!("sqrt_law_{name}"), &t);
        out.push(t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponents_near_half_in_asymptotic_regime() {
        let net = NetworkParams::tornado_susu();
        // Jacobi's √n regime needs 2n ≫ (c/2)² ≈ 2e4, i.e. n ≳ 1e6.
        let pts: Vec<(f64, f64)> = [1_000_000usize, 4_000_000, 16_000_000, 64_000_000]
            .iter()
            .map(|&n| (n as f64, BsfModel::new(jacobi_params(n, &net)).k_bsf()))
            .collect();
        let p = growth_exponent(&pts);
        assert!((p - 0.5).abs() < 0.1, "jacobi exponent {p}");
        // Gravity's tiny t_a pushes the regime out to n ~ 1e8.
        let pts: Vec<(f64, f64)> = [100_000_000usize, 400_000_000, 1_600_000_000]
            .iter()
            .map(|&n| (n as f64, BsfModel::new(gravity_params(n, &net)).k_bsf()))
            .collect();
        let p = growth_exponent(&pts);
        assert!((p - 0.5).abs() < 0.1, "gravity exponent {p}");
    }

    #[test]
    fn gravity_preasymptotic_is_linear_like_table4() {
        // The paper's own Table 4 boundaries grow ∝ n (69→279 for
        // 300→1200); the model reproduces that pre-asymptotic behaviour.
        let net = NetworkParams::tornado_susu();
        let pts: Vec<(f64, f64)> = [300usize, 600, 1_200]
            .iter()
            .map(|&n| (n as f64, BsfModel::new(gravity_params(n, &net)).k_bsf()))
            .collect();
        let p = growth_exponent(&pts);
        assert!(p > 0.8, "pre-asymptotic exponent {p} should be near 1");
    }

    #[test]
    fn tables_render() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let ts = sqrt_law(&ctx).unwrap();
        assert_eq!(ts.len(), 2);
        assert!(ts[0].to_csv().contains("fit exponent"));
    }
}
