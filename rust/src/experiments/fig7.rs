//! Figure 7 — BSF-Gravity speedup curves, simulated vs analytic.
//!
//! Paper-params mode uses §6's published constants (`t_c = 5e-5`,
//! `t_p = 9.5e-7`, `t_a = 4.7e-9`, per-n `t_Map`) over
//! n ∈ {300, 600, 900, 1200}; measured mode calibrates the live
//! BSF-Gravity at the same sizes (they are small enough to run directly).

use anyhow::Result;

use crate::experiments::common::{
    analytic_provider, calibrate, k_sweep, paper_gravity_params, sampled_provider,
    simulated_curves, ExperimentCtx, ProblemKind, SweepJob,
};
use crate::model::BsfModel;
use crate::util::parallel::default_threads;
use crate::util::{table::sci, Rng, Table};

/// Payload sizes for BSF-Gravity (downlink `[X|V|t]`, uplink α).
const WORDS_DOWN: usize = 7;
const WORDS_UP: usize = 3;

/// Run Figure 7. Returns one table per size plus a peak summary.
pub fn fig7(ctx: &ExperimentCtx, measured: bool) -> Result<Vec<Table>> {
    let mut out = Vec::new();
    let mut summary = Table::new(
        if measured {
            "Fig. 7 summary (measured on this machine, projected on modelled cluster)"
        } else {
            "Fig. 7 summary (paper's §6 parameters)"
        },
        &["n", "K_BSF (eq.14)", "K_test (sim peak)", "peak speedup", "err (eq.26)"],
    );
    let measured_ctx = crate::experiments::common::measured_cluster(ctx);
    let ctx = if measured { &measured_ctx } else { ctx };
    let mut rng = Rng::new(ctx.seed ^ 0x9);

    // Paper sizes for paper-params mode. Measured mode uses block-multiple
    // sizes (B = 256): at n = 300 the PJRT per-call overhead (~45 µs)
    // dominates the map and breaks the model's linear-in-chunk assumption;
    // at multiples of the block the per-element cost is constant and the
    // model applies.
    let mut sizes = if measured {
        vec![4_096usize, 16_384, 65_536]
    } else {
        vec![300usize, 600, 900, 1_200]
    };
    if ctx.quick {
        sizes.truncate(2);
    }

    // Serial per-size prep (calibration runs live), then one pooled
    // (size × K) work queue, then serial rendering — see fig6.
    let mut preps: Vec<(usize, crate::model::CostParams, Box<dyn crate::simulator::CostFactory>)> =
        Vec::with_capacity(sizes.len());
    for n in sizes {
        let (params, factory): (_, Box<dyn crate::simulator::CostFactory>) = if measured {
            let problem = ProblemKind::Gravity.build(n);
            let (params, cal) = calibrate(ctx, problem)?;
            let prov = sampled_provider(&cal, &params, ctx.seed ^ n as u64);
            (params, Box::new(prov))
        } else {
            let params = paper_gravity_params(n).expect("published size");
            (params, Box::new(analytic_provider(&params)))
        };
        preps.push((n, params, factory));
    }

    let iters = if ctx.quick { 3 } else { 7 };
    let mut jobs = Vec::with_capacity(preps.len());
    for (n, params, factory) in &preps {
        let ks = k_sweep(BsfModel::new(*params).k_bsf(), ctx.quick);
        let mut sim_params = ctx.sim_params(WORDS_DOWN, WORDS_UP);
        sim_params.net = crate::experiments::common::effective_net_with_latency(
            params.t_c,
            WORDS_DOWN,
            WORDS_UP,
            ctx.cluster.net.latency,
        );
        jobs.push(SweepJob::new(sim_params, *n, factory.as_ref(), ks, iters, &mut rng));
    }
    let curves = simulated_curves(&jobs, default_threads());

    for ((n, params, _factory), curve) in preps.iter().zip(&curves) {
        let n = *n;
        let model = BsfModel::new(*params);
        let k_bsf = model.k_bsf();
        let ks = k_sweep(k_bsf, ctx.quick);

        let mut t = Table::new(
            format!("Fig. 7, n = {n}: BSF-Gravity speedup (K_BSF = {k_bsf:.1})"),
            &["K", "a_sim (empirical)", "a_BSF (eq.9)", "T_K sim", "T_K eq.8"],
        );
        for p in curve {
            t.row(&[
                p.k.to_string(),
                format!("{:.2}", p.speedup),
                format!("{:.2}", model.speedup(p.k)),
                sci(p.t_k),
                sci(model.t_k(p.k)),
            ]);
        }
        ctx.save(&format!("fig7_n{n}{}", if measured { "_measured" } else { "" }), &t);
        crate::experiments::fig6::save_curve_svg(
            ctx,
            &format!("fig7_n{n}{}", if measured { "_measured" } else { "" }),
            &format!("BSF-Gravity speedup, n = {n}"),
            curve,
            &model,
            k_bsf,
        );

        let w = (ks.len() / 10).max(5);
        let pk = crate::model::scalability::peak_knee(curve, w, 0.99).expect("curve");
        summary.row(&[
            n.to_string(),
            format!("{k_bsf:.1}"),
            pk.k.to_string(),
            format!("{:.1}", pk.speedup),
            format!("{:.3}", crate::model::prediction_error(pk.k as f64, k_bsf)),
        ]);
        out.push(t);
    }
    ctx.save(if measured { "fig7_summary_measured" } else { "fig7_summary" }, &summary);
    out.push(summary);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper's Table 4 says K_BSF ∈ {69, 141, 210, 279} for the four sizes.
    #[test]
    fn paper_mode_k_bsf_matches_table4() {
        for (n, want) in [(300usize, 69.0), (600, 141.0), (900, 210.0), (1_200, 279.1)] {
            let params = paper_gravity_params(n).unwrap();
            let got = BsfModel::new(params).k_bsf();
            assert!(
                (got - want).abs() / want < 0.03,
                "n={n}: got {got:.1}, paper {want}"
            );
        }
    }

    #[test]
    fn quick_run_produces_tables() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let tables = fig7(&ctx, false).unwrap();
        assert_eq!(tables.len(), 3); // 2 sizes + summary in quick mode
    }
}
