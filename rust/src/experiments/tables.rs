//! Tables 2, 3 and 4 of the paper.
//!
//! * Table 2 — calibrated cost parameters for BSF-Jacobi per size, plus the
//!   comp/comm ratio. Paper-params mode echoes the published values through
//!   the same code path (a consistency check of our formulas); measured
//!   mode prints this machine's calibration.
//! * Table 3 — K_BSF (closed form) vs K_test (simulated peak) + eq. (26)
//!   error for BSF-Jacobi.
//! * Table 4 — the same for BSF-Gravity.

use anyhow::Result;

use crate::experiments::common::{
    analytic_provider, boundary_rows, calibrate, paper_gravity_params, paper_jacobi_params,
    sampled_provider, BoundarySpec, ExperimentCtx, ProblemKind,
};
use crate::model::CostParams;
use crate::util::{table::sci, Rng, Table};

/// Paper's published Table 3 rows (for side-by-side display).
const PAPER_TABLE3: [(usize, f64, f64, f64); 4] = [
    (1_500, 47.0, 40.0, 0.15),
    (5_000, 64.0, 60.0, 0.06),
    (10_000, 112.0, 120.0, 0.07),
    (16_000, 150.0, 160.0, 0.06),
];

/// Paper's published Table 4 rows.
const PAPER_TABLE4: [(usize, f64, f64, f64); 4] = [
    (300, 69.0, 60.0, 0.13),
    (600, 141.0, 140.0, 0.01),
    (900, 210.0, 200.0, 0.05),
    (1_200, 279.1, 280.0, 3.6e-4),
];

/// Table 2: cost parameters per Jacobi size.
pub fn table2(ctx: &ExperimentCtx, measured: bool) -> Result<Vec<Table>> {
    let measured_ctx = crate::experiments::common::measured_cluster(ctx);
    let ctx = if measured { &measured_ctx } else { ctx };
    let mut t = Table::new(
        if measured {
            "Table 2 (measured): BSF-Jacobi cost parameters on this machine"
        } else {
            "Table 2 (paper): BSF-Jacobi cost parameters, Tornado SUSU"
        },
        &["n", "t_c", "t_p", "t_a", "t_Map", "comp/comm"],
    );
    let sizes: Vec<usize> = if measured {
        if ctx.quick { vec![512, 1_024] } else { vec![512, 1_024, 2_048] }
    } else {
        vec![1_500, 5_000, 10_000, 16_000]
    };
    for n in sizes {
        let params: CostParams = if measured {
            let (p, _cal) = calibrate(ctx, ProblemKind::Jacobi.build(n))?;
            p
        } else {
            paper_jacobi_params(n).expect("published size")
        };
        t.row(&[
            n.to_string(),
            sci(params.t_c),
            sci(params.t_p),
            sci(params.t_a),
            sci(params.t_map),
            format!("{:.0}", params.comp_comm_ratio()),
        ]);
    }
    ctx.save(if measured { "table2_measured" } else { "table2" }, &t);
    Ok(vec![t])
}

fn boundary_table(
    _ctx: &ExperimentCtx,
    title: &str,
    rows: Vec<crate::experiments::common::BoundaryRow>,
    paper_rows: Option<&[(usize, f64, f64, f64)]>,
) -> Table {
    let mut t = Table::new(
        title,
        &[
            "n",
            "K_BSF",
            "K_test",
            "plateau(1%)",
            "Error",
            "paper K_BSF",
            "paper K_test",
            "paper Error",
        ],
    );
    for r in rows {
        let paper = paper_rows.and_then(|ps| ps.iter().find(|p| p.0 == r.n));
        let (pk_bsf, pk_test, perr) = match paper {
            Some(&(_, a, b, c)) => (format!("{a:.0}"), format!("{b:.0}"), format!("{c:.2}")),
            None => ("-".into(), "-".into(), "-".into()),
        };
        t.row(&[
            r.n.to_string(),
            format!("{:.0}", r.k_bsf),
            format!("{:.0}", r.k_test),
            format!("{}-{}", r.plateau.0, r.plateau.1),
            format!("{:.3}", r.error),
            pk_bsf,
            pk_test,
            perr,
        ]);
    }
    t
}

/// Table 3: Jacobi prediction errors (analytic vs simulated boundary).
pub fn table3(ctx: &ExperimentCtx, measured: bool) -> Result<Vec<Table>> {
    let measured_ctx = crate::experiments::common::measured_cluster(ctx);
    let ctx = if measured { &measured_ctx } else { ctx };
    let mut rng = Rng::new(ctx.seed ^ 0x3);
    let sizes: Vec<usize> = if measured {
        if ctx.quick { vec![512, 1_024] } else { vec![512, 1_024, 2_048] }
    } else {
        vec![1_500, 5_000, 10_000, 16_000]
    };
    // Serial prep (calibration), then every (size × K) point through one
    // pooled work queue.
    let mut preps: Vec<(usize, CostParams, Box<dyn crate::simulator::CostFactory>)> =
        Vec::with_capacity(sizes.len());
    for n in sizes {
        let (params, factory): (_, Box<dyn crate::simulator::CostFactory>) = if measured {
            let (p, cal) = calibrate(ctx, ProblemKind::Jacobi.build(n))?;
            let prov = sampled_provider(&cal, &p, ctx.seed ^ n as u64);
            (p, Box::new(prov))
        } else {
            let p = paper_jacobi_params(n).expect("published size");
            (p, Box::new(analytic_provider(&p)))
        };
        preps.push((n, params, factory));
    }
    let specs: Vec<BoundarySpec> = preps
        .iter()
        .map(|(n, params, factory)| BoundarySpec {
            n: *n,
            params: *params,
            words_down: *n,
            words_up: *n,
            factory: factory.as_ref(),
        })
        .collect();
    let rows = boundary_rows(ctx, &specs, &mut rng);
    let t = boundary_table(
        ctx,
        if measured {
            "Table 3 (measured): BSF-Jacobi scalability boundaries"
        } else {
            "Table 3 (paper params): BSF-Jacobi scalability boundaries"
        },
        rows,
        (!measured).then_some(&PAPER_TABLE3[..]),
    );
    ctx.save(if measured { "table3_measured" } else { "table3" }, &t);
    Ok(vec![t])
}

/// Table 4: Gravity prediction errors.
pub fn table4(ctx: &ExperimentCtx, measured: bool) -> Result<Vec<Table>> {
    let measured_ctx = crate::experiments::common::measured_cluster(ctx);
    let ctx = if measured { &measured_ctx } else { ctx };
    let mut rng = Rng::new(ctx.seed ^ 0x4);
    let mut sizes = if measured {
        // block-multiple sizes: see fig7.rs on the per-call-overhead regime
        vec![4_096usize, 16_384, 65_536]
    } else {
        vec![300usize, 600, 900, 1_200]
    };
    if ctx.quick {
        sizes.truncate(2);
    }
    let mut preps: Vec<(usize, CostParams, Box<dyn crate::simulator::CostFactory>)> =
        Vec::with_capacity(sizes.len());
    for n in sizes {
        let (params, factory): (_, Box<dyn crate::simulator::CostFactory>) = if measured {
            let (p, cal) = calibrate(ctx, ProblemKind::Gravity.build(n))?;
            let prov = sampled_provider(&cal, &p, ctx.seed ^ n as u64);
            (p, Box::new(prov))
        } else {
            let p = paper_gravity_params(n).expect("published size");
            (p, Box::new(analytic_provider(&p)))
        };
        preps.push((n, params, factory));
    }
    let specs: Vec<BoundarySpec> = preps
        .iter()
        .map(|(n, params, factory)| BoundarySpec {
            n: *n,
            params: *params,
            words_down: 7,
            words_up: 3,
            factory: factory.as_ref(),
        })
        .collect();
    let rows = boundary_rows(ctx, &specs, &mut rng);
    let t = boundary_table(
        ctx,
        if measured {
            "Table 4 (measured): BSF-Gravity scalability boundaries"
        } else {
            "Table 4 (paper params): BSF-Gravity scalability boundaries"
        },
        rows,
        (!measured).then_some(&PAPER_TABLE4[..]),
    );
    ctx.save(if measured { "table4_measured" } else { "table4" }, &t);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_paper_mode_echoes_published() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let t = table2(&ctx, false).unwrap().remove(0);
        assert_eq!(t.len(), 4);
        let csv = t.to_csv();
        assert!(csv.contains("7.20E-5"), "csv: {csv}");
        assert!(csv.contains("126")); // comp/comm at n=1500
    }

    #[test]
    fn table3_paper_mode_errors_small() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let t = table3(&ctx, false).unwrap().remove(0);
        assert_eq!(t.len(), 4);
        // every simulated-vs-analytic error stays within ~2x the paper's
        // worst case (0.15); column 4 is the eq.-26 error (3 is the
        // plateau range)
        for line in t.to_csv().lines().skip(1) {
            let err: f64 = line.split(',').nth(4).unwrap().parse().unwrap();
            assert!(err < 0.30, "line: {line}");
        }
    }
}
