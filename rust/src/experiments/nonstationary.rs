//! Non-stationary fault plane (ROADMAP "scenario diversity", second
//! wave): drifting worker speeds, failure hazards that rise with job age,
//! contended shared-link communication, and checkpoint/restart recovery,
//! all over the BSF-Cimmino workload.
//!
//! Two tables:
//!
//! 1. **Boundary shift** — where the simulated K* lands when per-worker
//!    speeds drift iteration by iteration, the failure hazard grows over
//!    the run, and concurrent Gather/Scatter transfers split one shared
//!    link, vs the clean closed form (eq. 14). The stationary per-edge
//!    row doubles as the DES-vs-analytic validation row; an "ambient" row
//!    takes its link mode from `BSF_NET` and is pinned bitwise to its
//!    explicit twin (the module test checks both).
//! 2. **Checkpoint interval** — mean DES iteration cost at a fixed K
//!    over a failure-rate × interval grid under
//!    [`RecoveryPolicy::Checkpoint`], the measured cost-optimal interval
//!    per rate, and Young's analytic interval
//!    ([`BsfModel::optimal_checkpoint_interval`]) alongside: the optimum
//!    tightens as the failure rate grows.

use anyhow::Result;

use crate::experiments::common::{
    analytic_provider, effective_net_with_latency, k_sweep, simulated_curves, ExperimentCtx,
    ProblemKind, SweepJob,
};
use crate::model::BsfModel;
use crate::net::{default_link_mode, LinkMode};
use crate::simulator::{
    run_faulty_into, CostFactory, FaultPlan, FaultScratch, FaultSpec, RecoveryPolicy,
};
use crate::simulator::IterationTemplate;
use crate::util::parallel::default_threads;
use crate::util::{Rng, Table};

/// One cell of the boundary-shift sweep.
struct NsCell {
    fail_prob: f64,
    speed_drift: f64,
    hazard_drift: f64,
    link: LinkMode,
    /// True for the row whose link mode comes from `BSF_NET` — it must be
    /// bitwise identical to the explicit row of the same mode.
    ambient: bool,
}

fn link_name(l: LinkMode) -> &'static str {
    match l {
        LinkMode::PerEdge => "per-edge",
        LinkMode::Shared => "shared",
    }
}

/// The non-stationary sweep: K* boundary shift under drift/hazard/link
/// contention, and the cost-optimal checkpoint interval vs failure rate.
pub fn nonstationary(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let n = if ctx.quick { 1_000 } else { 4_000 };
    let problem = ProblemKind::Cimmino.build(n);
    let spec = problem.cost_spec();
    let l = spec.l;
    let params = spec.cost_params(9.3e-10, &ctx.cluster.net);
    let k_bsf = BsfModel::new(params).k_bsf();
    let ks = k_sweep(k_bsf, ctx.quick);
    let iters = if ctx.quick { 3 } else { 7 };

    // Same discipline as the stationary faulty sweep: charge the DES a
    // network consistent with the derived t_c, and give every cell an
    // identically-seeded root so the ambient row is bitwise the twin of
    // its explicit-link counterpart.
    let prov = analytic_provider(&params);
    let mut sim = ctx.sim_params(spec.words_down, spec.words_up);
    sim.net = effective_net_with_latency(
        params.t_c,
        spec.words_down,
        spec.words_up,
        ctx.cluster.net.latency,
    );

    let cells = [
        NsCell { fail_prob: 0.00, speed_drift: 0.00, hazard_drift: 0.0, link: LinkMode::PerEdge, ambient: false },
        NsCell { fail_prob: 0.00, speed_drift: 0.00, hazard_drift: 0.0, link: LinkMode::Shared, ambient: false },
        NsCell { fail_prob: 0.02, speed_drift: 0.00, hazard_drift: 0.0, link: LinkMode::PerEdge, ambient: false },
        NsCell { fail_prob: 0.02, speed_drift: 0.00, hazard_drift: 2.0, link: LinkMode::PerEdge, ambient: false },
        NsCell { fail_prob: 0.02, speed_drift: 0.00, hazard_drift: 2.0, link: LinkMode::Shared, ambient: false },
        NsCell { fail_prob: 0.00, speed_drift: 0.02, hazard_drift: 0.0, link: LinkMode::PerEdge, ambient: false },
        NsCell { fail_prob: 0.00, speed_drift: 0.00, hazard_drift: 0.0, link: default_link_mode(), ambient: true },
    ];

    let mut jobs = Vec::with_capacity(cells.len());
    for cell in &cells {
        let fspec = FaultSpec {
            fail_prob: cell.fail_prob,
            downtime: 2,
            policy: RecoveryPolicy::Redistribute,
            speed_drift: cell.speed_drift,
            hazard_drift: cell.hazard_drift,
            ..FaultSpec::clean()
        };
        let mut cs = sim.clone();
        cs.net.link = cell.link;
        let mut rng = Rng::new(ctx.seed ^ 0x2517);
        let mut job = SweepJob::new(cs, l, &prov, ks.clone(), iters, &mut rng);
        if cell.fail_prob > 0.0 || cell.speed_drift != 0.0 {
            job = job.with_fault(fspec);
        }
        jobs.push(job);
    }
    let curves = simulated_curves(&jobs, default_threads());

    let mut t1 = Table::new(
        format!("Non-stationary Cimmino (n={n}): K* under drift, hazard and link contention"),
        &[
            "fail rate",
            "speed drift",
            "hazard drift",
            "link",
            "K* (sim)",
            "peak speedup",
            "ΔK* vs clean",
            "K_BSF (clean, eq.14)",
            "error vs eq.14",
        ],
    );
    let w = (ks.len() / 10).max(5);
    let mut clean_k = 0usize;
    for (i, (cell, curve)) in cells.iter().zip(&curves).enumerate() {
        let pk = crate::model::scalability::peak_knee(curve, w, 0.99).expect("non-empty curve");
        if i == 0 {
            clean_k = pk.k;
        }
        let err = crate::model::prediction_error(pk.k as f64, k_bsf);
        t1.row(&[
            format!("{:.2}", cell.fail_prob),
            format!("{:.2}", cell.speed_drift),
            format!("{:.1}", cell.hazard_drift),
            if cell.ambient {
                format!("{} (BSF_NET)", link_name(cell.link))
            } else {
                link_name(cell.link).into()
            },
            pk.k.to_string(),
            format!("{:.1}", pk.speedup),
            format!("{}", clean_k as i64 - pk.k as i64),
            format!("{k_bsf:.0}"),
            if i == 0 { format!("{err:.2}") } else { "—".into() },
        ]);
    }
    ctx.save("nonstationary_boundary", &t1);

    // Table 2: cost-optimal checkpoint interval vs failure rate at a
    // fixed K near half the clean boundary. Every cell replays the same
    // horizon under RecoveryPolicy::Checkpoint from its own pure stream;
    // the Young column is the analytic argmin over real-valued intervals
    // with the snapshot priced exactly like the DES save task (one
    // downlink payload) and λ = the whole-cluster per-iteration death
    // probability.
    let k_fix = (k_bsf * 0.5).round().max(4.0) as usize;
    let horizon = if ctx.quick { 24 } else { 48 };
    let fails = [0.02, 0.05, 0.10];
    let intervals = [1u64, 2, 4, 8, 16, 32];
    let model = BsfModel::new(params);
    let t_save = sim.net.p2p(spec.words_down);
    let mut t2 = Table::new(
        format!("Checkpoint/restart (Cimmino n={n}, K={k_fix}): mean DES iteration cost"),
        &["fail rate", "iv=1", "iv=2", "iv=4", "iv=8", "iv=16", "iv=32", "iv* (DES)", "iv* (Young)"],
    );
    let mut tmpl = IterationTemplate::new(k_fix, l, &sim);
    let mut scratch = FaultScratch::default();
    let mut runs = Vec::new();
    for (fi, &fail) in fails.iter().enumerate() {
        let mut row = vec![format!("{fail:.2}")];
        let mut best = (f64::INFINITY, intervals[0]);
        for &iv in &intervals {
            let fspec = FaultSpec {
                fail_prob: fail,
                downtime: 2,
                policy: RecoveryPolicy::Checkpoint { interval: iv },
                ..FaultSpec::clean()
            };
            let cell_root = Rng::new(ctx.seed ^ 0xC4E).split(((fi as u64) << 8) | iv);
            let plan = FaultPlan::generate(&fspec, k_fix, horizon as u64, &cell_root.split(1));
            let mut provider = prov.instance(k_fix as u64);
            let mut rng = cell_root.split(2);
            run_faulty_into(
                &mut tmpl,
                &plan,
                l,
                &sim,
                horizon,
                provider.as_mut(),
                &mut rng,
                &mut runs,
                &mut scratch,
            );
            let mean = runs.iter().map(|t| t.total).sum::<f64>() / runs.len() as f64;
            if mean < best.0 {
                best = (mean, iv);
            }
            row.push(format!("{mean:.4e}"));
        }
        let lam = 1.0 - (1.0 - fail).powi(k_fix as i32);
        let young = model.optimal_checkpoint_interval(k_fix, lam, t_save);
        row.push(best.1.to_string());
        row.push(format!("{young:.1}"));
        t2.row(&row);
    }
    ctx.save("nonstationary_checkpoint", &t2);

    Ok(vec![t1, t2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_and_checkpoint_tables() {
        let ctx = ExperimentCtx { quick: true, ..Default::default() };
        let mut tables = nonstationary(&ctx).unwrap();
        let t2 = tables.pop().unwrap();
        let t1 = tables.pop().unwrap();

        assert_eq!(t1.len(), 7);
        let csv = t1.to_csv();
        let rows: Vec<Vec<&str>> = csv.lines().skip(1).map(|l| l.split(',').collect()).collect();
        // The stationary per-edge row is the eq. 14 validation row.
        assert_eq!(rows[0][3], "per-edge");
        let err: f64 = rows[0][8].parse().unwrap();
        assert!(err < 0.30, "stationary-cell DES error too large: {csv}");
        // Link contention only adds comm time: the shared-link boundary
        // must not exceed the per-edge one.
        let k_clean: usize = rows[0][4].parse().unwrap();
        let k_shared: usize = rows[1][4].parse().unwrap();
        assert!(k_shared <= k_clean, "{csv}");
        // The ambient (BSF_NET) row is bitwise the twin of the explicit
        // row of the same link mode — same peak, same speedup string.
        let twin = if rows[6][3].starts_with("shared") { &rows[1] } else { &rows[0] };
        assert_eq!(rows[6][4], twin[4], "{csv}");
        assert_eq!(rows[6][5], twin[5], "{csv}");
        // Every row produced a real peak.
        for r in &rows {
            assert!(r[4].parse::<usize>().unwrap() >= 1, "{csv}");
        }

        assert_eq!(t2.len(), 3);
        let csv2 = t2.to_csv();
        let r2: Vec<Vec<&str>> = csv2.lines().skip(1).map(|l| l.split(',').collect()).collect();
        // The cost-optimal interval must not grow with the failure rate —
        // in the DES argmin and exactly in Young's analytic column.
        let iv_lo: u64 = r2[0][7].parse().unwrap();
        let iv_hi: u64 = r2[2][7].parse().unwrap();
        assert!(iv_hi <= iv_lo, "iv* grew with failure rate: {csv2}");
        let y_lo: f64 = r2[0][8].parse().unwrap();
        let y_hi: f64 = r2[2][8].parse().unwrap();
        assert!(y_hi < y_lo, "{csv2}");
    }
}
