//! `bsf` — the BSF command-line interface.
//!
//! ```text
//! bsf experiment <name> [--measured=1] [--quick=1] [--out=results] [--config=FILE] [--cluster.*=...]
//!     names: fig6 | fig7 | table2 | table3 | table4 | sqrt-law |
//!            ablation-collectives | ablation-masters | baselines | all
//! bsf run       --problem=jacobi|gravity|cimmino --n=512 --k=4 [--iters=N] [--no-artifacts=1]
//! bsf calibrate --problem=jacobi --n=1024
//! bsf predict   --problem=jacobi --n=10000 [--tau-op=9.3e-10]
//! bsf sweep     --problem=jacobi --n=1024 [--kmax=K]
//! bsf fleet-serial [--fleet.problem=jacobi] [--fleet.sizes=1500,5000] [--quick=1]
//! bsf fleet-coord  [--fleet.addr=127.0.0.1:7500] [--fleet.*=...]
//! bsf fleet-worker [--fleet.addr=127.0.0.1:7500] [--fleet.name=w1]
//! ```
//!
//! Any `--key=value` flag overrides the config file (see
//! `bsf::config::Settings`); `[cluster]` keys describe the modelled
//! interconnect.


use anyhow::{anyhow, bail, Result};

use bsf::config::{ClusterConfig, Settings};
use bsf::coordinator::{calibrate_problem, LiveRunner};
use bsf::experiments::{
    ablation_collectives, ablation_masters, baselines, faulty, fig6, fig7, nonstationary,
    paper_jacobi_params, sqrt_law, table2, table3, table4, ExperimentCtx, ProblemKind,
};
use bsf::model::BsfModel;
use bsf::util::{table::sci, Rng, Table};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "usage: bsf <experiment|run|calibrate|predict|sweep|trace|fleet-serial|fleet-coord|fleet-worker> \
     [--key=value ...]\n\
     experiments: fig6 fig7 table2 table3 table4 sqrt-law faulty nonstationary \
     ablation-collectives ablation-masters baselines explorer all"
        .to_string()
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut settings = Settings::new();
    if let Some(path) = args.iter().find_map(|a| a.strip_prefix("--config=")) {
        settings = Settings::load(path)?;
    }
    let rest = settings.merge_cli(args.iter().map(String::as_str));
    let rest: Vec<&str> = rest.iter().map(String::as_str).collect();

    let ctx = make_ctx(&settings)?;
    match rest.first().copied() {
        Some("experiment") => {
            let name = rest.get(1).copied().ok_or_else(|| anyhow!(usage()))?;
            run_experiment(&ctx, &settings, name)
        }
        Some("run") => cmd_run(&ctx, &settings),
        Some("calibrate") => cmd_calibrate(&ctx, &settings),
        Some("predict") => cmd_predict(&ctx, &settings),
        Some("sweep") => cmd_sweep(&ctx, &settings),
        Some("trace") => cmd_trace(&ctx, &settings),
        Some("fleet-serial") => cmd_fleet_serial(&ctx, &settings),
        Some("fleet-coord") => cmd_fleet_coord(&ctx, &settings),
        Some("fleet-worker") => cmd_fleet_worker(&settings),
        _ => bail!(usage()),
    }
}

/// Shared `fleet.*` spec flags (the worker receives the spec on the wire,
/// so only `fleet-serial` and `fleet-coord` read these).
fn fleet_spec(ctx: &ExperimentCtx, settings: &Settings) -> Result<bsf::fleet::FleetSpec> {
    let pname = settings.get("fleet.problem").unwrap_or("jacobi");
    let problem = ProblemKind::parse(pname)
        .ok_or_else(|| anyhow!("fleet.problem={pname}: expected jacobi|gravity"))?;
    let default_sizes: &[usize] = match problem {
        ProblemKind::Gravity => &[300, 600],
        _ => &[1_500, 5_000],
    };
    Ok(bsf::fleet::FleetSpec {
        problem,
        sizes: settings.usize_list_or("fleet.sizes", default_sizes)?,
        iters: settings.usize_or("fleet.iters", if ctx.quick { 3 } else { 7 })?,
        seed: ctx.seed,
        quick: ctx.quick,
        jitter: settings.f64_or("fleet.jitter", 0.05)?,
    })
}

fn fleet_addr(settings: &Settings) -> String {
    settings.get("fleet.addr").unwrap_or("127.0.0.1:7500").to_string()
}

/// `bsf fleet-serial` — the single-process ground truth: run the grid
/// serially and save the result table a fleet run must match byte for
/// byte.
fn cmd_fleet_serial(ctx: &ExperimentCtx, settings: &Settings) -> Result<()> {
    let grid = bsf::fleet::FleetGrid::new(fleet_spec(ctx, settings)?)?;
    let times = bsf::fleet::serial_times(&grid);
    let t = bsf::fleet::fleet_table(&grid, &times);
    println!("{}", t.render());
    ctx.save("fleet_serial", &t);
    println!("(CSV saved under {:?})", ctx.out_dir);
    Ok(())
}

/// `bsf fleet-coord` — bind the fleet address, serve leases until the
/// grid completes, save the result table and print the fault report.
fn cmd_fleet_coord(ctx: &ExperimentCtx, settings: &Settings) -> Result<()> {
    let grid = bsf::fleet::FleetGrid::new(fleet_spec(ctx, settings)?)?;
    let ms = |key: &str, default: usize| -> Result<std::time::Duration> {
        Ok(std::time::Duration::from_millis(settings.usize_or(key, default)? as u64))
    };
    let cfg = bsf::fleet::FleetConfig {
        heartbeat: ms("fleet.heartbeat-ms", 200)?,
        grace: settings.usize_or("fleet.grace", 10)? as u32,
        min_deadline: ms("fleet.min-deadline-ms", 5_000)?,
        lease_target: ms("fleet.lease-target-ms", 500)?,
        max_lease_cells: settings.usize_or("fleet.max-lease-cells", 16)?,
        idle_timeout: ms("fleet.idle-timeout-ms", 120_000)?,
        ..Default::default()
    };
    let addr = fleet_addr(settings);
    let listener = std::net::TcpListener::bind(&addr)
        .map_err(|e| anyhow!("fleet-coord: cannot bind {addr}: {e}"))?;
    println!("fleet coordinator listening on {addr} ({} cells)...", grid.cells());
    let (times, report) = bsf::fleet::serve(&grid, &cfg, listener)?;
    let t = bsf::fleet::fleet_table(&grid, &times);
    println!("{}", t.render());
    ctx.save("fleet_result", &t);
    let mut rt = Table::new(
        "fleet report",
        &["workers", "leases", "re-leases", "expired", "deaths", "dup done", "dup mismatch", "re-exec cells"],
    );
    rt.row(&[
        report.workers_joined.to_string(),
        report.leases_issued.to_string(),
        report.releases.to_string(),
        report.leases_expired.to_string(),
        report.worker_deaths.to_string(),
        report.duplicate_completions.to_string(),
        report.duplicate_mismatches.to_string(),
        report.re_executed_cells.to_string(),
    ]);
    println!("{}", rt.render());
    if report.duplicate_mismatches > 0 {
        bail!("fleet determinism violated: {} duplicate completions disagreed", report.duplicate_mismatches);
    }
    Ok(())
}

/// `bsf fleet-worker` — join the fleet at `fleet.addr` and execute leases
/// until the coordinator shuts the run down.
fn cmd_fleet_worker(settings: &Settings) -> Result<()> {
    let addr = fleet_addr(settings);
    let name = settings
        .get("fleet.name")
        .map(str::to_string)
        .unwrap_or_else(|| format!("worker-{}", std::process::id()));
    let mut cfg = bsf::fleet::WorkerConfig::new(addr, name);
    cfg.connect_attempts = settings.usize_or("fleet.connect-attempts", 12)?;
    let summary = bsf::fleet::run_worker(&cfg)?;
    println!(
        "fleet worker '{}' done: {} cells over {} leases ({} reconnects, {} drained)",
        cfg.name, summary.cells, summary.leases, summary.reconnects, summary.drained_cells
    );
    Ok(())
}

fn make_ctx(settings: &Settings) -> Result<ExperimentCtx> {
    let mut ctx = ExperimentCtx {
        cluster: ClusterConfig::from_settings(settings)?,
        ..Default::default()
    };
    if let Some(out) = settings.get("out") {
        ctx.out_dir = out.into();
    }
    ctx.quick = settings.bool_or("quick", false)?;
    ctx.seed = settings.usize_or("seed", 0xB5F)? as u64;
    if settings.bool_or("no-artifacts", false)? {
        ctx.artifact_dir = None;
    }
    Ok(ctx)
}

fn print_tables(tables: &[Table]) {
    for t in tables {
        println!("{}", t.render());
    }
}

fn run_experiment(ctx: &ExperimentCtx, settings: &Settings, name: &str) -> Result<()> {
    let measured = settings.bool_or("measured", false)?;
    let tables = match name {
        "fig6" => fig6(ctx, measured)?,
        "fig7" => fig7(ctx, measured)?,
        "table2" => table2(ctx, measured)?,
        "table3" => table3(ctx, measured)?,
        "table4" => table4(ctx, measured)?,
        "sqrt-law" => sqrt_law(ctx)?,
        "faulty" => faulty(ctx)?,
        "nonstationary" => nonstationary(ctx)?,
        "ablation-collectives" => ablation_collectives(ctx)?,
        "ablation-masters" => ablation_masters(ctx)?,
        "baselines" => baselines(ctx)?,
        "explorer" => {
            let kind = settings
                .get("problem")
                .and_then(ProblemKind::parse)
                .unwrap_or(ProblemKind::Jacobi);
            let tau_op = settings.f64_or("tau-op", 9.3e-10)?;
            bsf::experiments::explorer(ctx, kind, tau_op)?
        }
        "all" => {
            let mut all = Vec::new();
            for (label, f) in [
                ("fig6", fig6 as fn(&ExperimentCtx, bool) -> Result<Vec<Table>>),
                ("fig7", fig7),
                ("table2", table2),
                ("table3", table3),
                ("table4", table4),
            ] {
                eprintln!("== running {label} (paper params) ==");
                all.extend(f(ctx, false)?);
                if measured {
                    eprintln!("== running {label} (measured) ==");
                    all.extend(f(ctx, true)?);
                }
            }
            eprintln!("== running sqrt-law ==");
            all.extend(sqrt_law(ctx)?);
            eprintln!("== running faulty ==");
            all.extend(faulty(ctx)?);
            eprintln!("== running nonstationary ==");
            all.extend(nonstationary(ctx)?);
            eprintln!("== running ablations + baselines ==");
            all.extend(ablation_collectives(ctx)?);
            all.extend(ablation_masters(ctx)?);
            all.extend(baselines(ctx)?);
            all
        }
        other => bail!("unknown experiment '{other}'\n{}", usage()),
    };
    print_tables(&tables);
    println!("(CSV copies saved under {:?})", ctx.out_dir);
    Ok(())
}

fn problem_from(settings: &Settings) -> Result<(ProblemKind, usize)> {
    let kind = settings
        .get("problem")
        .and_then(ProblemKind::parse)
        .ok_or_else(|| anyhow!("--problem=jacobi|gravity|cimmino required"))?;
    let n = settings.usize_or("n", 1024)?;
    Ok((kind, n))
}

fn cmd_run(ctx: &ExperimentCtx, settings: &Settings) -> Result<()> {
    let (kind, n) = problem_from(settings)?;
    let k = settings.usize_or("k", 4)?;
    let iters = settings.usize_or("iters", 1000)?;
    let problem = kind.build(n);
    let name = problem.name().to_string();
    let mut runner = LiveRunner::new(k, iters);
    runner.artifact_dir = ctx.artifact_dir.clone();
    println!("running {name} (n={n}) live with K={k} workers...");
    let report = runner.run(problem)?;
    let mut t = Table::new(
        format!("{name}: live run, K={k}, n={n}"),
        &["iterations", "converged", "wall (s)", "mean iter (s)", "mean map (s)", "mean post (s)"],
    );
    let m = report.metrics.without_warmup(1.min(report.metrics.len().saturating_sub(1)));
    t.row(&[
        report.iterations.to_string(),
        report.converged.to_string(),
        format!("{:.3}", report.wall),
        sci(m.total_summary().mean),
        sci(m.map_summary().mean),
        sci(m.post_summary().mean),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_calibrate(ctx: &ExperimentCtx, settings: &Settings) -> Result<()> {
    let (kind, n) = problem_from(settings)?;
    let problem = kind.build(n);
    let spec = problem.cost_spec();
    let cal = calibrate_problem(problem, ctx.artifact_dir.clone(), 3, 12, 64)?;
    let params = cal.params_with_net(&ctx.cluster.net, spec.words_down, spec.words_up);
    let model = BsfModel::new(params);
    let mut t = Table::new(
        format!("calibration: {kind:?} n={n} (network: modelled cluster)"),
        &["t_c", "t_p", "t_a", "t_Map", "comp/comm", "K_BSF (eq.14)"],
    );
    t.row(&[
        sci(params.t_c),
        sci(params.t_p),
        sci(params.t_a),
        sci(params.t_map),
        format!("{:.0}", params.comp_comm_ratio()),
        format!("{:.1}", model.k_bsf()),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_predict(ctx: &ExperimentCtx, settings: &Settings) -> Result<()> {
    let (kind, n) = problem_from(settings)?;
    let tau_op = settings.f64_or("tau-op", 9.3e-10)?;
    // Analytic-only path (paper §5: before any implementation): cost spec
    // from the problem's op counts, machine speeds from flags.
    let params = if let (ProblemKind::Jacobi, Some(p)) = (kind, paper_jacobi_params(n)) {
        println!("(using the paper's published Table 2 parameters for n={n})");
        p
    } else {
        let problem = kind.build(n.min(4096)); // spec only; rescaled below
        let mut spec = problem.cost_spec();
        rescale_spec(&mut spec, kind, n);
        spec.cost_params(tau_op, &ctx.cluster.net)
    };
    let model = BsfModel::new(params);
    let mut t = Table::new(
        format!("prediction: {kind:?} n={n}"),
        &["T_1 (eq.7)", "K_BSF (eq.14)", "a(K_BSF)", "a(2·K_BSF)"],
    );
    let k_bsf = model.k_bsf();
    t.row(&[
        sci(model.t1()),
        format!("{k_bsf:.1}"),
        format!("{:.1}", model.speedup((k_bsf.round() as usize).max(1))),
        format!("{:.1}", model.speedup(((2.0 * k_bsf).round() as usize).max(1))),
    ]);
    println!("{}", t.render());
    Ok(())
}

/// Rescale a cost spec captured at a small instance to dimension `n`
/// (op counts are analytic in n for all shipped problems).
fn rescale_spec(spec: &mut bsf::coordinator::CostSpec, kind: ProblemKind, n: usize) {
    match kind {
        ProblemKind::Jacobi => {
            spec.l = n;
            spec.words_down = n;
            spec.words_up = n;
            spec.ops_map_per_elem = n as f64;
            spec.ops_combine = n as f64;
            spec.ops_post = 4.0 * n as f64 + 1.0;
        }
        ProblemKind::Gravity => {
            spec.l = n;
        }
        ProblemKind::Cimmino => {
            let cols = (n / 4).max(8);
            spec.l = n;
            spec.words_down = cols;
            spec.words_up = cols;
            spec.ops_map_per_elem = 6.0 * cols as f64 + 2.0;
            spec.ops_combine = cols as f64;
            spec.ops_post = 5.0 * cols as f64 + 2.0;
        }
    }
}

fn cmd_sweep(ctx: &ExperimentCtx, settings: &Settings) -> Result<()> {
    let (kind, n) = problem_from(settings)?;
    let problem = kind.build(n);
    let spec = problem.cost_spec();
    println!("calibrating {kind:?} n={n} live (1 master + 1 worker)...");
    let cal = calibrate_problem(problem, ctx.artifact_dir.clone(), 2, 8, 32)?;
    let params = cal.params_with_net(&ctx.cluster.net, spec.words_down, spec.words_up);
    let model = BsfModel::new(params);
    let k_bsf = model.k_bsf();
    let kmax = settings.usize_or("kmax", (k_bsf * 2.4) as usize)?;
    let ks = bsf::experiments::k_sweep(kmax as f64 / 2.4, ctx.quick);
    let prov = bsf::simulator::SampledCost {
        per_elem: std::sync::Arc::new(
            cal.map_samples.iter().map(|s| s / cal.l as f64).collect(),
        ),
        t_a: params.t_a,
        t_p: params.t_p,
        rng: Rng::new(ctx.seed),
    };
    let sim = ctx.sim_params(spec.words_down, spec.words_up);
    let mut rng = Rng::new(ctx.seed ^ 0x5);
    let curve = bsf::experiments::simulated_curve(ctx, &sim, params.l, &prov, &ks, 5, &mut rng);
    let mut t = Table::new(
        format!("sweep: {kind:?} n={n}, K_BSF={k_bsf:.1}"),
        &["K", "T_K sim", "a_sim", "a_BSF (eq.9)"],
    );
    for p in &curve {
        t.row(&[
            p.k.to_string(),
            sci(p.t_k),
            format!("{:.2}", p.speedup),
            format!("{:.2}", model.speedup(p.k)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `bsf trace --problem=jacobi --n=5000 --k=16 [--out=results]` — simulate
/// one Algorithm-2 iteration and export its per-node timeline as Chrome
/// trace-event JSON (open in chrome://tracing or ui.perfetto.dev).
fn cmd_trace(ctx: &ExperimentCtx, settings: &Settings) -> Result<()> {
    let (kind, n) = problem_from(settings)?;
    let k = settings.usize_or("k", 16)?;
    // Paper parameters when available, else analytic from the cost spec.
    let params = match kind {
        ProblemKind::Jacobi => paper_jacobi_params(n),
        ProblemKind::Gravity => bsf::experiments::paper_gravity_params(n),
        ProblemKind::Cimmino => None,
    }
    .unwrap_or_else(|| {
        let problem = kind.build(n.min(4096));
        let mut spec = problem.cost_spec();
        rescale_spec(&mut spec, kind, n);
        spec.cost_params(settings.f64_or("tau-op", 9.3e-10).unwrap_or(9.3e-10), &ctx.cluster.net)
    });
    let spec_words = match kind {
        ProblemKind::Gravity => (7usize, 3usize),
        _ => (n, n),
    };
    let mut sim = ctx.sim_params(spec_words.0, spec_words.1);
    sim.net = bsf::experiments::effective_net_with_latency(
        params.t_c,
        spec_words.0,
        spec_words.1,
        ctx.cluster.net.latency,
    );
    let mut prov = bsf::experiments::analytic_provider(&params);
    let mut rng = Rng::new(ctx.seed);
    let (timing, trace) =
        bsf::simulator::trace_iteration(k, params.l, &sim, &mut prov, &mut rng);
    let path = ctx.out_dir.join(format!("trace_{kind:?}_n{n}_k{k}.json").to_lowercase());
    trace.save(&path)?;
    println!(
        "one iteration at K={k}: total {:.3e}s (bcast {:.1e}, map {:.1e}, reduce {:.1e}); \
         master utilization {:.0}%, slowest worker {:.0}%",
        timing.total,
        timing.broadcast_done,
        timing.map_done - timing.broadcast_done,
        timing.reduce_done - timing.map_done,
        100.0 * trace.utilization(0),
        100.0
            * (1..=k as u32)
                .map(|w| trace.utilization(w))
                .fold(0.0, f64::max),
    );
    println!("trace written to {path:?} ({} events) — open in chrome://tracing", trace.events.len());
    Ok(())
}
