//! Configuration system: experiment/cluster settings from simple
//! `key = value` config files (an INI-like TOML subset — offline build, no
//! external parser) plus `--key=value` CLI overrides.
//!
//! Precedence: defaults < config file < CLI overrides. Every experiment
//! binary and the `bsf` CLI share this loader, so a cluster description
//! (latency, bandwidth, per-op time, jitter) can be pinned in a file and
//! reused across runs.

mod settings;

pub use settings::{ClusterConfig, Settings};
