//! `key = value` settings store with file + CLI layering.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::net::{CollectiveAlgo, LinkMode, NetworkParams};
use crate::simulator::ReduceMode;

/// A layered string→string settings store.
#[derive(Debug, Clone, Default)]
pub struct Settings {
    values: BTreeMap<String, String>,
}

impl Settings {
    /// Empty settings.
    pub fn new() -> Settings {
        Settings::default()
    }

    /// Load from an INI-like file: `key = value` lines, `#`/`;` comments,
    /// blank lines ignored, optional `[section]` headers that prefix keys
    /// with `section.`.
    pub fn load(path: impl AsRef<Path>) -> Result<Settings> {
        let src = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        let mut s = Settings::new();
        s.merge_str(&src)?;
        Ok(s)
    }

    /// Merge config text (later keys win).
    pub fn merge_str(&mut self, src: &str) -> Result<()> {
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim();
            // strip optional quotes
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = &val[1..val.len() - 1];
            }
            self.values.insert(key, val.to_string());
        }
        Ok(())
    }

    /// Apply `--key=value` style CLI overrides; unrecognised args are
    /// returned untouched (for the caller's own flags).
    pub fn merge_cli<'a>(&mut self, args: impl IntoIterator<Item = &'a str>) -> Vec<String> {
        let mut rest = Vec::new();
        for a in args {
            if let Some(kv) = a.strip_prefix("--") {
                if let Some((k, v)) = kv.split_once('=') {
                    self.values.insert(k.to_string(), v.to_string());
                    continue;
                }
            }
            rest.push(a.to_string());
        }
        rest
    }

    /// Set a value programmatically.
    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// f64 lookup with default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not a number")),
        }
    }

    /// usize lookup with default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not an integer")),
        }
    }

    /// Comma-separated usize list lookup with default (used for size
    /// grids like `--sizes=1500,5000`). Empty entries are rejected so a
    /// trailing comma fails loudly instead of silently shrinking a grid.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .with_context(|| format!("config {key}={v}: '{p}' is not an integer"))
                })
                .collect(),
        }
    }

    /// bool lookup with default (`true/false/1/0/yes/no`).
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config {key}={v}: not a boolean"),
        }
    }

    /// All keys (sorted).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

/// The modelled cluster, as read from settings (section `[cluster]`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterConfig {
    /// Interconnect parameters.
    pub net: NetworkParams,
    /// Collective schedule.
    pub algo: CollectiveAlgo,
    /// Reduce strategy.
    pub reduce_mode: ReduceMode,
    /// Compute jitter sigma for the simulator.
    pub jitter_comp: f64,
    /// Communication jitter sigma.
    pub jitter_comm: f64,
    /// Master count (1 = the BSF model).
    pub masters: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            net: NetworkParams::tornado_susu(),
            algo: CollectiveAlgo::BinomialTree,
            reduce_mode: ReduceMode::TreeMasterFold,
            jitter_comp: 0.0,
            jitter_comm: 0.0,
            masters: 1,
        }
    }
}

impl ClusterConfig {
    /// Read from settings keys `cluster.latency`, `cluster.tau_tr`,
    /// `cluster.link` (`per-edge`|`shared`), `cluster.collective`
    /// (`tree`|`linear`), `cluster.reduce` (`paper`|`mpi-reduce`|`gather`),
    /// `cluster.jitter_comp`, `cluster.jitter_comm`, `cluster.masters`.
    pub fn from_settings(s: &Settings) -> Result<ClusterConfig> {
        let d = ClusterConfig::default();
        let algo = match s.get("cluster.collective").unwrap_or("tree") {
            "tree" => CollectiveAlgo::BinomialTree,
            "linear" => CollectiveAlgo::Linear,
            other => bail!("cluster.collective={other}: expected tree|linear"),
        };
        let reduce_mode = match s.get("cluster.reduce").unwrap_or("paper") {
            "paper" => ReduceMode::TreeMasterFold,
            "mpi-reduce" | "tree" => ReduceMode::InTree,
            "gather" => ReduceMode::GatherThenFold,
            other => bail!("cluster.reduce={other}: expected paper|mpi-reduce|gather"),
        };
        let link = match s.get("cluster.link").unwrap_or("per-edge") {
            "per-edge" => LinkMode::PerEdge,
            "shared" => LinkMode::Shared,
            other => bail!("cluster.link={other}: expected per-edge|shared"),
        };
        Ok(ClusterConfig {
            net: NetworkParams {
                latency: s.f64_or("cluster.latency", d.net.latency)?,
                tau_tr: s.f64_or("cluster.tau_tr", d.net.tau_tr)?,
                link,
            },
            algo,
            reduce_mode,
            jitter_comp: s.f64_or("cluster.jitter_comp", 0.0)?,
            jitter_comm: s.f64_or("cluster.jitter_comm", 0.0)?,
            masters: s.usize_or("cluster.masters", 1)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let mut s = Settings::new();
        s.merge_str(
            "# comment\nfoo = 1\n[cluster]\nlatency = 2e-5\ncollective = \"linear\"\n; more\n",
        )
        .unwrap();
        assert_eq!(s.get("foo"), Some("1"));
        assert_eq!(s.get("cluster.latency"), Some("2e-5"));
        assert_eq!(s.get("cluster.collective"), Some("linear"));
    }

    #[test]
    fn cli_overrides_and_passthrough() {
        let mut s = Settings::new();
        s.merge_str("a = 1\n").unwrap();
        let rest = s.merge_cli(["--a=2", "run", "--flag"]);
        assert_eq!(s.get("a"), Some("2"));
        assert_eq!(rest, vec!["run", "--flag"]);
    }

    #[test]
    fn typed_lookups() {
        let mut s = Settings::new();
        s.merge_str("x = 2.5\nn = 10\nb = yes\n").unwrap();
        assert_eq!(s.f64_or("x", 0.0).unwrap(), 2.5);
        assert_eq!(s.f64_or("missing", 7.0).unwrap(), 7.0);
        assert_eq!(s.usize_or("n", 0).unwrap(), 10);
        assert!(s.bool_or("b", false).unwrap());
        assert!(s.f64_or("b", 0.0).is_err());
    }

    #[test]
    fn usize_list_parses_and_rejects() {
        let mut s = Settings::new();
        assert_eq!(s.usize_list_or("sizes", &[1, 2]).unwrap(), vec![1, 2]);
        s.merge_str("sizes = 1500, 5000,16000\n").unwrap();
        assert_eq!(s.usize_list_or("sizes", &[]).unwrap(), vec![1500, 5000, 16000]);
        s.merge_str("bad = 1,,2\n").unwrap();
        assert!(s.usize_list_or("bad", &[]).is_err());
        s.merge_str("worse = 1,x\n").unwrap();
        assert!(s.usize_list_or("worse", &[]).is_err());
    }

    #[test]
    fn cluster_config_defaults_and_overrides() {
        let mut s = Settings::new();
        let d = ClusterConfig::from_settings(&s).unwrap();
        assert_eq!(d, ClusterConfig::default());
        s.merge_str("[cluster]\nlatency = 1e-6\ncollective = linear\nreduce = gather\nmasters = 2\n")
            .unwrap();
        let c = ClusterConfig::from_settings(&s).unwrap();
        assert_eq!(c.net.latency, 1e-6);
        assert_eq!(c.algo, CollectiveAlgo::Linear);
        assert_eq!(c.reduce_mode, ReduceMode::GatherThenFold);
        assert_eq!(c.masters, 2);
    }

    #[test]
    fn bad_enum_rejected() {
        let mut s = Settings::new();
        s.merge_str("[cluster]\ncollective = ring\n").unwrap();
        assert!(ClusterConfig::from_settings(&s).is_err());
    }

    #[test]
    fn cluster_link_parses_and_rejects() {
        let mut s = Settings::new();
        assert_eq!(ClusterConfig::from_settings(&s).unwrap().net.link, LinkMode::PerEdge);
        s.merge_str("[cluster]\nlink = shared\n").unwrap();
        assert_eq!(ClusterConfig::from_settings(&s).unwrap().net.link, LinkMode::Shared);
        let mut bad = Settings::new();
        bad.merge_str("[cluster]\nlink = bonded\n").unwrap();
        assert!(ClusterConfig::from_settings(&bad).is_err());
    }

    #[test]
    fn malformed_line_rejected() {
        let mut s = Settings::new();
        assert!(s.merge_str("just words\n").is_err());
    }
}
