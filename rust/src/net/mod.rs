//! Message-passing substrate.
//!
//! Two halves, sharing one cost vocabulary:
//!
//! * **Cost model** ([`NetworkParams`], [`collectives`]) — how long a
//!   point-to-point message or an MPI-style collective takes on the modelled
//!   interconnect. This is what the discrete-event simulator charges and
//!   what the BSF cost metric's `t_c` and `L` parameters come from.
//! * **Live transport** ([`transport`]) — an in-process channel fabric
//!   (master ↔ K worker threads) used by the live runner for real parallel
//!   execution on this machine.
//!
//! The default parameters are calibrated to the paper's testbed (Table 2:
//! `L = 1.5e-5 s`, and `t_c = 2(n·τ_tr + L)` giving `τ_tr ≈ 6.6e-9 s/f64
//! ≈ 1.2 GB/s effective — InfiniBand QDR with MPI overheads).

pub mod collectives;
pub mod transport;

pub use collectives::{CollectiveAlgo, CollectiveSchedule};

/// Interconnect cost parameters.
///
/// A point-to-point message of `w` f64 words costs `latency + w * tau_tr`
/// seconds — the standard postal/Hockney model, which is exactly the shape
/// the BSF metric assumes in eq. (20): `t_c = c_c·τ_tr + 2L`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkParams {
    /// One-byte message latency `L` (seconds). Paper §6: `1.5e-5`.
    pub latency: f64,
    /// Per-f64-word transfer time `τ_tr` (seconds/word).
    pub tau_tr: f64,
}

impl NetworkParams {
    /// The paper's calibrated testbed ("Tornado SUSU", Table 2).
    ///
    /// `τ_tr` is recovered from Table 2's `t_c` at n = 16000:
    /// `t_c = 2(n·τ_tr + L)` ⇒ `τ_tr = (2.95e-3/2 − 1.5e-5)/16000 ≈ 9.13e-8`.
    pub fn tornado_susu() -> NetworkParams {
        NetworkParams { latency: 1.5e-5, tau_tr: 9.13e-8 }
    }

    /// An idealised fast fabric (for ablations): 1 µs latency, 10 GB/s.
    pub fn fast_fabric() -> NetworkParams {
        NetworkParams { latency: 1e-6, tau_tr: 8.0 / 10e9 }
    }

    /// Cost of one point-to-point message of `words` f64 payload.
    pub fn p2p(&self, words: usize) -> f64 {
        self.latency + words as f64 * self.tau_tr
    }

    /// The BSF cost parameter `t_c` for a payload of `words` f64 each way:
    /// master sends the approximation **to** and receives a folding **from**
    /// one worker (eq. 20 generalised): `t_c = words·τ_tr·2 + 2L` when both
    /// directions carry `words` words.
    pub fn t_c(&self, words_down: usize, words_up: usize) -> f64 {
        self.p2p(words_down) + self.p2p(words_up)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_postal_model() {
        let p = NetworkParams { latency: 1e-5, tau_tr: 1e-8 };
        assert!((p.p2p(0) - 1e-5).abs() < 1e-18);
        assert!((p.p2p(1000) - (1e-5 + 1e-5)).abs() < 1e-12);
    }

    #[test]
    fn t_c_matches_eq20_shape() {
        // eq. (20): t_c = 2(n tau_tr + L) when both directions carry n words
        let p = NetworkParams { latency: 1.5e-5, tau_tr: 9.13e-8 };
        let n = 16000;
        let tc = p.t_c(n, n);
        let eq20 = 2.0 * (n as f64 * p.tau_tr + p.latency);
        assert!((tc - eq20).abs() < 1e-15);
        // and lands near the paper's measured 2.95e-3 s
        assert!((tc - 2.95e-3).abs() / 2.95e-3 < 0.02, "tc={tc}");
    }

    #[test]
    fn tornado_susu_matches_table2_at_other_sizes() {
        // Check the recovered tau_tr against Table 2's t_c at n = 10000
        // (2.17e-3): postal model predicts 2(1e4*9.13e-8 + 1.5e-5) = 1.86e-3,
        // within ~15% — the paper itself notes latency effects at small n.
        let p = NetworkParams::tornado_susu();
        let tc = p.t_c(10_000, 10_000);
        assert!((tc - 2.17e-3).abs() / 2.17e-3 < 0.2, "tc={tc}");
    }
}
