//! Message-passing substrate.
//!
//! Two halves, sharing one cost vocabulary:
//!
//! * **Cost model** ([`NetworkParams`], [`collectives`]) — how long a
//!   point-to-point message or an MPI-style collective takes on the modelled
//!   interconnect. This is what the discrete-event simulator charges and
//!   what the BSF cost metric's `t_c` and `L` parameters come from.
//! * **Live transport** ([`transport`]) — an in-process channel fabric
//!   (master ↔ K worker threads) used by the live runner for real parallel
//!   execution on this machine.
//!
//! The default parameters are calibrated to the paper's testbed (Table 2:
//! `L = 1.5e-5 s`, and `t_c = 2(n·τ_tr + L)` giving `τ_tr ≈ 6.6e-9 s/f64
//! ≈ 1.2 GB/s effective — InfiniBand QDR with MPI overheads).

pub mod collectives;
pub mod transport;

use std::sync::OnceLock;

pub use collectives::{CollectiveAlgo, CollectiveSchedule};

/// How concurrent transfers share the interconnect.
///
/// [`LinkMode::PerEdge`] is the classical postal model every existing
/// configuration uses: each edge has its own full-bandwidth pipe, so a
/// transfer's cost never depends on what else is in flight.
/// [`LinkMode::Shared`] models a contended fabric: the `contenders`
/// transfers of one collective round split the bandwidth term (`τ_tr`
/// scales by the contender count; latency is per-message and unaffected).
/// Zero-contention shared pricing (`contenders <= 1`) is **bitwise equal**
/// to per-edge pricing — the contract the simulator's `comm_base`
/// re-pricing relies on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LinkMode {
    /// Independent full-bandwidth edges (the default; today's constants).
    #[default]
    PerEdge,
    /// One shared link: concurrent transfers split bandwidth.
    Shared,
}

/// Interconnect cost parameters.
///
/// A point-to-point message of `w` f64 words costs `latency + w * tau_tr`
/// seconds — the standard postal/Hockney model, which is exactly the shape
/// the BSF metric assumes in eq. (20): `t_c = c_c·τ_tr + 2L`. The
/// [`LinkMode`] field selects how *concurrent* transfers are priced; it
/// defaults to [`LinkMode::PerEdge`], which reproduces today's per-edge
/// constants bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkParams {
    /// One-byte message latency `L` (seconds). Paper §6: `1.5e-5`.
    pub latency: f64,
    /// Per-f64-word transfer time `τ_tr` (seconds/word).
    pub tau_tr: f64,
    /// Bandwidth sharing discipline for concurrent transfers.
    pub link: LinkMode,
}

impl NetworkParams {
    /// The paper's calibrated testbed ("Tornado SUSU", Table 2).
    ///
    /// `τ_tr` is recovered from Table 2's `t_c` at n = 16000:
    /// `t_c = 2(n·τ_tr + L)` ⇒ `τ_tr = (2.95e-3/2 − 1.5e-5)/16000 ≈ 9.13e-8`.
    pub fn tornado_susu() -> NetworkParams {
        NetworkParams { latency: 1.5e-5, tau_tr: 9.13e-8, link: LinkMode::PerEdge }
    }

    /// An idealised fast fabric (for ablations): 1 µs latency, 10 GB/s.
    pub fn fast_fabric() -> NetworkParams {
        NetworkParams { latency: 1e-6, tau_tr: 8.0 / 10e9, link: LinkMode::PerEdge }
    }

    /// The same parameters under a different [`LinkMode`] (builder form).
    pub fn with_link(mut self, link: LinkMode) -> NetworkParams {
        self.link = link;
        self
    }

    /// Cost of one point-to-point message of `words` f64 payload.
    pub fn p2p(&self, words: usize) -> f64 {
        self.latency + words as f64 * self.tau_tr
    }

    /// Cost of one point-to-point message when `contenders` transfers are
    /// concurrently in flight on the same fabric.
    ///
    /// Per-edge mode ignores `contenders` and runs the *identical*
    /// arithmetic as [`NetworkParams::p2p`] — bitwise equal, so existing
    /// configurations cannot drift. Shared mode splits the bandwidth term
    /// across the contenders (latency is per-message, not shared); a
    /// single transfer (`contenders <= 1`) also routes through the
    /// untouched [`NetworkParams::p2p`] arithmetic.
    pub fn p2p_contended(&self, words: usize, contenders: u32) -> f64 {
        match self.link {
            LinkMode::PerEdge => self.p2p(words),
            LinkMode::Shared => {
                if contenders <= 1 {
                    self.p2p(words)
                } else {
                    self.latency + words as f64 * self.tau_tr * contenders as f64
                }
            }
        }
    }

    /// The BSF cost parameter `t_c` for a payload of `words` f64 each way:
    /// master sends the approximation **to** and receives a folding **from**
    /// one worker (eq. 20 generalised): `t_c = words·τ_tr·2 + 2L` when both
    /// directions carry `words` words.
    pub fn t_c(&self, words_down: usize, words_up: usize) -> f64 {
        self.p2p(words_down) + self.p2p(words_up)
    }
}

/// Parse a `BSF_NET` value into the default [`LinkMode`].
///
/// `None` (unset) and `per-edge` select [`LinkMode::PerEdge`]; `shared`
/// selects [`LinkMode::Shared`]. Anything else panics listing the valid
/// set — the same contract as `BSF_KERNEL`/`BSF_SCHED`/`BSF_FAULTS`, so
/// typos fail loudly instead of silently running the wrong model.
pub fn select_net(val: Option<&str>) -> LinkMode {
    match val {
        None | Some("per-edge") => LinkMode::PerEdge,
        Some("shared") => LinkMode::Shared,
        Some(other) => {
            panic!("BSF_NET must be `shared` or `per-edge` (or unset), got `{other}`")
        }
    }
}

/// The process-wide default link mode, from the `BSF_NET` env switch.
///
/// Cached on first use. This is *only* a default for configurations that
/// opt in to ambient selection (the `nonstationary` experiment's ambient
/// row); every explicit `NetworkParams.link` field wins over it, and the
/// struct default stays [`LinkMode::PerEdge`] so existing configurations
/// are untouched even in a `BSF_NET=shared` environment.
pub fn default_link_mode() -> LinkMode {
    static MODE: OnceLock<LinkMode> = OnceLock::new();
    *MODE.get_or_init(|| select_net(std::env::var("BSF_NET").ok().as_deref()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_postal_model() {
        let p = NetworkParams { latency: 1e-5, tau_tr: 1e-8, link: LinkMode::PerEdge };
        assert!((p.p2p(0) - 1e-5).abs() < 1e-18);
        assert!((p.p2p(1000) - (1e-5 + 1e-5)).abs() < 1e-12);
    }

    #[test]
    fn t_c_matches_eq20_shape() {
        // eq. (20): t_c = 2(n tau_tr + L) when both directions carry n words
        let p = NetworkParams { latency: 1.5e-5, tau_tr: 9.13e-8, link: LinkMode::PerEdge };
        let n = 16000;
        let tc = p.t_c(n, n);
        let eq20 = 2.0 * (n as f64 * p.tau_tr + p.latency);
        assert!((tc - eq20).abs() < 1e-15);
        // and lands near the paper's measured 2.95e-3 s
        assert!((tc - 2.95e-3).abs() / 2.95e-3 < 0.02, "tc={tc}");
    }

    #[test]
    fn tornado_susu_matches_table2_at_other_sizes() {
        // Check the recovered tau_tr against Table 2's t_c at n = 10000
        // (2.17e-3): postal model predicts 2(1e4*9.13e-8 + 1.5e-5) = 1.86e-3,
        // within ~15% — the paper itself notes latency effects at small n.
        let p = NetworkParams::tornado_susu();
        let tc = p.t_c(10_000, 10_000);
        assert!((tc - 2.17e-3).abs() / 2.17e-3 < 0.2, "tc={tc}");
    }

    #[test]
    fn per_edge_contention_is_bitwise_p2p() {
        // PerEdge must ignore the contender count entirely: identical bits.
        let p = NetworkParams::tornado_susu();
        for contenders in [0u32, 1, 2, 7, 64] {
            for words in [0usize, 1, 1000, 16_000] {
                assert_eq!(
                    p.p2p_contended(words, contenders).to_bits(),
                    p.p2p(words).to_bits()
                );
            }
        }
    }

    #[test]
    fn shared_single_transfer_is_bitwise_p2p() {
        // A lone transfer on a shared link runs the unscaled arithmetic.
        let p = NetworkParams::tornado_susu().with_link(LinkMode::Shared);
        for words in [0usize, 1, 1000, 16_000] {
            assert_eq!(p.p2p_contended(words, 1).to_bits(), p.p2p(words).to_bits());
            assert_eq!(p.p2p_contended(words, 0).to_bits(), p.p2p(words).to_bits());
        }
    }

    #[test]
    fn shared_contention_scales_bandwidth_term_only() {
        let p = NetworkParams { latency: 1e-5, tau_tr: 1e-8, link: LinkMode::Shared };
        // 4 contenders quadruple the transfer term, leave latency alone.
        let t = p.p2p_contended(1000, 4);
        assert!((t - (1e-5 + 4.0 * 1e-5)).abs() < 1e-15, "t={t}");
        // Zero-payload messages are pure latency at any contention.
        assert_eq!(p.p2p_contended(0, 64).to_bits(), p.latency.to_bits());
    }

    #[test]
    fn select_net_parses_the_valid_set() {
        assert_eq!(select_net(None), LinkMode::PerEdge);
        assert_eq!(select_net(Some("per-edge")), LinkMode::PerEdge);
        assert_eq!(select_net(Some("shared")), LinkMode::Shared);
    }

    #[test]
    #[should_panic(expected = "BSF_NET must be `shared` or `per-edge`")]
    fn select_net_rejects_unknown_values() {
        select_net(Some("fast"));
    }
}
