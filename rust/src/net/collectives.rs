//! MPI-style collective schedules and their costs.
//!
//! Eq. (8) of the paper assumes a good MPI implementation performs a
//! broadcast or reduce over K processes in `O(log K)` point-to-point rounds
//! (Hoefler et al., paper ref [35]). The canonical such schedule is the
//! **binomial tree**: in round r, every process that already holds the
//! message forwards it to a partner, doubling the covered set.
//!
//! We implement both the binomial tree and the naive **linear** (flat)
//! schedule; the `ablation-collectives` experiment contrasts them — the
//! linear schedule turns eq. (8)'s `log2(K)·t_c` term into `K·t_c` and
//! collapses the scalability boundary, which is precisely why the paper's
//! model assumes tree collectives.

use crate::net::NetworkParams;

/// Which collective schedule the cluster uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveAlgo {
    /// Binomial tree: `ceil(log2(K+1))` rounds for K receivers.
    BinomialTree,
    /// Flat: the root contacts each of the K receivers in sequence.
    Linear,
}

/// A concrete send schedule: list of rounds, each a set of `(from, to)`
/// pairs that proceed in parallel. Node 0 is the root (master); nodes
/// `1..=k` are the workers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectiveSchedule {
    /// Rounds of parallel point-to-point transfers.
    pub rounds: Vec<Vec<(usize, usize)>>,
    /// Total participant count (root + k receivers).
    pub size: usize,
}

impl CollectiveSchedule {
    /// Broadcast schedule from the root to `k` receivers.
    pub fn broadcast(algo: CollectiveAlgo, k: usize) -> CollectiveSchedule {
        let size = k + 1;
        let rounds = match algo {
            CollectiveAlgo::Linear => (1..=k).map(|w| vec![(0usize, w)]).collect(),
            CollectiveAlgo::BinomialTree => {
                // Covered set doubles each round: after r rounds, nodes
                // 0..2^r hold the message (capped at size).
                let mut rounds = Vec::new();
                let mut covered = 1usize;
                while covered < size {
                    let mut round = Vec::new();
                    let senders = covered.min(size - covered);
                    for s in 0..senders {
                        round.push((s, covered + s));
                    }
                    covered += senders;
                    rounds.push(round);
                }
                rounds
            }
        };
        CollectiveSchedule { rounds, size }
    }

    /// Reduce schedule (k leaves folding into the root): the broadcast
    /// schedule reversed, with edges flipped.
    pub fn reduce(algo: CollectiveAlgo, k: usize) -> CollectiveSchedule {
        let bcast = CollectiveSchedule::broadcast(algo, k);
        let rounds = bcast
            .rounds
            .into_iter()
            .rev()
            .map(|round| round.into_iter().map(|(a, b)| (b, a)).collect())
            .collect();
        CollectiveSchedule { rounds, size: bcast.size }
    }

    /// Number of rounds (the latency-critical depth).
    pub fn depth(&self) -> usize {
        self.rounds.len()
    }

    /// Completion time of the collective for a payload of `words` f64:
    /// each round costs one point-to-point message; `combine_cost` is added
    /// per round at the receiving side (e.g. `t_a` for a reduce's `⊕`;
    /// 0 for a broadcast).
    pub fn cost(&self, net: &NetworkParams, words: usize, combine_cost: f64) -> f64 {
        self.depth() as f64 * (net.p2p(words) + combine_cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn covered_by(s: &CollectiveSchedule) -> Vec<usize> {
        // Simulate the broadcast: who holds the message at the end?
        let mut has = vec![false; s.size];
        has[0] = true;
        for round in &s.rounds {
            let snapshot = has.clone();
            for &(from, to) in round {
                assert!(snapshot[from], "sender {from} doesn't hold the message");
                has[to] = true;
            }
        }
        (0..s.size).filter(|&i| has[i]).collect()
    }

    #[test]
    fn binomial_broadcast_covers_everyone() {
        for k in [1usize, 2, 3, 4, 7, 8, 100] {
            let s = CollectiveSchedule::broadcast(CollectiveAlgo::BinomialTree, k);
            assert_eq!(covered_by(&s).len(), k + 1, "k={k}");
        }
    }

    #[test]
    fn binomial_depth_is_log() {
        for (k, want) in [(1usize, 1usize), (2, 2), (3, 2), (4, 3), (7, 3), (8, 4), (100, 7)] {
            let s = CollectiveSchedule::broadcast(CollectiveAlgo::BinomialTree, k);
            assert_eq!(s.depth(), want, "k={k}");
        }
    }

    #[test]
    fn linear_broadcast_depth_is_k() {
        let s = CollectiveSchedule::broadcast(CollectiveAlgo::Linear, 9);
        assert_eq!(s.depth(), 9);
        assert_eq!(covered_by(&s).len(), 10);
    }

    #[test]
    fn reduce_mirrors_broadcast() {
        let b = CollectiveSchedule::broadcast(CollectiveAlgo::BinomialTree, 5);
        let r = CollectiveSchedule::reduce(CollectiveAlgo::BinomialTree, 5);
        assert_eq!(b.depth(), r.depth());
        // Every reduce edge is a flipped broadcast edge.
        let b_edges: Vec<(usize, usize)> = b.rounds.iter().flatten().copied().collect();
        let r_edges: Vec<(usize, usize)> = r.rounds.iter().flatten().map(|&(a, b)| (b, a)).collect();
        let mut b_sorted = b_edges.clone();
        let mut r_sorted = r_edges.clone();
        b_sorted.sort_unstable();
        r_sorted.sort_unstable();
        assert_eq!(b_sorted, r_sorted);
    }

    #[test]
    fn reduce_edges_flow_toward_root() {
        let r = CollectiveSchedule::reduce(CollectiveAlgo::BinomialTree, 7);
        // After all rounds, information from every leaf must reach node 0:
        // run the dataflow.
        let mut holds: Vec<std::collections::HashSet<usize>> =
            (0..r.size).map(|i| std::collections::HashSet::from([i])).collect();
        for round in &r.rounds {
            let snapshot = holds.clone();
            for &(from, to) in round {
                let s = snapshot[from].clone();
                holds[to].extend(s);
            }
        }
        assert_eq!(holds[0].len(), r.size, "root must fold all partials");
    }

    #[test]
    fn cost_scales_with_depth_and_payload() {
        let net = NetworkParams { latency: 1e-5, tau_tr: 1e-8, link: crate::net::LinkMode::PerEdge };
        let tree = CollectiveSchedule::broadcast(CollectiveAlgo::BinomialTree, 8);
        let lin = CollectiveSchedule::broadcast(CollectiveAlgo::Linear, 8);
        assert!(tree.cost(&net, 1000, 0.0) < lin.cost(&net, 1000, 0.0));
        let with_combine = tree.cost(&net, 1000, 1e-6);
        assert!((with_combine - tree.cost(&net, 1000, 0.0) - tree.depth() as f64 * 1e-6).abs() < 1e-15);
    }

    #[test]
    fn k1_single_round() {
        let s = CollectiveSchedule::broadcast(CollectiveAlgo::BinomialTree, 1);
        assert_eq!(s.rounds, vec![vec![(0, 1)]]);
    }
}
