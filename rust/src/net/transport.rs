//! Live in-process transport: master ↔ K worker threads.
//!
//! This is the fabric of the **live runner** — real parallel execution on
//! this machine, used for correctness checks and for calibrating the BSF
//! cost parameters exactly the way the paper prescribes (§7, Q6: run on one
//! node, measure, divide).
//!
//! The message vocabulary mirrors Algorithm 2: the master sends each worker
//! the current approximation (Step 2/3), each worker returns its partial
//! folding (Step 5/6), and the master broadcasts the exit flag (Step
//! 10/13). Both phases are *implicit global synchronisations*, exactly as
//! the paper notes.
//!
//! ## Zero-allocation uplink
//!
//! The uplink is an **inbox bus**: one pre-sized slot per worker under a
//! shared mutex + condvar, instead of an `mpsc` channel (whose every send
//! heap-allocates a queue node on the *worker* thread). A worker's send is
//! lock → move the [`Uplink`] into its slot → notify: zero heap
//! allocations. Combined with the downlink's buffer recycling
//! ([`Downlink::Approximation::reuse`] returns each worker's partial
//! buffer on the next iteration — the double-buffer swap protocol), the
//! worker steady state allocates nothing per iteration (asserted by
//! `rust/benches/coordinator_hotpath.rs`).
//!
//! The approximation payload is `Arc`-shared: one allocation per
//! iteration on the master (wrapping `post()`'s output), K pointer clones
//! instead of K payload clones on the downlink.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// One iteration's downlink payload: the current approximation (opaque f64
/// blob; problems define the encoding) or a stop signal.
#[derive(Debug, Clone)]
pub enum Downlink {
    /// Next iteration's approximation, tagged with the iteration number
    /// (the *epoch*) so late uplinks from recovered/hung workers can be
    /// identified and discarded.
    Approximation {
        /// The approximation payload (shared across the K downlinks).
        x: Arc<Vec<f64>>,
        /// Iteration number.
        epoch: u64,
        /// This worker's partial buffer from the previous iteration,
        /// handed back for reuse (the uplink double-buffer swap). `None`
        /// on the first iteration.
        reuse: Option<Vec<f64>>,
        /// Extra list ranges re-dispatched to this worker because their
        /// owner died (`RecoveryPolicy::Redistribute`). Almost always
        /// empty — an empty `Vec` never allocates, so the clean path's
        /// zero-allocation steady state is untouched. The worker folds
        /// these into the same partial it uplinks.
        extra: Vec<std::ops::Range<usize>>,
    },
    /// Terminate: the StopCond fired (carries the final iteration count).
    Stop {
        /// Iterations executed.
        iterations: usize,
    },
}

/// One worker's uplink payload: its partial folding.
#[derive(Debug, Clone)]
pub struct Uplink {
    /// Worker id `1..=K`.
    pub worker: usize,
    /// Epoch echoed from the downlink (stale-partial detection).
    pub epoch: u64,
    /// Partial folding `s_j` (encoding defined by the problem). Owned, so
    /// the master can fold it and recycle the buffer downlink.
    pub partial: Vec<f64>,
    /// Seconds the worker spent in Map + local fold this iteration
    /// (calibration metadata; a real MPI skeleton would piggyback this the
    /// same way).
    pub map_seconds: f64,
}

/// The shared uplink inbox state: one slot per worker, plus liveness.
#[derive(Debug)]
struct Inbox {
    /// Slot per worker (index = id − 1); `Some` = undelivered partial.
    slots: Vec<Option<Uplink>>,
    /// Set when a worker endpoint drops (normal exit *or* panic unwind),
    /// so a gather stops waiting for a peer that can never answer —
    /// the fail-fast disconnect detection the old mpsc uplink had.
    gone: Vec<bool>,
    /// Incarnation counter per worker, bumped by [`MasterEndpoint::respawn`].
    /// A superseded endpoint (an old incarnation that was replaced while
    /// hung) must neither re-flag `gone` on its delayed drop nor clobber
    /// the new incarnation's slot with a late partial.
    generation: Vec<u32>,
}

/// The shared uplink bus.
#[derive(Debug)]
struct UplinkBus {
    inbox: Mutex<Inbox>,
    /// Signals the master after a slot fill or a worker departure.
    ready: Condvar,
    /// Set when the master endpoint drops (workers detect a dead master).
    closed: std::sync::atomic::AtomicBool,
}

impl UplinkBus {
    fn lock(&self) -> std::sync::MutexGuard<'_, Inbox> {
        // A worker panicking inside `send` cannot leave the inbox in a
        // broken state (it only moves an Option / flips a bool), so
        // poisoning is safe to clear — required for fault-tolerant runs
        // to survive panics.
        self.inbox.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Master-side endpoint: one sender per worker, one shared uplink inbox.
#[derive(Debug)]
pub struct MasterEndpoint {
    downlinks: Vec<Sender<Downlink>>,
    bus: Arc<UplinkBus>,
}

impl Drop for MasterEndpoint {
    fn drop(&mut self) {
        self.bus.closed.store(true, std::sync::atomic::Ordering::Release);
    }
}

/// Worker-side endpoint.
#[derive(Debug)]
pub struct WorkerEndpoint {
    /// This worker's id (`1..=K`).
    pub id: usize,
    /// Incarnation this endpoint belongs to (see `Inbox::generation`).
    generation: u32,
    downlink: Receiver<Downlink>,
    bus: Arc<UplinkBus>,
}

impl Drop for WorkerEndpoint {
    fn drop(&mut self) {
        // Runs on normal exit *and* on panic unwind: flag this worker
        // gone and wake the master so an in-flight gather fails fast
        // instead of sleeping out its deadline. A superseded incarnation
        // (replaced by `respawn` while it was hung) must not re-flag the
        // live one.
        {
            let mut inbox = self.bus.lock();
            if inbox.generation[self.id - 1] == self.generation {
                inbox.gone[self.id - 1] = true;
            }
        }
        self.bus.ready.notify_one();
    }
}

/// Create a master endpoint and `k` worker endpoints.
///
/// # Panics
/// On `k == 0` — a fabric with no workers can never complete a gather,
/// and failing here names the mistake instead of surfacing it as an
/// index error deep in the runner.
pub fn fabric(k: usize) -> (MasterEndpoint, Vec<WorkerEndpoint>) {
    assert!(k > 0, "fabric requires at least one worker (k = 0)");
    let bus = Arc::new(UplinkBus {
        inbox: Mutex::new(Inbox {
            slots: (0..k).map(|_| None).collect(),
            gone: vec![false; k],
            generation: vec![0; k],
        }),
        ready: Condvar::new(),
        closed: std::sync::atomic::AtomicBool::new(false),
    });
    let mut downlinks = Vec::with_capacity(k);
    let mut workers = Vec::with_capacity(k);
    for id in 1..=k {
        let (d_tx, d_rx) = channel::<Downlink>();
        downlinks.push(d_tx);
        workers.push(WorkerEndpoint { id, generation: 0, downlink: d_rx, bus: bus.clone() });
    }
    (MasterEndpoint { downlinks, bus }, workers)
}

/// Error surfaced when a peer disappears (worker panic / master drop) or a
/// gather deadline expires.
#[derive(Debug)]
pub enum TransportError {
    /// A worker's channel closed before the protocol finished.
    WorkerGone(usize),
    /// The master's endpoint dropped.
    MasterGone,
    /// A superseded incarnation tried to send: the master respawned this
    /// worker id while the old endpoint was hung, so its delayed uplink
    /// was refused rather than clobbering the live incarnation's slot.
    /// Distinct from [`TransportError::WorkerGone`] so fleet/runner logs
    /// can tell a dead peer from a zombie one.
    StaleGeneration {
        /// Worker id whose send was refused.
        worker: usize,
        /// Generation the stale endpoint belonged to.
        generation: u32,
    },
    /// Timed out waiting for worker partials.
    Timeout {
        /// How many partials never arrived.
        missing: usize,
        /// How many were expected.
        expected: usize,
    },
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::WorkerGone(id) => write!(f, "worker {id} disconnected"),
            TransportError::MasterGone => write!(f, "master disconnected"),
            TransportError::StaleGeneration { worker, generation } => write!(
                f,
                "worker {worker} send refused: stale incarnation (generation {generation} superseded by respawn)"
            ),
            TransportError::Timeout { missing, expected } => {
                write!(
                    f,
                    "gather timed out waiting for {missing} of {expected} partials (deadline expired; peers still registered)"
                )
            }
        }
    }
}

impl std::error::Error for TransportError {}

impl MasterEndpoint {
    /// Number of attached workers.
    pub fn k(&self) -> usize {
        self.downlinks.len()
    }

    /// Replace worker `id`'s channel with a fresh incarnation and return
    /// its endpoint (for the caller to hand to a new thread). The old
    /// downlink sender is dropped — a hung old incarnation blocked on
    /// `recv` wakes with `MasterGone` and exits — and the inbox bumps the
    /// worker's generation, so the old endpoint's delayed drop or late
    /// `send` can no longer disturb the new one. Any undelivered partial
    /// from the old incarnation is discarded.
    pub fn respawn(&mut self, id: usize) -> WorkerEndpoint {
        let (d_tx, d_rx) = channel::<Downlink>();
        self.downlinks[id - 1] = d_tx;
        let generation = {
            let mut inbox = self.bus.lock();
            inbox.generation[id - 1] += 1;
            inbox.gone[id - 1] = false;
            inbox.slots[id - 1] = None;
            inbox.generation[id - 1]
        };
        WorkerEndpoint { id, generation, downlink: d_rx, bus: self.bus.clone() }
    }

    /// Send one downlink to worker `id` (1-based) — the per-worker form of
    /// Algorithm 2 Step 2, which the approximation path must use so each
    /// worker receives its own recycled buffer.
    pub fn send_to(&self, id: usize, msg: Downlink) -> Result<(), TransportError> {
        self.downlinks[id - 1].send(msg).map_err(|_| TransportError::WorkerGone(id))
    }

    /// `SendToAllWorkers(x)` — clone-broadcast (Stop, tests). The
    /// approximation hot path sends per worker via
    /// [`MasterEndpoint::send_to`] instead, threading each worker's
    /// recycled buffer.
    pub fn broadcast(&self, msg: &Downlink) -> Result<(), TransportError> {
        for (i, tx) in self.downlinks.iter().enumerate() {
            tx.send(msg.clone()).map_err(|_| TransportError::WorkerGone(i + 1))?;
        }
        Ok(())
    }

    /// `RecvFromWorkers(s_1..s_K)` — Algorithm 2 Step 5. Returns partials
    /// ordered by worker id. `timeout` bounds the whole gather.
    pub fn gather(&self, epoch: u64, timeout: Duration) -> Result<Vec<Uplink>, TransportError> {
        let mut got = Vec::new();
        let received = self.gather_into(&vec![true; self.k()], epoch, timeout, &mut got);
        if received == self.k() {
            Ok(got.into_iter().map(|o| o.expect("no missing")).collect())
        } else {
            Err(TransportError::Timeout { missing: self.k() - received, expected: self.k() })
        }
    }

    /// Gather partials from the workers marked in `expect` into `got`
    /// (resized to K; index = worker id − 1), waiting up to `timeout` for
    /// the whole gather. Stale-epoch slots are discarded, and a worker
    /// whose endpoint dropped (panic or exit) with its slot empty stops
    /// being waited for — the gather returns as soon as every still-
    /// reachable expected partial is in, rather than sleeping out the
    /// deadline on a dead peer. Returns how many expected partials
    /// arrived; the caller decides how to treat the rest (see
    /// `LiveRunner::fault_tolerant`). Never errors, never allocates
    /// beyond growing `got` to K once.
    pub fn gather_into(
        &self,
        expect: &[bool],
        epoch: u64,
        timeout: Duration,
        got: &mut Vec<Option<Uplink>>,
    ) -> usize {
        self.gather_with_stats(expect, epoch, timeout, got).0
    }

    /// [`MasterEndpoint::gather_into`] that also reports how many **late
    /// uplinks** were dropped during the gather: stale-epoch partials from
    /// expected workers, and anything a no-longer-expected worker (marked
    /// dead in an earlier iteration, woken from a hang since) parked in
    /// its slot. Dropping the latter also frees its buffer instead of
    /// letting it sit in the inbox for the rest of the run. Returns
    /// `(received, late_dropped)`.
    pub fn gather_with_stats(
        &self,
        expect: &[bool],
        epoch: u64,
        timeout: Duration,
        got: &mut Vec<Option<Uplink>>,
    ) -> (usize, usize) {
        let k = self.k();
        debug_assert_eq!(expect.len(), k);
        got.clear();
        got.resize_with(k, || None);
        let want = expect.iter().filter(|&&e| e).count();
        let mut received = 0usize;
        let mut late_dropped = 0usize;
        let deadline = std::time::Instant::now() + timeout;
        let mut inbox = self.bus.lock();
        loop {
            let mut unreachable = 0usize;
            for i in 0..k {
                if !expect[i] {
                    // Not waited for this epoch (marked dead): a parked
                    // partial here can only be late — drop and count it.
                    if inbox.slots[i].is_some() {
                        inbox.slots[i] = None;
                        late_dropped += 1;
                    }
                    continue;
                }
                if got[i].is_some() {
                    continue;
                }
                if let Some(u) = inbox.slots[i].take() {
                    if u.epoch == epoch {
                        got[i] = Some(u);
                        received += 1;
                        continue;
                    }
                    // Stale partial from a worker that missed an earlier
                    // deadline: dropped (its range was already recovered
                    // by the master that iteration).
                    late_dropped += 1;
                }
                if inbox.gone[i] {
                    unreachable += 1;
                }
            }
            if received + unreachable >= want {
                break;
            }
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                break;
            }
            let (guard, _timed_out) = self
                .bus
                .ready
                .wait_timeout(inbox, remaining)
                .unwrap_or_else(|e| e.into_inner());
            inbox = guard;
        }
        (received, late_dropped)
    }

    /// Best-effort broadcast: deliver to every worker whose channel is
    /// still open, ignoring dead peers (used for the final Stop — a plain
    /// `broadcast` would abort at the first closed channel and leave the
    /// remaining workers blocked on `recv` forever).
    pub fn broadcast_best_effort(&self, msg: &Downlink) {
        for tx in &self.downlinks {
            let _ = tx.send(msg.clone());
        }
    }
}

impl WorkerEndpoint {
    /// `RecvFromMaster(x)` — blocks until the next downlink.
    pub fn recv(&self) -> Result<Downlink, TransportError> {
        self.downlink.recv().map_err(|_| TransportError::MasterGone)
    }

    /// `SendToMaster(s_j)` — moves the partial into this worker's inbox
    /// slot. Zero heap allocations: the buffer travels by move and comes
    /// back through the next downlink's `reuse`. A superseded incarnation
    /// (the master respawned this worker id while this endpoint was hung)
    /// gets [`TransportError::StaleGeneration`] instead of clobbering the
    /// new incarnation's slot.
    pub fn send(
        &self,
        epoch: u64,
        partial: Vec<f64>,
        map_seconds: f64,
    ) -> Result<(), TransportError> {
        if self.bus.closed.load(std::sync::atomic::Ordering::Acquire) {
            return Err(TransportError::MasterGone);
        }
        {
            let mut inbox = self.bus.lock();
            if inbox.generation[self.id - 1] != self.generation {
                return Err(TransportError::StaleGeneration {
                    worker: self.id,
                    generation: self.generation,
                });
            }
            inbox.slots[self.id - 1] =
                Some(Uplink { worker: self.id, epoch, partial, map_seconds });
        }
        self.bus.ready.notify_one();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn approx(x: Vec<f64>, epoch: u64) -> Downlink {
        Downlink::Approximation { x: Arc::new(x), epoch, reuse: None, extra: Vec::new() }
    }

    #[test]
    fn roundtrip_one_iteration() {
        let (master, workers) = fabric(4);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || loop {
                    match w.recv().unwrap() {
                        Downlink::Approximation { x, epoch, .. } => {
                            let s: f64 = x.iter().sum::<f64>() * w.id as f64;
                            w.send(epoch, vec![s], 0.0).unwrap();
                        }
                        Downlink::Stop { .. } => break,
                    }
                })
            })
            .collect();

        master.broadcast(&approx(vec![1.0, 2.0], 0)).unwrap();
        let partials = master.gather(0, Duration::from_secs(5)).unwrap();
        assert_eq!(partials.len(), 4);
        // ordered by worker id; worker j returns 3*j
        for (i, p) in partials.iter().enumerate() {
            assert_eq!(p.worker, i + 1);
            assert_eq!(p.partial, vec![3.0 * (i + 1) as f64]);
        }
        master.broadcast(&Downlink::Stop { iterations: 1 }).unwrap();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn gather_times_out_on_silent_worker() {
        let (master, workers) = fabric(2);
        // Only worker 1 answers.
        let w1 = &workers[0];
        w1.send(0, vec![1.0], 0.0).unwrap();
        let err = master.gather(0, Duration::from_millis(50)).unwrap_err();
        match err {
            TransportError::Timeout { missing, expected } => {
                assert_eq!((missing, expected), (1, 2));
            }
            other => panic!("unexpected: {other}"),
        }
        drop(workers);
    }

    #[test]
    fn broadcast_detects_dead_worker() {
        let (master, workers) = fabric(2);
        drop(workers); // both endpoints gone
        let err = master.broadcast(&Downlink::Stop { iterations: 0 }).unwrap_err();
        assert!(matches!(err, TransportError::WorkerGone(1)));
    }

    #[test]
    fn worker_detects_dead_master() {
        let (master, workers) = fabric(1);
        drop(master);
        let w = &workers[0];
        assert!(matches!(w.recv().unwrap_err(), TransportError::MasterGone));
        // The uplink side notices too (the bus is flagged closed).
        assert!(matches!(
            w.send(0, vec![1.0], 0.0).unwrap_err(),
            TransportError::MasterGone
        ));
    }

    #[test]
    fn gather_completes_on_first_partial_per_worker() {
        let (master, workers) = fabric(1);
        workers[0].send(0, vec![1.0], 0.0).unwrap();
        let got = master.gather(0, Duration::from_millis(50)).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].partial, vec![1.0]);
        // The lock-step protocol sends exactly one partial per iteration;
        // a second send is consumed by the *next* gather.
        workers[0].send(1, vec![2.0], 0.0).unwrap();
        let got2 = master.gather(1, Duration::from_millis(50)).unwrap();
        assert_eq!(got2[0].partial, vec![2.0]);
    }

    #[test]
    fn gather_fails_fast_when_worker_drops() {
        // A dead worker (endpoint dropped — what a panic unwind does)
        // must not make the gather sleep out its deadline: worker 1's
        // partial arrives, worker 2 is gone, and the gather returns
        // immediately despite the long timeout.
        let (master, mut workers) = fabric(2);
        let w2 = workers.pop().unwrap();
        workers[0].send(0, vec![1.0], 0.0).unwrap();
        drop(w2);
        let start = std::time::Instant::now();
        let mut got = Vec::new();
        let received =
            master.gather_into(&[true, true], 0, Duration::from_secs(30), &mut got);
        assert_eq!(received, 1);
        assert!(got[1].is_none());
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "gather slept on a dead worker"
        );
    }

    #[test]
    fn stale_epochs_are_discarded() {
        let (master, workers) = fabric(2);
        workers[0].send(3, vec![9.0], 0.0).unwrap(); // stale (epoch 3 ≠ 4)
        workers[1].send(4, vec![2.0], 0.0).unwrap();
        let mut got = Vec::new();
        let received =
            master.gather_into(&[true, true], 4, Duration::from_millis(40), &mut got);
        assert_eq!(received, 1);
        assert!(got[0].is_none());
        assert_eq!(got[1].as_ref().unwrap().partial, vec![2.0]);
    }

    #[test]
    fn send_to_targets_one_worker() {
        let (master, workers) = fabric(2);
        master
            .send_to(2, Downlink::Approximation {
                x: Arc::new(vec![7.0]),
                epoch: 0,
                reuse: Some(vec![0.0; 3]),
                extra: vec![4..8],
            })
            .unwrap();
        // worker 1 has nothing pending; worker 2 got the message + buffer.
        match workers[1].recv().unwrap() {
            Downlink::Approximation { x, epoch, reuse, extra } => {
                assert_eq!(*x, vec![7.0]);
                assert_eq!(epoch, 0);
                assert_eq!(reuse.unwrap().len(), 3);
                assert_eq!(extra, vec![4..8]);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn gather_counts_late_uplinks() {
        let (master, workers) = fabric(3);
        // worker 1: stale epoch while still expected; worker 3: parked
        // partial while no longer expected (marked dead earlier).
        workers[0].send(3, vec![9.0], 0.0).unwrap();
        workers[1].send(4, vec![2.0], 0.0).unwrap();
        workers[2].send(3, vec![8.0], 0.0).unwrap();
        let mut got = Vec::new();
        let (received, late) = master.gather_with_stats(
            &[true, true, false],
            4,
            Duration::from_millis(40),
            &mut got,
        );
        assert_eq!(received, 1);
        assert_eq!(late, 2);
        assert!(got[0].is_none());
        assert_eq!(got[1].as_ref().unwrap().partial, vec![2.0]);
        assert!(got[2].is_none());
    }

    #[test]
    fn respawn_supersedes_old_incarnation() {
        let (mut master, mut workers) = fabric(2);
        let old = workers.remove(1);
        let new = master.respawn(2);
        // The old incarnation can no longer deliver — and the error names
        // the zombie (stale generation), not a dead peer.
        assert!(matches!(
            old.send(0, vec![1.0], 0.0).unwrap_err(),
            TransportError::StaleGeneration { worker: 2, generation: 0 }
        ));
        // ...its recv fails fast (the old downlink sender was dropped)...
        assert!(matches!(old.recv().unwrap_err(), TransportError::MasterGone));
        // ...and its drop must NOT mark the respawned worker gone.
        drop(old);
        new.send(0, vec![5.0], 0.0).unwrap();
        workers[0].send(0, vec![1.0], 0.0).unwrap();
        let got = master.gather(0, Duration::from_millis(100)).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].partial, vec![5.0]);
        // The fresh downlink reaches the new incarnation.
        master.send_to(2, approx(vec![3.0], 1)).unwrap();
        match new.recv().unwrap() {
            Downlink::Approximation { x, epoch, .. } => {
                assert_eq!(*x, vec![3.0]);
                assert_eq!(epoch, 1);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "at least one worker")]
    fn fabric_rejects_zero_workers() {
        let _ = fabric(0);
    }

    #[test]
    fn error_display_distinguishes_timeout_and_stale_generation() {
        let t = TransportError::Timeout { missing: 2, expected: 4 }.to_string();
        assert!(t.contains("timed out") && t.contains("2 of 4"), "{t}");
        let s = TransportError::StaleGeneration { worker: 3, generation: 1 }.to_string();
        assert!(s.contains("stale incarnation") && s.contains("worker 3"), "{s}");
    }

    #[test]
    fn respawn_discards_parked_partial() {
        let (mut master, mut workers) = fabric(1);
        let old = workers.pop().unwrap();
        old.send(7, vec![1.0], 0.0).unwrap(); // parked late partial
        let new = master.respawn(1);
        new.send(8, vec![2.0], 0.0).unwrap();
        let got = master.gather(8, Duration::from_millis(100)).unwrap();
        assert_eq!(got[0].partial, vec![2.0]);
    }
}
