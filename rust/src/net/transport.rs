//! Live in-process transport: master ↔ K worker threads over std channels.
//!
//! This is the fabric of the **live runner** — real parallel execution on
//! this machine, used for correctness checks and for calibrating the BSF
//! cost parameters exactly the way the paper prescribes (§7, Q6: run on one
//! node, measure, divide).
//!
//! The message vocabulary mirrors Algorithm 2: the master broadcasts the
//! current approximation (Step 2/3), each worker returns its partial folding
//! (Step 5/6), and the master broadcasts the exit flag (Step 10/13). Both
//! broadcast phases are *implicit global synchronisations*, exactly as the
//! paper notes.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// One iteration's downlink payload: the current approximation (opaque f64
/// blob; problems define the encoding) or a stop signal.
#[derive(Debug, Clone)]
pub enum Downlink {
    /// Next iteration's approximation, tagged with the iteration number
    /// (the *epoch*) so late uplinks from recovered/hung workers can be
    /// identified and discarded.
    Approximation {
        /// The approximation payload.
        x: Vec<f64>,
        /// Iteration number.
        epoch: u64,
    },
    /// Terminate: the StopCond fired (carries the final iteration count).
    Stop { iterations: usize },
}

/// One worker's uplink payload: its partial folding.
#[derive(Debug, Clone)]
pub struct Uplink {
    /// Worker id `1..=K`.
    pub worker: usize,
    /// Epoch echoed from the downlink (stale-partial detection).
    pub epoch: u64,
    /// Partial folding `s_j` (encoding defined by the problem).
    pub partial: Vec<f64>,
    /// Seconds the worker spent in Map + local fold this iteration
    /// (calibration metadata; a real MPI skeleton would piggyback this the
    /// same way).
    pub map_seconds: f64,
}

/// Master-side endpoint: one sender per worker, one shared return channel.
#[derive(Debug)]
pub struct MasterEndpoint {
    downlinks: Vec<Sender<Downlink>>,
    uplink: Receiver<Uplink>,
}

/// Worker-side endpoint.
#[derive(Debug)]
pub struct WorkerEndpoint {
    /// This worker's id (`1..=K`).
    pub id: usize,
    downlink: Receiver<Downlink>,
    uplink: Sender<Uplink>,
}

/// Create a master endpoint and `k` worker endpoints.
pub fn fabric(k: usize) -> (MasterEndpoint, Vec<WorkerEndpoint>) {
    let (up_tx, up_rx) = channel::<Uplink>();
    let mut downlinks = Vec::with_capacity(k);
    let mut workers = Vec::with_capacity(k);
    for id in 1..=k {
        let (d_tx, d_rx) = channel::<Downlink>();
        downlinks.push(d_tx);
        workers.push(WorkerEndpoint { id, downlink: d_rx, uplink: up_tx.clone() });
    }
    (MasterEndpoint { downlinks, uplink: up_rx }, workers)
}

/// Error surfaced when a peer disappears (worker panic / master drop).
#[derive(Debug, thiserror::Error)]
pub enum TransportError {
    /// A worker's channel closed before the protocol finished.
    #[error("worker {0} disconnected")]
    WorkerGone(usize),
    /// The master's channel closed.
    #[error("master disconnected")]
    MasterGone,
    /// Timed out waiting for worker partials.
    #[error("timed out waiting for {missing} of {expected} partials")]
    Timeout {
        /// How many partials never arrived.
        missing: usize,
        /// How many were expected.
        expected: usize,
    },
}

impl MasterEndpoint {
    /// Number of attached workers.
    pub fn k(&self) -> usize {
        self.downlinks.len()
    }

    /// `SendToAllWorkers(x)` — Algorithm 2 Step 2.
    pub fn broadcast(&self, msg: &Downlink) -> Result<(), TransportError> {
        for (i, tx) in self.downlinks.iter().enumerate() {
            tx.send(msg.clone()).map_err(|_| TransportError::WorkerGone(i + 1))?;
        }
        Ok(())
    }

    /// `RecvFromWorkers(s_1..s_K)` — Algorithm 2 Step 5. Returns partials
    /// ordered by worker id. `timeout` bounds the whole gather.
    pub fn gather(&self, epoch: u64, timeout: Duration) -> Result<Vec<Uplink>, TransportError> {
        let (got, missing) = self.gather_partial(&vec![true; self.k()], epoch, timeout);
        if missing.is_empty() {
            Ok(got.into_iter().map(|o| o.expect("no missing")).collect())
        } else {
            Err(TransportError::Timeout { missing: missing.len(), expected: self.k() })
        }
    }

    /// Fault-tolerant gather: wait (up to `timeout`) for partials from the
    /// workers marked alive in `expect`; returns whatever arrived plus the
    /// ids (1-based) that never answered. Never errors — the caller decides
    /// how to recover (see `LiveRunner::fault_tolerant`).
    pub fn gather_partial(
        &self,
        expect: &[bool],
        epoch: u64,
        timeout: Duration,
    ) -> (Vec<Option<Uplink>>, Vec<usize>) {
        let k = self.k();
        debug_assert_eq!(expect.len(), k);
        let want = expect.iter().filter(|&&e| e).count();
        let mut got: Vec<Option<Uplink>> = (0..k).map(|_| None).collect();
        let mut received = 0usize;
        let deadline = std::time::Instant::now() + timeout;
        while received < want {
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            match self.uplink.recv_timeout(remaining) {
                Ok(up) => {
                    if up.epoch != epoch {
                        // Stale partial from a worker that missed an
                        // earlier deadline: discard (its range was already
                        // recovered by the master that iteration).
                        continue;
                    }
                    let idx = up.worker - 1;
                    if got[idx].is_none() && expect[idx] {
                        received += 1;
                    }
                    got[idx] = Some(up);
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let missing = (0..k)
            .filter(|&i| expect[i] && got[i].is_none())
            .map(|i| i + 1)
            .collect();
        (got, missing)
    }

    /// Best-effort broadcast: deliver to every worker whose channel is
    /// still open, ignoring dead peers (used for the final Stop — a plain
    /// `broadcast` would abort at the first closed channel and leave the
    /// remaining workers blocked on `recv` forever).
    pub fn broadcast_best_effort(&self, msg: &Downlink) {
        for tx in &self.downlinks {
            let _ = tx.send(msg.clone());
        }
    }

    /// Broadcast to the workers marked alive only (dead peers are skipped
    /// instead of erroring). Returns ids (1-based) newly found dead.
    pub fn broadcast_alive(&self, msg: &Downlink, alive: &mut [bool]) -> Vec<usize> {
        let mut newly_dead = Vec::new();
        for (i, tx) in self.downlinks.iter().enumerate() {
            if alive[i] && tx.send(msg.clone()).is_err() {
                alive[i] = false;
                newly_dead.push(i + 1);
            }
        }
        newly_dead
    }
}

impl WorkerEndpoint {
    /// `RecvFromMaster(x)` — blocks until the next downlink.
    pub fn recv(&self) -> Result<Downlink, TransportError> {
        self.downlink.recv().map_err(|_| TransportError::MasterGone)
    }

    /// `SendToMaster(s_j)`.
    pub fn send(&self, epoch: u64, partial: Vec<f64>, map_seconds: f64) -> Result<(), TransportError> {
        self.uplink
            .send(Uplink { worker: self.id, epoch, partial, map_seconds })
            .map_err(|_| TransportError::MasterGone)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn roundtrip_one_iteration() {
        let (master, workers) = fabric(4);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || loop {
                    match w.recv().unwrap() {
                        Downlink::Approximation { x, epoch } => {
                            let s: f64 = x.iter().sum::<f64>() * w.id as f64;
                            w.send(epoch, vec![s], 0.0).unwrap();
                        }
                        Downlink::Stop { .. } => break,
                    }
                })
            })
            .collect();

        master.broadcast(&Downlink::Approximation { x: vec![1.0, 2.0], epoch: 0 }).unwrap();
        let partials = master.gather(0, Duration::from_secs(5)).unwrap();
        assert_eq!(partials.len(), 4);
        // ordered by worker id; worker j returns 3*j
        for (i, p) in partials.iter().enumerate() {
            assert_eq!(p.worker, i + 1);
            assert_eq!(p.partial, vec![3.0 * (i + 1) as f64]);
        }
        master.broadcast(&Downlink::Stop { iterations: 1 }).unwrap();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn gather_times_out_on_silent_worker() {
        let (master, workers) = fabric(2);
        // Only worker 1 answers.
        let w1 = &workers[0];
        w1.send(0, vec![1.0], 0.0).unwrap();
        let err = master.gather(0, Duration::from_millis(50)).unwrap_err();
        match err {
            TransportError::Timeout { missing, expected } => {
                assert_eq!((missing, expected), (1, 2));
            }
            other => panic!("unexpected: {other}"),
        }
        drop(workers);
    }

    #[test]
    fn broadcast_detects_dead_worker() {
        let (master, workers) = fabric(2);
        drop(workers); // both endpoints gone
        let err = master.broadcast(&Downlink::Stop { iterations: 0 }).unwrap_err();
        assert!(matches!(err, TransportError::WorkerGone(1)));
    }

    #[test]
    fn worker_detects_dead_master() {
        let (master, workers) = fabric(1);
        drop(master);
        let w = &workers[0];
        assert!(matches!(w.recv().unwrap_err(), TransportError::MasterGone));
    }

    #[test]
    fn gather_completes_on_first_partial_per_worker() {
        let (master, workers) = fabric(1);
        workers[0].send(0, vec![1.0], 0.0).unwrap();
        let got = master.gather(0, Duration::from_millis(50)).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].partial, vec![1.0]);
        // The lock-step protocol sends exactly one partial per iteration;
        // a second send is consumed by the *next* gather.
        workers[0].send(1, vec![2.0], 0.0).unwrap();
        let got2 = master.gather(1, Duration::from_millis(50)).unwrap();
        assert_eq!(got2[0].partial, vec![2.0]);
    }
}
