//! Small self-contained utilities: deterministic PRNG, descriptive
//! statistics, wall-clock timing and table/CSV rendering.
//!
//! These are hand-rolled substrates (the build is fully offline; no external
//! crates beyond `xla`/`anyhow`), each with its own unit tests.

pub mod backoff;
pub mod bench;
pub mod svg;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use backoff::Backoff;
pub use json::Json;

pub use rng::Rng;
pub use stats::Summary;
pub use table::Table;
pub use timer::Timer;
