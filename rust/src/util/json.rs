//! Minimal JSON parser (offline build — no serde), sufficient for the AOT
//! `manifest.json` and experiment config files: objects, arrays, strings
//! (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (f64 precision).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys — deterministic iteration).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), at: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.at != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field accessor.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer value (exact f64), if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    /// Array contents, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object contents, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Compact serializer — the write half of the fleet wire protocol
/// (`fleet::proto`). Deterministic output: object keys iterate in
/// `BTreeMap` order, numbers print via Rust's shortest-round-trip f64
/// `Display` (so `Json::parse(v.to_string()) == v` for every value this
/// crate produces). Wire-critical floats should still travel as
/// `f64::to_bits` hex strings — JSON numbers only guarantee exactness up
/// to 2^53 for integers, and text round-trips of exotic values (NaN,
/// infinities) are not representable at all.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => f.write_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    write!(f, "{x}")
                } else {
                    // JSON has no NaN/inf literal; null is the least-bad text.
                    f.write_str("null")
                }
            }
            Json::Str(s) => write_json_str(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{e}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_json_str(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_json_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the failure.
    pub at: usize,
    /// Description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl fmt::Display) -> JsonError {
        JsonError { at: self.at, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.at).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.at += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.at += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.at += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.at..].starts_with(word.as_bytes()) {
            self.at += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.at += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.at += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.at - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.at = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.at;
        if self.peek() == Some(b'-') {
            self.at += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.at += 1;
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.at]).expect("ascii");
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{txt}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{
          "block": 256,
          "sizes": [256, 512],
          "artifacts": {
            "jacobi_map_n256": {
              "inputs": [{"shape": [256, 256], "dtype": "float64"}],
              "outputs": [{"shape": [256], "dtype": "float64"}],
              "file": "jacobi_map_n256.hlo.txt",
              "sha256": "abé"
            }
          }
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("block").unwrap().as_usize(), Some(256));
        let art = v.get("artifacts").unwrap().get("jacobi_map_n256").unwrap();
        let inp = &art.get("inputs").unwrap().as_arr().unwrap()[0];
        let dims: Vec<usize> =
            inp.get("shape").unwrap().as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![256, 256]);
        assert_eq!(art.get("sha256").unwrap().as_str(), Some("abé"));
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\"b\"A"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#""héllo — ok""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    /// Serializer round-trip: parse(to_string(v)) == v for every value
    /// shape the fleet protocol emits, including escapes and multibyte
    /// UTF-8.
    #[test]
    fn display_round_trips() {
        let cases = [
            "null",
            "true",
            "[1,2.5,-3]",
            r#"{"a":[1,{"b":"c"}],"d":{},"e":"q\"w\\x\ny"}"#,
            r#""héllo — ok""#,
            "[]",
            "{}",
        ];
        for src in cases {
            let v = Json::parse(src).unwrap();
            let text = v.to_string();
            assert_eq!(Json::parse(&text).unwrap(), v, "round-trip of {src}: {text}");
        }
    }

    /// Object keys serialize in sorted (BTreeMap) order — the wire format
    /// is deterministic regardless of insertion order.
    #[test]
    fn display_is_deterministic() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"m":3,"z":1}"#);
    }

    /// Control characters escape as \u00XX and survive the round trip.
    #[test]
    fn display_escapes_control_chars() {
        let v = Json::Str("a\u{1}b".into());
        assert_eq!(v.to_string(), "\"a\\u0001b\"");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    /// Non-finite numbers have no JSON literal; they serialize as null
    /// (callers moving exact f64s use to_bits hex strings instead).
    #[test]
    fn display_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
