//! ASCII table and CSV rendering for experiment reports.
//!
//! Every experiment harness prints its results through [`Table`] so the
//! regenerated paper tables/figures share one visual format, and can also be
//! dumped as CSV for external plotting.

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with a title and column headers.
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: append a row of displayable items.
    pub fn row_fmt<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:>w$} ", cells.get(i).map(String::as_str).unwrap_or(""), w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    /// Render as CSV (title omitted; header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write the CSV to `path` (creating parent directories).
    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format seconds in engineering notation matching the paper's tables
/// (e.g. `7.20E-5`).
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    format!("{x:.2E}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["n", "value"]);
        t.row(&["5".into(), "1.5".into()]);
        t.row(&["10000".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("10000"));
        let lines: Vec<&str> = r.lines().skip(1).collect();
        // all data lines same width
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(7.2e-5), "7.20E-5");
        assert_eq!(sci(0.0), "0");
    }

    #[test]
    fn row_fmt_display() {
        let mut t = Table::new("", &["k", "v"]);
        t.row_fmt(&[1.5, 2.0]);
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
    }

    #[test]
    fn save_csv_roundtrip() {
        let dir = std::env::temp_dir().join("bsf_table_test");
        let path = dir.join("t.csv");
        let mut t = Table::new("", &["a"]);
        t.row(&["1".into()]);
        t.save_csv(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a\n1\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
