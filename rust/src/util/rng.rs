//! Deterministic pseudo-random number generation.
//!
//! A [SplitMix64](https://prng.di.unimi.it/splitmix64.c) seeder feeding a
//! [xoshiro256++](https://prng.di.unimi.it/xoshiro256plusplus.c) core — the
//! standard, well-tested construction. Every stochastic component of the
//! library (workload generators, simulator jitter, property tests) draws from
//! this generator, so whole experiments replay bit-identically from a seed.

/// xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker / per-experiment rngs).
    /// Advances this generator, so successive forks differ.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Derive an independent child stream *without* advancing this
    /// generator: `split(s)` is a pure function of `(self, s)`, so any
    /// number of callers — in any order, on any thread — obtain the same
    /// child for the same stream id. This is the contract parallel K-sweeps
    /// rely on for bitwise reproducibility: one root rng, one split stream
    /// per K, identical results at any thread count.
    pub fn split(&self, stream: u64) -> Rng {
        let mut sm = self.s[0]
            .wrapping_add(self.s[1].rotate_left(17))
            .wrapping_add(self.s[2].rotate_left(31))
            .wrapping_add(self.s[3].rotate_left(47))
            ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` (53-bit resolution).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire rejection-free for our use).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply method; bias < 2^-64, irrelevant for simulation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Lognormal multiplicative jitter with multiplicative sigma `sigma`
    /// (mean-one: E[jitter] = 1).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        if sigma == 0.0 {
            return 1.0;
        }
        let mu = -0.5 * sigma * sigma; // so that E[e^X] = 1
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn jitter_mean_one() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.jitter(0.2)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
        assert_eq!(r.jitter(0.0), 1.0);
    }

    #[test]
    fn jitter_positive() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.jitter(0.5) > 0.0);
        }
    }

    #[test]
    fn split_is_pure_and_keeps_parent_state() {
        let root = Rng::new(42);
        let mut a = root.split(7);
        let mut b = root.split(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64(), "same (state, stream) must match");
        }
        // parent unchanged: a later split of the same root still agrees
        let mut c = root.split(7);
        let mut d = Rng::new(42).split(7);
        for _ in 0..64 {
            assert_eq!(c.next_u64(), d.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = Rng::new(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
