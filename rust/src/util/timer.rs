//! Wall-clock measurement helpers used by calibration and the bench harness.

use std::time::Instant;

/// A simple monotonic stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let t = self.elapsed();
        self.start = Instant::now();
        t
    }
}

/// Measure `f` repeatedly: `warmup` unrecorded runs, then `reps` timed runs.
/// Returns per-run seconds.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        out.push(t.elapsed());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let lap = t.lap();
        assert!(lap >= 0.002);
        assert!(t.elapsed() < lap + 0.1);
    }

    #[test]
    fn measure_counts() {
        let mut calls = 0usize;
        let samples = measure(2, 5, || calls += 1);
        assert_eq!(samples.len(), 5);
        assert_eq!(calls, 7);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }
}
