//! Descriptive statistics for timing samples (the bench harness's core).

/// Summary statistics over a sample of f64s.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// 5th percentile.
    pub p05: f64,
    /// 95th percentile.
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Summary {
            n,
            mean,
            sd: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.sd / self.mean
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Index of the maximum element (first occurrence). Returns `None` if empty.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        match best {
            Some((_, bx)) if x <= bx => {}
            _ => best = Some((i, x)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.sd - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.sd, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn argmax_finds_peak() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), Some(1));
        assert_eq!(argmax(&[]), None);
        // first occurrence on ties
        assert_eq!(argmax(&[2.0, 2.0]), Some(0));
    }

    #[test]
    fn cv_zero_mean() {
        let s = Summary::of(&[0.0, 0.0]);
        assert_eq!(s.cv(), 0.0);
    }
}
