//! Minimal SVG line-chart renderer (offline build — no plotting crates).
//!
//! Renders the paper's figure style: speedup-vs-K curves with multiple
//! series (empirical solid, analytic dashed), axis ticks, a legend and
//! optional vertical marker lines (the red K_BSF boundary in Fig. 6/7).
//! Output is standalone SVG viewable in any browser.

use std::fmt::Write as _;

/// One plotted series.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label.
    pub label: String,
    /// Data points (x, y).
    pub points: Vec<(f64, f64)>,
    /// Stroke colour (CSS).
    pub color: String,
    /// Dash pattern (`""` = solid, e.g. `"6,4"` = dashed).
    pub dash: String,
    /// Draw point markers.
    pub markers: bool,
}

impl Series {
    /// Solid line with markers.
    pub fn solid(label: impl Into<String>, points: Vec<(f64, f64)>, color: &str) -> Series {
        Series { label: label.into(), points, color: color.into(), dash: String::new(), markers: true }
    }

    /// Dashed line without markers.
    pub fn dashed(label: impl Into<String>, points: Vec<(f64, f64)>, color: &str) -> Series {
        Series { label: label.into(), points, color: color.into(), dash: "6,4".into(), markers: false }
    }
}

/// A line chart.
#[derive(Debug, Clone)]
pub struct Chart {
    /// Title (rendered at the top).
    pub title: String,
    /// X-axis label.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
    /// The series to draw.
    pub series: Vec<Series>,
    /// Vertical marker lines `(x, label)` (e.g. K_BSF).
    pub vlines: Vec<(f64, String)>,
    /// Canvas size in px.
    pub width: u32,
    /// Canvas height in px.
    pub height: u32,
}

impl Chart {
    /// New chart with default size (720×480).
    pub fn new(
        title: impl Into<String>,
        x_label: impl Into<String>,
        y_label: impl Into<String>,
    ) -> Chart {
        Chart {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
            vlines: Vec::new(),
            width: 720,
            height: 480,
        }
    }

    /// Add a series.
    pub fn push(&mut self, s: Series) -> &mut Self {
        self.series.push(s);
        self
    }

    /// Add a vertical marker (e.g. the analytic boundary).
    pub fn vline(&mut self, x: f64, label: impl Into<String>) -> &mut Self {
        self.vlines.push((x, label.into()));
        self
    }

    fn bounds(&self) -> (f64, f64, f64, f64) {
        let mut xs: Vec<f64> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for s in &self.series {
            for &(x, y) in &s.points {
                xs.push(x);
                ys.push(y);
            }
        }
        for &(x, _) in &self.vlines {
            xs.push(x);
        }
        let xmin = xs.iter().copied().fold(f64::INFINITY, f64::min).min(0.0);
        let xmax = xs.iter().copied().fold(0.0, f64::max).max(1.0);
        let ymin = ys.iter().copied().fold(f64::INFINITY, f64::min).min(0.0);
        let ymax = ys.iter().copied().fold(0.0, f64::max).max(1.0);
        (xmin, xmax * 1.04, ymin, ymax * 1.08)
    }

    /// Render to SVG text.
    pub fn render(&self) -> String {
        let (w, h) = (self.width as f64, self.height as f64);
        let (ml, mr, mt, mb) = (64.0, 16.0, 40.0, 52.0); // margins
        let (pw, ph) = (w - ml - mr, h - mt - mb);
        let (xmin, xmax, ymin, ymax) = self.bounds();
        let sx = |x: f64| ml + (x - xmin) / (xmax - xmin) * pw;
        let sy = |y: f64| mt + ph - (y - ymin) / (ymax - ymin) * ph;

        let mut out = String::new();
        let _ = writeln!(
            out,
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}" font-family="sans-serif">"#
        );
        let _ = writeln!(out, r#"<rect width="{w}" height="{h}" fill="white"/>"#);
        let esc = |s: &str| s.replace('&', "&amp;").replace('<', "&lt;");
        let _ = writeln!(
            out,
            r#"<text x="{}" y="22" text-anchor="middle" font-size="15" font-weight="bold">{}</text>"#,
            w / 2.0,
            esc(&self.title)
        );

        // Axes + ticks.
        let _ = writeln!(
            out,
            r#"<line x1="{ml}" y1="{}" x2="{}" y2="{}" stroke="black"/>"#,
            mt + ph,
            ml + pw,
            mt + ph
        );
        let _ = writeln!(out, r#"<line x1="{ml}" y1="{mt}" x2="{ml}" y2="{}" stroke="black"/>"#, mt + ph);
        for i in 0..=6 {
            let fx = xmin + (xmax - xmin) * i as f64 / 6.0;
            let fy = ymin + (ymax - ymin) * i as f64 / 6.0;
            let _ = writeln!(
                out,
                r#"<text x="{:.1}" y="{:.1}" text-anchor="middle" font-size="11">{}</text>"#,
                sx(fx),
                mt + ph + 16.0,
                fmt_tick(fx)
            );
            let _ = writeln!(
                out,
                r#"<text x="{:.1}" y="{:.1}" text-anchor="end" font-size="11">{}</text>"#,
                ml - 6.0,
                sy(fy) + 4.0,
                fmt_tick(fy)
            );
            let _ = writeln!(
                out,
                r##"<line x1="{ml}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="#e0e0e0"/>"##,
                sy(fy),
                ml + pw,
                sy(fy)
            );
        }
        let _ = writeln!(
            out,
            r#"<text x="{}" y="{}" text-anchor="middle" font-size="12">{}</text>"#,
            ml + pw / 2.0,
            h - 12.0,
            esc(&self.x_label)
        );
        let _ = writeln!(
            out,
            r#"<text x="16" y="{}" text-anchor="middle" font-size="12" transform="rotate(-90 16 {})">{}</text>"#,
            mt + ph / 2.0,
            mt + ph / 2.0,
            esc(&self.y_label)
        );

        // Vertical markers.
        for (x, label) in &self.vlines {
            let px = sx(*x);
            let _ = writeln!(
                out,
                r#"<line x1="{px:.1}" y1="{mt}" x2="{px:.1}" y2="{}" stroke="red" stroke-dasharray="3,3"/>"#,
                mt + ph
            );
            let _ = writeln!(
                out,
                r#"<text x="{:.1}" y="{:.1}" font-size="11" fill="red">{}</text>"#,
                px + 4.0,
                mt + 14.0,
                esc(label)
            );
        }

        // Series.
        for s in &self.series {
            if s.points.is_empty() {
                continue;
            }
            let path: String = s
                .points
                .iter()
                .enumerate()
                .map(|(i, &(x, y))| {
                    format!("{}{:.1},{:.1}", if i == 0 { "M" } else { "L" }, sx(x), sy(y))
                })
                .collect();
            let dash = if s.dash.is_empty() {
                String::new()
            } else {
                format!(r#" stroke-dasharray="{}""#, s.dash)
            };
            let _ = writeln!(
                out,
                r#"<path d="{path}" fill="none" stroke="{}" stroke-width="1.8"{dash}/>"#,
                s.color
            );
            if s.markers {
                for &(x, y) in &s.points {
                    let _ = writeln!(
                        out,
                        r#"<circle cx="{:.1}" cy="{:.1}" r="2.4" fill="{}"/>"#,
                        sx(x),
                        sy(y),
                        s.color
                    );
                }
            }
        }

        // Legend.
        for (i, s) in self.series.iter().enumerate() {
            let ly = mt + 16.0 + i as f64 * 18.0;
            let lx = ml + pw - 170.0;
            let dash = if s.dash.is_empty() {
                String::new()
            } else {
                format!(r#" stroke-dasharray="{}""#, s.dash)
            };
            let _ = writeln!(
                out,
                r#"<line x1="{lx}" y1="{ly}" x2="{}" y2="{ly}" stroke="{}" stroke-width="1.8"{dash}/>"#,
                lx + 28.0,
                s.color
            );
            let _ = writeln!(
                out,
                r#"<text x="{}" y="{}" font-size="11">{}</text>"#,
                lx + 34.0,
                ly + 4.0,
                esc(&s.label)
            );
        }
        out.push_str("</svg>\n");
        out
    }

    /// Write the SVG to a file (creating parent directories).
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.render())
    }
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{:.0}", v)
    } else if v.abs() >= 10.0 {
        format!("{:.0}", v)
    } else {
        format!("{:.1}", v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chart() -> Chart {
        let mut c = Chart::new("demo", "K", "speedup");
        c.push(Series::solid("sim", vec![(1.0, 1.0), (10.0, 5.0), (20.0, 4.0)], "#1f77b4"));
        c.push(Series::dashed("model", vec![(1.0, 1.0), (20.0, 4.5)], "#555"));
        c.vline(12.0, "K_BSF");
        c
    }

    #[test]
    fn renders_valid_svg_shell() {
        let svg = chart().render();
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
        assert_eq!(svg.matches("<path").count(), 2);
        assert!(svg.contains("stroke-dasharray=\"6,4\""));
        assert!(svg.contains("K_BSF"));
        // 3 markers for the solid series
        assert_eq!(svg.matches("<circle").count(), 3);
    }

    #[test]
    fn escapes_labels() {
        let mut c = Chart::new("a < b & c", "x", "y");
        c.push(Series::solid("s", vec![(0.0, 0.0)], "red"));
        let svg = c.render();
        assert!(svg.contains("a &lt; b &amp; c"));
    }

    #[test]
    fn bounds_include_vlines_and_zero() {
        let c = chart();
        let (xmin, xmax, ymin, _ymax) = c.bounds();
        assert_eq!(xmin, 0.0);
        assert!(xmax >= 20.0);
        assert_eq!(ymin, 0.0);
    }

    #[test]
    fn save_writes_file() {
        let dir = std::env::temp_dir().join("bsf_svg_test");
        let path = dir.join("c.svg");
        chart().save(&path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("</svg>"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_series_ok() {
        let mut c = Chart::new("t", "x", "y");
        c.push(Series::solid("empty", vec![], "blue"));
        let svg = c.render();
        assert!(svg.contains("</svg>"));
        assert_eq!(svg.matches("<path").count(), 0);
    }
}
