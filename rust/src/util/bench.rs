//! Micro-benchmark harness (offline build — no criterion): warmup +
//! timed repetitions with summary statistics, and a criterion-like
//! console report. Used by every target in `rust/benches/`.

use crate::util::stats::Summary;
use crate::util::timer::measure;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Per-iteration seconds.
    pub summary: Summary,
    /// Optional throughput denominator (items per iteration).
    pub items: Option<u64>,
}

impl BenchResult {
    /// Render one report line.
    pub fn report(&self) -> String {
        let s = &self.summary;
        let mut line = format!(
            "{:<44} {:>12} ± {:>10}  (median {:>12}, n={})",
            self.name,
            human_time(s.mean),
            human_time(s.sd),
            human_time(s.median),
            s.n
        );
        if let Some(items) = self.items {
            let rate = items as f64 / s.mean;
            line.push_str(&format!("  [{:.2e} items/s]", rate));
        }
        line
    }
}

/// Human-readable seconds.
pub fn human_time(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3} s")
    } else if t >= 1e-3 {
        format!("{:.3} ms", t * 1e3)
    } else if t >= 1e-6 {
        format!("{:.3} µs", t * 1e6)
    } else {
        format!("{:.1} ns", t * 1e9)
    }
}

/// Run one case: `warmup` unrecorded + `reps` timed calls of `f`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, f: F) -> BenchResult {
    let samples = measure(warmup, reps, f);
    let r = BenchResult { name: name.to_string(), summary: Summary::of(&samples), items: None };
    println!("{}", r.report());
    r
}

/// Like [`bench`] but reports items/second throughput.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    warmup: usize,
    reps: usize,
    items: u64,
    f: F,
) -> BenchResult {
    let samples = measure(warmup, reps, f);
    let r = BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples),
        items: Some(items),
    };
    println!("{}", r.report());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut calls = 0;
        let r = bench("noop", 1, 5, || calls += 1);
        assert_eq!(calls, 6);
        assert_eq!(r.summary.n, 5);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn throughput_line_includes_rate() {
        let r = bench_throughput("items", 0, 3, 1000, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.report().contains("items/s"));
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with("ms"));
        assert!(human_time(2e-6).ends_with("µs"));
        assert!(human_time(2e-9).ends_with("ns"));
    }
}
