//! Micro-benchmark harness (offline build — no criterion): warmup +
//! timed repetitions with summary statistics, a criterion-like console
//! report, and a machine-readable [`CiReport`] that merges each bench
//! target's headline figures (tasks/sec, allocation counts) into one
//! `BENCH_ci.json` artifact per run — CI uploads it so the perf
//! trajectory is tracked per commit instead of scraped from logs.

use crate::util::stats::Summary;
use crate::util::timer::measure;
use crate::util::Json;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Per-iteration seconds.
    pub summary: Summary,
    /// Optional throughput denominator (items per iteration).
    pub items: Option<u64>,
}

impl BenchResult {
    /// Render one report line.
    pub fn report(&self) -> String {
        let s = &self.summary;
        let mut line = format!(
            "{:<44} {:>12} ± {:>10}  (median {:>12}, n={})",
            self.name,
            human_time(s.mean),
            human_time(s.sd),
            human_time(s.median),
            s.n
        );
        if let Some(items) = self.items {
            let rate = items as f64 / s.mean;
            line.push_str(&format!("  [{:.2e} items/s]", rate));
        }
        line
    }
}

/// Human-readable seconds.
pub fn human_time(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3} s")
    } else if t >= 1e-3 {
        format!("{:.3} ms", t * 1e3)
    } else if t >= 1e-6 {
        format!("{:.3} µs", t * 1e6)
    } else {
        format!("{:.1} ns", t * 1e9)
    }
}

/// Run one case: `warmup` unrecorded + `reps` timed calls of `f`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, f: F) -> BenchResult {
    let samples = measure(warmup, reps, f);
    let r = BenchResult { name: name.to_string(), summary: Summary::of(&samples), items: None };
    println!("{}", r.report());
    r
}

/// Like [`bench`] but reports items/second throughput.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    warmup: usize,
    reps: usize,
    items: u64,
    f: F,
) -> BenchResult {
    let samples = measure(warmup, reps, f);
    let r = BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples),
        items: Some(items),
    };
    println!("{}", r.report());
    r
}

/// Machine-readable benchmark figures for one bench target, merged into a
/// shared JSON artifact. Each bench owns one *section* (keyed by target
/// name); saving re-reads the file and replaces only its own section, so
/// `simulator_hotpath` and `coordinator_hotpath` can both contribute to
/// one `BENCH_ci.json`.
#[derive(Debug)]
pub struct CiReport {
    section: String,
    metrics: Vec<(String, f64)>,
}

impl CiReport {
    /// A report contributing to the section `section`.
    pub fn new(section: impl Into<String>) -> CiReport {
        CiReport { section: section.into(), metrics: Vec::new() }
    }

    /// Record a raw metric (allocation counts, medians in seconds, …).
    pub fn metric(&mut self, name: impl Into<String>, value: f64) {
        self.metrics.push((name.into(), value));
    }

    /// Record a throughput benchmark's items/second (requires the result
    /// to have been produced by [`bench_throughput`]).
    pub fn rate(&mut self, r: &BenchResult) {
        if let Some(items) = r.items {
            self.metric(format!("{} [items/s]", r.name), items as f64 / r.summary.mean);
        }
    }

    /// Merge this section into the JSON artifact at `path` (other
    /// sections are preserved; a missing or unparsable file is
    /// recreated).
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        let mut sections: Vec<(String, Vec<(String, f64)>)> = Vec::new();
        if let Ok(src) = std::fs::read_to_string(path) {
            if let Ok(Json::Obj(obj)) = Json::parse(&src) {
                for (k, v) in &obj {
                    if k == &self.section {
                        continue;
                    }
                    if let Json::Obj(metrics) = v {
                        let ms: Vec<(String, f64)> = metrics
                            .iter()
                            .filter_map(|(n, j)| j.as_f64().map(|x| (n.clone(), x)))
                            .collect();
                        sections.push((k.clone(), ms));
                    }
                }
            }
        }
        sections.push((self.section.clone(), self.metrics.clone()));
        sections.sort_by(|a, b| a.0.cmp(&b.0));
        let mut out = String::from("{\n");
        for (si, (name, metrics)) in sections.iter().enumerate() {
            let _ = writeln!(out, "  {}: {{", json_str(name));
            for (mi, (k, v)) in metrics.iter().enumerate() {
                let sep = if mi + 1 == metrics.len() { "" } else { "," };
                let _ = writeln!(out, "    {}: {v:e}{sep}", json_str(k));
            }
            let sep = if si + 1 == sections.len() { "" } else { "," };
            let _ = writeln!(out, "  }}{sep}");
        }
        out.push_str("}\n");
        std::fs::write(path, out)
    }
}

use std::fmt::Write as _;

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut calls = 0;
        let r = bench("noop", 1, 5, || calls += 1);
        assert_eq!(calls, 6);
        assert_eq!(r.summary.n, 5);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn throughput_line_includes_rate() {
        let r = bench_throughput("items", 0, 3, 1000, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.report().contains("items/s"));
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with("ms"));
        assert!(human_time(2e-6).ends_with("µs"));
        assert!(human_time(2e-9).ends_with("ns"));
    }

    #[test]
    fn ci_report_merges_sections() {
        let path = std::env::temp_dir().join(format!(
            "bsf_bench_ci_test_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut a = CiReport::new("alpha");
        a.metric("tasks_per_sec", 1.5e6);
        a.metric("allocs_per_replay", 0.0);
        a.save(&path).unwrap();
        let mut b = CiReport::new("beta");
        b.metric("overhead_sec", 2e-6);
        b.save(&path).unwrap();
        // Re-saving a section replaces it without touching the other.
        let mut a2 = CiReport::new("alpha");
        a2.metric("tasks_per_sec", 2.5e6);
        a2.save(&path).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let alpha = parsed.get("alpha").unwrap();
        assert_eq!(alpha.get("tasks_per_sec").and_then(Json::as_f64), Some(2.5e6));
        assert!(alpha.get("allocs_per_replay").is_none(), "stale metric survived");
        let beta = parsed.get("beta").unwrap();
        assert_eq!(beta.get("overhead_sec").and_then(Json::as_f64), Some(2e-6));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn json_str_escapes() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\ny"), "\"x\\ny\"");
    }
}
