//! Minimal deterministic fork–join helper for the sweep hot path.
//!
//! [`parallel_map`] evaluates `f(0..n)` across a fixed number of scoped OS
//! threads (no external crates) and returns the results **in index order**,
//! whatever order the workers finished in. Work is handed out through an
//! atomic cursor, so long items (e.g. large-K simulations) don't serialise
//! behind a static chunking. Determinism contract: `f` must be a pure
//! function of its index (the simulator guarantees this by deriving one
//! RNG stream per K — see [`crate::util::Rng::split`]), in which case the
//! output is bitwise identical at any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Worker-thread count for parallel sweeps: the `BSF_SWEEP_THREADS`
/// environment variable when set (0/unparsable → fall through), else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    if let Some(n) =
        std::env::var("BSF_SWEEP_THREADS").ok().and_then(|s| s.parse::<usize>().ok())
    {
        if n >= 1 {
            return n;
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Evaluate `f(i)` for `i in 0..n` on up to `threads` scoped threads and
/// collect the results in index order. `threads <= 1` (or `n <= 1`) runs
/// inline with no thread spawned.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_with(n, threads, || (), |_, i| f(i))
}

/// [`parallel_map`] with per-worker scratch: `init()` runs once on each
/// worker thread (and once for the inline path) and its value is handed to
/// every `f` call that worker makes. The sweep hot path uses this to keep
/// one `simulator::Engine`/`IterationTemplate` per worker across the whole
/// (experiment × size × K) work queue.
///
/// Determinism contract: the scratch must only cache *capacity* — each
/// `f(&mut state, i)` result must stay a pure function of `i`, or the
/// output would depend on which worker pulled which index.
pub fn parallel_map_with<S, T, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let f = &f;
    let init = &init;
    let next = &next;
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            s.spawn(move || {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n || tx.send((i, f(&mut state, i))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        while let Ok((i, v)) = rx.recv() {
            out[i] = Some(v);
        }
    });
    out.into_iter().map(|o| o.expect("every index produced")).collect()
}

/// [`parallel_map_with`] over **groups** of consecutive indices: each
/// group is one unit of work handed to one worker, which appends exactly
/// `group.len()` results to its output buffer (one per index, in index
/// order). Results come back flattened in index order, so the caller sees
/// the same `Vec` as `parallel_map_with` over the underlying indices.
///
/// `groups` must partition `0..n` contiguously and in order
/// (`groups[i].end == groups[i+1].start`, first starts at 0). The sweep
/// queue has moved to [`parallel_map_index_groups_with`], whose buckets
/// need not be contiguous; this range flavor remains for callers whose
/// groups are naturally consecutive runs. Determinism contract: each
/// group's results must be a pure function of the group (scratch caches
/// capacity only), so the output is bitwise identical at any thread
/// count.
pub fn parallel_map_groups_with<S, T, I, F>(
    groups: &[std::ops::Range<usize>],
    threads: usize,
    init: I,
    f: F,
) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, std::ops::Range<usize>, &mut Vec<T>) + Sync,
{
    debug_assert!(groups.first().map_or(true, |g| g.start == 0));
    debug_assert!(groups.windows(2).all(|w| w[0].end == w[1].start));
    let n = groups.last().map_or(0, |g| g.end);
    let threads = threads.clamp(1, groups.len().max(1));
    if threads <= 1 {
        let mut state = init();
        let mut out = Vec::with_capacity(n);
        for g in groups {
            let before = out.len();
            f(&mut state, g.clone(), &mut out);
            assert_eq!(out.len(), before + g.len(), "one result per index, in order");
        }
        return out;
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, Vec<T>)>();
    let f = &f;
    let init = &init;
    let next = &next;
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            s.spawn(move || {
                let mut state = init();
                loop {
                    let gi = next.fetch_add(1, Ordering::Relaxed);
                    if gi >= groups.len() {
                        break;
                    }
                    let g = groups[gi].clone();
                    let mut buf = Vec::with_capacity(g.len());
                    f(&mut state, g.clone(), &mut buf);
                    assert_eq!(buf.len(), g.len(), "one result per index, in order");
                    if tx.send((gi, buf)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        while let Ok((gi, buf)) = rx.recv() {
            for (off, v) in buf.into_iter().enumerate() {
                out[groups[gi].start + off] = Some(v);
            }
        }
    });
    out.into_iter().map(|o| o.expect("every index produced")).collect()
}

/// [`parallel_map_groups_with`] for groups of **arbitrary** (not
/// necessarily consecutive) indices: each group is one unit of work handed
/// to one worker, which appends exactly `group.len()` results to its
/// output buffer — one per index, in the group's own order. Results are
/// scattered back by index, so the caller sees the same `Vec` as
/// `parallel_map_with` over `0..n` regardless of how the groups carve it
/// up. The sweep queue uses this for shape-bucketed partitions, where one
/// group collects same-[`crate::simulator::ShapeClass`] cells from all
/// over the flat job list.
///
/// `groups` must partition `0..n` exactly — every index in exactly one
/// group (debug-asserted). Determinism contract: each group's results
/// must be a pure function of the group (scratch caches capacity only),
/// so the output is bitwise identical at any thread count.
pub fn parallel_map_index_groups_with<S, T, I, F>(
    groups: &[Vec<usize>],
    n: usize,
    threads: usize,
    init: I,
    f: F,
) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &[usize], &mut Vec<T>) + Sync,
{
    #[cfg(debug_assertions)]
    {
        let mut seen = vec![false; n];
        for g in groups {
            for &i in g {
                assert!(i < n && !seen[i], "groups must partition 0..n exactly");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "groups must cover every index");
    }
    let threads = threads.clamp(1, groups.len().max(1));
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    if threads <= 1 {
        let mut state = init();
        let mut buf = Vec::new();
        for g in groups {
            buf.clear();
            f(&mut state, g, &mut buf);
            assert_eq!(buf.len(), g.len(), "one result per index, in group order");
            for (&i, v) in g.iter().zip(buf.drain(..)) {
                out[i] = Some(v);
            }
        }
        return out.into_iter().map(|o| o.expect("every index produced")).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, Vec<T>)>();
    let f = &f;
    let init = &init;
    let next = &next;
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            s.spawn(move || {
                let mut state = init();
                loop {
                    let gi = next.fetch_add(1, Ordering::Relaxed);
                    if gi >= groups.len() {
                        break;
                    }
                    let g = &groups[gi];
                    let mut buf = Vec::with_capacity(g.len());
                    f(&mut state, g, &mut buf);
                    assert_eq!(buf.len(), g.len(), "one result per index, in group order");
                    if tx.send((gi, buf)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        while let Ok((gi, buf)) = rx.recv() {
            for (&i, v) in groups[gi].iter().zip(buf) {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|o| o.expect("every index produced")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let got = parallel_map(100, threads, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn handles_more_threads_than_items() {
        assert_eq!(parallel_map(3, 64, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i), vec![0]);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn with_state_matches_stateless_at_any_thread_count() {
        // State that only caches capacity must not change results.
        let want: Vec<usize> = (0..64).map(|i| i * 3).collect();
        for threads in [1usize, 2, 8] {
            let got = parallel_map_with(
                64,
                threads,
                Vec::<usize>::new,
                |scratch, i| {
                    scratch.clear();
                    scratch.extend(0..i);
                    scratch.len() * 3
                },
            );
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn grouped_map_flattens_in_index_order_at_any_thread_count() {
        // Uneven groups over 0..13; each group emits (index, group length).
        let groups = vec![0usize..1, 1..4, 4..5, 5..10, 10..13];
        let want: Vec<(usize, usize)> = groups
            .iter()
            .flat_map(|g| g.clone().map(move |i| (i, g.len())))
            .collect();
        for threads in [1usize, 2, 4, 9] {
            let got = parallel_map_groups_with(
                &groups,
                threads,
                || (),
                |_, g, out| {
                    for i in g.clone() {
                        out.push((i, g.len()));
                    }
                },
            );
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn index_grouped_map_scatters_back_at_any_thread_count() {
        // Non-consecutive, interleaved groups over 0..10; each group
        // emits (index, position-in-group).
        let groups: Vec<Vec<usize>> =
            vec![vec![0, 3, 7], vec![1, 2], vec![9, 4, 6, 5], vec![8]];
        let mut want = vec![(0usize, 0usize); 10];
        for g in &groups {
            for (pos, &i) in g.iter().enumerate() {
                want[i] = (i, pos);
            }
        }
        for threads in [1usize, 2, 4, 9] {
            let got = parallel_map_index_groups_with(
                &groups,
                10,
                threads,
                || (),
                |_, g, out| {
                    for (pos, &i) in g.iter().enumerate() {
                        out.push((i, pos));
                    }
                },
            );
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn index_grouped_map_handles_empty_and_single() {
        assert_eq!(
            parallel_map_index_groups_with(
                &[],
                0,
                4,
                || (),
                |_: &mut (), _, _: &mut Vec<usize>| {}
            ),
            Vec::<usize>::new()
        );
        let one = parallel_map_index_groups_with(
            &[vec![2, 0, 1]],
            3,
            4,
            || (),
            |_, g, out| out.extend(g.iter().map(|&i| i * 10)),
        );
        assert_eq!(one, vec![0, 10, 20]);
    }

    #[test]
    fn grouped_map_handles_empty_and_single() {
        assert_eq!(
            parallel_map_groups_with(&[], 4, || (), |_: &mut (), _, _: &mut Vec<usize>| {}),
            Vec::<usize>::new()
        );
        let one = parallel_map_groups_with(&[0..3], 4, || (), |_, g, out| out.extend(g));
        assert_eq!(one, vec![0, 1, 2]);
    }

    #[test]
    fn init_runs_once_per_worker_inline() {
        let inits = std::sync::atomic::AtomicUsize::new(0);
        let _ = parallel_map_with(
            10,
            1,
            || inits.fetch_add(1, Ordering::Relaxed),
            |_, i| i,
        );
        assert_eq!(inits.load(Ordering::Relaxed), 1);
    }
}
