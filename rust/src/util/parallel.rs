//! Minimal deterministic fork–join helper for the sweep hot path.
//!
//! [`parallel_map`] evaluates `f(0..n)` across a fixed number of scoped OS
//! threads (no external crates) and returns the results **in index order**,
//! whatever order the workers finished in. Work is handed out through an
//! atomic cursor, so long items (e.g. large-K simulations) don't serialise
//! behind a static chunking. Determinism contract: `f` must be a pure
//! function of its index (the simulator guarantees this by deriving one
//! RNG stream per K — see [`crate::util::Rng::split`]), in which case the
//! output is bitwise identical at any thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Worker-thread count for parallel sweeps: the `BSF_SWEEP_THREADS`
/// environment variable when set (0/unparsable → fall through), else the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    if let Some(n) =
        std::env::var("BSF_SWEEP_THREADS").ok().and_then(|s| s.parse::<usize>().ok())
    {
        if n >= 1 {
            return n;
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Evaluate `f(i)` for `i in 0..n` on up to `threads` scoped threads and
/// collect the results in index order. `threads <= 1` (or `n <= 1`) runs
/// inline with no thread spawned.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let f = &f;
    let next = &next;
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n || tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        while let Ok((i, v)) = rx.recv() {
            out[i] = Some(v);
        }
    });
    out.into_iter().map(|o| o.expect("every index produced")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let got = parallel_map(100, threads, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn handles_more_threads_than_items() {
        assert_eq!(parallel_map(3, 64, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i), vec![0]);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
