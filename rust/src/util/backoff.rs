//! Bounded exponential backoff — one retry discipline shared by the live
//! runner's worker respawns and the fleet workers' coordinator reconnects.
//!
//! The schedule is `base × 2^attempt` (exponent clamped at 16 so the
//! multiplier never overflows), optionally stretched by a deterministic
//! jitter factor drawn from a caller-supplied [`Rng`] stream — two
//! processes given the same split stream compute the same delays, so
//! retry storms stay replayable.

use std::time::Duration;

use crate::util::Rng;

/// Exponent clamp: `2^16 × base` is already minutes-to-hours for any
/// sensible base, and clamping keeps the multiplier within u32.
const MAX_EXP: u32 = 16;

/// Jitter stretch range: each delay is multiplied by a uniform draw in
/// `[1.0, 1.5)`. Stretch-only (never shrink) so a jittered schedule still
/// respects the un-jittered schedule as a lower bound.
const JITTER_SPAN: f64 = 0.5;

/// A bounded exponential-backoff schedule.
///
/// `next_delay` yields `Some(delay)` for the first `limit` attempts and
/// `None` once the budget is exhausted; the caller decides what
/// exhaustion means (give up the worker, abort the connect loop).
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    limit: usize,
    attempts: usize,
    jitter: Option<Rng>,
}

impl Backoff {
    /// A schedule of at most `limit` retries spaced `base × 2^attempt`
    /// apart. `limit == 0` means "never retry" — the first `next_delay`
    /// call returns `None`.
    pub fn new(base: Duration, limit: usize) -> Backoff {
        Backoff { base, limit, attempts: 0, jitter: None }
    }

    /// Stretch each delay by a deterministic factor in `[1.0, 1.5)` drawn
    /// from `rng` (builder form). Pass a [`Rng::split`] stream so
    /// every retry schedule in a process is a pure function of the root
    /// seed.
    pub fn with_jitter(mut self, rng: Rng) -> Backoff {
        self.jitter = Some(rng);
        self
    }

    /// The un-jittered delay before retry number `attempt` (0-based) on a
    /// schedule with base `base` — exposed so tests and log lines can name
    /// the deadline a live schedule is about to impose.
    pub fn delay_for(base: Duration, attempt: usize) -> Duration {
        base * 2u32.saturating_pow((attempt as u32).min(MAX_EXP))
    }

    /// Delay to wait before the next retry, or `None` when the retry
    /// budget is spent. Consumes one attempt; ignoring the returned delay
    /// still burns the attempt, hence `#[must_use]`.
    #[must_use]
    pub fn next_delay(&mut self) -> Option<Duration> {
        if self.attempts >= self.limit {
            return None;
        }
        let mut delay = Self::delay_for(self.base, self.attempts);
        self.attempts += 1;
        if let Some(rng) = self.jitter.as_mut() {
            delay = delay.mul_f64(1.0 + JITTER_SPAN * rng.uniform());
        }
        Some(delay)
    }

    /// Attempts consumed so far.
    pub fn attempts(&self) -> usize {
        self.attempts
    }

    /// Retries remaining in the budget.
    pub fn remaining(&self) -> usize {
        self.limit - self.attempts.min(self.limit)
    }

    /// Reset the attempt counter — a successful (re)connection earns the
    /// peer a fresh budget.
    pub fn reset(&mut self) {
        self.attempts = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_doubles_until_exhausted() {
        let base = Duration::from_millis(10);
        let mut b = Backoff::new(base, 4);
        let delays: Vec<Duration> = std::iter::from_fn(|| b.next_delay()).collect();
        assert_eq!(delays, vec![base, base * 2, base * 4, base * 8]);
        assert!(b.next_delay().is_none(), "budget stays spent");
        assert_eq!(b.attempts(), 4);
        assert_eq!(b.remaining(), 0);
    }

    #[test]
    fn zero_limit_never_retries() {
        let mut b = Backoff::new(Duration::from_millis(1), 0);
        assert!(b.next_delay().is_none());
    }

    #[test]
    fn exponent_is_clamped() {
        let base = Duration::from_nanos(1);
        assert_eq!(Backoff::delay_for(base, 16), base * (1 << 16));
        assert_eq!(Backoff::delay_for(base, 63), base * (1 << 16));
    }

    #[test]
    fn reset_restores_the_budget() {
        let mut b = Backoff::new(Duration::from_millis(5), 1);
        assert!(b.next_delay().is_some());
        assert!(b.next_delay().is_none());
        b.reset();
        assert_eq!(b.next_delay(), Some(Duration::from_millis(5)));
    }

    #[test]
    fn jitter_is_deterministic_and_stretch_only() {
        let root = Rng::new(0xB5F);
        let mut a = Backoff::new(Duration::from_millis(10), 6).with_jitter(root.split(1));
        let mut b = Backoff::new(Duration::from_millis(10), 6).with_jitter(root.split(1));
        for attempt in 0..6 {
            let (da, db) = (a.next_delay().unwrap(), b.next_delay().unwrap());
            assert_eq!(da, db, "same stream, same schedule");
            let floor = Backoff::delay_for(Duration::from_millis(10), attempt);
            assert!(da >= floor, "jitter never shrinks: {da:?} < {floor:?}");
            assert!(da <= floor.mul_f64(1.5), "jitter bounded: {da:?}");
        }
    }
}
