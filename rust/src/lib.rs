//! # bsf — Bulk Synchronous Farm
//!
//! A production-shaped reproduction of
//! *"BSF: a parallel computation model for scalability estimation of iterative
//! numerical algorithms on cluster computing systems"* (L. B. Sokolinsky,
//! JPDC 2020, doi 10.1016/j.jpdc.2020.12.009).
//!
//! The crate provides, as first-class subsystems:
//!
//! * [`lists`] — the Bird–Meertens list algebra (`Map`/`Reduce`, the promotion
//!   theorem, sublist partitioning) that BSF algorithms are specified in;
//! * [`linalg`] — a dense linear-algebra substrate (vectors, matrices, the
//!   paper's scalable test systems);
//! * [`coordinator`] — the BSF *skeleton*: a [`coordinator::BsfProblem`] trait
//!   plus master/worker runners that mechanically parallelize Algorithm 1 into
//!   Algorithm 2;
//! * [`net`] — the message-passing substrate: costed virtual-clock channels and
//!   MPI-style collectives (binomial tree and linear);
//! * [`simulator`] — a discrete-event cluster simulator that executes
//!   Algorithm-2 timelines for arbitrary `K` (the stand-in for the paper's
//!   480-node "Tornado SUSU" cluster);
//! * [`model`] — the cost metrics: the BSF model (eqs. 6–14), plus BSP and
//!   LogP/LogGP baselines, calibration, and scalability-boundary analysis;
//! * [`problems`] — the paper's applications: BSF-Jacobi, BSF-Gravity,
//!   BSF-Cimmino (linear inequalities, ref [31]) and a Map-only Monte-Carlo
//!   estimator (§7 Q2, ref [33]);
//! * [`runtime`] — the PJRT runtime that loads AOT-compiled HLO artifacts
//!   (JAX + Pallas, built once by `make artifacts`) and executes them on the
//!   worker hot path;
//! * [`experiments`] — harnesses regenerating every table and figure of the
//!   paper's evaluation (Fig. 6, Fig. 7, Tables 2–4) plus ablations;
//! * [`fleet`] — a lease-based coordinator/worker plane that shards the pooled
//!   sweep queue across OS processes with heartbeats, re-lease recovery, and a
//!   bitwise-deterministic result table under any single-worker failure.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for measured
//! results.

pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod linalg;
pub mod lists;
pub mod model;
pub mod net;
pub mod problems;
pub mod runtime;
pub mod simulator;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
