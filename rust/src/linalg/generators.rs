//! Workload generators for the evaluation harness.
//!
//! * [`paper_system`] — the paper's §6 scalable linear system (solution
//!   `x* = (1,…,1)`), used to regenerate Fig. 6 / Tables 2–3 workloads.
//! * [`dominant_system`] — a strongly diagonally dominant system on which the
//!   Jacobi iteration provably converges (used for correctness tests; the
//!   paper's matrix is only weakly dominant and Jacobi need not converge on
//!   it — the paper measures *timing*, not convergence).
//! * [`random_bodies`] — body distributions for BSF-Gravity (Fig. 7 / Table 4).
//! * [`feasible_inequalities`] — random feasible `A x ≤ b` systems for
//!   BSF-Cimmino with a known interior point.

use crate::linalg::Matrix;
use crate::util::Rng;

/// A linear system `A x = b` together with its Jacobi iteration data
/// `C, d` (paper §5: `c_ij = -a_ij/a_ii` off-diagonal, `d_i = b_i/a_ii`).
#[derive(Debug, Clone)]
pub struct LinearSystem {
    /// Coefficient matrix `A`.
    pub a: Matrix,
    /// Right-hand side `b`.
    pub b: Vec<f64>,
    /// Jacobi iteration matrix `C`.
    pub c: Matrix,
    /// Jacobi offset `d`.
    pub d: Vec<f64>,
}

impl LinearSystem {
    /// Derive the Jacobi `C, d` from `A, b`; panics on a zero diagonal.
    pub fn from_ab(a: Matrix, b: Vec<f64>) -> LinearSystem {
        let n = a.rows();
        assert_eq!(a.cols(), n, "Jacobi needs a square system");
        assert_eq!(b.len(), n);
        let mut c = Matrix::zeros(n, n);
        let mut d = vec![0.0; n];
        for i in 0..n {
            let aii = a.get(i, i);
            assert!(aii != 0.0, "zero diagonal at {i}");
            for j in 0..n {
                if j != i {
                    c.set(i, j, -a.get(i, j) / aii);
                }
            }
            d[i] = b[i] / aii;
        }
        LinearSystem { a, b, c, d }
    }

    /// Dimension `n`.
    pub fn n(&self) -> usize {
        self.d.len()
    }

    /// Residual `‖A x − b‖` (solution-quality check).
    pub fn residual(&self, x: &[f64]) -> f64 {
        let ax = self.a.matvec(x);
        crate::linalg::norm2(&crate::linalg::sub(&ax, &self.b))
    }
}

/// The paper's scalable test system (§6):
///
/// ```text
/// A = [[1, 1, …, 1],          b = [n, n+1, …, 2n-1]
///      [1, 2, 1, …],
///      [1, …, 1, n]]           (a_ii = i, off-diag = 1)
/// ```
///
/// Unique solution `x* = (1, …, 1)` since row i sums to `(n-1) + i = b_i`.
pub fn paper_system(n: usize) -> LinearSystem {
    assert!(n >= 2, "paper system needs n >= 2");
    let a = Matrix::from_fn(n, n, |i, j| if i == j { (i + 1) as f64 } else { 1.0 });
    let b: Vec<f64> = (0..n).map(|i| (n + i) as f64).collect();
    LinearSystem::from_ab(a, b)
}

/// Strongly diagonally dominant system with solution `x* = (1, …, 1)`:
/// `a_ij = 1` off-diagonal, `a_ii = n + i + 1` (dominance margin > n).
/// Jacobi's iteration matrix has `‖C‖_∞ ≤ (n-1)/(n+1) < 1`, so the method
/// converges geometrically — suitable for convergence tests.
pub fn dominant_system(n: usize) -> LinearSystem {
    assert!(n >= 2);
    let a = Matrix::from_fn(n, n, |i, j| if i == j { (n + i + 1) as f64 } else { 1.0 });
    let ones = vec![1.0; n];
    let b = a.matvec(&ones);
    LinearSystem::from_ab(a, b)
}

/// A random n-body workload for BSF-Gravity: `n` bodies uniform in a cube of
/// half-side `extent` centred at the origin, masses uniform in
/// `[0.5, 1.5)`, and a probe at `(extent*2, 0, 0)` with unit initial speed
/// toward the cloud — matching the paper's simplified problem setup.
#[derive(Debug, Clone)]
pub struct BodyWorkload {
    /// Positions, length `n`, each `[x, y, z]`.
    pub bodies: Vec<[f64; 3]>,
    /// Masses, length `n`.
    pub masses: Vec<f64>,
    /// Probe initial position.
    pub x0: [f64; 3],
    /// Probe initial velocity.
    pub v0: [f64; 3],
}

/// Generate a [`BodyWorkload`] deterministically from `seed`.
pub fn random_bodies(n: usize, extent: f64, seed: u64) -> BodyWorkload {
    let mut rng = Rng::new(seed);
    let bodies: Vec<[f64; 3]> = (0..n)
        .map(|_| {
            [
                rng.range(-extent, extent),
                rng.range(-extent, extent),
                rng.range(-extent, extent),
            ]
        })
        .collect();
    let masses: Vec<f64> = (0..n).map(|_| rng.range(0.5, 1.5)).collect();
    BodyWorkload {
        bodies,
        masses,
        x0: [2.0 * extent, 0.0, 0.0],
        v0: [-1.0, 0.0, 0.0],
    }
}

/// A feasible inequality system `A x ≤ b` (m rows, n cols) with a known
/// interior point `x_int` (margin ≥ `slack` on every row), plus a starting
/// point well outside the feasible region.
#[derive(Debug, Clone)]
pub struct InequalitySystem {
    /// Constraint rows.
    pub a: Matrix,
    /// Right-hand sides.
    pub b: Vec<f64>,
    /// A point satisfying every row with margin ≥ `slack`.
    pub interior: Vec<f64>,
    /// Infeasible starting point for the iteration.
    pub x0: Vec<f64>,
}

/// Generate a random feasible system: rows are unit-normal directions, and
/// `b_i = a_i · x_int + slack` so `x_int` is `slack`-deep inside.
pub fn feasible_inequalities(m: usize, n: usize, slack: f64, seed: u64) -> InequalitySystem {
    let mut rng = Rng::new(seed);
    let interior: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
    let mut a = Matrix::zeros(m, n);
    let mut b = vec![0.0; m];
    for i in 0..m {
        let mut row: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let nrm = crate::linalg::norm2(&row).max(1e-12);
        for v in row.iter_mut() {
            *v /= nrm;
        }
        for (j, v) in row.iter().enumerate() {
            a.set(i, j, *v);
        }
        b[i] = crate::linalg::dot(&row, &interior) + slack;
    }
    // Start far along a random direction so a good fraction of rows are violated.
    let mut x0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let nrm = crate::linalg::norm2(&x0).max(1e-12);
    for v in x0.iter_mut() {
        *v = *v / nrm * 10.0 * (slack + 1.0);
    }
    InequalitySystem { a, b, interior, x0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    #[test]
    fn paper_system_solution_is_ones() {
        for n in [2usize, 5, 64] {
            let sys = paper_system(n);
            let ones = vec![1.0; n];
            assert!(sys.residual(&ones) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn paper_system_matches_paper_matrix() {
        let sys = paper_system(4);
        // A diag = 1,2,3,4; off-diag 1; b = [4,5,6,7]
        assert_eq!(sys.a.get(0, 0), 1.0);
        assert_eq!(sys.a.get(3, 3), 4.0);
        assert_eq!(sys.a.get(2, 0), 1.0);
        assert_eq!(sys.b, vec![4.0, 5.0, 6.0, 7.0]);
        // C: c_ij = -1/a_ii off-diag, 0 diag
        assert_eq!(sys.c.get(1, 0), -0.5);
        assert_eq!(sys.c.get(1, 1), 0.0);
        // d_i = b_i / a_ii
        assert_eq!(sys.d[1], 2.5);
    }

    #[test]
    fn dominant_system_converges_by_jacobi() {
        let n = 32;
        let sys = dominant_system(n);
        let mut x = sys.d.clone();
        for _ in 0..200 {
            let mut next = sys.c.matvec(&x);
            for (v, di) in next.iter_mut().zip(&sys.d) {
                *v += di;
            }
            x = next;
        }
        let err: f64 = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10, "max err {err}");
    }

    #[test]
    fn random_bodies_deterministic_and_bounded() {
        let w1 = random_bodies(100, 5.0, 42);
        let w2 = random_bodies(100, 5.0, 42);
        assert_eq!(w1.bodies, w2.bodies);
        assert_eq!(w1.masses, w2.masses);
        assert!(w1.bodies.iter().flatten().all(|&c| c.abs() <= 5.0));
        assert!(w1.masses.iter().all(|&m| (0.5..1.5).contains(&m)));
        let w3 = random_bodies(100, 5.0, 43);
        assert_ne!(w1.bodies, w3.bodies);
    }

    #[test]
    fn feasible_inequalities_interior_is_feasible() {
        let sys = feasible_inequalities(50, 8, 0.1, 7);
        for i in 0..50 {
            let lhs = dot(sys.a.row(i), &sys.interior);
            assert!(lhs <= sys.b[i] - 0.099, "row {i}");
        }
    }

    #[test]
    fn feasible_inequalities_x0_violates_something() {
        let sys = feasible_inequalities(50, 8, 0.1, 7);
        let violated = (0..50)
            .filter(|&i| dot(sys.a.row(i), &sys.x0) > sys.b[i])
            .count();
        assert!(violated > 0, "starting point should be infeasible");
    }

    #[test]
    #[should_panic(expected = "zero diagonal")]
    fn zero_diagonal_rejected() {
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 1.0]);
        LinearSystem::from_ab(a, vec![1.0, 1.0]);
    }
}
