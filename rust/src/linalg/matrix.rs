//! Dense row-major matrix with the access patterns the BSF problems need:
//! row slices (Cimmino's constraint rows), column gathers (Jacobi's
//! `F_x(j) = x_j c_j`), matvec, and a column-block extractor matching the
//! AOT kernel layout `(n, B)`.

use crate::linalg::kernels;

/// Dense row-major `rows × cols` matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Wrap an existing row-major buffer (must have `rows*cols` elements).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Mutable element accessor.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row `i` as a slice (zero-copy).
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` gathered into a new vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Raw row-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Matrix–vector product `A x`. Delegates to the row-blocked
    /// [`Matrix::col_block_matvec_acc`] kernel over the full column range,
    /// so both paths share one (fast) inner loop.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dimension mismatch");
        let mut y = vec![0.0; self.rows];
        self.col_block_matvec_acc(0, self.cols, x, &mut y);
        y
    }

    /// `y += A[:, j0..j1] @ x_blk` — the column-block partial matvec that is
    /// BSF-Jacobi's worker folding (the rust-native twin of the Pallas
    /// kernel; used as fallback for sizes with no AOT artifact).
    ///
    /// This dominates live-calibration runs, so it is register-blocked:
    /// rows are processed four at a time against one shared pass over
    /// `x_blk` (each load of `x` feeds four independent accumulator
    /// chains), with the inner loops dispatched once per call to the
    /// process-selected [`kernels`] implementation (AVX2 on capable
    /// x86_64, scalar elsewhere; `BSF_KERNEL` overrides). Both kernels
    /// are bitwise identical by construction, so the choice never changes
    /// results.
    pub fn col_block_matvec_acc(&self, j0: usize, j1: usize, x_blk: &[f64], y: &mut [f64]) {
        assert!(j1 <= self.cols && j0 <= j1, "column range out of bounds");
        assert_eq!(x_blk.len(), j1 - j0, "x block length mismatch");
        assert_eq!(y.len(), self.rows, "output length mismatch");
        let w = j1 - j0;
        if w == 0 {
            return;
        }
        let kind = kernels::active();
        let cols = self.cols;
        let mut i = 0;
        while i + 4 <= self.rows {
            let b0 = i * cols + j0;
            let (s0, s1, s2, s3) = kernels::dot4_with(
                kind,
                &self.data[b0..b0 + w],
                &self.data[b0 + cols..b0 + cols + w],
                &self.data[b0 + 2 * cols..b0 + 2 * cols + w],
                &self.data[b0 + 3 * cols..b0 + 3 * cols + w],
                x_blk,
            );
            y[i] += s0;
            y[i + 1] += s1;
            y[i + 2] += s2;
            y[i + 3] += s3;
            i += 4;
        }
        while i < self.rows {
            let b = i * cols + j0;
            y[i] += kernels::dot_with(kind, &self.data[b..b + w], x_blk);
            i += 1;
        }
    }

    /// Copy the column block `A[:, j0..j1]` into a row-major `(rows, j1-j0)`
    /// buffer — the exact input layout of the `jacobi_map` AOT artifact,
    /// zero-padded to `width` columns.
    pub fn col_block_padded(&self, j0: usize, j1: usize, width: usize) -> Vec<f64> {
        assert!(j1 <= self.cols && j0 <= j1 && j1 - j0 <= width);
        let mut out = vec![0.0; self.rows * width];
        for i in 0..self.rows {
            let src = &self.data[i * self.cols + j0..i * self.cols + j1];
            out[i * width..i * width + (j1 - j0)].copy_from_slice(src);
        }
        out
    }

    /// Transpose (used by tests and generators).
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        // [[1,2,3],[4,5,6]]
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn accessors() {
        let m = sample();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.col(1), vec![2.0, 5.0]);
    }

    #[test]
    fn set_and_from_fn() {
        let mut m = Matrix::zeros(2, 2);
        m.set(0, 1, 9.0);
        assert_eq!(m.get(0, 1), 9.0);
        let f = Matrix::from_fn(3, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(f.get(2, 1), 21.0);
    }

    #[test]
    fn matvec_known() {
        let m = sample();
        assert_eq!(m.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    #[should_panic(expected = "dimension mismatch")]
    fn matvec_checks_dims() {
        sample().matvec(&[1.0]);
    }

    #[test]
    fn col_block_matvec_acc_equals_full() {
        let m = Matrix::from_fn(5, 7, |i, j| ((i + 1) * (j + 2)) as f64);
        let x: Vec<f64> = (0..7).map(|j| (j as f64) - 3.0).collect();
        let full = m.matvec(&x);
        let mut acc = vec![0.0; 5];
        m.col_block_matvec_acc(0, 3, &x[0..3], &mut acc);
        m.col_block_matvec_acc(3, 7, &x[3..7], &mut acc);
        for (a, b) in acc.iter().zip(&full) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn col_block_padded_layout() {
        let m = sample();
        let blk = m.col_block_padded(1, 3, 4);
        // rows of [[2,3,0,0],[5,6,0,0]]
        assert_eq!(blk, vec![2.0, 3.0, 0.0, 0.0, 5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(2, 1), 6.0);
    }

    #[test]
    #[should_panic(expected = "buffer size mismatch")]
    fn from_vec_checks_size() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }

    /// The blocked/unrolled kernel against a scalar reference, on shapes
    /// that exercise every tail combination (rows % 4, cols % 4).
    #[test]
    fn blocked_kernel_matches_scalar_reference() {
        for rows in [1usize, 3, 4, 5, 8, 11] {
            for cs in [1usize, 2, 4, 7, 9, 16] {
                let m = Matrix::from_fn(rows, cs, |i, j| ((i * 31 + j * 7) % 13) as f64 - 6.0);
                let x: Vec<f64> = (0..cs).map(|j| (j as f64 * 0.5) - 1.0).collect();
                let got = m.matvec(&x);
                let want: Vec<f64> = (0..rows)
                    .map(|i| (0..cs).map(|j| m.get(i, j) * x[j]).sum::<f64>())
                    .collect();
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-12, "rows={rows} cols={cs}: {a} vs {b}");
                }
                // partial column blocks, including empty
                let mut acc = vec![0.0; rows];
                let mid = cs / 2;
                m.col_block_matvec_acc(0, mid, &x[..mid], &mut acc);
                m.col_block_matvec_acc(mid, mid, &[], &mut acc);
                m.col_block_matvec_acc(mid, cs, &x[mid..], &mut acc);
                for (a, b) in acc.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-12, "rows={rows} cols={cs} blocked");
                }
            }
        }
    }
}
