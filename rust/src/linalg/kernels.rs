//! Runtime-dispatched inner-loop kernels for the dense hot path.
//!
//! The `dot`/`dot4` inner loops dominate live-calibration runs (they are
//! the whole of `Matrix::col_block_matvec_acc`, which is BSF-Jacobi's
//! worker folding). This module selects, **once per process**, between:
//!
//! * `scalar` — portable Rust, written with four independent per-lane
//!   accumulator chains per row (the exact association AVX2 uses), and
//! * `avx2` — `std::arch` intrinsics on x86_64 when the CPU supports
//!   AVX2 (`_mm256_mul_pd`/`_mm256_add_pd`; deliberately **no FMA**, which
//!   would contract the multiply-add and change rounding).
//!
//! **Bitwise-equality contract.** Both implementations perform the *same*
//! sequence of IEEE-754 operations: per row, lane `m ∈ {0,1,2,3}`
//! accumulates `Σ_chunks r[4c+m]·x[4c+m]` in chunk order, the four lanes
//! reduce as `((s0 + s1) + s2) + s3`, and the `len % 4` tail is folded in
//! scalarly. Every operation is exactly rounded and order-identical, so
//! the two kernels agree bit for bit on every input — pinned by
//! `rust/tests/properties.rs::prop_kernel_dispatch_bitwise_identical`
//! over random shapes (remainder rows and columns included) and exercised
//! end to end by CI running the whole test suite under both
//! `BSF_KERNEL=scalar` and `BSF_KERNEL=avx2`.
//!
//! Dispatch: `BSF_KERNEL=scalar|avx2` overrides; unset auto-detects via
//! `is_x86_feature_detected!("avx2")` (scalar elsewhere). Requesting
//! `avx2` on hardware without it panics loudly rather than silently
//! falling back — an override that does nothing would invalidate any
//! benchmark run on top of it.

use std::sync::OnceLock;

/// Which inner-loop implementation is active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable Rust (4-lane accumulator chains, autovectorizable).
    Scalar,
    /// x86_64 AVX2 intrinsics (no FMA contraction).
    Avx2,
}

impl KernelKind {
    /// Human-readable name (reports, BENCH_ci.json).
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
        }
    }
}

/// True when `kind` can execute on this CPU.
pub fn available(kind: KernelKind) -> bool {
    match kind {
        KernelKind::Scalar => true,
        KernelKind::Avx2 => avx2_supported(),
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_supported() -> bool {
    false
}

static ACTIVE: OnceLock<KernelKind> = OnceLock::new();

/// The kernel selected for this process (reads `BSF_KERNEL` once).
pub fn active() -> KernelKind {
    *ACTIVE.get_or_init(|| select(std::env::var("BSF_KERNEL").ok().as_deref()))
}

/// Pure selection logic (unit-tested separately from process env state).
fn select(request: Option<&str>) -> KernelKind {
    match request {
        Some("scalar") => KernelKind::Scalar,
        Some("avx2") => {
            assert!(
                avx2_supported(),
                "BSF_KERNEL=avx2 requested but this CPU/arch has no AVX2"
            );
            KernelKind::Avx2
        }
        Some(other) => panic!("BSF_KERNEL must be 'scalar' or 'avx2', got '{other}'"),
        None => {
            if avx2_supported() {
                KernelKind::Avx2
            } else {
                KernelKind::Scalar
            }
        }
    }
}

/// Dot product `x · y` through the active kernel.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    dot_with(active(), x, y)
}

/// Four simultaneous dot products against one shared `x` through the
/// active kernel (`r0..r3` must all have `x.len()` elements).
#[inline]
pub fn dot4(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> (f64, f64, f64, f64) {
    dot4_with(active(), r0, r1, r2, r3, x)
}

/// [`dot`] with an explicit kernel (the property suite compares
/// implementations directly). Panics if `kind` is unavailable here or the
/// slices differ in length (a hard assert — the AVX2 path reads `y` with
/// raw loads and must never see a short slice).
#[inline]
pub fn dot_with(kind: KernelKind, x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot operand length mismatch");
    match kind {
        KernelKind::Scalar => dot_scalar(x, y),
        KernelKind::Avx2 => dot_avx2_checked(x, y),
    }
}

/// [`dot4`] with an explicit kernel. Panics if `kind` is unavailable here
/// or any row is shorter than `x` (hard assert — the AVX2 path reads the
/// rows with raw loads).
#[inline]
pub fn dot4_with(
    kind: KernelKind,
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    x: &[f64],
) -> (f64, f64, f64, f64) {
    let n = x.len();
    assert!(
        r0.len() >= n && r1.len() >= n && r2.len() >= n && r3.len() >= n,
        "dot4 row shorter than x"
    );
    match kind {
        KernelKind::Scalar => dot4_scalar(r0, r1, r2, r3, x),
        KernelKind::Avx2 => dot4_avx2_checked(r0, r1, r2, r3, x),
    }
}

// ---------------------------------------------------------------- scalar

/// Portable dot: four independent lane accumulators over 4-column chunks,
/// ordered lane reduce, scalar tail — the association the AVX2 kernel
/// reproduces exactly.
fn dot_scalar(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut j = 0;
    while j + 4 <= n {
        s0 += x[j] * y[j];
        s1 += x[j + 1] * y[j + 1];
        s2 += x[j + 2] * y[j + 2];
        s3 += x[j + 3] * y[j + 3];
        j += 4;
    }
    let mut s = ((s0 + s1) + s2) + s3;
    while j < n {
        s += x[j] * y[j];
        j += 1;
    }
    s
}

/// Portable dot4: 16 accumulators (4 rows × 4 lanes) in one shared pass
/// over `x` — per row the operation sequence is identical to
/// [`dot_scalar`], so `dot4(..)[i] == dot(r_i, x)` bitwise.
fn dot4_scalar(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], x: &[f64]) -> (f64, f64, f64, f64) {
    let n = x.len();
    let mut a = [0.0f64; 4];
    let mut b = [0.0f64; 4];
    let mut c = [0.0f64; 4];
    let mut d = [0.0f64; 4];
    let mut j = 0;
    while j + 4 <= n {
        for m in 0..4 {
            a[m] += r0[j + m] * x[j + m];
            b[m] += r1[j + m] * x[j + m];
            c[m] += r2[j + m] * x[j + m];
            d[m] += r3[j + m] * x[j + m];
        }
        j += 4;
    }
    let mut s0 = ((a[0] + a[1]) + a[2]) + a[3];
    let mut s1 = ((b[0] + b[1]) + b[2]) + b[3];
    let mut s2 = ((c[0] + c[1]) + c[2]) + c[3];
    let mut s3 = ((d[0] + d[1]) + d[2]) + d[3];
    while j < n {
        let xj = x[j];
        s0 += r0[j] * xj;
        s1 += r1[j] * xj;
        s2 += r2[j] * xj;
        s3 += r3[j] * xj;
        j += 1;
    }
    (s0, s1, s2, s3)
}

// ----------------------------------------------------------------- avx2

#[cfg(target_arch = "x86_64")]
fn dot_avx2_checked(x: &[f64], y: &[f64]) -> f64 {
    assert!(avx2_supported(), "AVX2 kernel invoked without CPU support");
    // SAFETY: AVX2 support verified above; slice bounds respected inside.
    unsafe { dot_avx2(x, y) }
}

#[cfg(target_arch = "x86_64")]
fn dot4_avx2_checked(
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    x: &[f64],
) -> (f64, f64, f64, f64) {
    assert!(avx2_supported(), "AVX2 kernel invoked without CPU support");
    // SAFETY: AVX2 support verified above; slice bounds respected inside.
    unsafe { dot4_avx2(r0, r1, r2, r3, x) }
}

#[cfg(not(target_arch = "x86_64"))]
fn dot_avx2_checked(_x: &[f64], _y: &[f64]) -> f64 {
    unreachable!("AVX2 kernel selected on a non-x86_64 target")
}

#[cfg(not(target_arch = "x86_64"))]
fn dot4_avx2_checked(
    _r0: &[f64],
    _r1: &[f64],
    _r2: &[f64],
    _r3: &[f64],
    _x: &[f64],
) -> (f64, f64, f64, f64) {
    unreachable!("AVX2 kernel selected on a non-x86_64 target")
}

/// Ordered horizontal sum `((lane0 + lane1) + lane2) + lane3` — matches
/// the scalar kernels' lane-reduce association exactly.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn hsum_ordered(v: std::arch::x86_64::__m256d) -> f64 {
    use std::arch::x86_64::*;
    let lo = _mm256_castpd256_pd128(v); // lanes 0, 1
    let hi = _mm256_extractf128_pd::<1>(v); // lanes 2, 3
    let e0 = _mm_cvtsd_f64(lo);
    let e1 = _mm_cvtsd_f64(_mm_unpackhi_pd(lo, lo));
    let e2 = _mm_cvtsd_f64(hi);
    let e3 = _mm_cvtsd_f64(_mm_unpackhi_pd(hi, hi));
    ((e0 + e1) + e2) + e3
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(x: &[f64], y: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let n = x.len();
    let mut acc = _mm256_setzero_pd();
    let mut j = 0;
    while j + 4 <= n {
        let xv = _mm256_loadu_pd(x.as_ptr().add(j));
        let yv = _mm256_loadu_pd(y.as_ptr().add(j));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(xv, yv));
        j += 4;
    }
    let mut s = hsum_ordered(acc);
    while j < n {
        s += x[j] * y[j];
        j += 1;
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot4_avx2(
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    x: &[f64],
) -> (f64, f64, f64, f64) {
    use std::arch::x86_64::*;
    let n = x.len();
    let mut a0 = _mm256_setzero_pd();
    let mut a1 = _mm256_setzero_pd();
    let mut a2 = _mm256_setzero_pd();
    let mut a3 = _mm256_setzero_pd();
    let mut j = 0;
    while j + 4 <= n {
        let xv = _mm256_loadu_pd(x.as_ptr().add(j));
        a0 = _mm256_add_pd(a0, _mm256_mul_pd(_mm256_loadu_pd(r0.as_ptr().add(j)), xv));
        a1 = _mm256_add_pd(a1, _mm256_mul_pd(_mm256_loadu_pd(r1.as_ptr().add(j)), xv));
        a2 = _mm256_add_pd(a2, _mm256_mul_pd(_mm256_loadu_pd(r2.as_ptr().add(j)), xv));
        a3 = _mm256_add_pd(a3, _mm256_mul_pd(_mm256_loadu_pd(r3.as_ptr().add(j)), xv));
        j += 4;
    }
    let mut s0 = hsum_ordered(a0);
    let mut s1 = hsum_ordered(a1);
    let mut s2 = hsum_ordered(a2);
    let mut s3 = hsum_ordered(a3);
    while j < n {
        let xj = x[j];
        s0 += r0[j] * xj;
        s1 += r1[j] * xj;
        s2 += r2[j] * xj;
        s3 += r3[j] * xj;
        j += 1;
    }
    (s0, s1, s2, s3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_parses_overrides() {
        assert_eq!(select(Some("scalar")), KernelKind::Scalar);
        if avx2_supported() {
            assert_eq!(select(Some("avx2")), KernelKind::Avx2);
            assert_eq!(select(None), KernelKind::Avx2);
        } else {
            assert_eq!(select(None), KernelKind::Scalar);
        }
    }

    #[test]
    #[should_panic(expected = "BSF_KERNEL must be")]
    fn select_rejects_unknown_kernel() {
        select(Some("sse9"));
    }

    #[test]
    fn scalar_dot_matches_naive_within_roundoff() {
        let x: Vec<f64> = (0..19).map(|i| (i as f64 * 0.7).sin()).collect();
        let y: Vec<f64> = (0..19).map(|i| (i as f64 * 0.3).cos()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot_scalar(&x, &y) - naive).abs() < 1e-12);
    }

    #[test]
    fn dot4_rows_equal_single_dots_bitwise() {
        // The per-row association of dot4 is identical to dot, tails
        // included — for every length class mod 4.
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 16, 31] {
            let mk = |s: usize| -> Vec<f64> {
                (0..n).map(|j| ((s * 31 + j * 7) % 13) as f64 * 0.37 - 1.9).collect()
            };
            let (r0, r1, r2, r3, x) = (mk(1), mk(2), mk(3), mk(4), mk(5));
            let (s0, s1, s2, s3) = dot4_scalar(&r0, &r1, &r2, &r3, &x);
            assert_eq!(s0.to_bits(), dot_scalar(&r0, &x).to_bits(), "n={n}");
            assert_eq!(s1.to_bits(), dot_scalar(&r1, &x).to_bits(), "n={n}");
            assert_eq!(s2.to_bits(), dot_scalar(&r2, &x).to_bits(), "n={n}");
            assert_eq!(s3.to_bits(), dot_scalar(&r3, &x).to_bits(), "n={n}");
        }
    }

    #[test]
    fn avx2_matches_scalar_bitwise_when_supported() {
        if !available(KernelKind::Avx2) {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        for n in [0usize, 1, 3, 4, 5, 8, 11, 16, 29, 64, 127] {
            let mk = |s: usize| -> Vec<f64> {
                (0..n).map(|j| ((s * 17 + j * 29) % 101) as f64 * 1e-2 - 0.5).collect()
            };
            let (r0, r1, r2, r3, x) = (mk(1), mk(2), mk(3), mk(4), mk(9));
            assert_eq!(
                dot_with(KernelKind::Scalar, &r0, &x).to_bits(),
                dot_with(KernelKind::Avx2, &r0, &x).to_bits(),
                "dot n={n}"
            );
            let a = dot4_with(KernelKind::Scalar, &r0, &r1, &r2, &r3, &x);
            let b = dot4_with(KernelKind::Avx2, &r0, &r1, &r2, &r3, &x);
            assert_eq!(a.0.to_bits(), b.0.to_bits(), "dot4 n={n}");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "dot4 n={n}");
            assert_eq!(a.2.to_bits(), b.2.to_bits(), "dot4 n={n}");
            assert_eq!(a.3.to_bits(), b.3.to_bits(), "dot4 n={n}");
        }
    }

    #[test]
    fn active_kernel_is_available() {
        assert!(available(active()));
    }
}
