//! Dense vector operations over `&[f64]` / `Vec<f64>`.
//!
//! Free functions (not a newtype) so the coordinator, problems and runtime
//! can pass slices around without conversions; the hot paths (`dot`,
//! `axpy`) are written to autovectorize.

/// Dot product `x · y`, dispatched through the process-selected
/// [`crate::linalg::kernels`] implementation (AVX2 / scalar — bitwise
/// identical by construction).
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    crate::linalg::kernels::dot(x, y)
}

/// Squared Euclidean norm `‖x‖²` (the paper's termination quantity).
pub fn sq_norm2(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm `‖x‖`.
pub fn norm2(x: &[f64]) -> f64 {
    sq_norm2(x).sqrt()
}

/// `y += a * x` in place.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Elementwise difference `x - y` as a new vector.
pub fn sub(x: &[f64], y: &[f64]) -> Vec<f64> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

/// Scale in place: `x *= a`.
pub fn scale(a: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn norms() {
        assert_eq!(sq_norm2(&[3.0, 4.0]), 25.0);
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
    }

    #[test]
    fn axpy_updates_in_place() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 41.0]);
    }

    #[test]
    fn sub_and_scale() {
        assert_eq!(sub(&[5.0, 7.0], &[1.0, 2.0]), vec![4.0, 5.0]);
        let mut x = vec![2.0, -3.0];
        scale(-1.5, &mut x);
        assert_eq!(x, vec![-3.0, 4.5]);
    }
}
