//! Dense linear-algebra substrate.
//!
//! The BSF applications operate on dense vectors and matrices; this module
//! supplies exactly the operations the paper's algorithms need (§5, §6,
//! ref [31]) plus the workload generators used by the evaluation — notably
//! the paper's scalable test system (§6) whose unique solution is
//! `x* = (1, …, 1)`.

mod matrix;
mod vector;

pub mod generators;
pub mod kernels;

pub use matrix::Matrix;
pub use vector::{axpy, dot, norm2, scale, sq_norm2, sub};
