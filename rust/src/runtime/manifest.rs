//! Artifact manifest (`artifacts/manifest.json`) parsing + validation.
//!
//! `python/compile/aot.py` records, per artifact, the file name and the
//! input/output tensor specs of the lowered computation. The runtime
//! validates every `execute` call against these specs, so a stale artifact
//! directory fails loudly instead of feeding PJRT mis-shaped buffers.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;

use crate::util::Json;

/// Shape + dtype of one tensor parameter or result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Dtype string as recorded by JAX (the whole stack uses `float64`).
    pub dtype: String,
}

/// One artifact's metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// HLO text file name, relative to the artifact directory.
    pub file: String,
    /// Input tensor specs, in parameter order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs (the lowering always returns a tuple).
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Worker block width `B` the kernels were compiled for.
    pub block: usize,
    /// Matrix sizes `n` with per-size artifacts.
    pub sizes: Vec<usize>,
    /// Artifact name → metadata.
    pub artifacts: HashMap<String, ArtifactMeta>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor spec missing 'shape'"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("tensor spec missing 'dtype'"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    /// Parse `manifest.json` source text.
    pub fn parse(src: &str) -> Result<Manifest> {
        let root = Json::parse(src).context("parsing manifest.json")?;
        let block = root
            .get("block")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing 'block'"))?;
        let sizes = root
            .get("sizes")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'sizes'"))?
            .iter()
            .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad size")))
            .collect::<Result<Vec<_>>>()?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        let mut artifacts = HashMap::with_capacity(arts.len());
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'file'"))?
                .to_string();
            let inputs = entry
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'inputs'"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact '{name}' missing 'outputs'"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(name.clone(), ArtifactMeta { file, inputs, outputs });
        }
        Ok(Manifest { block, sizes, artifacts })
    }

    /// Name of the Jacobi map-block artifact for dimension `n`, if compiled.
    pub fn jacobi_map(&self, n: usize) -> Option<String> {
        let name = format!("jacobi_map_n{n}");
        self.artifacts.contains_key(&name).then_some(name)
    }

    /// Name of the gravity map-block artifact (block width = `self.block`).
    pub fn gravity_map(&self) -> Option<String> {
        let name = format!("gravity_map_b{}", self.block);
        self.artifacts.contains_key(&name).then_some(name)
    }

    /// Name of the Cimmino map-block artifact for dimension `n`.
    pub fn cimmino_map(&self, n: usize) -> Option<String> {
        let name = format!("cimmino_map_n{n}");
        self.artifacts.contains_key(&name).then_some(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "block": 256,
      "sizes": [256],
      "artifacts": {
        "jacobi_map_n256": {
          "file": "jacobi_map_n256.hlo.txt",
          "inputs": [
            {"shape": [256, 256], "dtype": "float64"},
            {"shape": [256], "dtype": "float64"}
          ],
          "outputs": [{"shape": [256], "dtype": "float64"}],
          "sha256": "x"
        },
        "gravity_map_b256": {
          "file": "gravity_map_b256.hlo.txt",
          "inputs": [
            {"shape": [256, 3], "dtype": "float64"},
            {"shape": [256], "dtype": "float64"},
            {"shape": [3], "dtype": "float64"}
          ],
          "outputs": [{"shape": [3], "dtype": "float64"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.block, 256);
        assert_eq!(m.sizes, vec![256]);
        let j = &m.artifacts["jacobi_map_n256"];
        assert_eq!(j.inputs.len(), 2);
        assert_eq!(j.inputs[0].shape, vec![256, 256]);
        assert_eq!(j.outputs[0].dtype, "float64");
    }

    #[test]
    fn name_helpers() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.jacobi_map(256), Some("jacobi_map_n256".into()));
        assert_eq!(m.jacobi_map(512), None);
        assert_eq!(m.gravity_map(), Some("gravity_map_b256".into()));
        assert_eq!(m.cimmino_map(256), None);
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"block": 1, "sizes": []}"#).is_err());
        let bad = r#"{"block": 1, "sizes": [], "artifacts": {"a": {"file": "f"}}}"#;
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // When `make artifacts` has run, validate the real manifest too.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&src).unwrap();
            assert!(m.jacobi_map(256).is_some());
            assert!(m.gravity_map().is_some());
            assert!(!m.artifacts.is_empty());
        }
    }
}
