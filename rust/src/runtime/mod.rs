//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) built once
//! by `make artifacts` and executes them from the Rust hot path.
//!
//! Python never runs here: the HLO text (lowered from the L2 JAX model and
//! L1 Pallas kernels) is parsed by XLA's C++ HLO parser
//! (`HloModuleProto::from_text_file`), compiled by the PJRT CPU client, and
//! cached per artifact name. See `/opt/xla-example/README.md` for why text —
//! not serialized protos — is the interchange format.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each worker thread owns its
//! own [`KernelRuntime`]; compilation happens once per thread per artifact
//! and is excluded from calibration timings (the BSF model's "iterative
//! algorithm" assumption: initialization cost is negligible against the
//! iterative process).

mod manifest;

pub use manifest::{ArtifactMeta, Manifest, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

/// A tensor argument: f64 data plus dimensions (row-major).
///
/// The payload is `Arc`-shared so iteration-invariant inputs (a worker's
/// packed matrix blocks) can be replayed every iteration without copying
/// megabytes on the hot path.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Row-major payload (shared).
    pub data: std::sync::Arc<Vec<f64>>,
    /// Dimensions (empty = scalar).
    pub dims: Vec<usize>,
}

impl Tensor {
    /// Vector tensor.
    pub fn vec(data: Vec<f64>) -> Tensor {
        let dims = vec![data.len()];
        Tensor { data: std::sync::Arc::new(data), dims }
    }

    /// Matrix tensor (row-major `rows × cols`).
    pub fn mat(data: Vec<f64>, rows: usize, cols: usize) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor { data: std::sync::Arc::new(data), dims: vec![rows, cols] }
    }

    /// Matrix tensor over pre-shared data (zero-copy hot path).
    pub fn mat_shared(data: std::sync::Arc<Vec<f64>>, rows: usize, cols: usize) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor { data, dims: vec![rows, cols] }
    }

    /// Vector tensor over pre-shared data (zero-copy hot path).
    pub fn vec_shared(data: std::sync::Arc<Vec<f64>>) -> Tensor {
        let dims = vec![data.len()];
        Tensor { data, dims }
    }

    /// Scalar tensor.
    pub fn scalar(x: f64) -> Tensor {
        Tensor { data: std::sync::Arc::new(vec![x]), dims: vec![] }
    }

    /// Element count implied by dims.
    pub fn len(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    /// True when the tensor holds no data (zero-sized dims).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Per-thread PJRT runtime: one CPU client + compiled-executable cache +
/// device-buffer cache for iteration-invariant inputs.
pub struct KernelRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Payloads pinned alive for the buffer cache (address-keyed).
    pinned: RefCell<Vec<std::sync::Arc<Vec<f64>>>>,
    /// Device buffers for shared tensors, keyed by the `Arc` payload's
    /// address (stable for the tensor's lifetime). A worker's packed
    /// matrix blocks are uploaded once and replayed every iteration —
    /// without this the hot path re-uploads megabytes per call (see
    /// EXPERIMENTS.md §Perf).
    buffers: RefCell<HashMap<usize, Rc<xla::PjRtBuffer>>>,
}

impl std::fmt::Debug for KernelRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelRuntime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.cache.borrow().len())
            .finish()
    }
}

impl KernelRuntime {
    /// Open the artifact directory (reads + validates `manifest.json`,
    /// creates the PJRT CPU client). Fails if the directory or manifest is
    /// missing — run `make artifacts` first.
    pub fn open(dir: impl AsRef<Path>) -> Result<KernelRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`?)"))?;
        let manifest = Manifest::parse(&src)?;
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        Ok(KernelRuntime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            pinned: RefCell::new(Vec::new()),
            buffers: RefCell::new(HashMap::new()),
        })
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Worker block width `B` the artifacts were compiled for.
    pub fn block(&self) -> usize {
        self.manifest.block
    }

    /// Whether an artifact exists for `name`.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// The compiled executable for `name`, compiling and caching on first
    /// use.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?;
        let path = self.dir.join(&meta.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(wrap_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).map_err(wrap_xla)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (so first-use cost is excluded from timed
    /// sections).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute artifact `name` on the given inputs; returns the tuple of
    /// outputs as flat f64 vectors. Input shapes are validated against the
    /// manifest.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f64>>> {
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.dims != spec.shape {
                bail!(
                    "artifact '{name}' input {i}: shape {:?} != manifest {:?}",
                    t.dims,
                    spec.shape
                );
            }
            if t.data.len() != t.len() {
                bail!(
                    "artifact '{name}' input {i}: data length {} != dims product {}",
                    t.data.len(),
                    t.len()
                );
            }
        }
        let exe = self.executable(name)?;
        let buffers: Vec<Rc<xla::PjRtBuffer>> = inputs
            .iter()
            .map(|t| self.device_buffer(t))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().map(|b| b.as_ref()).collect();
        let result = exe.execute_b(&refs).map_err(wrap_xla)?;
        let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
        // All artifacts are lowered with return_tuple=True.
        let parts = tuple.to_tuple().map_err(wrap_xla)?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f64>().map_err(wrap_xla)?);
        }
        Ok(out)
    }

    /// Device buffer for a tensor. Shared tensors (anything also held by a
    /// problem's block cache, detected by `Arc` refcount) are uploaded once
    /// and cached by payload address — the cache co-owns the `Arc`, so the
    /// address stays valid for the cache's lifetime. Ephemeral tensors
    /// (per-iteration payloads) are uploaded per call.
    fn device_buffer(&self, t: &Tensor) -> Result<Rc<xla::PjRtBuffer>> {
        let shared = std::sync::Arc::strong_count(&t.data) > 1;
        if shared {
            let key = std::sync::Arc::as_ptr(&t.data) as usize;
            if let Some(buf) = self.buffers.borrow().get(&key) {
                return Ok(buf.clone());
            }
            let buf = Rc::new(
                self.client
                    .buffer_from_host_buffer::<f64>(&t.data, &t.dims, None)
                    .map_err(wrap_xla)?,
            );
            // Keep the payload alive so its address cannot be recycled
            // while the cached buffer exists.
            self.pinned.borrow_mut().push(t.data.clone());
            self.buffers.borrow_mut().insert(key, buf.clone());
            Ok(buf)
        } else {
            Ok(Rc::new(
                self.client
                    .buffer_from_host_buffer::<f64>(&t.data, &t.dims, None)
                    .map_err(wrap_xla)?,
            ))
        }
    }

    /// Number of compiled (cached) executables.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Number of cached device buffers.
    pub fn buffer_count(&self) -> usize {
        self.buffers.borrow().len()
    }
}

/// Convert the xla crate's error (non-`Sync`) into an anyhow error.
fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_constructors() {
        let v = Tensor::vec(vec![1.0, 2.0]);
        assert_eq!(v.dims, vec![2]);
        let m = Tensor::mat(vec![0.0; 6], 2, 3);
        assert_eq!(m.dims, vec![2, 3]);
        assert_eq!(m.len(), 6);
        let s = Tensor::scalar(5.0);
        assert!(s.dims.is_empty());
        assert_eq!(s.len(), 1);
        assert!(!s.is_empty());
    }

    #[test]
    #[should_panic]
    fn mat_size_checked() {
        Tensor::mat(vec![0.0; 5], 2, 3);
    }

    #[test]
    fn open_missing_dir_fails_helpfully() {
        let err = KernelRuntime::open("/nonexistent/artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
