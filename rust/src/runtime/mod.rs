//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`) built once
//! by `make artifacts` and executes them from the Rust hot path.
//!
//! Python never runs here: the HLO text (lowered from the L2 JAX model and
//! L1 Pallas kernels) is parsed by XLA's C++ HLO parser
//! (`HloModuleProto::from_text_file`), compiled by the PJRT CPU client, and
//! cached per artifact name. See `/opt/xla-example/README.md` for why text —
//! not serialized protos — is the interchange format.
//!
//! The XLA client lives behind the **`pjrt` cargo feature**. The build
//! must stay fully offline, so the feature resolves against
//! `rust/vendor/xla` — an API **stub** of the real vendored FFI crate
//! whose client fails at startup (CI compile-checks the whole gated path
//! against it); hosts provisioned with the XLA toolchain swap that path
//! dependency for the real crate. Without the feature every type and API
//! below still compiles — manifest parsing, shape validation, tensor
//! views — but [`KernelRuntime::open`] fails with a clear message, which
//! every caller already treats as "run the native path". That keeps the
//! whole-crate tier-1 build green on plain containers while the kernel
//! path stays exercised wherever artifacts + the toolchain exist.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each worker thread owns its
//! own [`KernelRuntime`]; compilation happens once per thread per artifact
//! and is excluded from calibration timings (the BSF model's "iterative
//! algorithm" assumption: initialization cost is negligible against the
//! iterative process).
//!
//! ## Zero-copy data plane
//!
//! Two input paths feed an executable:
//!
//! * **Owned/shared tensors** ([`Tensor`]) — `Arc`-shared payloads;
//!   iteration-invariant inputs (a worker's packed matrix blocks) are
//!   uploaded to the device once and cached by payload address.
//! * **Borrowed views** ([`TensorView`]) — zero-copy slices over caller
//!   buffers, used with [`KernelRuntime::execute_into`] so the per-
//!   iteration staging of `map_fold_into`'s kernel path (x-blocks, shifted
//!   b-blocks, result accumulation) runs entirely through reused
//!   [`crate::coordinator::Workspace`] buffers: **zero steady-state heap
//!   allocations on the staging layer**, matching the native path's bar
//!   (asserted by `rust/benches/coordinator_hotpath.rs`).

mod manifest;

pub use manifest::{ArtifactMeta, Manifest, TensorSpec};

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

/// Tensor dimensions, allocation-free (rank ≤ 2 covers every artifact:
/// scalars, vectors, row-major matrices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dims {
    d: [usize; 2],
    rank: u8,
}

impl Dims {
    /// Scalar (rank 0).
    pub fn scalar() -> Dims {
        Dims { d: [0, 0], rank: 0 }
    }

    /// Vector of length `n`.
    pub fn vector(n: usize) -> Dims {
        Dims { d: [n, 0], rank: 1 }
    }

    /// Row-major `rows × cols` matrix.
    pub fn matrix(rows: usize, cols: usize) -> Dims {
        Dims { d: [rows, cols], rank: 2 }
    }

    /// The dimensions as a slice (empty = scalar).
    pub fn as_slice(&self) -> &[usize] {
        &self.d[..self.rank as usize]
    }

    /// Element count implied by the dims.
    pub fn len(&self) -> usize {
        self.as_slice().iter().product::<usize>().max(1)
    }

    /// True for zero-sized shapes.
    pub fn is_empty(&self) -> bool {
        self.as_slice().iter().any(|&d| d == 0)
    }

    /// Shape equality against a manifest spec, without allocating.
    pub fn matches(&self, shape: &[usize]) -> bool {
        self.as_slice() == shape
    }
}

/// A tensor argument: f64 data plus dimensions (row-major).
///
/// The payload is `Arc`-shared so iteration-invariant inputs (a worker's
/// packed matrix blocks) can be replayed every iteration without copying
/// megabytes on the hot path. Per-iteration payloads should prefer the
/// borrowed [`TensorView`] + [`KernelRuntime::execute_into`] path, which
/// does not allocate at all.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Row-major payload (shared).
    pub data: std::sync::Arc<Vec<f64>>,
    /// Dimensions (empty = scalar).
    pub dims: Vec<usize>,
}

impl Tensor {
    /// Vector tensor.
    pub fn vec(data: Vec<f64>) -> Tensor {
        let dims = vec![data.len()];
        Tensor { data: std::sync::Arc::new(data), dims }
    }

    /// Matrix tensor (row-major `rows × cols`).
    pub fn mat(data: Vec<f64>, rows: usize, cols: usize) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor { data: std::sync::Arc::new(data), dims: vec![rows, cols] }
    }

    /// Matrix tensor over pre-shared data (zero-copy hot path).
    pub fn mat_shared(data: std::sync::Arc<Vec<f64>>, rows: usize, cols: usize) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor { data, dims: vec![rows, cols] }
    }

    /// Vector tensor over pre-shared data (zero-copy hot path).
    pub fn vec_shared(data: std::sync::Arc<Vec<f64>>) -> Tensor {
        let dims = vec![data.len()];
        Tensor { data, dims }
    }

    /// Scalar tensor.
    pub fn scalar(x: f64) -> Tensor {
        Tensor { data: std::sync::Arc::new(vec![x]), dims: vec![] }
    }

    /// Element count implied by dims.
    pub fn len(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    /// True when the tensor holds no data (zero-sized dims).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// A borrowed view of this tensor (shared payloads stay device-buffer
    /// cacheable through the view).
    pub fn view(&self) -> TensorView<'_> {
        let dims = match self.dims.len() {
            0 => Dims::scalar(),
            1 => Dims::vector(self.dims[0]),
            2 => Dims::matrix(self.dims[0], self.dims[1]),
            r => panic!("rank-{r} tensors are not supported"),
        };
        let shared =
            (std::sync::Arc::strong_count(&self.data) > 1).then_some(&self.data);
        TensorView { data: self.data.as_slice(), dims, shared }
    }
}

/// A borrowed tensor argument — the zero-copy input path of
/// [`KernelRuntime::execute_into`]. Constructing one performs no heap
/// allocation, so per-iteration kernel inputs can be staged in reusable
/// [`crate::coordinator::Workspace`] buffers and passed straight through.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    /// Row-major payload (borrowed).
    pub data: &'a [f64],
    /// Dimensions.
    pub dims: Dims,
    /// When `Some`, the payload is also owned by a long-lived `Arc` (a
    /// problem's packed-block cache): the runtime may upload it once and
    /// cache the device buffer by payload address, pinning the `Arc` so
    /// the address stays valid. `None` marks an ephemeral per-iteration
    /// payload, uploaded per call.
    shared: Option<&'a std::sync::Arc<Vec<f64>>>,
}

impl<'a> TensorView<'a> {
    /// Borrowed vector view (ephemeral payload).
    pub fn vec_view(data: &'a [f64]) -> TensorView<'a> {
        TensorView { data, dims: Dims::vector(data.len()), shared: None }
    }

    /// Borrowed row-major matrix view (ephemeral payload).
    pub fn mat_view(data: &'a [f64], rows: usize, cols: usize) -> TensorView<'a> {
        assert_eq!(data.len(), rows * cols);
        TensorView { data, dims: Dims::matrix(rows, cols), shared: None }
    }

    /// Borrowed scalar view (ephemeral payload).
    pub fn scalar_view(x: &'a f64) -> TensorView<'a> {
        TensorView { data: std::slice::from_ref(x), dims: Dims::scalar(), shared: None }
    }

    /// Vector view of a long-lived shared payload (device-buffer
    /// cacheable, like [`Tensor::vec_shared`] but allocation-free).
    pub fn vec_cached(data: &'a std::sync::Arc<Vec<f64>>) -> TensorView<'a> {
        TensorView { data: data.as_slice(), dims: Dims::vector(data.len()), shared: Some(data) }
    }

    /// Matrix view of a long-lived shared payload (device-buffer
    /// cacheable, like [`Tensor::mat_shared`] but allocation-free).
    pub fn mat_cached(
        data: &'a std::sync::Arc<Vec<f64>>,
        rows: usize,
        cols: usize,
    ) -> TensorView<'a> {
        assert_eq!(data.len(), rows * cols);
        TensorView { data: data.as_slice(), dims: Dims::matrix(rows, cols), shared: Some(data) }
    }

    /// True when the view's payload is device-buffer cacheable (backed by
    /// a long-lived shared `Arc`).
    pub fn is_shared(&self) -> bool {
        self.shared.is_some()
    }
}

/// Per-thread PJRT runtime: one CPU client + compiled-executable cache +
/// device-buffer cache for iteration-invariant inputs.
pub struct KernelRuntime {
    dir: PathBuf,
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Payloads pinned alive for the buffer cache (address-keyed).
    #[cfg(feature = "pjrt")]
    pinned: RefCell<Vec<std::sync::Arc<Vec<f64>>>>,
    /// Device buffers for shared tensors, keyed by the `Arc` payload's
    /// address (stable for the tensor's lifetime). A worker's packed
    /// matrix blocks are uploaded once and replayed every iteration —
    /// without this the hot path re-uploads megabytes per call (see
    /// EXPERIMENTS.md §Perf).
    #[cfg(feature = "pjrt")]
    buffers: RefCell<HashMap<usize, Rc<xla::PjRtBuffer>>>,
}

impl std::fmt::Debug for KernelRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelRuntime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.compiled_count())
            .finish()
    }
}

impl KernelRuntime {
    /// Open the artifact directory (reads + validates `manifest.json`,
    /// creates the PJRT CPU client). Fails if the directory or manifest is
    /// missing — run `make artifacts` first — or when the crate was built
    /// without the `pjrt` feature.
    pub fn open(dir: impl AsRef<Path>) -> Result<KernelRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`?)"))?;
        let manifest = Manifest::parse(&src)?;
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = &manifest;
            bail!(
                "artifacts found at {dir:?} but this build has no PJRT client: \
                 rebuild with `--features pjrt` against the real vendored xla \
                 crate (rust/vendor/xla is an offline API stub; callers \
                 degrade to the native compute path)"
            );
        }
        #[cfg(feature = "pjrt")]
        {
            let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
            return Ok(KernelRuntime {
                client,
                dir,
                manifest,
                cache: RefCell::new(HashMap::new()),
                pinned: RefCell::new(Vec::new()),
                buffers: RefCell::new(HashMap::new()),
            });
        }
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Worker block width `B` the artifacts were compiled for.
    pub fn block(&self) -> usize {
        self.manifest.block
    }

    /// Whether an artifact exists for `name`.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Validate borrowed views against the manifest entry for `name`
    /// (allocation-free on success).
    fn validate(&self, name: &str, inputs: &[TensorView<'_>]) -> Result<&ArtifactMeta> {
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if !t.dims.matches(&spec.shape) {
                bail!(
                    "artifact '{name}' input {i}: shape {:?} != manifest {:?}",
                    t.dims.as_slice(),
                    spec.shape
                );
            }
            if t.data.len() != t.dims.len() {
                bail!(
                    "artifact '{name}' input {i}: data length {} != dims product {}",
                    t.data.len(),
                    t.dims.len()
                );
            }
        }
        Ok(meta)
    }

    /// Pre-compile an artifact (so first-use cost is excluded from timed
    /// sections).
    #[cfg(feature = "pjrt")]
    pub fn warm(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Pre-compile an artifact (placeholder without the `pjrt` feature —
    /// the runtime cannot be constructed in that configuration).
    #[cfg(not(feature = "pjrt"))]
    pub fn warm(&self, name: &str) -> Result<()> {
        let _ = name;
        bail!("PJRT disabled (built without the `pjrt` feature)")
    }

    /// Execute artifact `name` on the given inputs; returns the tuple of
    /// outputs as flat f64 vectors. Input shapes are validated against the
    /// manifest.
    ///
    /// One-shot convenience path; the hot path should prefer
    /// [`KernelRuntime::execute_into`], which neither copies inputs nor
    /// allocates result vectors on the caller's side.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f64>>> {
        // Rank-check before building views: `Tensor`'s fields are public,
        // so a rank-3 shape must surface as the usual validation error
        // (every call site treats Err as "fall back to native"), not as
        // `Tensor::view`'s panic.
        for (i, t) in inputs.iter().enumerate() {
            if t.dims.len() > 2 {
                bail!(
                    "artifact '{name}' input {i}: unsupported rank-{} shape {:?}",
                    t.dims.len(),
                    t.dims
                );
            }
        }
        let views: Vec<TensorView<'_>> = inputs.iter().map(Tensor::view).collect();
        self.validate(name, &views)?;
        #[cfg(feature = "pjrt")]
        {
            let exe = self.executable(name)?;
            // The views carry the shared/ephemeral classification
            // (`Tensor::view` checks the Arc refcount), so the device
            // upload path is the same one `execute_into` uses.
            let buffers: Vec<Rc<xla::PjRtBuffer>> = views
                .iter()
                .map(|v| self.device_buffer_view(v))
                .collect::<Result<_>>()?;
            let refs: Vec<&xla::PjRtBuffer> = buffers.iter().map(|b| b.as_ref()).collect();
            let result = exe.execute_b(&refs).map_err(wrap_xla)?;
            let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
            // All artifacts are lowered with return_tuple=True.
            let parts = tuple.to_tuple().map_err(wrap_xla)?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f64>().map_err(wrap_xla)?);
            }
            return Ok(out);
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = views;
            bail!("PJRT disabled (built without the `pjrt` feature)")
        }
    }

    /// Execute artifact `name` on borrowed inputs, copying each output
    /// into the caller's buffers — the zero-copy live data plane.
    ///
    /// * `inputs` are [`TensorView`]s: ephemeral views are uploaded per
    ///   call straight from the borrowed slice (no host-side staging
    ///   copy); `*_cached` views of long-lived shared payloads hit the
    ///   device-buffer cache exactly like shared [`Tensor`]s.
    /// * `outs` must hold one `&mut [f64]` per manifest output, each
    ///   exactly the output's element count.
    ///
    /// The caller-side staging layer performs zero heap allocations; the
    /// result copy-out still routes through the XLA literal API (one
    /// transitional vector per output inside the gated client — tracked
    /// as the remaining PJRT copy in PERF.md).
    pub fn execute_into(
        &self,
        name: &str,
        inputs: &[TensorView<'_>],
        outs: &mut [&mut [f64]],
    ) -> Result<()> {
        let meta = self.validate(name, inputs)?;
        if outs.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' produces {} outputs, caller supplied {}",
                meta.outputs.len(),
                outs.len()
            );
        }
        for (i, (o, spec)) in outs.iter().zip(&meta.outputs).enumerate() {
            let want = spec.shape.iter().product::<usize>().max(1);
            if o.len() != want {
                bail!(
                    "artifact '{name}' output {i}: buffer length {} != manifest {}",
                    o.len(),
                    want
                );
            }
        }
        #[cfg(feature = "pjrt")]
        {
            let exe = self.executable(name)?;
            let buffers: Vec<Rc<xla::PjRtBuffer>> = inputs
                .iter()
                .map(|v| self.device_buffer_view(v))
                .collect::<Result<_>>()?;
            let refs: Vec<&xla::PjRtBuffer> = buffers.iter().map(|b| b.as_ref()).collect();
            let result = exe.execute_b(&refs).map_err(wrap_xla)?;
            let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
            let parts = tuple.to_tuple().map_err(wrap_xla)?;
            if parts.len() != outs.len() {
                bail!("artifact '{name}': runtime returned {} outputs", parts.len());
            }
            for (p, o) in parts.iter().zip(outs.iter_mut()) {
                let v = p.to_vec::<f64>().map_err(wrap_xla)?;
                o.copy_from_slice(&v);
            }
            return Ok(());
        }
        #[cfg(not(feature = "pjrt"))]
        bail!("PJRT disabled (built without the `pjrt` feature)")
    }

    /// The compiled executable for `name`, compiling and caching on first
    /// use.
    #[cfg(feature = "pjrt")]
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?;
        let path = self.dir.join(&meta.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(wrap_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).map_err(wrap_xla)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Device buffer for a borrowed view — the single upload path of both
    /// `execute` and `execute_into`. Shared payloads (`*_cached` views,
    /// or shared [`Tensor`]s via `Tensor::view`'s refcount check — e.g. a
    /// problem's packed block cache) are uploaded once and cached by
    /// payload address; the cache co-owns the `Arc`, so the address stays
    /// valid for the cache's lifetime. Ephemeral views are uploaded per
    /// call.
    #[cfg(feature = "pjrt")]
    fn device_buffer_view(&self, v: &TensorView<'_>) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some(arc) = v.shared {
            self.cached_upload(arc, v.dims.as_slice())
        } else {
            Ok(Rc::new(
                self.client
                    .buffer_from_host_buffer::<f64>(v.data, v.dims.as_slice(), None)
                    .map_err(wrap_xla)?,
            ))
        }
    }

    #[cfg(feature = "pjrt")]
    fn cached_upload(
        &self,
        data: &std::sync::Arc<Vec<f64>>,
        dims: &[usize],
    ) -> Result<Rc<xla::PjRtBuffer>> {
        let key = std::sync::Arc::as_ptr(data) as usize;
        if let Some(buf) = self.buffers.borrow().get(&key) {
            return Ok(buf.clone());
        }
        let buf = Rc::new(
            self.client
                .buffer_from_host_buffer::<f64>(data, dims, None)
                .map_err(wrap_xla)?,
        );
        // Keep the payload alive so its address cannot be recycled while
        // the cached buffer exists.
        self.pinned.borrow_mut().push(data.clone());
        self.buffers.borrow_mut().insert(key, buf.clone());
        Ok(buf)
    }

    /// Number of compiled (cached) executables.
    pub fn compiled_count(&self) -> usize {
        #[cfg(feature = "pjrt")]
        return self.cache.borrow().len();
        #[cfg(not(feature = "pjrt"))]
        0
    }

    /// Number of cached device buffers.
    pub fn buffer_count(&self) -> usize {
        #[cfg(feature = "pjrt")]
        return self.buffers.borrow().len();
        #[cfg(not(feature = "pjrt"))]
        0
    }
}

/// Convert the xla crate's error (non-`Sync`) into an anyhow error.
#[cfg(feature = "pjrt")]
fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_constructors() {
        let v = Tensor::vec(vec![1.0, 2.0]);
        assert_eq!(v.dims, vec![2]);
        let m = Tensor::mat(vec![0.0; 6], 2, 3);
        assert_eq!(m.dims, vec![2, 3]);
        assert_eq!(m.len(), 6);
        let s = Tensor::scalar(5.0);
        assert!(s.dims.is_empty());
        assert_eq!(s.len(), 1);
        assert!(!s.is_empty());
    }

    #[test]
    #[should_panic]
    fn mat_size_checked() {
        Tensor::mat(vec![0.0; 5], 2, 3);
    }

    #[test]
    fn open_missing_dir_fails_helpfully() {
        let err = KernelRuntime::open("/nonexistent/artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn dims_shapes_and_matching() {
        assert_eq!(Dims::scalar().as_slice(), &[] as &[usize]);
        assert_eq!(Dims::vector(5).as_slice(), &[5]);
        assert_eq!(Dims::matrix(2, 3).as_slice(), &[2, 3]);
        assert_eq!(Dims::matrix(2, 3).len(), 6);
        assert_eq!(Dims::scalar().len(), 1);
        assert!(Dims::vector(4).matches(&[4]));
        assert!(!Dims::vector(4).matches(&[4, 1]));
        assert!(Dims::matrix(0, 3).is_empty());
        assert!(!Dims::vector(1).is_empty());
    }

    #[test]
    fn views_borrow_without_copying() {
        let buf = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let v = TensorView::vec_view(&buf);
        assert_eq!(v.dims.as_slice(), &[6]);
        assert!(std::ptr::eq(v.data.as_ptr(), buf.as_ptr()));
        let m = TensorView::mat_view(&buf, 2, 3);
        assert_eq!(m.dims.as_slice(), &[2, 3]);
        let x = 7.0;
        let s = TensorView::scalar_view(&x);
        assert_eq!(s.dims.len(), 1);
        assert!(s.dims.as_slice().is_empty());
    }

    #[test]
    fn cached_views_carry_shared_payload() {
        let arc = std::sync::Arc::new(vec![0.0; 12]);
        let m = TensorView::mat_cached(&arc, 3, 4);
        assert!(m.is_shared());
        let v = TensorView::vec_cached(&arc);
        assert_eq!(v.dims.as_slice(), &[12]);
        assert!(!TensorView::vec_view(&arc[..]).is_shared());
        // Tensor::view marks shared payloads only when another owner
        // exists (the block-cache pattern).
        let lone = Tensor::vec(vec![1.0]);
        assert!(!lone.view().is_shared());
        let t = Tensor::vec_shared(arc.clone());
        assert!(t.view().is_shared());
    }

    #[test]
    #[should_panic]
    fn mat_view_size_checked() {
        let buf = [0.0; 5];
        TensorView::mat_view(&buf, 2, 3);
    }
}
