//! LogP / LogGP baselines (Culler et al. 1993; Alexandrov et al. 1997 —
//! paper §2, refs [12], [38]).
//!
//! LogP charges a short message `o + L + o` and spaces consecutive sends by
//! the gap `g`; LogGP adds a per-byte gap `G` for long messages, making a
//! message of `m` bytes cost `o + (m−1)·G + L + o`.
//!
//! Instantiated on Algorithm 2 with tree collectives (depth `⌈log2 K⌉+…` as
//! in the LogP broadcast literature), LogGP predicts iteration times close
//! to the BSF model's — the point of the comparison is that neither LogP
//! nor LogGP *yields a closed-form scalability boundary*; the prediction
//! must be swept numerically, which is exactly what the paper's
//! introduction argues motivates BSF.

use crate::model::CostParams;

/// LogGP machine parameters (seconds; `big_g` per *word* to share the f64
/// vocabulary of the rest of the crate).
#[derive(Debug, Clone, Copy)]
pub struct LogGpParams {
    /// Wire latency `L`.
    pub l: f64,
    /// Per-message CPU overhead `o` (send or receive side).
    pub o: f64,
    /// Inter-message gap `g`.
    pub g: f64,
    /// Per-word gap `G` (long-message bandwidth term).
    pub big_g: f64,
}

impl LogGpParams {
    /// Cost of one message of `words` f64 under LogGP:
    /// `o + (words−1)·G + L + o`.
    pub fn message(&self, words: usize) -> f64 {
        let w = words.saturating_sub(1) as f64;
        self.o + w * self.big_g + self.l + self.o
    }

    /// Cost of `n` back-to-back messages of `words` each from one node:
    /// `(n−1)·g + message(words)` (LogP pipelining rule).
    pub fn pipelined(&self, n: usize, words: usize) -> f64 {
        (n.saturating_sub(1)) as f64 * self.g + self.message(words)
    }
}

/// LogGP prediction of one Algorithm-2 iteration with tree collectives.
#[derive(Debug, Clone, Copy)]
pub struct LogGpModel {
    /// Algorithm cost parameters.
    pub p: CostParams,
    /// Machine parameters.
    pub m: LogGpParams,
    /// Downlink payload words.
    pub words_down: usize,
    /// Uplink payload words.
    pub words_up: usize,
}

impl LogGpModel {
    /// Tree depth for K receivers.
    fn depth(k: usize) -> f64 {
        ((k + 1) as f64).log2().ceil()
    }

    /// Predicted time of one iteration with `k` workers.
    pub fn t_k(&self, k: usize) -> f64 {
        assert!(k >= 1);
        let kf = k as f64;
        let p = &self.p;
        let bcast = Self::depth(k) * self.m.message(self.words_down);
        let map = (p.t_map + (p.l as f64 - kf) * p.t_a) / kf;
        let reduce = Self::depth(k) * (self.m.message(self.words_up) + p.t_a);
        let post = p.t_p + self.m.message(0); // exit flag
        bcast + map + reduce + post
    }

    /// Predicted speedup `T_1 / T_K`.
    pub fn speedup(&self, k: usize) -> f64 {
        self.t_k(1) / self.t_k(k)
    }

    /// Numeric speedup peak over `K ∈ [1, k_max]`.
    pub fn k_peak(&self, k_max: usize) -> usize {
        (1..=k_max)
            .max_by(|&a, &b| {
                self.speedup(a)
                    .partial_cmp(&self.speedup(b))
                    .expect("finite speedups")
            })
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> LogGpParams {
        LogGpParams { l: 1.5e-5, o: 2e-6, g: 4e-6, big_g: 9.13e-8 }
    }

    fn model() -> LogGpModel {
        LogGpModel {
            p: CostParams { l: 10_000, t_c: 2.17e-3, t_p: 3.7e-5, t_map: 0.373, t_a: 9.31e-6 },
            m: machine(),
            words_down: 10_000,
            words_up: 10_000,
        }
    }

    #[test]
    fn message_cost_formula() {
        let m = machine();
        // o + (w-1)G + L + o
        let want = 2e-6 + 999.0 * 9.13e-8 + 1.5e-5 + 2e-6;
        assert!((m.message(1_000) - want).abs() < 1e-15);
        // zero/one-word messages cost the constant part only
        assert_eq!(m.message(0), m.message(1));
    }

    #[test]
    fn pipelined_adds_gaps() {
        let m = machine();
        let one = m.pipelined(1, 100);
        let five = m.pipelined(5, 100);
        assert!((five - one - 4.0 * m.g).abs() < 1e-15);
    }

    #[test]
    fn speedup_at_1_is_1() {
        assert!((model().speedup(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn peak_same_ballpark_as_bsf() {
        let lg = model();
        let bsf = crate::model::BsfModel::new(lg.p);
        let lg_peak = lg.k_peak(2_000) as f64;
        let bsf_peak = bsf.k_bsf();
        // Same communication structure, slightly different constants:
        // peaks agree within a factor of 2.
        let ratio = lg_peak / bsf_peak;
        assert!((0.5..2.0).contains(&ratio), "loggp={lg_peak} bsf={bsf_peak}");
    }

    #[test]
    fn unimodal_in_practice() {
        let lg = model();
        let pk = lg.k_peak(2_000);
        assert!(lg.speedup(pk) >= lg.speedup(pk.saturating_sub(10).max(1)));
        assert!(lg.speedup(pk) > lg.speedup(2_000));
    }
}
