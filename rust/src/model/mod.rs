//! Cost metrics — the analytical heart of the paper.
//!
//! * [`bsf`] — the BSF cost metric: per-iteration times `T_1` (eq. 7) and
//!   `T_K` (eq. 8), the speedup function `a_BSF(K)` (eq. 9) with its
//!   properties (10)–(12), and the closed-form scalability boundary
//!   `K_BSF` (Proposition 1 / eq. 14).
//! * [`bsp`] and [`logp`] — baseline models (Valiant's BSP; LogP/LogGP)
//!   instantiated on the same Algorithm-2 communication pattern, for the
//!   `baselines` comparison experiment. Neither yields a closed-form
//!   boundary — the paper's point — but both predict iteration times we
//!   can contrast with BSF's.
//! * [`calibrate`] — recover the cost parameters from live measurements on
//!   one master + one worker, the way the paper's §6 does (Table 2).
//! * [`scalability`] — speedup-curve utilities: peak finding over integer K,
//!   the prediction-error metric (eq. 26), and the `O(√n)` growth-law check
//!   (eqs. 24–25, 36–37).

pub mod bsf;
pub mod bsp;
pub mod calibrate;
pub mod logp;
pub mod scalability;

pub use bsf::{BsfModel, CostParams};
pub use calibrate::Calibration;
pub use scalability::{prediction_error, speedup_curve, SpeedupPoint};
