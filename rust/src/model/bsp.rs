//! The BSP baseline (Valiant 1990, paper §2).
//!
//! A BSP superstep costs `w + h·g + L_sync` where `w` is the local-compute
//! maximum, `h` the largest per-processor message volume of the h-relation,
//! `g` the per-word gap and `L_sync` the barrier cost. One Algorithm-2
//! iteration is two supersteps:
//!
//! 1. master broadcasts the approximation (h = K·words_down at the master),
//!    workers Map + locally Reduce;
//! 2. workers send partials (h = K·words_up at the master), master folds
//!    and post-processes.
//!
//! BSP has no notion of tree collectives — the h-relation is charged at the
//! congested root — so its predicted iteration time grows linearly in K and
//! its implied boundary is far more pessimistic than BSF's. That contrast
//! is the `baselines` experiment.

use crate::model::CostParams;

/// BSP machine parameters.
#[derive(Debug, Clone, Copy)]
pub struct BspParams {
    /// Per-word gap `g` (seconds/word).
    pub g: f64,
    /// Barrier synchronisation cost `L_sync` (seconds).
    pub l_sync: f64,
}

/// BSP prediction of one Algorithm-2 iteration.
#[derive(Debug, Clone, Copy)]
pub struct BspModel {
    /// Algorithm cost parameters (shared vocabulary with the BSF model).
    pub p: CostParams,
    /// Machine parameters.
    pub m: BspParams,
    /// Downlink payload words (approximation size).
    pub words_down: usize,
    /// Uplink payload words (partial folding size).
    pub words_up: usize,
}

impl BspModel {
    /// Predicted time of one iteration with `k` workers.
    ///
    /// Superstep 1: `w₁ = (t_Map + (l−k)·t_a)/k` (worker Map + local fold),
    /// `h₁ = k·words_down` at the master.
    /// Superstep 2: `w₂ = (k−1)·t_a + t_p` (master fold + post),
    /// `h₂ = k·words_up` at the master.
    pub fn t_k(&self, k: usize) -> f64 {
        assert!(k >= 1);
        let kf = k as f64;
        let p = &self.p;
        let w1 = (p.t_map + (p.l as f64 - kf) * p.t_a) / kf;
        let h1 = kf * self.words_down as f64;
        let w2 = (kf - 1.0) * p.t_a + p.t_p;
        let h2 = kf * self.words_up as f64;
        (w1 + h1 * self.m.g + self.m.l_sync) + (w2 + h2 * self.m.g + self.m.l_sync)
    }

    /// Predicted speedup `T_1 / T_K`.
    pub fn speedup(&self, k: usize) -> f64 {
        self.t_k(1) / self.t_k(k)
    }

    /// Numeric speedup peak over `K ∈ [1, k_max]` (BSP yields no closed
    /// form for this pattern — the paper's motivating observation).
    pub fn k_peak(&self, k_max: usize) -> usize {
        (1..=k_max)
            .max_by(|&a, &b| {
                self.speedup(a)
                    .partial_cmp(&self.speedup(b))
                    .expect("finite speedups")
            })
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> BspModel {
        BspModel {
            p: CostParams { l: 10_000, t_c: 2.17e-3, t_p: 3.7e-5, t_map: 0.373, t_a: 9.31e-6 },
            m: BspParams { g: 9.13e-8, l_sync: 3e-5 },
            words_down: 10_000,
            words_up: 10_000,
        }
    }

    #[test]
    fn t1_dominated_by_compute() {
        let m = model();
        let t1 = m.t_k(1);
        assert!(t1 > 0.37 && t1 < 0.6, "t1={t1}");
    }

    #[test]
    fn speedup_at_1_is_1() {
        assert!((model().speedup(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_h_relation_limits_scalability() {
        let m = model();
        // BSP's h-relation grows ~linearly in K at the root, so its peak
        // must come earlier than the BSF model's log-collective peak.
        let bsf = crate::model::BsfModel::new(m.p);
        let bsp_peak = m.k_peak(1_000);
        let bsf_peak = bsf.k_bsf();
        assert!(
            (bsp_peak as f64) < bsf_peak,
            "bsp={bsp_peak} bsf={bsf_peak:.0}"
        );
    }

    #[test]
    fn speedup_degrades_at_large_k() {
        let m = model();
        let pk = m.k_peak(1_000);
        assert!(m.speedup(pk) > m.speedup(1_000));
    }
}
