//! Speedup-curve analysis: peak finding, the eq.-(26) prediction error,
//! and the √n growth-law check (eqs. 24–25 / 36–37).

use crate::util::stats::argmax;

/// One point of a speedup curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeedupPoint {
    /// Worker count.
    pub k: usize,
    /// Iteration time at this K (seconds).
    pub t_k: f64,
    /// Speedup `a(K) = T_1 / T_K`.
    pub speedup: f64,
}

/// Build a speedup curve from an iteration-time function over the given Ks.
/// `T_1` is taken from the first entry of `ks` if it is 1, otherwise
/// evaluated separately.
pub fn speedup_curve(ks: &[usize], mut t_of_k: impl FnMut(usize) -> f64) -> Vec<SpeedupPoint> {
    let t1 = if ks.first() == Some(&1) { None } else { Some(t_of_k(1)) };
    let mut times: Vec<(usize, f64)> = ks.iter().map(|&k| (k, t_of_k(k))).collect();
    let t1 = t1.unwrap_or_else(|| times[0].1);
    times
        .drain(..)
        .map(|(k, t_k)| SpeedupPoint { k, t_k, speedup: t1 / t_k })
        .collect()
}

/// The K at which the curve peaks (the empirical scalability boundary
/// `K_test`). Returns `None` for an empty curve.
pub fn peak(curve: &[SpeedupPoint]) -> Option<SpeedupPoint> {
    let speeds: Vec<f64> = curve.iter().map(|p| p.speedup).collect();
    argmax(&speeds).map(|i| curve[i])
}

/// Peak of the moving-average-smoothed curve (window of `w` points,
/// centred). Near the boundary the speedup surface is a flat plateau with
/// integer-granularity sawtooth (collective-depth steps at powers of two,
/// chunk-size steps at divisors of `l`); raw argmax there is sensitive to
/// the sweep grid, exactly like reading a peak off the paper's Fig. 6/7.
/// Smoothing picks the centre of the plateau instead of a sawtooth tooth.
pub fn peak_smoothed(curve: &[SpeedupPoint], w: usize) -> Option<SpeedupPoint> {
    if curve.is_empty() {
        return None;
    }
    let half = w / 2;
    let smooth: Vec<f64> = (0..curve.len())
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(curve.len());
            curve[lo..hi].iter().map(|p| p.speedup).sum::<f64>() / (hi - lo) as f64
        })
        .collect();
    argmax(&smooth).map(|i| curve[i])
}

/// The *knee* of the smoothed curve: the smallest K whose smoothed speedup
/// reaches `frac` (e.g. 0.99) of the smoothed maximum.
///
/// Near the boundary the speedup surface is a plateau (the marginal value
/// of a node crosses zero slowly), so the raw argmax wanders over a wide
/// flat region — visibly so in the paper's own Fig. 6/7, where the
/// "measured" peaks are read off flat-topped curves on a coarse K grid.
/// The knee is the practically meaningful boundary: the smallest node
/// count achieving (within noise) peak throughput; every node beyond it is
/// wasted. We report it as `K_test`.
pub fn peak_knee(curve: &[SpeedupPoint], w: usize, frac: f64) -> Option<SpeedupPoint> {
    if curve.is_empty() {
        return None;
    }
    let half = w / 2;
    let smooth: Vec<f64> = (0..curve.len())
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(curve.len());
            curve[lo..hi].iter().map(|p| p.speedup).sum::<f64>() / (hi - lo) as f64
        })
        .collect();
    let max = smooth.iter().copied().fold(f64::MIN, f64::max);
    smooth
        .iter()
        .position(|&s| s >= frac * max)
        .map(|i| curve[i])
}

/// The K-range within `frac` of the smoothed maximum — the peak *plateau*.
/// Near the boundary the marginal value of a node crosses zero slowly, so
/// the curve is flat over a wide K span; reporting the span is the honest
/// summary (any point inside it is an equally valid "measured peak").
pub fn peak_plateau(curve: &[SpeedupPoint], w: usize, frac: f64) -> Option<(usize, usize)> {
    if curve.is_empty() {
        return None;
    }
    let half = w / 2;
    let smooth: Vec<f64> = (0..curve.len())
        .map(|i| {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(curve.len());
            curve[lo..hi].iter().map(|p| p.speedup).sum::<f64>() / (hi - lo) as f64
        })
        .collect();
    let max = smooth.iter().copied().fold(f64::MIN, f64::max);
    let lo = smooth.iter().position(|&s| s >= frac * max)?;
    let hi = smooth.iter().rposition(|&s| s >= frac * max)?;
    Some((curve[lo].k, curve[hi].k))
}

/// The paper's prediction-error metric (eq. 26):
/// `|K_test − K_BSF| / max(K_test, K_BSF)`.
pub fn prediction_error(k_test: f64, k_bsf: f64) -> f64 {
    if k_test == 0.0 && k_bsf == 0.0 {
        return 0.0;
    }
    (k_test - k_bsf).abs() / k_test.max(k_bsf)
}

/// Fit the exponent `p` of `K_max ≈ c · n^p` over (n, K_max) pairs by
/// least squares in log-log space. The paper's eqs. (25)/(37) predict
/// `p ≈ 0.5`.
pub fn growth_exponent(points: &[(f64, f64)]) -> f64 {
    assert!(points.len() >= 2, "need at least two points to fit");
    let logs: Vec<(f64, f64)> = points.iter().map(|&(n, k)| (n.ln(), k.ln())).collect();
    let m = logs.len() as f64;
    let sx: f64 = logs.iter().map(|p| p.0).sum();
    let sy: f64 = logs.iter().map(|p| p.1).sum();
    let sxx: f64 = logs.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = logs.iter().map(|p| p.0 * p.1).sum();
    (m * sxy - sx * sy) / (m * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_and_peak() {
        // iteration time: U-shaped in 1/k then rising (like eq. 8)
        let t = |k: usize| 1.0 / k as f64 + 0.001 * k as f64;
        let ks: Vec<usize> = (1..=100).collect();
        let curve = speedup_curve(&ks, t);
        assert_eq!(curve.len(), 100);
        assert!((curve[0].speedup - 1.0).abs() < 1e-12);
        let p = peak(&curve).unwrap();
        // minimum of 1/k + 0.001k is at k = sqrt(1000) ≈ 31.6
        assert!((30..=33).contains(&p.k), "peak at {}", p.k);
    }

    #[test]
    fn curve_without_k1_computes_t1() {
        let t = |k: usize| 1.0 / k as f64;
        let curve = speedup_curve(&[10, 20], t);
        assert!((curve[0].speedup - 10.0).abs() < 1e-12);
        assert!((curve[1].speedup - 20.0).abs() < 1e-12);
    }

    #[test]
    fn error_metric_eq26() {
        // Table 3's n=1500 row: K_test=40, K_BSF=47 -> 0.15
        assert!((prediction_error(40.0, 47.0) - 0.1489).abs() < 1e-3);
        // symmetric
        assert_eq!(prediction_error(47.0, 40.0), prediction_error(40.0, 47.0));
        // exact match
        assert_eq!(prediction_error(5.0, 5.0), 0.0);
        assert_eq!(prediction_error(0.0, 0.0), 0.0);
    }

    #[test]
    fn growth_exponent_recovers_sqrt() {
        let pts: Vec<(f64, f64)> = [100.0, 400.0, 1600.0, 6400.0]
            .iter()
            .map(|&n: &f64| (n, 3.0 * n.sqrt()))
            .collect();
        let p = growth_exponent(&pts);
        assert!((p - 0.5).abs() < 1e-12, "p={p}");
    }

    #[test]
    fn growth_exponent_linear_law() {
        let pts: Vec<(f64, f64)> = (1..=5).map(|i| (i as f64 * 100.0, i as f64 * 7.0)).collect();
        let p = growth_exponent(&pts);
        assert!((p - 1.0).abs() < 1e-9, "p={p}");
    }

    #[test]
    fn empty_peak_none() {
        assert!(peak(&[]).is_none());
        assert!(peak_smoothed(&[], 3).is_none());
    }

    #[test]
    fn smoothed_peak_ignores_sawtooth() {
        // Plateau centred at k=50 with a spurious tooth at k=80.
        let curve: Vec<SpeedupPoint> = (1..=100)
            .map(|k| {
                let base = 10.0 - ((k as f64 - 50.0) / 50.0).powi(2);
                let tooth = if k == 80 { 0.9 } else { 0.0 };
                SpeedupPoint { k, t_k: 1.0, speedup: base + tooth }
            })
            .collect();
        let raw = peak(&curve).unwrap();
        assert_eq!(raw.k, 80, "the tooth wins the raw argmax");
        let smooth = peak_smoothed(&curve, 5).unwrap();
        assert!((45..=55).contains(&smooth.k), "smoothed peak at {}", smooth.k);
    }

    #[test]
    fn smoothed_equals_raw_on_clean_curve() {
        let t = |k: usize| 1.0 / k as f64 + 0.001 * k as f64;
        let ks: Vec<usize> = (1..=100).collect();
        let curve = speedup_curve(&ks, t);
        let raw = peak(&curve).unwrap();
        let smooth = peak_smoothed(&curve, 3).unwrap();
        assert!((raw.k as i64 - smooth.k as i64).abs() <= 1);
    }
}
