//! The BSF cost metric (paper §4, eqs. 6–14).
//!
//! Given the per-iteration cost parameters measured (or derived) for an
//! algorithm, this module evaluates:
//!
//! * `T_1` — single-worker iteration time (eq. 7);
//! * `T_K` — K-worker iteration time (eq. 8), assuming `O(log K)` tree
//!   collectives and master-side folding of the K partials;
//! * `a_BSF(K) = T_1 / T_K` — the speedup function (eq. 9);
//! * `K_BSF` — the closed-form scalability boundary (Proposition 1,
//!   eq. 14), the paper's headline contribution: the number of workers at
//!   which the speedup peaks, computable **before any implementation**.

/// Per-iteration cost parameters of a BSF algorithm (paper §4).
///
/// All times in seconds. `t_rdc` is derived from `t_a` via eq. (6):
/// `t_a = t_Rdc / (l − 1)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostParams {
    /// Length `l` of the list A.
    pub l: usize,
    /// Master ↔ one-worker exchange time `t_c` (send approximation +
    /// receive folding, including both latencies).
    pub t_c: f64,
    /// Master post-processing time `t_p` (Compute + StopCond).
    pub t_p: f64,
    /// Whole-list Map time on one node, `t_Map`.
    pub t_map: f64,
    /// One application of `⊕`, `t_a`.
    pub t_a: f64,
}

impl CostParams {
    /// Whole-list Reduce time `t_Rdc = (l − 1) · t_a` (eq. 6 inverted).
    pub fn t_rdc(&self) -> f64 {
        (self.l.saturating_sub(1)) as f64 * self.t_a
    }

    /// The paper's computation/communication cost ratio (§6, Table 2):
    /// `comp = t_Map + (l−1)·t_a + t_p`, `comm = t_c`.
    pub fn comp_comm_ratio(&self) -> f64 {
        (self.t_map + self.t_rdc() + self.t_p) / self.t_c
    }
}

/// The BSF model over a set of cost parameters.
#[derive(Debug, Clone, Copy)]
pub struct BsfModel {
    /// The algorithm's cost parameters.
    pub p: CostParams,
}

impl BsfModel {
    /// Construct from cost parameters.
    pub fn new(p: CostParams) -> BsfModel {
        BsfModel { p }
    }

    /// `T_1 = t_p + t_c + t_Map + t_Rdc` — eq. (7).
    pub fn t1(&self) -> f64 {
        self.p.t_p + self.p.t_c + self.p.t_map + self.p.t_rdc()
    }

    /// `T_K` — eq. (8):
    ///
    /// ```text
    /// T_K = (K−1)·t_a + t_p + (log2(K)+1)·t_c + (t_Map + (l−K)·t_a)/K
    /// ```
    ///
    /// Reduces to eq. (7) at K = 1.
    pub fn t_k(&self, k: usize) -> f64 {
        assert!(k >= 1, "K must be at least 1");
        let kf = k as f64;
        let p = &self.p;
        (kf - 1.0) * p.t_a
            + p.t_p
            + (kf.log2() + 1.0) * p.t_c
            + (p.t_map + (p.l as f64 - kf) * p.t_a) / kf
    }

    /// `a_BSF(K) = T_1 / T_K` — eq. (9).
    pub fn speedup(&self, k: usize) -> f64 {
        self.t1() / self.t_k(k)
    }

    /// The scalability boundary `K_BSF` — Proposition 1 / eq. (14):
    ///
    /// ```text
    /// K_BSF = 1/2·sqrt( (t_c/(t_a·ln2))² + 4·(t_Map/t_a + l) ) − t_c/(2·t_a·ln2)
    /// ```
    ///
    /// (Roots of `−t_a·K² − (t_c/ln2)·K + t_Map + l·t_a = 0`; see note on
    /// eq. (14)'s radical below.) Requires `t_a > 0`; use
    /// [`BsfModel::k_bsf_numeric`] for the `t_a = 0` (Map-only) case.
    pub fn k_bsf(&self) -> f64 {
        let p = &self.p;
        assert!(p.t_a > 0.0, "closed form needs t_a > 0 (use k_bsf_numeric)");
        let c = p.t_c / (p.t_a * std::f64::consts::LN_2);
        // Quadratic −t_a K² − (t_c/ln2) K + (t_Map + l t_a) = 0
        //   ⇒ K = ( −(t_c/ln2) + sqrt((t_c/ln2)² + 4 t_a (t_Map + l t_a)) ) / (2 t_a)
        //        = 1/2 sqrt(c² + 4 (t_Map/t_a + l)) − c/2.
        //
        // NOTE: the paper prints the radical as `(c)² + t_Map/t_a + 4l`
        // with the −c term un-halved; solving its own quadratic (p. 17)
        // gives the form used here. The two agree in the regimes the paper
        // evaluates (where t_Map/t_a ≈ l ≫ c) — see tests below, which
        // reproduce Table 3/4's K_BSF values from Table 2's parameters.
        0.5 * (c * c + 4.0 * (p.t_map / p.t_a + p.l as f64)).sqrt() - 0.5 * c
    }

    /// Numeric argmax of the speedup over integer `K ∈ [1, k_max]` —
    /// model-agnostic peak finding (works for `t_a = 0` too).
    pub fn k_bsf_numeric(&self, k_max: usize) -> usize {
        let mut best_k = 1;
        let mut best = self.speedup(1);
        for k in 2..=k_max {
            let s = self.speedup(k);
            if s > best {
                best = s;
                best_k = k;
            }
        }
        best_k
    }

    /// Property (12): the communication-bound limit of the speedup,
    /// `lim_{t_comp→0} a_BSF(K) = 1 / (log2(K) + 1)`.
    pub fn comm_bound_limit(k: usize) -> f64 {
        1.0 / ((k as f64).log2() + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 2 cost parameters for the BSF-Jacobi runs.
    pub(crate) fn table2(n: usize) -> CostParams {
        let (t_c, t_p, t_a, t_map) = match n {
            1_500 => (7.20e-5, 5.01e-6, 1.89e-6, 6.23e-3),
            5_000 => (1.06e-3, 1.72e-5, 5.27e-6, 9.28e-2),
            10_000 => (2.17e-3, 3.70e-5, 9.31e-6, 3.73e-1),
            16_000 => (2.95e-3, 5.61e-5, 2.10e-5, 7.73e-1),
            _ => panic!("no Table 2 entry for n={n}"),
        };
        CostParams { l: n, t_c, t_p, t_map, t_a }
    }

    #[test]
    fn tk_at_1_equals_t1() {
        let m = BsfModel::new(table2(5_000));
        assert!((m.t_k(1) - m.t1()).abs() < 1e-15);
    }

    #[test]
    fn property_10_speedup_at_1_is_1() {
        for n in [1_500, 5_000, 10_000, 16_000] {
            let m = BsfModel::new(table2(n));
            assert!((m.speedup(1) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn property_11_speedup_positive() {
        let m = BsfModel::new(table2(10_000));
        for k in [1usize, 2, 10, 100, 1000, 10_000] {
            assert!(m.speedup(k) > 0.0, "k={k}");
        }
    }

    #[test]
    fn property_12_comm_bound_limit() {
        // As t_comp -> 0 the speedup tends to 1/(log2 K + 1).
        let mut p = table2(5_000);
        p.t_map = 1e-15;
        p.t_a = 1e-18;
        p.t_p = 1e-15;
        let m = BsfModel::new(p);
        for k in [2usize, 8, 64, 512] {
            let lim = BsfModel::comm_bound_limit(k);
            assert!(
                (m.speedup(k) - lim).abs() / lim < 1e-3,
                "k={k}: {} vs {}",
                m.speedup(k),
                lim
            );
        }
    }

    /// The headline reproduction check: eq. (14) on Table 2's measured
    /// parameters must give Table 3's published boundaries (47/64/112/150,
    /// allowing ±2 for the paper's rounding of the inputs).
    #[test]
    fn k_bsf_reproduces_table3() {
        for (n, want) in [(1_500usize, 47.0), (5_000, 64.0), (10_000, 112.0), (16_000, 150.0)] {
            let m = BsfModel::new(table2(n));
            let got = m.k_bsf();
            assert!(
                (got - want).abs() <= 2.0,
                "n={n}: K_BSF={got:.1}, paper says {want}"
            );
        }
    }

    #[test]
    fn closed_form_matches_numeric_argmax() {
        for n in [1_500usize, 5_000, 10_000, 16_000] {
            let m = BsfModel::new(table2(n));
            let closed = m.k_bsf();
            let numeric = m.k_bsf_numeric(2_000) as f64;
            // integer argmax within 1 of the real-valued optimum
            assert!(
                (closed - numeric).abs() <= 1.0,
                "n={n}: closed={closed:.2} numeric={numeric}"
            );
        }
    }

    #[test]
    fn speedup_unimodal_proposition1() {
        // Rising before the boundary, falling after (Proposition 1).
        let m = BsfModel::new(table2(10_000));
        let peak = m.k_bsf().round() as usize;
        for k in 2..peak {
            assert!(m.speedup(k) > m.speedup(k - 1), "rising at k={k}");
        }
        for k in (peak + 2)..(peak + 500) {
            assert!(m.speedup(k) < m.speedup(k - 1), "falling at k={k}");
        }
    }

    #[test]
    fn t_rdc_eq6() {
        let p = CostParams { l: 101, t_c: 1.0, t_p: 0.0, t_map: 0.0, t_a: 0.5 };
        assert_eq!(p.t_rdc(), 50.0);
    }

    #[test]
    fn comp_comm_ratio_matches_table2() {
        // Table 2's comp/comm row: 126, 113, 215, 376.
        for (n, want) in [(1_500usize, 126.0), (5_000, 113.0), (10_000, 215.0), (16_000, 376.0)] {
            let r = table2(n).comp_comm_ratio();
            assert!(
                (r - want).abs() / want < 0.06,
                "n={n}: comp/comm={r:.0}, paper says {want}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "t_a > 0")]
    fn k_bsf_requires_positive_ta() {
        let p = CostParams { l: 100, t_c: 1.0, t_p: 0.0, t_map: 1.0, t_a: 0.0 };
        BsfModel::new(p).k_bsf();
    }

    #[test]
    fn map_only_numeric_boundary() {
        // t_a = 0 (Map-only algorithm, §7 Q2): numeric peak still exists
        // because of the log2(K) t_c term.
        let p = CostParams { l: 10_000, t_c: 1e-4, t_p: 1e-6, t_map: 1e-1, t_a: 0.0 };
        let m = BsfModel::new(p);
        let k = m.k_bsf_numeric(5_000);
        assert!(k > 10 && k < 5_000, "k={k}");
        assert!(m.speedup(k) > m.speedup(k * 2), "degrades past peak");
    }
}
