//! The BSF cost metric (paper §4, eqs. 6–14).
//!
//! Given the per-iteration cost parameters measured (or derived) for an
//! algorithm, this module evaluates:
//!
//! * `T_1` — single-worker iteration time (eq. 7);
//! * `T_K` — K-worker iteration time (eq. 8), assuming `O(log K)` tree
//!   collectives and master-side folding of the K partials;
//! * `a_BSF(K) = T_1 / T_K` — the speedup function (eq. 9);
//! * `K_BSF` — the closed-form scalability boundary (Proposition 1,
//!   eq. 14), the paper's headline contribution: the number of workers at
//!   which the speedup peaks, computable **before any implementation**.

/// Per-iteration cost parameters of a BSF algorithm (paper §4).
///
/// All times in seconds. `t_rdc` is derived from `t_a` via eq. (6):
/// `t_a = t_Rdc / (l − 1)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostParams {
    /// Length `l` of the list A.
    pub l: usize,
    /// Master ↔ one-worker exchange time `t_c` (send approximation +
    /// receive folding, including both latencies).
    pub t_c: f64,
    /// Master post-processing time `t_p` (Compute + StopCond).
    pub t_p: f64,
    /// Whole-list Map time on one node, `t_Map`.
    pub t_map: f64,
    /// One application of `⊕`, `t_a`.
    pub t_a: f64,
}

impl CostParams {
    /// Whole-list Reduce time `t_Rdc = (l − 1) · t_a` (eq. 6 inverted).
    pub fn t_rdc(&self) -> f64 {
        (self.l.saturating_sub(1)) as f64 * self.t_a
    }

    /// The paper's computation/communication cost ratio (§6, Table 2):
    /// `comp = t_Map + (l−1)·t_a + t_p`, `comm = t_c`.
    pub fn comp_comm_ratio(&self) -> f64 {
        (self.t_map + self.t_rdc() + self.t_p) / self.t_c
    }
}

/// The BSF model over a set of cost parameters.
#[derive(Debug, Clone, Copy)]
pub struct BsfModel {
    /// The algorithm's cost parameters.
    pub p: CostParams,
}

impl BsfModel {
    /// Construct from cost parameters.
    pub fn new(p: CostParams) -> BsfModel {
        BsfModel { p }
    }

    /// `T_1 = t_p + t_c + t_Map + t_Rdc` — eq. (7).
    pub fn t1(&self) -> f64 {
        self.p.t_p + self.p.t_c + self.p.t_map + self.p.t_rdc()
    }

    /// `T_K` — eq. (8):
    ///
    /// ```text
    /// T_K = (K−1)·t_a + t_p + (log2(K)+1)·t_c + (t_Map + (l−K)·t_a)/K
    /// ```
    ///
    /// Reduces to eq. (7) at K = 1.
    pub fn t_k(&self, k: usize) -> f64 {
        assert!(k >= 1, "K must be at least 1");
        let kf = k as f64;
        let p = &self.p;
        (kf - 1.0) * p.t_a
            + p.t_p
            + (kf.log2() + 1.0) * p.t_c
            + (p.t_map + (p.l as f64 - kf) * p.t_a) / kf
    }

    /// `a_BSF(K) = T_1 / T_K` — eq. (9).
    pub fn speedup(&self, k: usize) -> f64 {
        self.t1() / self.t_k(k)
    }

    /// The scalability boundary `K_BSF` — Proposition 1 / eq. (14):
    ///
    /// ```text
    /// K_BSF = 1/2·sqrt( (t_c/(t_a·ln2))² + 4·(t_Map/t_a + l) ) − t_c/(2·t_a·ln2)
    /// ```
    ///
    /// (Roots of `−t_a·K² − (t_c/ln2)·K + t_Map + l·t_a = 0`; see note on
    /// eq. (14)'s radical below.) Requires `t_a > 0`; use
    /// [`BsfModel::k_bsf_numeric`] for the `t_a = 0` (Map-only) case.
    pub fn k_bsf(&self) -> f64 {
        let p = &self.p;
        assert!(p.t_a > 0.0, "closed form needs t_a > 0 (use k_bsf_numeric)");
        let c = p.t_c / (p.t_a * std::f64::consts::LN_2);
        // Quadratic −t_a K² − (t_c/ln2) K + (t_Map + l t_a) = 0
        //   ⇒ K = ( −(t_c/ln2) + sqrt((t_c/ln2)² + 4 t_a (t_Map + l t_a)) ) / (2 t_a)
        //        = 1/2 sqrt(c² + 4 (t_Map/t_a + l)) − c/2.
        //
        // NOTE: the paper prints the radical as `(c)² + t_Map/t_a + 4l`
        // with the −c term un-halved; solving its own quadratic (p. 17)
        // gives the form used here. The two agree in the regimes the paper
        // evaluates (where t_Map/t_a ≈ l ≫ c) — see tests below, which
        // reproduce Table 3/4's K_BSF values from Table 2's parameters.
        0.5 * (c * c + 4.0 * (p.t_map / p.t_a + p.l as f64)).sqrt() - 0.5 * c
    }

    /// Numeric argmax of the speedup over integer `K ∈ [1, k_max]` —
    /// model-agnostic peak finding (works for `t_a = 0` too).
    pub fn k_bsf_numeric(&self, k_max: usize) -> usize {
        let mut best_k = 1;
        let mut best = self.speedup(1);
        for k in 2..=k_max {
            let s = self.speedup(k);
            if s > best {
                best = s;
                best_k = k;
            }
        }
        best_k
    }

    /// Property (12): the communication-bound limit of the speedup,
    /// `lim_{t_comp→0} a_BSF(K) = 1 / (log2(K) + 1)`.
    pub fn comm_bound_limit(k: usize) -> f64 {
        1.0 / ((k as f64).log2() + 1.0)
    }

    /// `T_K` on a contended shared link: every `t_c` term of eq. (8) is
    /// stretched by `factor ≥ 1` (the bandwidth-splitting slowdown of the
    /// simulator's [`crate::net::LinkMode::Shared`] mode, aggregated into
    /// one scalar). `factor == 1.0` routes through [`BsfModel::t_k`]
    /// unchanged — bitwise identical to the per-edge model.
    pub fn t_k_contended(&self, k: usize, factor: f64) -> f64 {
        if factor == 1.0 {
            return self.t_k(k);
        }
        assert!(factor > 0.0, "contention factor must be positive");
        let mut p = self.p;
        p.t_c *= factor;
        BsfModel::new(p).t_k(k)
    }

    /// Eq. (14) under link contention: the boundary for `t_c` stretched
    /// by `factor`. Since `c = t_c/(t_a ln2)` grows linearly with the
    /// factor, the boundary can only shrink — contention always moves K*
    /// down. `factor == 1.0` routes through [`BsfModel::k_bsf`] bitwise.
    pub fn k_bsf_contended(&self, factor: f64) -> f64 {
        if factor == 1.0 {
            return self.k_bsf();
        }
        assert!(factor > 0.0, "contention factor must be positive");
        let mut p = self.p;
        p.t_c *= factor;
        BsfModel::new(p).k_bsf()
    }

    /// Expected per-iteration cost of checkpoint/restart recovery at
    /// interval `iv` (first-order model, failures rare and independent):
    ///
    /// ```text
    /// E[T] = T_K + t_save/iv + λ · (iv − 1)/2 · T_K
    /// ```
    ///
    /// — the amortised snapshot cost plus the expected rework (a failure
    /// lands uniformly inside the interval, so on average `(iv − 1)/2`
    /// iterations are rolled back and re-executed). With `λ = 0` and
    /// `t_save = 0` this is exactly `T_K` (one float add of `0.0` twice —
    /// bitwise identity is pinned in tests).
    pub fn t_k_checkpoint(&self, k: usize, interval: u64, fail_rate: f64, t_save: f64) -> f64 {
        let iv = interval.max(1) as f64;
        let t_k = self.t_k(k);
        t_k + t_save / iv + fail_rate * ((iv - 1.0) / 2.0) * t_k
    }

    /// Young's approximation of the cost-optimal checkpoint interval (in
    /// iterations): the argmin of [`BsfModel::t_k_checkpoint`] over real
    /// `iv`, `iv* = sqrt(2·t_save / (λ·T_K))`. Decreasing in the failure
    /// rate `λ` — more failures, tighter checkpoints. Returns `+∞` when
    /// `λ ≤ 0` (no failures: never snapshot).
    pub fn optimal_checkpoint_interval(&self, k: usize, fail_rate: f64, t_save: f64) -> f64 {
        if fail_rate <= 0.0 {
            return f64::INFINITY;
        }
        (2.0 * t_save / (fail_rate * self.t_k(k))).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 2 cost parameters for the BSF-Jacobi runs.
    pub(crate) fn table2(n: usize) -> CostParams {
        let (t_c, t_p, t_a, t_map) = match n {
            1_500 => (7.20e-5, 5.01e-6, 1.89e-6, 6.23e-3),
            5_000 => (1.06e-3, 1.72e-5, 5.27e-6, 9.28e-2),
            10_000 => (2.17e-3, 3.70e-5, 9.31e-6, 3.73e-1),
            16_000 => (2.95e-3, 5.61e-5, 2.10e-5, 7.73e-1),
            _ => panic!("no Table 2 entry for n={n}"),
        };
        CostParams { l: n, t_c, t_p, t_map, t_a }
    }

    #[test]
    fn tk_at_1_equals_t1() {
        let m = BsfModel::new(table2(5_000));
        assert!((m.t_k(1) - m.t1()).abs() < 1e-15);
    }

    #[test]
    fn property_10_speedup_at_1_is_1() {
        for n in [1_500, 5_000, 10_000, 16_000] {
            let m = BsfModel::new(table2(n));
            assert!((m.speedup(1) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn property_11_speedup_positive() {
        let m = BsfModel::new(table2(10_000));
        for k in [1usize, 2, 10, 100, 1000, 10_000] {
            assert!(m.speedup(k) > 0.0, "k={k}");
        }
    }

    #[test]
    fn property_12_comm_bound_limit() {
        // As t_comp -> 0 the speedup tends to 1/(log2 K + 1).
        let mut p = table2(5_000);
        p.t_map = 1e-15;
        p.t_a = 1e-18;
        p.t_p = 1e-15;
        let m = BsfModel::new(p);
        for k in [2usize, 8, 64, 512] {
            let lim = BsfModel::comm_bound_limit(k);
            assert!(
                (m.speedup(k) - lim).abs() / lim < 1e-3,
                "k={k}: {} vs {}",
                m.speedup(k),
                lim
            );
        }
    }

    /// The headline reproduction check: eq. (14) on Table 2's measured
    /// parameters must give Table 3's published boundaries (47/64/112/150,
    /// allowing ±2 for the paper's rounding of the inputs).
    #[test]
    fn k_bsf_reproduces_table3() {
        for (n, want) in [(1_500usize, 47.0), (5_000, 64.0), (10_000, 112.0), (16_000, 150.0)] {
            let m = BsfModel::new(table2(n));
            let got = m.k_bsf();
            assert!(
                (got - want).abs() <= 2.0,
                "n={n}: K_BSF={got:.1}, paper says {want}"
            );
        }
    }

    #[test]
    fn closed_form_matches_numeric_argmax() {
        for n in [1_500usize, 5_000, 10_000, 16_000] {
            let m = BsfModel::new(table2(n));
            let closed = m.k_bsf();
            let numeric = m.k_bsf_numeric(2_000) as f64;
            // integer argmax within 1 of the real-valued optimum
            assert!(
                (closed - numeric).abs() <= 1.0,
                "n={n}: closed={closed:.2} numeric={numeric}"
            );
        }
    }

    #[test]
    fn speedup_unimodal_proposition1() {
        // Rising before the boundary, falling after (Proposition 1).
        let m = BsfModel::new(table2(10_000));
        let peak = m.k_bsf().round() as usize;
        for k in 2..peak {
            assert!(m.speedup(k) > m.speedup(k - 1), "rising at k={k}");
        }
        for k in (peak + 2)..(peak + 500) {
            assert!(m.speedup(k) < m.speedup(k - 1), "falling at k={k}");
        }
    }

    #[test]
    fn t_rdc_eq6() {
        let p = CostParams { l: 101, t_c: 1.0, t_p: 0.0, t_map: 0.0, t_a: 0.5 };
        assert_eq!(p.t_rdc(), 50.0);
    }

    #[test]
    fn comp_comm_ratio_matches_table2() {
        // Table 2's comp/comm row: 126, 113, 215, 376.
        for (n, want) in [(1_500usize, 126.0), (5_000, 113.0), (10_000, 215.0), (16_000, 376.0)] {
            let r = table2(n).comp_comm_ratio();
            assert!(
                (r - want).abs() / want < 0.06,
                "n={n}: comp/comm={r:.0}, paper says {want}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "t_a > 0")]
    fn k_bsf_requires_positive_ta() {
        let p = CostParams { l: 100, t_c: 1.0, t_p: 0.0, t_map: 1.0, t_a: 0.0 };
        BsfModel::new(p).k_bsf();
    }

    #[test]
    fn contention_factor_one_is_bitwise_identity() {
        let m = BsfModel::new(table2(10_000));
        for k in [1usize, 8, 64, 512] {
            assert_eq!(m.t_k_contended(k, 1.0).to_bits(), m.t_k(k).to_bits());
        }
        assert_eq!(m.k_bsf_contended(1.0).to_bits(), m.k_bsf().to_bits());
    }

    #[test]
    fn contention_shrinks_the_boundary() {
        let m = BsfModel::new(table2(10_000));
        let clean = m.k_bsf();
        let mut prev = clean;
        for factor in [2.0, 4.0, 8.0] {
            let contended = m.k_bsf_contended(factor);
            assert!(contended < prev, "factor={factor}: {contended} !< {prev}");
            prev = contended;
        }
        // And T_K only grows under contention.
        assert!(m.t_k_contended(64, 4.0) > m.t_k(64));
    }

    #[test]
    fn checkpoint_cost_reduces_to_tk_without_failures() {
        let m = BsfModel::new(table2(5_000));
        for k in [1usize, 16, 64] {
            let base = m.t_k(k);
            assert_eq!(m.t_k_checkpoint(k, 8, 0.0, 0.0).to_bits(), base.to_bits());
            // A pure snapshot cost amortises exactly.
            let with_save = m.t_k_checkpoint(k, 4, 0.0, 1e-3);
            assert!((with_save - (base + 1e-3 / 4.0)).abs() < 1e-15);
        }
    }

    #[test]
    fn optimal_interval_decreases_with_failure_rate() {
        let m = BsfModel::new(table2(5_000));
        let t_save = m.p.t_c; // snapshot priced like one exchange
        let lo = m.optimal_checkpoint_interval(16, 0.02, t_save);
        let hi = m.optimal_checkpoint_interval(16, 0.08, t_save);
        assert!(hi < lo, "λ=0.08 gives iv*={hi}, λ=0.02 gives iv*={lo}");
        assert!(m.optimal_checkpoint_interval(16, 0.0, t_save).is_infinite());
        // Young's iv* is the argmin of the expected-cost curve: the grid
        // argmin of t_k_checkpoint must bracket it.
        let grid: Vec<u64> = (1..=64).collect();
        let argmin = *grid
            .iter()
            .min_by(|&&a, &&b| {
                m.t_k_checkpoint(16, a, 0.05, t_save)
                    .partial_cmp(&m.t_k_checkpoint(16, b, 0.05, t_save))
                    .expect("finite")
            })
            .expect("non-empty grid");
        let young = m.optimal_checkpoint_interval(16, 0.05, t_save);
        assert!(
            (argmin as f64 - young).abs() <= 1.5,
            "grid argmin {argmin} vs Young {young:.2}"
        );
    }

    #[test]
    fn map_only_numeric_boundary() {
        // t_a = 0 (Map-only algorithm, §7 Q2): numeric peak still exists
        // because of the log2(K) t_c term.
        let p = CostParams { l: 10_000, t_c: 1e-4, t_p: 1e-6, t_map: 1e-1, t_a: 0.0 };
        let m = BsfModel::new(p);
        let k = m.k_bsf_numeric(5_000);
        assert!(k > 10 && k < 5_000, "k={k}");
        assert!(m.speedup(k) > m.speedup(k * 2), "degrades past peak");
    }
}
