//! Calibration: recovering the BSF cost parameters from measurements.
//!
//! The paper determines Table 2's values "experimentally … using a
//! configuration with one master and one worker" (§6) and prescribes the
//! measure-and-divide recipe for multicore nodes (§7, Q6). This module
//! implements that recipe over per-step timing samples produced by the
//! live runner's [`crate::coordinator::StepMetrics`]:
//!
//! * `t_Map`  — median worker Map time over the whole list;
//! * `t_a`    — median time per `⊕` application (measured over a batch and
//!   divided, §7's recipe);
//! * `t_p`    — median master Compute+StopCond time;
//! * `t_c`    — from the network parameters and payload sizes
//!   (eq. 20 shape), or measured round-trip when available.

use crate::model::CostParams;
use crate::net::NetworkParams;
use crate::util::stats::Summary;

/// Raw timing samples from a calibration run (one master + one worker).
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    /// Whole-list Map durations per iteration (seconds).
    pub map_samples: Vec<f64>,
    /// Whole-list local-Reduce durations per iteration (seconds); divided
    /// by `l − 1` to obtain `t_a` (eq. 6).
    pub reduce_samples: Vec<f64>,
    /// Master post-processing durations per iteration (seconds).
    pub post_samples: Vec<f64>,
    /// Measured master↔worker exchange durations per iteration, if the
    /// transport exposes them (the in-process fabric's are not
    /// representative of a cluster, so `params_with_net` is preferred).
    pub comm_samples: Vec<f64>,
    /// List length.
    pub l: usize,
}

impl Calibration {
    /// Robust location estimate used throughout (median — timing samples
    /// are right-skewed by OS noise).
    fn location(samples: &[f64]) -> f64 {
        Summary::of(samples).median
    }

    /// Derive [`CostParams`] charging communication from the postal network
    /// model (`t_c = p2p(words_down) + p2p(words_up)`, eq. 20's shape) —
    /// the standard path when simulating a target cluster.
    pub fn params_with_net(
        &self,
        net: &NetworkParams,
        words_down: usize,
        words_up: usize,
    ) -> CostParams {
        assert!(self.l >= 2, "need l >= 2");
        CostParams {
            l: self.l,
            t_c: net.t_c(words_down, words_up),
            t_p: Self::location(&self.post_samples),
            t_map: Self::location(&self.map_samples),
            t_a: Self::location(&self.reduce_samples) / (self.l - 1) as f64,
        }
    }

    /// Derive [`CostParams`] using measured round-trip samples for `t_c`
    /// (only meaningful when the transport is a real interconnect).
    pub fn params_measured(&self) -> CostParams {
        assert!(self.l >= 2, "need l >= 2");
        assert!(!self.comm_samples.is_empty(), "no comm samples recorded");
        CostParams {
            l: self.l,
            t_c: Self::location(&self.comm_samples),
            t_p: Self::location(&self.post_samples),
            t_map: Self::location(&self.map_samples),
            t_a: Self::location(&self.reduce_samples) / (self.l - 1) as f64,
        }
    }

    /// Relative spread (CV) of the Map samples — used to set the
    /// simulator's compute-jitter sigma.
    pub fn map_jitter_sigma(&self) -> f64 {
        Summary::of(&self.map_samples).cv()
    }

    /// Merge samples from another calibration run (e.g. repeated trials).
    pub fn merge(&mut self, other: &Calibration) {
        assert_eq!(self.l, other.l, "cannot merge different list lengths");
        self.map_samples.extend_from_slice(&other.map_samples);
        self.reduce_samples.extend_from_slice(&other.reduce_samples);
        self.post_samples.extend_from_slice(&other.post_samples);
        self.comm_samples.extend_from_slice(&other.comm_samples);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> Calibration {
        Calibration {
            map_samples: vec![0.10, 0.11, 0.09, 0.10, 0.50], // one outlier
            reduce_samples: vec![0.099, 0.101, 0.100],
            post_samples: vec![1e-4, 1.2e-4, 0.8e-4],
            comm_samples: vec![2e-3, 2.2e-3, 1.8e-3],
            l: 101,
        }
    }

    #[test]
    fn median_resists_outliers() {
        let p = cal().params_with_net(&NetworkParams::tornado_susu(), 101, 101);
        assert!((p.t_map - 0.10).abs() < 1e-12, "t_map={}", p.t_map);
    }

    #[test]
    fn t_a_divides_by_l_minus_1() {
        let p = cal().params_with_net(&NetworkParams::tornado_susu(), 101, 101);
        assert!((p.t_a - 0.100 / 100.0).abs() < 1e-12);
    }

    #[test]
    fn t_c_from_postal_model() {
        let net = NetworkParams { latency: 1e-5, tau_tr: 1e-8, link: crate::net::LinkMode::PerEdge };
        let p = cal().params_with_net(&net, 1000, 1000);
        assert!((p.t_c - net.t_c(1000, 1000)).abs() < 1e-18);
    }

    #[test]
    fn measured_t_c_uses_samples() {
        let p = cal().params_measured();
        assert!((p.t_c - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn merge_concatenates() {
        let mut a = cal();
        let b = cal();
        a.merge(&b);
        assert_eq!(a.map_samples.len(), 10);
    }

    #[test]
    #[should_panic(expected = "different list lengths")]
    fn merge_checks_l() {
        let mut a = cal();
        let mut b = cal();
        b.l = 5;
        a.merge(&b);
    }

    #[test]
    fn jitter_sigma_nonzero_for_noisy_samples() {
        assert!(cal().map_jitter_sigma() > 0.1);
    }
}
