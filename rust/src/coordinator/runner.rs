//! Runners: sequential (Algorithm 1) and live master/worker (Algorithm 2).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{BsfProblem, IterationMetrics, Metrics, Workspace};
use crate::lists::partition_even;
use crate::model::Calibration;
use crate::net::transport::{fabric, Downlink, TransportError, Uplink};
use crate::runtime::KernelRuntime;
use crate::util::Timer;

/// Outcome of a run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Iterations executed (including the final one).
    pub iterations: usize,
    /// The final approximation (downlink encoding).
    pub final_approx: Vec<f64>,
    /// True if the run stopped because `StopCond` fired (vs the iteration
    /// cap).
    pub converged: bool,
    /// Per-iteration timings.
    pub metrics: Metrics,
    /// Total wall time (seconds).
    pub wall: f64,
}

/// Algorithm 1 — the sequential reference execution. Ground truth for every
/// parallel runner: `LiveRunner` must produce identical approximations
/// (up to fold-order roundoff).
pub fn run_sequential(
    problem: &dyn BsfProblem,
    max_iters: usize,
    kernels: Option<&KernelRuntime>,
) -> RunReport {
    let timer = Timer::start();
    let l = problem.list_len();
    let mut x = problem.initial_approx();
    // Reused across iterations: the fold buffer and the problem workspace
    // keep the whole loop allocation-free on the map side.
    let mut s = problem.fold_identity();
    let mut ws = Workspace::new();
    let mut iterations = 0;
    let mut converged = false;
    let mut metrics = Metrics::default();
    while iterations < max_iters {
        let mut it_timer = Timer::start();
        problem.map_fold_into(0..l, &x, &mut s, &mut ws, kernels);
        let map_time = it_timer.lap();
        let (next, stop) = problem.post(&x, &s, iterations);
        let post_time = it_timer.lap();
        x = next;
        iterations += 1;
        metrics.iterations.push(IterationMetrics {
            comm: 0.0,
            map_fold: vec![map_time],
            master_fold: 0.0,
            post: post_time,
            total: map_time + post_time,
        });
        if stop {
            converged = true;
            break;
        }
    }
    RunReport { iterations, final_approx: x, converged, metrics, wall: timer.elapsed() }
}

/// Algorithm 2 over real threads — the live BSF skeleton.
#[derive(Debug, Clone)]
pub struct LiveRunner {
    /// Worker count K.
    pub k: usize,
    /// Iteration cap (StopCond may fire earlier).
    pub max_iters: usize,
    /// Artifact directory for per-worker PJRT runtimes (`None` = native
    /// Rust compute only).
    pub artifact_dir: Option<PathBuf>,
    /// Bound on each gather (worker failure detection).
    pub gather_timeout: Duration,
    /// Degraded-mode recovery: when a worker dies (panic / hang past the
    /// gather timeout), the master marks it dead, computes its sublist
    /// itself from then on, and the iteration stream continues — the
    /// result is identical because Map is deterministic and `⊕` is
    /// associative. Off by default (a dead worker aborts the run, like
    /// `MPI_ERRORS_ARE_FATAL`).
    pub fault_tolerant: bool,
}

impl LiveRunner {
    /// Runner with defaults (no artifacts, 60 s gather timeout).
    pub fn new(k: usize, max_iters: usize) -> LiveRunner {
        LiveRunner {
            k,
            max_iters,
            artifact_dir: None,
            gather_timeout: Duration::from_secs(60),
            fault_tolerant: false,
        }
    }

    /// Use AOT artifacts from `dir` on the worker hot path.
    pub fn with_artifacts(mut self, dir: impl Into<PathBuf>) -> LiveRunner {
        self.artifact_dir = Some(dir.into());
        self
    }

    /// Execute Algorithm 2. Spawns K worker threads, runs the master loop
    /// on the calling thread, joins everything before returning.
    ///
    /// Worker steady state is **allocation-free**: the fold buffer
    /// double-buffers through the uplink (sent by move, returned via the
    /// next downlink's `reuse`), the map+fold writes into it in place, and
    /// the uplink slot send performs no allocation (see
    /// [`crate::net::transport`]).
    pub fn run(&self, problem: Arc<dyn BsfProblem>) -> Result<RunReport> {
        if self.k == 0 {
            bail!("LiveRunner needs at least one worker");
        }
        let timer = Timer::start();
        let l = problem.list_len();
        let parts = partition_even(l, self.k);
        let (master, workers) = fabric(self.k);

        let mut handles = Vec::with_capacity(self.k);
        for w in workers {
            let problem = problem.clone();
            let range = parts.range(w.id - 1);
            let artifact_dir = self.artifact_dir.clone();
            handles.push(std::thread::spawn(move || {
                // Each worker owns its PJRT runtime (the client is not
                // Send); a failed open degrades to native compute.
                let kernels = artifact_dir.and_then(|d| KernelRuntime::open(d).ok());
                // Double-buffer swap: `spare` seeds the first iteration;
                // afterwards each downlink returns the previously sent
                // buffer in `reuse`, so two owned buffers rotate and the
                // loop allocates nothing in steady state.
                let mut spare = Some(problem.fold_identity());
                let mut ws = Workspace::new();
                loop {
                    match w.recv() {
                        Ok(Downlink::Approximation { x, epoch, reuse }) => {
                            let mut partial = reuse
                                .or_else(|| spare.take())
                                .unwrap_or_else(|| problem.fold_identity());
                            let t = Timer::start();
                            problem.map_fold_into(
                                range.clone(),
                                &x,
                                &mut partial,
                                &mut ws,
                                kernels.as_ref(),
                            );
                            let dt = t.elapsed();
                            if w.send(epoch, partial, dt).is_err() {
                                break; // master gone; nothing to report to
                            }
                        }
                        Ok(Downlink::Stop { .. }) | Err(_) => break,
                    }
                }
            }));
        }

        let run = self.master_loop(problem.as_ref(), &master);
        // Always release the workers, even on error paths (best-effort:
        // a dead worker's closed channel must not prevent the Stop from
        // reaching the live ones).
        master.broadcast_best_effort(&Downlink::Stop {
            iterations: run.as_ref().map(|r| r.0).unwrap_or(0),
        });
        for h in handles {
            let joined = h.join();
            if !self.fault_tolerant {
                joined.ok().context("worker thread panicked")?;
            }
        }
        let (iterations, final_approx, converged, metrics) = run?;
        Ok(RunReport { iterations, final_approx, converged, metrics, wall: timer.elapsed() })
    }

    fn master_loop(
        &self,
        problem: &dyn BsfProblem,
        master: &crate::net::transport::MasterEndpoint,
    ) -> Result<(usize, Vec<f64>, bool, Metrics)> {
        let l = problem.list_len();
        let parts = partition_even(l, self.k);
        let mut alive = vec![true; self.k];
        // Lazily-opened master-side runtime for recovered sublists.
        let mut master_kernels: Option<Option<KernelRuntime>> = None;
        let mut x = Arc::new(problem.initial_approx());
        // Master-side fold state, reused across iterations: the identity
        // payload, the running accumulator, per-worker recycled uplink
        // buffers, the gather inbox, and (fault-tolerant mode) a buffer +
        // workspace for recomputed dead-worker sublists.
        let identity = problem.fold_identity();
        let mut acc = identity.clone();
        let mut dead_partial = identity.clone();
        let mut ws = Workspace::new();
        let mut recycle: Vec<Option<Vec<f64>>> = (0..self.k).map(|_| None).collect();
        let mut got: Vec<Option<Uplink>> = Vec::with_capacity(self.k);
        let mut iterations = 0;
        let mut converged = false;
        let mut metrics = Metrics::default();
        while iterations < self.max_iters {
            let mut it_timer = Timer::start();
            let epoch = iterations as u64;
            // Downlink: per-worker sends so each worker gets its own
            // recycled buffer back alongside the shared approximation.
            for wid in 1..=self.k {
                if !alive[wid - 1] {
                    continue;
                }
                let msg = Downlink::Approximation {
                    x: x.clone(),
                    epoch,
                    reuse: recycle[wid - 1].take(),
                };
                if let Err(e) = master.send_to(wid, msg) {
                    if self.fault_tolerant {
                        alive[wid - 1] = false;
                        eprintln!(
                            "bsf: worker {wid} died before downlink; master takes over its sublist"
                        );
                    } else {
                        return Err(e.into());
                    }
                }
            }
            let received = master.gather_into(&alive, epoch, self.gather_timeout, &mut got);
            let expected = alive.iter().filter(|&&a| a).count();
            if received < expected {
                if self.fault_tolerant {
                    for wid in 1..=self.k {
                        if alive[wid - 1] && got[wid - 1].is_none() {
                            alive[wid - 1] = false;
                            eprintln!(
                                "bsf: worker {wid} missed the gather deadline; marked dead"
                            );
                        }
                    }
                } else {
                    return Err(TransportError::Timeout {
                        missing: expected - received,
                        expected: self.k,
                    }
                    .into());
                }
            }
            let roundtrip = it_timer.lap();
            let map_fold: Vec<f64> =
                got.iter().flatten().map(|u| u.map_seconds).collect();
            // Fold in worker-id order (identical to the sequential fold
            // order), recycling each buffer for the next downlink.
            acc.copy_from_slice(&identity);
            for slot in got.iter_mut() {
                if let Some(u) = slot.take() {
                    problem.combine_into(&mut acc, &u.partial);
                    recycle[u.worker - 1] = Some(u.partial);
                }
            }
            // Degraded mode: the master computes dead workers' sublists.
            for wid in 1..=self.k {
                if alive[wid - 1] {
                    continue;
                }
                let kern = master_kernels
                    .get_or_insert_with(|| {
                        self.artifact_dir.clone().and_then(|d| KernelRuntime::open(d).ok())
                    })
                    .as_ref();
                problem.map_fold_into(parts.range(wid - 1), &x, &mut dead_partial, &mut ws, kern);
                problem.combine_into(&mut acc, &dead_partial);
            }
            let master_fold = it_timer.lap();
            let (next, stop) = problem.post(&x, &acc, iterations);
            let post = it_timer.lap();
            let slowest = map_fold.iter().copied().fold(0.0, f64::max);
            metrics.iterations.push(IterationMetrics {
                comm: (roundtrip - slowest).max(0.0),
                map_fold,
                master_fold,
                post,
                total: roundtrip + master_fold + post,
            });
            x = Arc::new(next);
            iterations += 1;
            if stop {
                converged = true;
                break;
            }
        }
        let final_approx = Arc::try_unwrap(x).unwrap_or_else(|a| (*a).clone());
        Ok((iterations, final_approx, converged, metrics))
    }
}

/// The §6/§7-Q6 calibration recipe: run one master + one worker live for
/// `iters` iterations (after `warmup` unrecorded ones), measure `t_Map`,
/// `t_a`, `t_p` on real payloads, and return the samples.
///
/// `t_a` is measured directly by timing `⊕` over representative partials
/// (`combine_reps` in-place `combine_into` applications over two
/// preallocated partials — the exact operation the hot path performs, with
/// no per-sample clones); the whole-list Reduce sample is then
/// `(l − 1) · t_a` per eq. (6), and the Map sample is the measured
/// map+fold time minus the fold share.
pub fn calibrate_problem(
    problem: Arc<dyn BsfProblem>,
    artifact_dir: Option<PathBuf>,
    warmup: usize,
    iters: usize,
    combine_reps: usize,
) -> Result<Calibration> {
    let runner = LiveRunner {
        k: 1,
        max_iters: warmup + iters,
        artifact_dir: artifact_dir.clone(),
        gather_timeout: Duration::from_secs(600),
        fault_tolerant: false,
    };
    let report = runner.run(problem.clone())?;
    let metrics = report.metrics.without_warmup(warmup.min(report.metrics.len().saturating_sub(1)));
    if metrics.is_empty() {
        bail!("calibration run produced no measurable iterations");
    }

    // Direct t_a measurement on real partials: `acc` is reset from the
    // representative partial before every timed `combine_into`, so the
    // timed section is purely the in-place `⊕` — no allocator traffic in
    // or around it.
    let l = problem.list_len();
    let kernels = artifact_dir.and_then(|d| KernelRuntime::open(d).ok());
    let x = problem.initial_approx();
    let sample_partial = problem.map_fold(0..l, &x, kernels.as_ref());
    let mut acc = sample_partial.clone();
    let mut t_a_samples = Vec::with_capacity(combine_reps);
    for _ in 0..combine_reps {
        acc.copy_from_slice(&sample_partial);
        let t = Timer::start();
        problem.combine_into(&mut acc, &sample_partial);
        t_a_samples.push(t.elapsed());
        std::hint::black_box(&acc);
    }
    t_a_samples.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let t_a = t_a_samples[t_a_samples.len() / 2];

    let mut cal = Calibration { l, ..Default::default() };
    for it in &metrics.iterations {
        let map_plus_fold = it.map_max();
        let fold_share = (l.saturating_sub(1)) as f64 * t_a;
        cal.map_samples.push((map_plus_fold - fold_share).max(0.0));
        cal.reduce_samples.push(fold_share);
        cal.post_samples.push(it.post);
        cal.comm_samples.push(it.comm);
    }
    Ok(cal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_problems::Relaxation;

    #[test]
    fn sequential_converges_to_fixed_point() {
        let p = Relaxation::unit(100);
        let r = run_sequential(&p, 200, None);
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        assert!((r.final_approx[0] - 2.0).abs() < 1e-9);
        assert_eq!(r.metrics.len(), r.iterations);
    }

    #[test]
    fn live_matches_sequential_for_all_k() {
        let seq = run_sequential(&Relaxation::unit(101), 200, None);
        for k in [1usize, 2, 3, 7] {
            let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(101));
            let live = LiveRunner::new(k, 200).run(p).unwrap();
            assert!(live.converged);
            assert_eq!(live.iterations, seq.iterations, "k={k}");
            assert!(
                (live.final_approx[0] - seq.final_approx[0]).abs() < 1e-12,
                "k={k}"
            );
        }
    }

    #[test]
    fn live_respects_iteration_cap() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(50));
        let r = LiveRunner::new(2, 3).run(p).unwrap();
        assert_eq!(r.iterations, 3);
        assert!(!r.converged);
        assert_eq!(r.metrics.len(), 3);
    }

    #[test]
    fn live_k_more_than_l_still_correct() {
        // More workers than list elements: some sublists are empty.
        let seq = run_sequential(&Relaxation::unit(3), 200, None);
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(3));
        let live = LiveRunner::new(6, 200).run(p).unwrap();
        assert!((live.final_approx[0] - seq.final_approx[0]).abs() < 1e-12);
    }

    #[test]
    fn zero_workers_rejected() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(10));
        assert!(LiveRunner::new(0, 1).run(p).is_err());
    }

    #[test]
    fn metrics_populated() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(64));
        let r = LiveRunner::new(4, 5).run(p).unwrap();
        for it in &r.metrics.iterations {
            assert_eq!(it.map_fold.len(), 4);
            assert!(it.total > 0.0);
        }
    }

    #[test]
    fn calibration_produces_positive_params() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(1000));
        let cal = calibrate_problem(p, None, 2, 8, 32).unwrap();
        assert_eq!(cal.l, 1000);
        assert_eq!(cal.map_samples.len(), 8);
        let params =
            cal.params_with_net(&crate::net::NetworkParams::tornado_susu(), 1, 1);
        assert!(params.t_map >= 0.0);
        assert!(params.t_a > 0.0);
        assert!(params.t_p > 0.0);
    }
}
