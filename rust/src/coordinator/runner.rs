//! Runners: sequential (Algorithm 1) and live master/worker (Algorithm 2).

use std::ops::Range;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{BsfProblem, IterationMetrics, Metrics, Workspace};
use crate::lists::partition_even;
use crate::model::{BsfModel, Calibration};
use crate::net::transport::{
    fabric, Downlink, MasterEndpoint, TransportError, Uplink, WorkerEndpoint,
};
use crate::net::NetworkParams;
use crate::runtime::KernelRuntime;
use crate::simulator::RecoveryPolicy;
use crate::util::{Backoff, Timer};

/// Fault telemetry accumulated by the live master loop. All zeros on a
/// clean run (and always for [`run_sequential`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Worker deaths detected (failed downlink, panic, or missed gather
    /// deadline).
    pub injected: usize,
    /// Successful respawns — a dead worker rejoined the farm.
    pub recovered: usize,
    /// Dead sublists re-dispatched to surviving workers (one count per
    /// range per iteration).
    pub redispatched: usize,
    /// Uplinks discarded by the gather: stale epochs and deliveries from
    /// superseded or dead incarnations.
    pub late_uplinks_dropped: usize,
    /// Master-side approximation snapshots taken
    /// ([`RecoveryPolicy::Checkpoint`] only; the checkpoint overhead).
    pub checkpoints: usize,
    /// Rollbacks to the last checkpoint after a detected death
    /// ([`RecoveryPolicy::Checkpoint`] only; each one re-executes the
    /// iterations since the snapshot).
    pub restarts: usize,
}

/// Per-phase deadlines for the live master loop. The scatter bound guards
/// the downlink phase; the gather bound is the worker-failure detector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseTimeouts {
    /// Bound on the downlink (scatter) phase of one iteration.
    pub scatter: Duration,
    /// Bound on each gather (worker failure detection).
    pub gather: Duration,
}

impl PhaseTimeouts {
    /// The "no deadline was enforced" marker reported by runners that
    /// have no scatter/gather phases at all ([`run_sequential`]). Zero on
    /// both bounds — distinct from any enforced value, since
    /// [`LiveRunner::resolve_timeouts`] clamps every derived bound to at
    /// least 2 s and explicit zero timeouts would fail the first gather.
    pub fn unenforced() -> PhaseTimeouts {
        PhaseTimeouts { scatter: Duration::ZERO, gather: Duration::ZERO }
    }
}

/// Outcome of a run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Iterations executed (including the final one).
    pub iterations: usize,
    /// The final approximation (downlink encoding).
    pub final_approx: Vec<f64>,
    /// True if the run stopped because `StopCond` fired (vs the iteration
    /// cap).
    pub converged: bool,
    /// Per-iteration timings.
    pub metrics: Metrics,
    /// Fault telemetry (all zeros on a clean run).
    pub faults: FaultCounters,
    /// The scatter deadline the run used (zero for [`run_sequential`]).
    pub scatter_timeout: Duration,
    /// The gather deadline the run used — explicit or derived from the
    /// problem's [`crate::coordinator::CostSpec`] (zero for
    /// [`run_sequential`]).
    pub gather_timeout: Duration,
    /// Uplinks the gather discarded as late or stale (mirrors
    /// [`FaultCounters::late_uplinks_dropped`]), surfaced at the top level
    /// **unconditionally**: clean and sequential runs report an explicit
    /// zero rather than omitting the figure, so downstream telemetry can
    /// difference runs without special-casing the clean path.
    pub late_uplinks_dropped: usize,
    /// Total wall time (seconds).
    pub wall: f64,
}

/// Algorithm 1 — the sequential reference execution. Ground truth for every
/// parallel runner: `LiveRunner` must produce identical approximations
/// (up to fold-order roundoff).
pub fn run_sequential(
    problem: &dyn BsfProblem,
    max_iters: usize,
    kernels: Option<&KernelRuntime>,
) -> RunReport {
    let timer = Timer::start();
    let l = problem.list_len();
    let mut x = problem.initial_approx();
    // Reused across iterations: the fold buffer and the problem workspace
    // keep the whole loop allocation-free on the map side.
    let mut s = problem.fold_identity();
    let mut ws = Workspace::new();
    let mut iterations = 0;
    let mut converged = false;
    let mut metrics = Metrics::default();
    while iterations < max_iters {
        let mut it_timer = Timer::start();
        problem.map_fold_into(0..l, &x, &mut s, &mut ws, kernels);
        let map_time = it_timer.lap();
        let (next, stop) = problem.post(&x, &s, iterations);
        let post_time = it_timer.lap();
        x = next;
        iterations += 1;
        metrics.iterations.push(IterationMetrics {
            comm: 0.0,
            map_fold: vec![map_time],
            master_fold: 0.0,
            post: post_time,
            total: map_time + post_time,
        });
        if stop {
            converged = true;
            break;
        }
    }
    // Sequential runs enforce no phase deadlines; report the explicit
    // marker rather than ad-hoc zeros so the report stays truthful about
    // what was actually enforced.
    let timeouts = PhaseTimeouts::unenforced();
    RunReport {
        iterations,
        final_approx: x,
        converged,
        metrics,
        faults: FaultCounters::default(),
        scatter_timeout: timeouts.scatter,
        gather_timeout: timeouts.gather,
        late_uplinks_dropped: 0,
        wall: timer.elapsed(),
    }
}

/// Algorithm 2 over real threads — the live BSF skeleton.
#[derive(Debug, Clone)]
pub struct LiveRunner {
    /// Worker count K.
    pub k: usize,
    /// Iteration cap (StopCond may fire earlier).
    pub max_iters: usize,
    /// Artifact directory for per-worker PJRT runtimes (`None` = native
    /// Rust compute only).
    pub artifact_dir: Option<PathBuf>,
    /// Per-phase deadlines. `None` (the default) derives both bounds from
    /// the problem's [`crate::coordinator::CostSpec`]: the estimated
    /// single-worker iteration time `T_1` scaled by a generous safety
    /// factor, clamped to `[10 s, 600 s]` (gather) and `[2 s, 60 s]`
    /// (scatter). The values actually used are surfaced on
    /// [`RunReport::gather_timeout`] / [`RunReport::scatter_timeout`].
    pub timeouts: Option<PhaseTimeouts>,
    /// Degraded-mode recovery: when a worker dies (panic / hang past the
    /// gather timeout), the master marks it dead and the iteration stream
    /// continues — the result is identical because Map is deterministic
    /// and `⊕` is associative. Off by default (a dead worker aborts the
    /// run, like `MPI_ERRORS_ARE_FATAL`).
    pub fault_tolerant: bool,
    /// What to do with a dead worker's sublist while it is down (only
    /// consulted when [`LiveRunner::fault_tolerant`] is set):
    /// [`RecoveryPolicy::MasterRecompute`] (the default) folds it on the
    /// master; [`RecoveryPolicy::Redistribute`] re-dispatches it across
    /// the survivors via the downlink's extra ranges, falling back to the
    /// master only when the carrier also misses the gather.
    pub recovery: RecoveryPolicy,
    /// Bounded retry: how many times to respawn each dead worker
    /// (0 = never respawn; dead workers stay dead).
    pub respawn_limit: usize,
    /// Base delay before the first respawn attempt; doubles per attempt
    /// (exponential backoff).
    pub respawn_backoff: Duration,
}

impl LiveRunner {
    /// Runner with defaults: no artifacts, timeouts derived from the
    /// problem's cost spec, faults fatal.
    pub fn new(k: usize, max_iters: usize) -> LiveRunner {
        LiveRunner {
            k,
            max_iters,
            artifact_dir: None,
            timeouts: None,
            fault_tolerant: false,
            recovery: RecoveryPolicy::MasterRecompute,
            respawn_limit: 0,
            respawn_backoff: Duration::from_millis(100),
        }
    }

    /// Use AOT artifacts from `dir` on the worker hot path.
    pub fn with_artifacts(mut self, dir: impl Into<PathBuf>) -> LiveRunner {
        self.artifact_dir = Some(dir.into());
        self
    }

    /// The phase deadlines this run will use: the explicit setting, or the
    /// cost-spec-derived default. Derivation prices the problem on a fast
    /// fabric at 1 ns/op — an underestimate of real iteration time only by
    /// bounded factors, which the 20×/200× safety margins and the floors
    /// absorb.
    pub fn resolve_timeouts(&self, problem: &dyn BsfProblem) -> PhaseTimeouts {
        if let Some(t) = self.timeouts {
            return t;
        }
        let params = problem.cost_spec().cost_params(1e-9, &NetworkParams::fast_fabric());
        let t1 = BsfModel::new(params).t1();
        PhaseTimeouts {
            scatter: Duration::from_secs_f64((t1 * 20.0).clamp(2.0, 60.0)),
            gather: Duration::from_secs_f64((t1 * 200.0).clamp(10.0, 600.0)),
        }
    }

    /// Execute Algorithm 2. Spawns K worker threads, runs the master loop
    /// on the calling thread, joins everything before returning.
    ///
    /// Worker steady state is **allocation-free**: the fold buffer
    /// double-buffers through the uplink (sent by move, returned via the
    /// next downlink's `reuse`), the map+fold writes into it in place, and
    /// the uplink slot send performs no allocation (see
    /// [`crate::net::transport`]).
    pub fn run(&self, problem: Arc<dyn BsfProblem>) -> Result<RunReport> {
        if self.k == 0 {
            bail!("LiveRunner needs at least one worker");
        }
        let timer = Timer::start();
        let timeouts = self.resolve_timeouts(problem.as_ref());
        let l = problem.list_len();
        let parts = partition_even(l, self.k);
        let (mut master, workers) = fabric(self.k);

        let mut handles = Vec::with_capacity(self.k);
        for w in workers {
            let range = parts.range(w.id - 1);
            handles.push(self.spawn_worker(&problem, w, range));
        }

        let run = self.master_loop(&problem, &mut master, &mut handles, timeouts);
        // Always release the workers, even on error paths (best-effort:
        // a dead worker's closed channel must not prevent the Stop from
        // reaching the live ones).
        master.broadcast_best_effort(&Downlink::Stop {
            iterations: run.as_ref().map(|r| r.0).unwrap_or(0),
        });
        for h in handles {
            let joined = h.join();
            if !self.fault_tolerant {
                joined.ok().context("worker thread panicked")?;
            }
        }
        let (iterations, final_approx, converged, metrics, faults) = run?;
        Ok(RunReport {
            iterations,
            final_approx,
            converged,
            metrics,
            faults,
            scatter_timeout: timeouts.scatter,
            gather_timeout: timeouts.gather,
            late_uplinks_dropped: faults.late_uplinks_dropped,
            wall: timer.elapsed(),
        })
    }

    /// Spawn one worker thread over its endpoint and static sublist. Also
    /// the respawn path: a recovered worker gets a fresh incarnation of
    /// the same range.
    fn spawn_worker(
        &self,
        problem: &Arc<dyn BsfProblem>,
        w: WorkerEndpoint,
        range: Range<usize>,
    ) -> JoinHandle<()> {
        let problem = problem.clone();
        let artifact_dir = self.artifact_dir.clone();
        std::thread::spawn(move || {
            // Each worker owns its PJRT runtime (the client is not
            // Send); a failed open degrades to native compute.
            let kernels = artifact_dir.and_then(|d| KernelRuntime::open(d).ok());
            // Double-buffer swap: `spare` seeds the first iteration;
            // afterwards each downlink returns the previously sent
            // buffer in `reuse`, so two owned buffers rotate and the
            // loop allocates nothing in steady state.
            let mut spare = Some(problem.fold_identity());
            let mut ws = Workspace::new();
            // Scratch partial for re-dispatched dead ranges; allocated
            // lazily so the clean path stays allocation-free.
            let mut extra_buf: Option<Vec<f64>> = None;
            loop {
                match w.recv() {
                    Ok(Downlink::Approximation { x, epoch, reuse, extra }) => {
                        let mut partial = reuse
                            .or_else(|| spare.take())
                            .unwrap_or_else(|| problem.fold_identity());
                        let t = Timer::start();
                        problem.map_fold_into(
                            range.clone(),
                            &x,
                            &mut partial,
                            &mut ws,
                            kernels.as_ref(),
                        );
                        // Redistributed sublists of dead workers fold into
                        // the same uplink partial — `⊕` is associative, so
                        // the master's per-worker fold stays unchanged.
                        for r in extra {
                            let buf = extra_buf.get_or_insert_with(|| problem.fold_identity());
                            problem.map_fold_into(r, &x, buf, &mut ws, kernels.as_ref());
                            problem.combine_into(&mut partial, buf);
                        }
                        let dt = t.elapsed();
                        if w.send(epoch, partial, dt).is_err() {
                            break; // master gone; nothing to report to
                        }
                    }
                    Ok(Downlink::Stop { .. }) | Err(_) => break,
                }
            }
        })
    }

    fn master_loop(
        &self,
        problem: &Arc<dyn BsfProblem>,
        master: &mut MasterEndpoint,
        handles: &mut Vec<JoinHandle<()>>,
        timeouts: PhaseTimeouts,
    ) -> Result<(usize, Vec<f64>, bool, Metrics, FaultCounters)> {
        let l = problem.list_len();
        let parts = partition_even(l, self.k);
        let mut alive = vec![true; self.k];
        // Lazily-opened master-side runtime for recovered sublists.
        let mut master_kernels: Option<Option<KernelRuntime>> = None;
        let mut x = Arc::new(problem.initial_approx());
        // Master-side fold state, reused across iterations: the identity
        // payload, the running accumulator, per-worker recycled uplink
        // buffers, the gather inbox, and (fault-tolerant mode) a buffer +
        // workspace for recomputed dead-worker sublists.
        let identity = problem.fold_identity();
        let mut acc = identity.clone();
        let mut dead_partial = identity.clone();
        let mut ws = Workspace::new();
        let mut recycle: Vec<Option<Vec<f64>>> = (0..self.k).map(|_| None).collect();
        let mut got: Vec<Option<Uplink>> = Vec::with_capacity(self.k);
        // Fault machinery, all reused across iterations: telemetry,
        // respawn bookkeeping, this iteration's re-dispatch assignments
        // (carrier wid, dead wid), per-carrier extra ranges, and which
        // workers' partials arrived (consulted after `got` is drained).
        let mut counters = FaultCounters::default();
        // One bounded-backoff schedule per worker (shared discipline with
        // the fleet workers' reconnect loop — `util::backoff`). Un-jittered
        // here: respawn scheduling shares one master thread, so there is
        // no thundering herd to spread out.
        let mut backoffs: Vec<Backoff> =
            (0..self.k).map(|_| Backoff::new(self.respawn_backoff, self.respawn_limit)).collect();
        let mut next_respawn_at: Vec<Option<Instant>> = vec![None; self.k];
        let mut assigned: Vec<(usize, usize)> = Vec::new();
        let mut extras: Vec<Vec<Range<usize>>> = vec![Vec::new(); self.k];
        let mut delivered = vec![false; self.k];
        let mut iterations = 0;
        let mut converged = false;
        let mut metrics = Metrics::default();
        // Checkpoint/restart: the master keeps the approximation from the
        // last interval boundary and, on a detected death, rolls the run
        // back to it instead of patching the failed iteration. Respawn
        // limits and backoff apply unchanged — the policy only changes
        // what happens to the iteration stream.
        let ckpt_interval = match self.recovery {
            RecoveryPolicy::Checkpoint { interval } => Some(interval.max(1) as usize),
            _ => None,
        };
        let mut snapshot: Option<(usize, Arc<Vec<f64>>)> = None;
        while iterations < self.max_iters {
            let mut it_timer = Timer::start();
            let epoch = iterations as u64;
            if ckpt_interval.is_some_and(|iv| iterations % iv == 0)
                && snapshot.as_ref().map_or(true, |(si, _)| *si != iterations)
            {
                snapshot = Some((iterations, x.clone()));
                counters.checkpoints += 1;
            }
            let injected_before = counters.injected;
            // Bounded retry: respawn dead workers whose backoff elapsed.
            for wid in 1..=self.k {
                if alive[wid - 1] {
                    continue;
                }
                let Some(at) = next_respawn_at[wid - 1] else { continue };
                if Instant::now() < at {
                    continue;
                }
                next_respawn_at[wid - 1] = None;
                let w = master.respawn(wid);
                handles.push(self.spawn_worker(problem, w, parts.range(wid - 1)));
                alive[wid - 1] = true;
                // The buffer sent to the dead incarnation is lost.
                recycle[wid - 1] = None;
                counters.recovered += 1;
                eprintln!(
                    "bsf: worker {wid} respawned (attempt {}/{})",
                    backoffs[wid - 1].attempts(),
                    self.respawn_limit
                );
            }
            // Redistribution: round-robin dead sublists over the survivors
            // as extra downlink ranges. Whole ranges only — an uneven split
            // across carriers costs at most one sublist of imbalance and
            // keeps the fallback (carrier also dies) trivially correct.
            assigned.clear();
            if self.recovery == RecoveryPolicy::Redistribute && alive.iter().any(|a| !a) {
                let survivors: Vec<usize> = (1..=self.k).filter(|&w| alive[w - 1]).collect();
                if !survivors.is_empty() {
                    let mut next = 0usize;
                    for wid in 1..=self.k {
                        if alive[wid - 1] {
                            continue;
                        }
                        let r = parts.range(wid - 1);
                        if r.is_empty() {
                            continue;
                        }
                        let carrier = survivors[next % survivors.len()];
                        next += 1;
                        extras[carrier - 1].push(r);
                        assigned.push((carrier, wid));
                        counters.redispatched += 1;
                    }
                }
            }
            // Downlink: per-worker sends so each worker gets its own
            // recycled buffer back alongside the shared approximation.
            let scatter_timer = Timer::start();
            for wid in 1..=self.k {
                if !alive[wid - 1] {
                    continue;
                }
                let msg = Downlink::Approximation {
                    x: x.clone(),
                    epoch,
                    reuse: recycle[wid - 1].take(),
                    extra: std::mem::take(&mut extras[wid - 1]),
                };
                if let Err(e) = master.send_to(wid, msg) {
                    if self.fault_tolerant {
                        mark_dead(
                            wid,
                            "died before downlink",
                            &mut alive,
                            &mut counters,
                            &mut backoffs,
                            &mut next_respawn_at,
                        );
                    } else {
                        return Err(e.into());
                    }
                }
            }
            // The in-process sends never block, so this guard only fires
            // under pathological scheduling — but it makes the scatter
            // phase a bounded step like the gather, as a real fabric needs.
            if scatter_timer.elapsed() > timeouts.scatter.as_secs_f64() {
                if self.fault_tolerant {
                    eprintln!("bsf: scatter phase overran its {:?} budget", timeouts.scatter);
                } else {
                    bail!("scatter phase exceeded its {:?} timeout", timeouts.scatter);
                }
            }
            let (received, late) =
                master.gather_with_stats(&alive, epoch, timeouts.gather, &mut got);
            counters.late_uplinks_dropped += late;
            let expected = alive.iter().filter(|&&a| a).count();
            if received < expected {
                if self.fault_tolerant {
                    for wid in 1..=self.k {
                        if alive[wid - 1] && got[wid - 1].is_none() {
                            mark_dead(
                                wid,
                                "missed the gather deadline",
                                &mut alive,
                                &mut counters,
                                &mut backoffs,
                                &mut next_respawn_at,
                            );
                        }
                    }
                } else {
                    return Err(TransportError::Timeout {
                        missing: expected - received,
                        expected: self.k,
                    }
                    .into());
                }
            }
            // Checkpoint rollback: any death detected this iteration sends
            // the run back to the last snapshot instead of patching the
            // current fold. Gathered partials are recycled, not folded —
            // their iterations will be re-executed from the snapshot.
            // Bounded: every rollback consumes at least one injection, and
            // injections are capped at k × (respawn_limit + 1).
            if ckpt_interval.is_some() && counters.injected > injected_before {
                if let Some((snap_iter, snap_x)) = snapshot.clone() {
                    for slot in got.iter_mut() {
                        if let Some(u) = slot.take() {
                            recycle[u.worker - 1] = Some(u.partial);
                        }
                    }
                    metrics.iterations.truncate(snap_iter);
                    x = snap_x;
                    iterations = snap_iter;
                    counters.restarts += 1;
                    eprintln!("bsf: rolling back to the iteration-{snap_iter} checkpoint");
                    continue;
                }
            }
            let roundtrip = it_timer.lap();
            for i in 0..self.k {
                delivered[i] = got[i].is_some();
            }
            let map_fold: Vec<f64> =
                got.iter().flatten().map(|u| u.map_seconds).collect();
            // Fold in worker-id order (identical to the sequential fold
            // order), recycling each buffer for the next downlink.
            acc.copy_from_slice(&identity);
            for slot in got.iter_mut() {
                if let Some(u) = slot.take() {
                    problem.combine_into(&mut acc, &u.partial);
                    recycle[u.worker - 1] = Some(u.partial);
                }
            }
            // Degraded mode: the master computes every dead sublist that a
            // surviving carrier did not deliver this iteration — not
            // re-dispatched (MasterRecompute, or the worker died after the
            // scatter), or re-dispatched to a carrier that also missed.
            for wid in 1..=self.k {
                if alive[wid - 1] {
                    continue;
                }
                let r = parts.range(wid - 1);
                if r.is_empty() {
                    continue;
                }
                if let Some(&(carrier, _)) = assigned.iter().find(|&&(_, d)| d == wid) {
                    if delivered[carrier - 1] {
                        continue;
                    }
                }
                let kern = master_kernels
                    .get_or_insert_with(|| {
                        self.artifact_dir.clone().and_then(|d| KernelRuntime::open(d).ok())
                    })
                    .as_ref();
                problem.map_fold_into(r, &x, &mut dead_partial, &mut ws, kern);
                problem.combine_into(&mut acc, &dead_partial);
            }
            let master_fold = it_timer.lap();
            let (next, stop) = problem.post(&x, &acc, iterations);
            let post = it_timer.lap();
            let slowest = map_fold.iter().copied().fold(0.0, f64::max);
            metrics.iterations.push(IterationMetrics {
                comm: (roundtrip - slowest).max(0.0),
                map_fold,
                master_fold,
                post,
                total: roundtrip + master_fold + post,
            });
            x = Arc::new(next);
            iterations += 1;
            if stop {
                converged = true;
                break;
            }
        }
        let final_approx = Arc::try_unwrap(x).unwrap_or_else(|a| (*a).clone());
        Ok((iterations, final_approx, converged, metrics, counters))
    }
}

/// Record a worker death: mark it dead, bump the telemetry, and — while
/// the worker's [`Backoff`] budget lasts — schedule a respawn at the
/// schedule's next delay.
fn mark_dead(
    wid: usize,
    why: &str,
    alive: &mut [bool],
    counters: &mut FaultCounters,
    backoffs: &mut [Backoff],
    next_respawn_at: &mut [Option<Instant>],
) {
    alive[wid - 1] = false;
    counters.injected += 1;
    if let Some(delay) = backoffs[wid - 1].next_delay() {
        next_respawn_at[wid - 1] = Some(Instant::now() + delay);
        eprintln!("bsf: worker {wid} {why}; respawn scheduled in {delay:?}");
    } else {
        eprintln!("bsf: worker {wid} {why}; master takes over its sublist");
    }
}

/// The §6/§7-Q6 calibration recipe: run one master + one worker live for
/// `iters` iterations (after `warmup` unrecorded ones), measure `t_Map`,
/// `t_a`, `t_p` on real payloads, and return the samples.
///
/// `t_a` is measured directly by timing `⊕` over representative partials
/// (`combine_reps` in-place `combine_into` applications over two
/// preallocated partials — the exact operation the hot path performs, with
/// no per-sample clones); the whole-list Reduce sample is then
/// `(l − 1) · t_a` per eq. (6), and the Map sample is the measured
/// map+fold time minus the fold share.
pub fn calibrate_problem(
    problem: Arc<dyn BsfProblem>,
    artifact_dir: Option<PathBuf>,
    warmup: usize,
    iters: usize,
    combine_reps: usize,
) -> Result<Calibration> {
    let runner = LiveRunner {
        k: 1,
        max_iters: warmup + iters,
        artifact_dir: artifact_dir.clone(),
        timeouts: Some(PhaseTimeouts {
            scatter: Duration::from_secs(60),
            gather: Duration::from_secs(600),
        }),
        fault_tolerant: false,
        recovery: RecoveryPolicy::MasterRecompute,
        respawn_limit: 0,
        respawn_backoff: Duration::from_millis(100),
    };
    let report = runner.run(problem.clone())?;
    let metrics = report.metrics.without_warmup(warmup.min(report.metrics.len().saturating_sub(1)));
    if metrics.is_empty() {
        bail!("calibration run produced no measurable iterations");
    }

    // Direct t_a measurement on real partials: `acc` is reset from the
    // representative partial before every timed `combine_into`, so the
    // timed section is purely the in-place `⊕` — no allocator traffic in
    // or around it.
    let l = problem.list_len();
    let kernels = artifact_dir.and_then(|d| KernelRuntime::open(d).ok());
    let x = problem.initial_approx();
    let sample_partial = problem.map_fold(0..l, &x, kernels.as_ref());
    let mut acc = sample_partial.clone();
    let mut t_a_samples = Vec::with_capacity(combine_reps);
    for _ in 0..combine_reps {
        acc.copy_from_slice(&sample_partial);
        let t = Timer::start();
        problem.combine_into(&mut acc, &sample_partial);
        t_a_samples.push(t.elapsed());
        std::hint::black_box(&acc);
    }
    t_a_samples.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let t_a = t_a_samples[t_a_samples.len() / 2];

    let mut cal = Calibration { l, ..Default::default() };
    for it in &metrics.iterations {
        let map_plus_fold = it.map_max();
        let fold_share = (l.saturating_sub(1)) as f64 * t_a;
        cal.map_samples.push((map_plus_fold - fold_share).max(0.0));
        cal.reduce_samples.push(fold_share);
        cal.post_samples.push(it.post);
        cal.comm_samples.push(it.comm);
    }
    Ok(cal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_problems::Relaxation;

    #[test]
    fn sequential_converges_to_fixed_point() {
        let p = Relaxation::unit(100);
        let r = run_sequential(&p, 200, None);
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        assert!((r.final_approx[0] - 2.0).abs() < 1e-9);
        assert_eq!(r.metrics.len(), r.iterations);
        assert_eq!(r.faults, FaultCounters::default());
    }

    #[test]
    fn live_matches_sequential_for_all_k() {
        let seq = run_sequential(&Relaxation::unit(101), 200, None);
        for k in [1usize, 2, 3, 7] {
            let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(101));
            let live = LiveRunner::new(k, 200).run(p).unwrap();
            assert!(live.converged);
            assert_eq!(live.iterations, seq.iterations, "k={k}");
            assert!(
                (live.final_approx[0] - seq.final_approx[0]).abs() < 1e-12,
                "k={k}"
            );
            assert_eq!(live.faults, FaultCounters::default(), "k={k}");
        }
    }

    #[test]
    fn live_respects_iteration_cap() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(50));
        let r = LiveRunner::new(2, 3).run(p).unwrap();
        assert_eq!(r.iterations, 3);
        assert!(!r.converged);
        assert_eq!(r.metrics.len(), 3);
    }

    #[test]
    fn live_k_more_than_l_still_correct() {
        // More workers than list elements: some sublists are empty.
        let seq = run_sequential(&Relaxation::unit(3), 200, None);
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(3));
        let live = LiveRunner::new(6, 200).run(p).unwrap();
        assert!((live.final_approx[0] - seq.final_approx[0]).abs() < 1e-12);
    }

    #[test]
    fn zero_workers_rejected() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(10));
        assert!(LiveRunner::new(0, 1).run(p).is_err());
    }

    #[test]
    fn metrics_populated() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(64));
        let r = LiveRunner::new(4, 5).run(p).unwrap();
        for it in &r.metrics.iterations {
            assert_eq!(it.map_fold.len(), 4);
            assert!(it.total > 0.0);
        }
    }

    #[test]
    fn derived_timeouts_are_clamped_and_reported() {
        // A tiny problem prices far below the floors, so the clamps bind.
        let runner = LiveRunner::new(2, 3);
        let p = Relaxation::unit(50);
        let t = runner.resolve_timeouts(&p);
        assert_eq!(t.gather, Duration::from_secs(10));
        assert_eq!(t.scatter, Duration::from_secs(2));
        let r = runner.run(Arc::new(p) as Arc<dyn BsfProblem>).unwrap();
        assert_eq!(r.gather_timeout, Duration::from_secs(10));
        assert_eq!(r.scatter_timeout, Duration::from_secs(2));
    }

    #[test]
    fn explicit_timeouts_win_over_derivation() {
        let mut runner = LiveRunner::new(1, 2);
        let t = PhaseTimeouts {
            scatter: Duration::from_millis(123),
            gather: Duration::from_millis(456),
        };
        runner.timeouts = Some(t);
        let p = Relaxation::unit(10);
        assert_eq!(runner.resolve_timeouts(&p), t);
        let r = runner.run(Arc::new(p) as Arc<dyn BsfProblem>).unwrap();
        assert_eq!(r.gather_timeout, t.gather);
        assert_eq!(r.scatter_timeout, t.scatter);
    }

    #[test]
    fn checkpoint_policy_snapshots_without_failures() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(64));
        let mut runner = LiveRunner::new(2, 8);
        runner.fault_tolerant = true;
        runner.recovery = RecoveryPolicy::Checkpoint { interval: 3 };
        let r = runner.run(p).unwrap();
        // One snapshot per interval boundary visited: iterations 0, 3, 6, …
        assert_eq!(r.faults.checkpoints, (r.iterations + 2) / 3);
        assert_eq!(r.faults.restarts, 0);
        assert_eq!(r.faults.injected, 0);
        // Snapshots are pure bookkeeping — the approximation is untouched.
        let seq = run_sequential(&Relaxation::unit(64), 8, None);
        assert!((r.final_approx[0] - seq.final_approx[0]).abs() < 1e-12);
    }

    /// Clean runs (live and sequential) surface an explicit zero for the
    /// late-uplink figure — the field exists unconditionally, it is not a
    /// faulty-path extra.
    #[test]
    fn clean_runs_report_zero_late_uplinks() {
        let seq = run_sequential(&Relaxation::unit(32), 5, None);
        assert_eq!(seq.late_uplinks_dropped, 0);
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(32));
        let live = LiveRunner::new(3, 5).run(p).unwrap();
        assert_eq!(live.late_uplinks_dropped, 0);
        assert_eq!(live.late_uplinks_dropped, live.faults.late_uplinks_dropped);
    }

    #[test]
    fn calibration_produces_positive_params() {
        let p: Arc<dyn BsfProblem> = Arc::new(Relaxation::unit(1000));
        let cal = calibrate_problem(p, None, 2, 8, 32).unwrap();
        assert_eq!(cal.l, 1000);
        assert_eq!(cal.map_samples.len(), 8);
        let params =
            cal.params_with_net(&crate::net::NetworkParams::tornado_susu(), 1, 1);
        assert!(params.t_map >= 0.0);
        assert!(params.t_a > 0.0);
        assert!(params.t_p > 0.0);
    }
}
