//! The BSF skeleton — the paper's Algorithm 1 → Algorithm 2 machinery.
//!
//! A numerical method is plugged in by implementing [`BsfProblem`]: the
//! Map + local-Reduce over an index range of the problem's list, the fold
//! `⊕`, and the master-side `Compute`/`StopCond`. The skeleton then
//! provides, with no further problem code:
//!
//! * [`run_sequential`] — Algorithm 1, the ground-truth serial execution;
//! * [`LiveRunner`] — Algorithm 2 over real threads and the in-process
//!   transport ([`crate::net::transport`]), with per-step metrics for
//!   calibration;
//! * [`calibrate_problem`] — the §6/§7-Q6 measurement recipe, producing the
//!   cost parameters (Table 2's rows) for the analytic model and simulator.
//!
//! This mirrors the paper's published C++ BSF-skeleton
//! (github.com/leonid-sokolinsky/BSF-skeleton) with the MPI fabric replaced
//! by threads+channels and the compute hot spot replaced by AOT-compiled
//! XLA executables.

mod metrics;
mod runner;

pub use metrics::{IterationMetrics, Metrics};
pub use runner::{
    calibrate_problem, run_sequential, FaultCounters, LiveRunner, PhaseTimeouts, RunReport,
};

use std::ops::Range;

use crate::runtime::KernelRuntime;

/// Per-iteration payload/op-count description used to derive analytic cost
/// parameters (the §5 quantities `c_c`, `c_Map`, `c_a`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostSpec {
    /// List length `l`.
    pub l: usize,
    /// f64 words the master sends to each worker per iteration (the
    /// approximation).
    pub words_down: usize,
    /// f64 words each worker returns (the partial folding).
    pub words_up: usize,
    /// Arithmetic ops to Map one list element (`c_Map / l`).
    pub ops_map_per_elem: f64,
    /// Arithmetic ops for one `⊕` application (`c_a`).
    pub ops_combine: f64,
    /// Arithmetic ops for the master's Compute + StopCond (`≈ t_p / τ_op`).
    pub ops_post: f64,
}

impl CostSpec {
    /// Analytic [`crate::model::CostParams`] given machine speeds: `τ_op`
    /// (seconds per arithmetic op) and the interconnect. This is the
    /// "before any implementation" path of the paper (§5: eqs. 20–23).
    pub fn cost_params(
        &self,
        tau_op: f64,
        net: &crate::net::NetworkParams,
    ) -> crate::model::CostParams {
        crate::model::CostParams {
            l: self.l,
            t_c: net.t_c(self.words_down, self.words_up),
            t_p: self.ops_post * tau_op,
            t_map: self.ops_map_per_elem * self.l as f64 * tau_op,
            t_a: self.ops_combine * tau_op,
        }
    }
}

/// Reusable per-caller scratch threaded through
/// [`BsfProblem::map_fold_into`]. Runners own one workspace per worker
/// thread and hand it to every call, so a plugged-in problem that needs
/// per-call temporary storage can borrow capacity instead of allocating
/// per iteration.
///
/// Besides the generic [`Workspace::zeroed`] scratch, the workspace owns
/// the **PJRT staging buffers** of the kernel path: one input-staging
/// buffer (padded x-blocks, drift-shifted b-blocks) and one
/// output-staging buffer (the block result accumulated into the caller's
/// fold buffer). Both only grow, so in steady state the kernel path
/// reuses caller capacity exactly like the native path — zero heap
/// allocations per call, asserted (staging layer included) by
/// `rust/benches/coordinator_hotpath.rs`'s counting allocator.
#[derive(Debug, Default)]
pub struct Workspace {
    buf: Vec<f64>,
    stage_in: Vec<f64>,
    stage_out: Vec<f64>,
}

impl Workspace {
    /// Empty workspace; buffers grow on first use and are then reused.
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// A zeroed f64 scratch slice of exactly `len` elements (capacity
    /// reused across calls).
    pub fn zeroed(&mut self, len: usize) -> &mut [f64] {
        self.buf.clear();
        self.buf.resize(len, 0.0);
        &mut self.buf
    }

    /// The kernel staging pair: an input-staging slice of `in_len`
    /// elements and an output-staging slice of `out_len` elements,
    /// borrowed simultaneously. Grow-only (allocation-free once warm) and
    /// **not** cleared between calls — contents are whatever the previous
    /// call left, so callers must fully write every element the kernel
    /// reads (the problems pad explicitly; `execute_into` overwrites the
    /// output stage in full). Skipping the memset matters: this sits on
    /// the per-block kernel hot path.
    pub fn staging(&mut self, in_len: usize, out_len: usize) -> (&mut [f64], &mut [f64]) {
        if self.stage_in.len() < in_len {
            self.stage_in.resize(in_len, 0.0);
        }
        if self.stage_out.len() < out_len {
            self.stage_out.resize(out_len, 0.0);
        }
        (&mut self.stage_in[..in_len], &mut self.stage_out[..out_len])
    }
}

/// A BSF algorithm: the problem-specific plugs of Algorithms 1/2.
///
/// The approximation and the partial foldings are opaque f64 payloads
/// (problems define their own encoding; e.g. BSF-Gravity packs
/// `[X, V, t]` downlink and a 3-vector uplink).
///
/// The worker hot path is the allocation-free pair
/// [`BsfProblem::map_fold_into`] / [`BsfProblem::combine_into`]; the
/// owning-`Vec` wrappers [`BsfProblem::map_fold`] / [`BsfProblem::combine`]
/// are provided for one-shot callers (tests, calibration sampling).
pub trait BsfProblem: Send + Sync {
    /// Human-readable name (reports, traces).
    fn name(&self) -> &str;

    /// Length `l` of the list A.
    fn list_len(&self) -> usize;

    /// The initial approximation `x⁽⁰⁾` (downlink encoding).
    fn initial_approx(&self) -> Vec<f64>;

    /// Worker step (Algorithm 2 steps 3–4): Map over `range` of the list
    /// and locally fold with `⊕`, **overwriting** `out` with the partial
    /// folding (`out.len()` equals the fold payload length, i.e.
    /// `fold_identity().len()`). `ws` is caller-owned scratch reused across
    /// calls; the native path must not allocate in steady state. `kernels`
    /// is this worker's PJRT runtime when artifacts are available;
    /// implementations fall back to native Rust when `None` or when no
    /// artifact matches the problem size.
    fn map_fold_into(
        &self,
        range: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        ws: &mut Workspace,
        kernels: Option<&KernelRuntime>,
    );

    /// The fold identity (empty-range result).
    fn fold_identity(&self) -> Vec<f64>;

    /// The associative `⊕` in place: `acc ← acc ⊕ b` (Algorithm 2 step 6's
    /// master fold).
    fn combine_into(&self, acc: &mut [f64], b: &[f64]);

    /// Master step (Algorithm 1 steps 5–7): `Compute` the next
    /// approximation from the current one and the full folding `s`, and
    /// evaluate `StopCond`. Returns `(next_approx, stop)`.
    fn post(&self, x: &[f64], s: &[f64], iteration: usize) -> (Vec<f64>, bool);

    /// Payload/op-count description for analytic cost modelling.
    fn cost_spec(&self) -> CostSpec;

    /// Owning convenience wrapper over [`BsfProblem::map_fold_into`].
    fn map_fold(
        &self,
        range: Range<usize>,
        x: &[f64],
        kernels: Option<&KernelRuntime>,
    ) -> Vec<f64> {
        let mut out = self.fold_identity();
        let mut ws = Workspace::new();
        self.map_fold_into(range, x, &mut out, &mut ws, kernels);
        out
    }

    /// Owning convenience wrapper over [`BsfProblem::combine_into`].
    fn combine(&self, mut a: Vec<f64>, b: Vec<f64>) -> Vec<f64> {
        self.combine_into(&mut a, &b);
        a
    }
}

#[cfg(test)]
pub(crate) mod test_problems {
    use super::*;

    /// Toy problem: x ∈ R, list = weights w_j; iteration computes
    /// s = Σ w_j · x and then x' = s/2 + 1, stopping when |x' − x| < 1e-12.
    /// Fixed point (for Σw = 1): x* = x/2 + 1 ⇒ x* = 2.
    #[derive(Debug)]
    pub struct Relaxation {
        pub weights: Vec<f64>,
    }

    impl Relaxation {
        pub fn unit(l: usize) -> Relaxation {
            Relaxation { weights: vec![1.0 / l as f64; l] }
        }
    }

    impl BsfProblem for Relaxation {
        fn name(&self) -> &str {
            "relaxation"
        }
        fn list_len(&self) -> usize {
            self.weights.len()
        }
        fn initial_approx(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn map_fold_into(
            &self,
            range: Range<usize>,
            x: &[f64],
            out: &mut [f64],
            _ws: &mut Workspace,
            _kernels: Option<&KernelRuntime>,
        ) {
            out[0] = self.weights[range].iter().map(|w| w * x[0]).sum();
        }
        fn fold_identity(&self) -> Vec<f64> {
            vec![0.0]
        }
        fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
            acc[0] += b[0];
        }
        fn post(&self, x: &[f64], s: &[f64], _iteration: usize) -> (Vec<f64>, bool) {
            let next = s[0] / 2.0 + 1.0;
            let stop = (next - x[0]).abs() < 1e-12;
            (vec![next], stop)
        }
        fn cost_spec(&self) -> CostSpec {
            CostSpec {
                l: self.weights.len(),
                words_down: 1,
                words_up: 1,
                ops_map_per_elem: 1.0,
                ops_combine: 1.0,
                ops_post: 3.0,
            }
        }
    }

    #[test]
    fn workspace_staging_grow_only_and_exact_len() {
        let mut ws = Workspace::new();
        {
            let (i1, o1) = ws.staging(8, 4);
            assert_eq!((i1.len(), o1.len()), (8, 4));
            i1[7] = 9.0;
        }
        let (i2, o2) = ws.staging(4, 2);
        assert_eq!((i2.len(), o2.len()), (4, 2));
        let _ = o2;
        let (i3, _) = ws.staging(8, 4);
        assert_eq!(i3[7], 9.0, "staging must not clear between calls (hot path)");
    }

    #[test]
    fn cost_spec_to_params() {
        let p = Relaxation::unit(100).cost_spec();
        let net = crate::net::NetworkParams {
            latency: 1e-5,
            tau_tr: 1e-8,
            link: crate::net::LinkMode::PerEdge,
        };
        let cp = p.cost_params(1e-9, &net);
        assert_eq!(cp.l, 100);
        assert!((cp.t_map - 100.0 * 1e-9).abs() < 1e-18);
        assert!((cp.t_a - 1e-9).abs() < 1e-20);
        assert!((cp.t_c - net.t_c(1, 1)).abs() < 1e-20);
    }
}
