//! Per-iteration timing instrumentation of the live runner.
//!
//! The quantities mirror the BSF cost vocabulary so calibration can read
//! them off directly: communication wall time (→ `t_c`), per-worker
//! Map+fold durations (→ `t_Map`+`t_Rdc`), and master post time (→ `t_p`).

use crate::util::stats::Summary;

/// Timings of one Algorithm-2 iteration (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct IterationMetrics {
    /// Master-side wall time from broadcast start to last partial received,
    /// minus the slowest worker's compute time — i.e. the communication +
    /// synchronisation share of the round trip.
    pub comm: f64,
    /// Per-worker Map+local-fold durations, indexed by worker-1.
    pub map_fold: Vec<f64>,
    /// Master fold of the K partials.
    pub master_fold: f64,
    /// Master Compute + StopCond duration.
    pub post: f64,
    /// Full iteration wall time at the master.
    pub total: f64,
}

impl IterationMetrics {
    /// Slowest worker's compute time (the straggler).
    pub fn map_max(&self) -> f64 {
        self.map_fold.iter().copied().fold(0.0, f64::max)
    }

    /// Mean worker compute time.
    pub fn map_mean(&self) -> f64 {
        if self.map_fold.is_empty() {
            0.0
        } else {
            self.map_fold.iter().sum::<f64>() / self.map_fold.len() as f64
        }
    }
}

/// All iterations of one run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Per-iteration records, in order.
    pub iterations: Vec<IterationMetrics>,
}

impl Metrics {
    /// Number of recorded iterations.
    pub fn len(&self) -> usize {
        self.iterations.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.iterations.is_empty()
    }

    /// Summary of total iteration times.
    pub fn total_summary(&self) -> Summary {
        Summary::of(&self.iterations.iter().map(|m| m.total).collect::<Vec<_>>())
    }

    /// Summary of the slowest-worker compute times.
    pub fn map_summary(&self) -> Summary {
        Summary::of(&self.iterations.iter().map(|m| m.map_max()).collect::<Vec<_>>())
    }

    /// Summary of master post times.
    pub fn post_summary(&self) -> Summary {
        Summary::of(&self.iterations.iter().map(|m| m.post).collect::<Vec<_>>())
    }

    /// Summary of communication shares.
    pub fn comm_summary(&self) -> Summary {
        Summary::of(&self.iterations.iter().map(|m| m.comm).collect::<Vec<_>>())
    }

    /// Drop the first `n` iterations (warmup: first-touch, cache effects,
    /// lazy artifact compilation).
    pub fn without_warmup(&self, n: usize) -> Metrics {
        Metrics { iterations: self.iterations.iter().skip(n).cloned().collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(total: f64) -> IterationMetrics {
        IterationMetrics {
            comm: 0.1,
            map_fold: vec![1.0, 2.0, 1.5],
            master_fold: 0.01,
            post: 0.05,
            total,
        }
    }

    #[test]
    fn map_max_and_mean() {
        let it = m(3.0);
        assert_eq!(it.map_max(), 2.0);
        assert!((it.map_mean() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn summaries() {
        let ms = Metrics { iterations: vec![m(3.0), m(4.0), m(5.0)] };
        assert_eq!(ms.len(), 3);
        assert!((ms.total_summary().mean - 4.0).abs() < 1e-12);
        assert_eq!(ms.map_summary().max, 2.0);
        assert!((ms.post_summary().mean - 0.05).abs() < 1e-12);
        assert!((ms.comm_summary().mean - 0.1).abs() < 1e-12);
    }

    #[test]
    fn warmup_skips() {
        let ms = Metrics { iterations: vec![m(10.0), m(1.0), m(1.0)] };
        let w = ms.without_warmup(1);
        assert_eq!(w.len(), 2);
        assert!((w.total_summary().mean - 1.0).abs() < 1e-12);
        assert!(!w.is_empty());
    }

    #[test]
    fn empty_map_fold_mean_zero() {
        let it = IterationMetrics {
            comm: 0.0,
            map_fold: vec![],
            master_fold: 0.0,
            post: 0.0,
            total: 0.0,
        };
        assert_eq!(it.map_mean(), 0.0);
        assert_eq!(it.map_max(), 0.0);
    }
}
