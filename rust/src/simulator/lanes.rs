//! Lane-parallel order-cached replay: simulate a batch of independent
//! jittered replays of one graph in a single pass over the cached pop
//! order, at a lane width chosen at runtime (up to [`LANES_MAX`]).
//!
//! PR 4's order-cached replay reduced a replay to two IEEE-754 operations
//! per task — `start = max(ready, resource_free)` and `end = start + dur` —
//! plus an exact `(ready, id)` validity check. Both `max` and `+` return
//! the unique correctly-rounded result for their operands, so evaluating
//! them **per lane** over independent duration sets is bitwise identical
//! to evaluating the replays one at a time — at *any* lane width: the
//! same trick `linalg::kernels` uses for the compute plane (identical
//! per-lane operation sequence in a scalar twin and a vector kernel),
//! applied to the simulation plane.
//!
//! ## Layout
//!
//! Every lane array is **lane-strided**: element `[task][lane]` lives at
//! `task * width + lane`, so one task's lanes are contiguous and a single
//! `_mm256_loadu_pd` (width 4) or `_mm512_loadu_pd` (width 8) fetches all
//! replays' values. The same layout covers `ready`/`finish` (per task)
//! and `free` (per resource).
//!
//! ## Per-lane validity
//!
//! The scalar validity check accepts task `id` when `(ready, id)` exceeds
//! the previous pop lexicographically. The task *order* is shared across
//! lanes (it is the one cached permutation), so the id comparison is one
//! scalar branch per task and only the `ready` comparison is lane-wise:
//! `id > prev_id` selects a `>=` compare, otherwise `>` — vectorized as
//! `_mm256_cmp_pd` + movemask (`!= 0b1111` rejects) at width 4 and
//! `_mm512_cmp_pd_mask` (`!= 0xFF` rejects) at width 8, all lanes
//! required to pass. Any failing lane aborts the whole pass ([`replay`]
//! returns `false`) because the sequential semantics of the failing lane
//! (a calendar fallback that *refreshes the cache*) would change what the
//! later lanes are checked against; the engine then re-runs the batch
//! through the ordinary scalar `run_reuse` path in lane order, which
//! reproduces the one-at-a-time loop exactly (see
//! `Engine::run_lanes`). NaN ready times (only reachable via unchecked
//! non-finite durations in release builds) fail both ordered compares and
//! reject, exactly like the scalar check.
//!
//! ## Dispatch
//!
//! Two independent axes pick the implementation:
//!
//! * **Kernel** — the *existing* `BSF_KERNEL` mechanism
//!   (`linalg::kernels::active()`): `scalar` forces the width-generic
//!   scalar twin, whose per-lane operation sequence mirrors the vector
//!   kernels literally (`a > b ? a : b` is the exact `_mm256_max_pd` /
//!   `_mm512_max_pd` operand selection, NaN included), so all
//!   implementations agree bit for bit on every input.
//! * **Width** — `BSF_LANE_WIDTH=4|8` (unset = 8 when the CPU reports
//!   `avx512f`, else 4; `8` on a host without `avx512f` panics loudly,
//!   as does any other value — an override that does nothing would
//!   invalidate any benchmark run on top of it). [`lane_width`] reads it
//!   once; `Engine::set_lane_width` overrides per instance so tests can
//!   race widths without touching process env. A (kernel, width)
//!   combination with no vector kernel — e.g. width 8 without `avx512f`
//!   via the per-instance override — takes the scalar twin at that
//!   width, so width-8 batches are testable on any host.
//!
//! A separate process-wide `BSF_LANES=on|off` switch (unset = `on`;
//! anything else panics loudly, like `BSF_SCHED`) disables the batched
//! pass entirely, forcing every lane batch through the sequential scalar
//! path — results are bitwise identical either way, so CI crosses it
//! with one representative kernel/scheduler cell.

use crate::linalg::kernels::KernelKind;
use crate::simulator::engine::TaskId;

/// Maximum lane width of the batched replay pass (AVX-512 holds eight
/// f64 lanes). The dispatched width is [`lane_width`] (or a per-engine
/// override); remainder batches are padded up to it with a duplicated
/// real lane whose results are discarded (see `Engine::run_lanes`).
pub const LANES_MAX: usize = 8;

static ACTIVE_LANES: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
static ACTIVE_WIDTH: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
static ACTIVE_GROUP: std::sync::OnceLock<bool> = std::sync::OnceLock::new();

/// Whether the vectorized lane pass is enabled for this process (reads
/// `BSF_LANES` once). Engines without an `Engine::set_lane_mode` override
/// dispatch through this, so CI can run the whole suite with the lane
/// pass forced off (every batch then exercises the sequential fallback).
pub fn lanes_enabled() -> bool {
    *ACTIVE_LANES.get_or_init(|| select_lanes(std::env::var("BSF_LANES").ok().as_deref()))
}

/// The process-wide lane width (reads `BSF_LANE_WIDTH` once): 8 when the
/// CPU reports `avx512f`, else 4, unless overridden. Engines without an
/// `Engine::set_lane_width` override dispatch through this.
pub fn lane_width() -> usize {
    *ACTIVE_WIDTH.get_or_init(|| {
        select_width(std::env::var("BSF_LANE_WIDTH").ok().as_deref(), avx512_supported())
    })
}

/// Whether the sweep queue buckets same-[`crate::simulator::ShapeClass`]
/// cells into shared-template groups for this process (reads `BSF_GROUP`
/// once; unset = on). Sweep jobs without a per-job
/// `SweepJob::set_group_mode` override dispatch through this, so CI and
/// the benches can race the grouped and per-cell partitions. Grouping is
/// bitwise-neutral by contract — `off` only changes which template
/// instance computes each cell, never the numbers.
pub fn group_enabled() -> bool {
    *ACTIVE_GROUP.get_or_init(|| select_group(std::env::var("BSF_GROUP").ok().as_deref()))
}

/// Whether this CPU can run the width-8 AVX-512 lane pass.
#[cfg(target_arch = "x86_64")]
pub(crate) fn avx512_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
}

#[cfg(not(target_arch = "x86_64"))]
pub(crate) fn avx512_supported() -> bool {
    false
}

/// Pure selection logic (unit-tested separately from process env state).
/// Requesting anything but `on`/`off` panics loudly rather than silently
/// falling back — an override that does nothing would invalidate any
/// benchmark run on top of it.
fn select_lanes(request: Option<&str>) -> bool {
    match request {
        Some("on") => true,
        Some("off") => false,
        Some(other) => panic!("BSF_LANES must be 'on' or 'off', got '{other}'"),
        None => true,
    }
}

/// Pure selection logic for the grouping switch (unit-tested separately
/// from process env state). Requesting anything but `on`/`off` panics
/// loudly rather than silently falling back, like every `BSF_*` switch.
fn select_group(request: Option<&str>) -> bool {
    match request {
        Some("on") => true,
        Some("off") => false,
        Some(other) => panic!("BSF_GROUP must be 'on' or 'off', got '{other}'"),
        None => true,
    }
}

/// Pure width selection (unit-tested separately from process env state
/// and CPU detection). Requesting width 8 on a host without `avx512f`
/// panics rather than silently narrowing: a benchmark run under a
/// half-honoured override would measure the wrong kernel.
fn select_width(request: Option<&str>, avx512_ok: bool) -> usize {
    match request {
        Some("4") => 4,
        Some("8") if avx512_ok => 8,
        Some("8") => panic!("BSF_LANE_WIDTH=8 requires avx512f, which this CPU does not report"),
        Some(other) => panic!("BSF_LANE_WIDTH must be '4' or '8', got '{other}'"),
        None if avx512_ok => 8,
        None => 4,
    }
}

/// Borrowed view of everything one lane-batched pass needs: the engine's
/// graph (cached pop order + SoA columns + CSR successors) and its
/// lane-strided scratch. `ready` and `free` must arrive zeroed; `durs`
/// holds `width` duration sets task-major (`[task * width + lane]`), and
/// `makespan` must hold at least `width` slots.
pub(crate) struct LanePass<'a> {
    pub order: &'a [TaskId],
    pub resources: &'a [u32],
    pub csr_off: &'a [usize],
    pub csr_dst: &'a [TaskId],
    pub durs: &'a [f64],
    pub ready: &'a mut [f64],
    pub free: &'a mut [f64],
    pub finish: &'a mut [f64],
    /// Per-lane running makespan (the fused `max` fold over finish times).
    pub makespan: &'a mut [f64],
    /// Lane count of this batch — the stride of every array above.
    pub width: usize,
}

/// Execute the lane-batched linear pass through the widest kernel that
/// fits `(kind, width, CPU)`; any combination without a vector kernel
/// takes the width-generic scalar twin (bitwise identical). Returns
/// `false` as soon as any lane fails the validity check (scratch is then
/// undefined — the caller re-runs the batch sequentially); returns `true`
/// with `finish`/`makespan` holding all `width` replays' results
/// otherwise. Zero heap allocations.
pub(crate) fn replay(kind: KernelKind, p: &mut LanePass<'_>) -> bool {
    match (kind, p.width) {
        (KernelKind::Avx2, 4) => replay_avx2_checked(p),
        (KernelKind::Avx2, 8) if avx512_supported() => replay_avx512_checked(p),
        _ => replay_scalar(p),
    }
}

/// Fold `out[lane] = max(0, max over tasks of finish[task][lane])` for
/// `lane < lanes` — the lane-parallel analogue of the per-replay
/// `fold(0.0, f64::max)` timing extraction. `max` is exact, so the fold
/// order is bitwise-irrelevant and all implementations trivially agree.
/// `out` must hold at least `lanes` slots; slots past `lanes` are left
/// untouched by the scalar path and may be clobbered by a vector one, so
/// callers read only `out[..lanes]`.
pub(crate) fn fold_max_tasks(
    kind: KernelKind,
    finish: &[f64],
    lanes: usize,
    tasks: &[TaskId],
    out: &mut [f64],
) {
    out[..lanes].fill(0.0);
    match (kind, lanes) {
        (KernelKind::Avx2, 4) => fold_max_avx2_checked(finish, tasks, out),
        (KernelKind::Avx2, 8) if avx512_supported() => fold_max_avx512_checked(finish, tasks, out),
        _ => {
            for &t in tasks {
                let at = t as usize * lanes;
                for m in 0..lanes {
                    let v = finish[at + m];
                    out[m] = if out[m] > v { out[m] } else { v };
                }
            }
        }
    }
}

// ---------------------------------------------------------------- scalar

/// Portable lane pass at any width: per task, the per-lane operation
/// sequence mirrors the vector kernels literally — `a > b ? a : b` for
/// every `max` (the exact `_mm256_max_pd`/`_mm512_max_pd` operand
/// selection, NaN included) and one `+` per lane — so all
/// implementations are bitwise identical on every input.
fn replay_scalar(p: &mut LanePass<'_>) -> bool {
    let w = p.width;
    let mut prev = [f64::NEG_INFINITY; LANES_MAX];
    let mut prev_id: TaskId = 0;
    let mut mk = [0.0f64; LANES_MAX];
    for &id in p.order {
        let i = id as usize;
        let at = i * w;
        // Validity first, all lanes, like the vector twins' masks.
        let ge = id > prev_id;
        for m in 0..w {
            let ready = p.ready[at + m];
            let ok = if ge { ready >= prev[m] } else { ready > prev[m] };
            if !ok {
                return false;
            }
        }
        let res = p.resources[i] as usize * w;
        let mut end = [0.0f64; LANES_MAX];
        for m in 0..w {
            let ready = p.ready[at + m];
            prev[m] = ready;
            let free = p.free[res + m];
            // Same float ops as the scalar calendar loop (`max`, `+`) —
            // ternary form mirrors the vector max exactly.
            let start = if ready > free { ready } else { free };
            let e = start + p.durs[at + m];
            p.free[res + m] = e;
            p.finish[at + m] = e;
            mk[m] = if mk[m] > e { mk[m] } else { e };
            end[m] = e;
        }
        prev_id = id;
        for e in p.csr_off[i]..p.csr_off[i + 1] {
            let s = p.csr_dst[e] as usize * w;
            for m in 0..w {
                let cur = p.ready[s + m];
                p.ready[s + m] = if cur > end[m] { cur } else { end[m] };
            }
        }
    }
    p.makespan[..w].copy_from_slice(&mk[..w]);
    true
}

// ----------------------------------------------------------------- avx2

#[cfg(target_arch = "x86_64")]
fn replay_avx2_checked(p: &mut LanePass<'_>) -> bool {
    assert!(
        crate::linalg::kernels::available(KernelKind::Avx2),
        "AVX2 lane pass invoked without CPU support"
    );
    debug_assert_eq!(p.width, 4, "AVX2 lane pass is width 4");
    // SAFETY: AVX2 support verified above; every strided index stays
    // inside the lane arrays (sized n * 4 / max_res * 4 by the engine
    // before the call), and `makespan` holds >= 4 slots.
    unsafe { replay_avx2(p) }
}

#[cfg(not(target_arch = "x86_64"))]
fn replay_avx2_checked(_p: &mut LanePass<'_>) -> bool {
    unreachable!("AVX2 lane pass selected on a non-x86_64 target")
}

#[cfg(target_arch = "x86_64")]
fn fold_max_avx2_checked(finish: &[f64], tasks: &[TaskId], out: &mut [f64]) {
    assert!(
        crate::linalg::kernels::available(KernelKind::Avx2),
        "AVX2 lane fold invoked without CPU support"
    );
    assert!(out.len() >= 4, "AVX2 lane fold stores 4 lanes");
    // SAFETY: AVX2 support verified above; `finish` is lane-strided with
    // 4 lanes, so `t * 4` is in bounds for every listed task.
    unsafe { fold_max_avx2(finish, tasks, out) }
}

#[cfg(not(target_arch = "x86_64"))]
fn fold_max_avx2_checked(_finish: &[f64], _tasks: &[TaskId], _out: &mut [f64]) {
    unreachable!("AVX2 lane fold selected on a non-x86_64 target")
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn replay_avx2(p: &mut LanePass<'_>) -> bool {
    use std::arch::x86_64::*;
    const W: usize = 4;
    let mut prev = _mm256_set1_pd(f64::NEG_INFINITY);
    let mut prev_id: TaskId = 0;
    let mut mk = _mm256_setzero_pd();
    for &id in p.order {
        let i = id as usize;
        let ready = _mm256_loadu_pd(p.ready.as_ptr().add(i * W));
        // Strictly increasing (ready, id) per lane; the id tie-break is
        // shared (one cached order), so it selects the compare predicate.
        let cmp = if id > prev_id {
            _mm256_cmp_pd::<_CMP_GE_OQ>(ready, prev)
        } else {
            _mm256_cmp_pd::<_CMP_GT_OQ>(ready, prev)
        };
        if _mm256_movemask_pd(cmp) != 0b1111 {
            return false;
        }
        prev = ready;
        prev_id = id;
        let res = p.resources[i] as usize * W;
        let free = _mm256_loadu_pd(p.free.as_ptr().add(res));
        // Same float ops as the scalar calendar loop, one per lane.
        let start = _mm256_max_pd(ready, free);
        let end = _mm256_add_pd(start, _mm256_loadu_pd(p.durs.as_ptr().add(i * W)));
        _mm256_storeu_pd(p.free.as_mut_ptr().add(res), end);
        _mm256_storeu_pd(p.finish.as_mut_ptr().add(i * W), end);
        mk = _mm256_max_pd(mk, end);
        for e in p.csr_off[i]..p.csr_off[i + 1] {
            let s = p.csr_dst[e] as usize * W;
            let cur = _mm256_loadu_pd(p.ready.as_ptr().add(s));
            _mm256_storeu_pd(p.ready.as_mut_ptr().add(s), _mm256_max_pd(cur, end));
        }
    }
    _mm256_storeu_pd(p.makespan.as_mut_ptr(), mk);
    true
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn fold_max_avx2(finish: &[f64], tasks: &[TaskId], out: &mut [f64]) {
    use std::arch::x86_64::*;
    let mut acc = _mm256_setzero_pd();
    for &t in tasks {
        acc = _mm256_max_pd(acc, _mm256_loadu_pd(finish.as_ptr().add(t as usize * 4)));
    }
    _mm256_storeu_pd(out.as_mut_ptr(), acc);
}

// --------------------------------------------------------------- avx512

#[cfg(target_arch = "x86_64")]
fn replay_avx512_checked(p: &mut LanePass<'_>) -> bool {
    assert!(avx512_supported(), "AVX-512 lane pass invoked without CPU support");
    debug_assert_eq!(p.width, 8, "AVX-512 lane pass is width 8");
    // SAFETY: avx512f support verified above; every strided index stays
    // inside the lane arrays (sized n * 8 / max_res * 8 by the engine
    // before the call), and `makespan` holds >= 8 slots.
    unsafe { replay_avx512(p) }
}

#[cfg(not(target_arch = "x86_64"))]
fn replay_avx512_checked(_p: &mut LanePass<'_>) -> bool {
    unreachable!("AVX-512 lane pass selected on a non-x86_64 target")
}

#[cfg(target_arch = "x86_64")]
fn fold_max_avx512_checked(finish: &[f64], tasks: &[TaskId], out: &mut [f64]) {
    assert!(avx512_supported(), "AVX-512 lane fold invoked without CPU support");
    assert!(out.len() >= 8, "AVX-512 lane fold stores 8 lanes");
    // SAFETY: avx512f support verified above; `finish` is lane-strided
    // with 8 lanes, so `t * 8` is in bounds for every listed task.
    unsafe { fold_max_avx512(finish, tasks, out) }
}

#[cfg(not(target_arch = "x86_64"))]
fn fold_max_avx512_checked(_finish: &[f64], _tasks: &[TaskId], _out: &mut [f64]) {
    unreachable!("AVX-512 lane fold selected on a non-x86_64 target")
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn replay_avx512(p: &mut LanePass<'_>) -> bool {
    use std::arch::x86_64::*;
    const W: usize = 8;
    let mut prev = _mm512_set1_pd(f64::NEG_INFINITY);
    let mut prev_id: TaskId = 0;
    let mut mk = _mm512_setzero_pd();
    for &id in p.order {
        let i = id as usize;
        let ready = _mm512_loadu_pd(p.ready.as_ptr().add(i * W));
        // Same predicate selection as the AVX2 pass; the 512-bit compare
        // yields a mask register directly — all 8 lanes must pass.
        let cmp = if id > prev_id {
            _mm512_cmp_pd_mask::<_CMP_GE_OQ>(ready, prev)
        } else {
            _mm512_cmp_pd_mask::<_CMP_GT_OQ>(ready, prev)
        };
        if cmp != 0xFF {
            return false;
        }
        prev = ready;
        prev_id = id;
        let res = p.resources[i] as usize * W;
        let free = _mm512_loadu_pd(p.free.as_ptr().add(res));
        // Same float ops as the scalar calendar loop, one per lane.
        let start = _mm512_max_pd(ready, free);
        let end = _mm512_add_pd(start, _mm512_loadu_pd(p.durs.as_ptr().add(i * W)));
        _mm512_storeu_pd(p.free.as_mut_ptr().add(res), end);
        _mm512_storeu_pd(p.finish.as_mut_ptr().add(i * W), end);
        mk = _mm512_max_pd(mk, end);
        for e in p.csr_off[i]..p.csr_off[i + 1] {
            let s = p.csr_dst[e] as usize * W;
            let cur = _mm512_loadu_pd(p.ready.as_ptr().add(s));
            _mm512_storeu_pd(p.ready.as_mut_ptr().add(s), _mm512_max_pd(cur, end));
        }
    }
    _mm512_storeu_pd(p.makespan.as_mut_ptr(), mk);
    true
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn fold_max_avx512(finish: &[f64], tasks: &[TaskId], out: &mut [f64]) {
    use std::arch::x86_64::*;
    let mut acc = _mm512_setzero_pd();
    for &t in tasks {
        acc = _mm512_max_pd(acc, _mm512_loadu_pd(finish.as_ptr().add(t as usize * 8)));
    }
    _mm512_storeu_pd(out.as_mut_ptr(), acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels;

    #[test]
    fn select_lanes_parses_overrides() {
        assert!(select_lanes(Some("on")));
        assert!(!select_lanes(Some("off")));
        assert!(select_lanes(None));
    }

    #[test]
    #[should_panic(expected = "BSF_LANES must be")]
    fn select_lanes_rejects_unknown_value() {
        select_lanes(Some("4"));
    }

    #[test]
    fn select_group_parses_overrides() {
        assert!(select_group(Some("on")));
        assert!(!select_group(Some("off")));
        assert!(select_group(None));
    }

    #[test]
    #[should_panic(expected = "BSF_GROUP must be")]
    fn select_group_rejects_unknown_value() {
        select_group(Some("auto"));
    }

    #[test]
    fn select_width_parses_overrides_and_detects() {
        assert_eq!(select_width(Some("4"), true), 4);
        assert_eq!(select_width(Some("4"), false), 4);
        assert_eq!(select_width(Some("8"), true), 8);
        assert_eq!(select_width(None, true), 8);
        assert_eq!(select_width(None, false), 4);
    }

    #[test]
    #[should_panic(expected = "BSF_LANE_WIDTH=8 requires avx512f")]
    fn select_width_rejects_8_without_avx512() {
        select_width(Some("8"), false);
    }

    #[test]
    #[should_panic(expected = "BSF_LANE_WIDTH must be")]
    fn select_width_rejects_unknown_value() {
        select_width(Some("16"), true);
    }

    /// A small hand-built chain-with-fork graph (raw arrays, no Engine)
    /// so the pass implementations can be compared in isolation, at any
    /// lane width.
    struct Case {
        order: Vec<TaskId>,
        resources: Vec<u32>,
        csr_off: Vec<usize>,
        csr_dst: Vec<TaskId>,
        durs: Vec<f64>,
        n_res: usize,
        width: usize,
    }

    fn chain_case(width: usize) -> Case {
        // 0 → 1 → 2 → 3 on alternating resources, distinct durations per
        // lane so lanes genuinely diverge.
        let n = 4;
        let mut durs = vec![0.0; n * width];
        for (i, d) in durs.iter_mut().enumerate() {
            let (task, lane) = (i / width, i % width);
            *d = 0.25 + task as f64 * 0.5 + lane as f64 * 0.125;
        }
        Case {
            order: vec![0, 1, 2, 3],
            resources: vec![0, 1, 0, 1],
            csr_off: vec![0, 1, 2, 3, 3],
            csr_dst: vec![1, 2, 3],
            durs,
            n_res: 2,
            width,
        }
    }

    fn run_case(kind: KernelKind, c: &Case) -> Option<(Vec<f64>, Vec<f64>)> {
        let n = c.resources.len();
        let w = c.width;
        let mut ready = vec![0.0; n * w];
        let mut free = vec![0.0; c.n_res * w];
        let mut finish = vec![f64::NAN; n * w];
        let mut mk = vec![0.0f64; LANES_MAX];
        let ok = replay(
            kind,
            &mut LanePass {
                order: &c.order,
                resources: &c.resources,
                csr_off: &c.csr_off,
                csr_dst: &c.csr_dst,
                durs: &c.durs,
                ready: &mut ready,
                free: &mut free,
                finish: &mut finish,
                makespan: &mut mk,
                width: w,
            },
        );
        mk.truncate(w);
        ok.then_some((finish, mk))
    }

    #[test]
    fn scalar_lane_pass_matches_per_lane_chain_arithmetic_at_both_widths() {
        for width in [4usize, 8] {
            let c = chain_case(width);
            let (finish, mk) = run_case(KernelKind::Scalar, &c).expect("valid chain order");
            for m in 0..width {
                let mut t = 0.0f64;
                for task in 0..4usize {
                    t += c.durs[task * width + m];
                    assert_eq!(
                        finish[task * width + m].to_bits(),
                        t.to_bits(),
                        "width {width} lane {m} task {task}"
                    );
                }
                assert_eq!(mk[m].to_bits(), t.to_bits(), "width {width} lane {m} makespan");
            }
        }
    }

    #[test]
    fn avx2_lane_pass_matches_scalar_bitwise_when_supported() {
        if !kernels::available(KernelKind::Avx2) {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        let c = chain_case(4);
        let (fs, ms) = run_case(KernelKind::Scalar, &c).expect("scalar pass valid");
        let (fv, mv) = run_case(KernelKind::Avx2, &c).expect("avx2 pass valid");
        for (i, (a, b)) in fs.iter().zip(&fv).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "finish slot {i}");
        }
        for m in 0..4 {
            assert_eq!(ms[m].to_bits(), mv[m].to_bits(), "lane {m} makespan");
        }
    }

    #[test]
    fn avx512_lane_pass_matches_scalar_bitwise_when_supported() {
        if !avx512_supported() {
            eprintln!("skipping: no avx512f on this host");
            return;
        }
        let c = chain_case(8);
        let (fs, ms) = run_case(KernelKind::Scalar, &c).expect("scalar pass valid");
        // The (Avx2 kernel, width 8) pair dispatches to the AVX-512 pass
        // on capable hosts — the exact production route.
        let (fv, mv) = run_case(KernelKind::Avx2, &c).expect("avx512 pass valid");
        for (i, (a, b)) in fs.iter().zip(&fv).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "finish slot {i}");
        }
        for m in 0..8 {
            assert_eq!(ms[m].to_bits(), mv[m].to_bits(), "lane {m} makespan");
        }
    }

    #[test]
    fn width_8_without_avx512_takes_the_scalar_twin() {
        // On hosts without avx512f the (Avx2, 8) pair must quietly take
        // the width-generic scalar twin (bitwise identical), not panic —
        // this is what lets width-8 tests run everywhere. On capable
        // hosts the same call dispatches to AVX-512, which the race
        // above already pins to the scalar result.
        let c = chain_case(8);
        let (fs, _) = run_case(KernelKind::Scalar, &c).expect("scalar pass valid");
        let (fd, _) = run_case(KernelKind::Avx2, &c).expect("dispatched pass valid");
        for (i, (a, b)) in fs.iter().zip(&fd).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "finish slot {i}");
        }
    }

    #[test]
    fn stale_order_rejected_by_all_implementations() {
        // Two independent same-resource tasks recorded in the order
        // [1, 0]: task 0's (0.0, 0) does not exceed task 1's (0.0, 1)
        // lexicographically, so every implementation must reject.
        for width in [4usize, 8] {
            let c = Case {
                order: vec![1, 0],
                resources: vec![0, 0],
                csr_off: vec![0, 0, 0],
                csr_dst: vec![],
                durs: vec![1.0; 2 * width],
                n_res: 1,
                width,
            };
            assert!(
                run_case(KernelKind::Scalar, &c).is_none(),
                "scalar accepted a stale order at width {width}"
            );
            if kernels::available(KernelKind::Avx2) {
                // Width 4 → AVX2; width 8 → AVX-512 when available, else
                // the scalar twin again — rejection is required either way.
                assert!(
                    run_case(KernelKind::Avx2, &c).is_none(),
                    "vector pass accepted a stale order at width {width}"
                );
            }
        }
    }

    #[test]
    fn fold_max_tasks_picks_lane_maxima_at_both_widths() {
        for width in [4usize, 8] {
            // finish for 3 tasks × width lanes; fold over tasks {0, 2}.
            let mut finish = vec![0.0; 3 * width];
            for (i, f) in finish.iter_mut().enumerate() {
                let (task, lane) = (i / width, i % width);
                *f = (task * 10 + lane) as f64;
            }
            let tasks: Vec<TaskId> = vec![0, 2];
            let mut out = [0.0f64; LANES_MAX];
            fold_max_tasks(KernelKind::Scalar, &finish, width, &tasks, &mut out);
            for (m, &v) in out.iter().take(width).enumerate() {
                assert_eq!(v, (20 + m) as f64, "width {width} lane {m}");
            }
            if kernels::available(KernelKind::Avx2) {
                let mut out_v = [0.0f64; LANES_MAX];
                fold_max_tasks(KernelKind::Avx2, &finish, width, &tasks, &mut out_v);
                for m in 0..width {
                    assert_eq!(out[m].to_bits(), out_v[m].to_bits(), "width {width} lane {m}");
                }
            }
        }
    }
}
