//! Lane-parallel order-cached replay: simulate up to [`LANES`] independent
//! jittered replays of one graph in a single pass over the cached pop
//! order.
//!
//! PR 4's order-cached replay reduced a replay to two IEEE-754 operations
//! per task — `start = max(ready, resource_free)` and `end = start + dur` —
//! plus an exact `(ready, id)` validity check. Both `max` and `+` return
//! the unique correctly-rounded result for their operands, so evaluating
//! them **per lane** over four independent duration sets is bitwise
//! identical to evaluating the four replays one at a time: the same trick
//! `linalg::kernels` uses for the compute plane (identical per-lane
//! operation sequence in a scalar twin and an AVX2 kernel), applied to
//! the simulation plane.
//!
//! ## Layout
//!
//! Every lane array is **lane-strided**: element `[task][lane]` lives at
//! `task * LANES + lane`, so one task's four lanes are contiguous and a
//! single `_mm256_loadu_pd` fetches all four replays' values. The same
//! layout covers `ready`/`finish` (per task) and `free` (per resource).
//!
//! ## Per-lane validity
//!
//! The scalar validity check accepts task `id` when `(ready, id)` exceeds
//! the previous pop lexicographically. The task *order* is shared across
//! lanes (it is the one cached permutation), so the id comparison is one
//! scalar branch per task and only the `ready` comparison is lane-wise:
//! `id > prev_id` selects a `>=` compare, otherwise `>` — vectorized as
//! `_mm256_cmp_pd` (`_CMP_GE_OQ`/`_CMP_GT_OQ`) + movemask, all four lanes
//! required to pass. Any failing lane aborts the whole pass ([`replay`]
//! returns `false`) because the sequential semantics of the failing lane
//! (a calendar fallback that *refreshes the cache*) would change what the
//! later lanes are checked against; the engine then re-runs the batch
//! through the ordinary scalar `run_reuse` path in lane order, which
//! reproduces the one-at-a-time loop exactly (see
//! `Engine::run_lanes`). NaN ready times (only reachable via unchecked
//! non-finite durations in release builds) fail both ordered compares and
//! reject, exactly like the scalar check.
//!
//! ## Dispatch
//!
//! The implementation pair dispatches through the *existing*
//! `BSF_KERNEL` mechanism (`linalg::kernels::active()`): the scalar twin
//! performs the identical per-lane operation sequence (`a > b ? a : b`
//! mirrors `_mm256_max_pd` exactly, including NaN operand selection), so
//! the two agree bit for bit on every input — pinned by the unit tests
//! below and by CI running the whole suite under both `BSF_KERNEL`
//! values. A separate process-wide `BSF_LANES=on|off` switch (unset =
//! `on`; anything else panics loudly, like `BSF_SCHED`) disables the
//! vectorized pass entirely, forcing every lane batch through the
//! sequential scalar path — results are bitwise identical either way, so
//! CI crosses it with one representative kernel/scheduler cell.

use crate::linalg::kernels::KernelKind;
use crate::simulator::engine::TaskId;

/// Lane width of the batched replay pass (AVX2 holds four f64 lanes).
/// Remainder batches (fewer than `LANES` replays left) take the scalar
/// one-at-a-time path.
pub const LANES: usize = 4;

static ACTIVE_LANES: std::sync::OnceLock<bool> = std::sync::OnceLock::new();

/// Whether the vectorized lane pass is enabled for this process (reads
/// `BSF_LANES` once). Engines without an `Engine::set_lane_mode` override
/// dispatch through this, so CI can run the whole suite with the lane
/// pass forced off (every batch then exercises the sequential fallback).
pub fn lanes_enabled() -> bool {
    *ACTIVE_LANES.get_or_init(|| select_lanes(std::env::var("BSF_LANES").ok().as_deref()))
}

/// Pure selection logic (unit-tested separately from process env state).
/// Requesting anything but `on`/`off` panics loudly rather than silently
/// falling back — an override that does nothing would invalidate any
/// benchmark run on top of it.
fn select_lanes(request: Option<&str>) -> bool {
    match request {
        Some("on") => true,
        Some("off") => false,
        Some(other) => panic!("BSF_LANES must be 'on' or 'off', got '{other}'"),
        None => true,
    }
}

/// Borrowed view of everything one lane-batched pass needs: the engine's
/// graph (cached pop order + SoA columns + CSR successors) and its
/// lane-strided scratch. `ready` and `free` must arrive zeroed; `durs`
/// holds the `LANES` duration sets task-major (`[task * LANES + lane]`).
pub(crate) struct LanePass<'a> {
    pub order: &'a [TaskId],
    pub resources: &'a [u32],
    pub csr_off: &'a [usize],
    pub csr_dst: &'a [TaskId],
    pub durs: &'a [f64],
    pub ready: &'a mut [f64],
    pub free: &'a mut [f64],
    pub finish: &'a mut [f64],
    /// Per-lane running makespan (the fused `max` fold over finish times).
    pub makespan: &'a mut [f64; LANES],
}

/// Execute the lane-batched linear pass through `kind`'s implementation.
/// Returns `false` as soon as any lane fails the validity check (scratch
/// is then undefined — the caller re-runs the batch sequentially);
/// returns `true` with `finish`/`makespan` holding all `LANES` replays'
/// results otherwise. Zero heap allocations.
pub(crate) fn replay(kind: KernelKind, p: &mut LanePass<'_>) -> bool {
    match kind {
        KernelKind::Scalar => replay_scalar(p),
        KernelKind::Avx2 => replay_avx2_checked(p),
    }
}

/// Fold `out[lane] = max(0, max over tasks of finish[task][lane])` — the
/// lane-parallel analogue of the per-replay `fold(0.0, f64::max)` timing
/// extraction. `max` is exact, so the fold order is bitwise-irrelevant
/// and both implementations trivially agree.
pub(crate) fn fold_max_tasks(
    kind: KernelKind,
    finish: &[f64],
    lanes: usize,
    tasks: &[TaskId],
    out: &mut [f64; LANES],
) {
    out.fill(0.0);
    if lanes == LANES && kind == KernelKind::Avx2 {
        fold_max_avx2_checked(finish, tasks, out);
    } else {
        for &t in tasks {
            let at = t as usize * lanes;
            for m in 0..lanes {
                let v = finish[at + m];
                out[m] = if out[m] > v { out[m] } else { v };
            }
        }
    }
}

// ---------------------------------------------------------------- scalar

/// Portable lane pass: per task, the per-lane operation sequence mirrors
/// the AVX2 kernel literally — `a > b ? a : b` for every `max` (the exact
/// `_mm256_max_pd` operand selection, NaN included) and one `+` per lane
/// — so the two implementations are bitwise identical on every input.
fn replay_scalar(p: &mut LanePass<'_>) -> bool {
    let mut prev = [f64::NEG_INFINITY; LANES];
    let mut prev_id: TaskId = 0;
    let mut mk = [0.0f64; LANES];
    for &id in p.order {
        let i = id as usize;
        let at = i * LANES;
        // Validity first, all lanes, like the vector twin's movemask.
        let ge = id > prev_id;
        for m in 0..LANES {
            let ready = p.ready[at + m];
            let ok = if ge { ready >= prev[m] } else { ready > prev[m] };
            if !ok {
                return false;
            }
        }
        let res = p.resources[i] as usize * LANES;
        let mut end = [0.0f64; LANES];
        for m in 0..LANES {
            let ready = p.ready[at + m];
            prev[m] = ready;
            let free = p.free[res + m];
            // Same float ops as the scalar calendar loop (`max`, `+`) —
            // ternary form mirrors `_mm256_max_pd` exactly.
            let start = if ready > free { ready } else { free };
            let e = start + p.durs[at + m];
            p.free[res + m] = e;
            p.finish[at + m] = e;
            mk[m] = if mk[m] > e { mk[m] } else { e };
            end[m] = e;
        }
        prev_id = id;
        for e in p.csr_off[i]..p.csr_off[i + 1] {
            let s = p.csr_dst[e] as usize * LANES;
            for m in 0..LANES {
                let cur = p.ready[s + m];
                p.ready[s + m] = if cur > end[m] { cur } else { end[m] };
            }
        }
    }
    *p.makespan = mk;
    true
}

// ----------------------------------------------------------------- avx2

#[cfg(target_arch = "x86_64")]
fn replay_avx2_checked(p: &mut LanePass<'_>) -> bool {
    assert!(
        crate::linalg::kernels::available(KernelKind::Avx2),
        "AVX2 lane pass invoked without CPU support"
    );
    // SAFETY: AVX2 support verified above; every strided index stays
    // inside the lane arrays (sized n * LANES / max_res * LANES by the
    // engine before the call).
    unsafe { replay_avx2(p) }
}

#[cfg(not(target_arch = "x86_64"))]
fn replay_avx2_checked(_p: &mut LanePass<'_>) -> bool {
    unreachable!("AVX2 lane pass selected on a non-x86_64 target")
}

#[cfg(target_arch = "x86_64")]
fn fold_max_avx2_checked(finish: &[f64], tasks: &[TaskId], out: &mut [f64; LANES]) {
    assert!(
        crate::linalg::kernels::available(KernelKind::Avx2),
        "AVX2 lane fold invoked without CPU support"
    );
    // SAFETY: AVX2 support verified above; `finish` is lane-strided with
    // LANES lanes, so `t * LANES` is in bounds for every listed task.
    unsafe { fold_max_avx2(finish, tasks, out) }
}

#[cfg(not(target_arch = "x86_64"))]
fn fold_max_avx2_checked(_finish: &[f64], _tasks: &[TaskId], _out: &mut [f64; LANES]) {
    unreachable!("AVX2 lane fold selected on a non-x86_64 target")
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn replay_avx2(p: &mut LanePass<'_>) -> bool {
    use std::arch::x86_64::*;
    let mut prev = _mm256_set1_pd(f64::NEG_INFINITY);
    let mut prev_id: TaskId = 0;
    let mut mk = _mm256_setzero_pd();
    for &id in p.order {
        let i = id as usize;
        let ready = _mm256_loadu_pd(p.ready.as_ptr().add(i * LANES));
        // Strictly increasing (ready, id) per lane; the id tie-break is
        // shared (one cached order), so it selects the compare predicate.
        let cmp = if id > prev_id {
            _mm256_cmp_pd::<_CMP_GE_OQ>(ready, prev)
        } else {
            _mm256_cmp_pd::<_CMP_GT_OQ>(ready, prev)
        };
        if _mm256_movemask_pd(cmp) != 0b1111 {
            return false;
        }
        prev = ready;
        prev_id = id;
        let res = p.resources[i] as usize * LANES;
        let free = _mm256_loadu_pd(p.free.as_ptr().add(res));
        // Same float ops as the scalar calendar loop, one per lane.
        let start = _mm256_max_pd(ready, free);
        let end = _mm256_add_pd(start, _mm256_loadu_pd(p.durs.as_ptr().add(i * LANES)));
        _mm256_storeu_pd(p.free.as_mut_ptr().add(res), end);
        _mm256_storeu_pd(p.finish.as_mut_ptr().add(i * LANES), end);
        mk = _mm256_max_pd(mk, end);
        for e in p.csr_off[i]..p.csr_off[i + 1] {
            let s = p.csr_dst[e] as usize * LANES;
            let cur = _mm256_loadu_pd(p.ready.as_ptr().add(s));
            _mm256_storeu_pd(p.ready.as_mut_ptr().add(s), _mm256_max_pd(cur, end));
        }
    }
    _mm256_storeu_pd(p.makespan.as_mut_ptr(), mk);
    true
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn fold_max_avx2(finish: &[f64], tasks: &[TaskId], out: &mut [f64; LANES]) {
    use std::arch::x86_64::*;
    let mut acc = _mm256_setzero_pd();
    for &t in tasks {
        acc = _mm256_max_pd(acc, _mm256_loadu_pd(finish.as_ptr().add(t as usize * LANES)));
    }
    _mm256_storeu_pd(out.as_mut_ptr(), acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::kernels;

    #[test]
    fn select_lanes_parses_overrides() {
        assert!(select_lanes(Some("on")));
        assert!(!select_lanes(Some("off")));
        assert!(select_lanes(None));
    }

    #[test]
    #[should_panic(expected = "BSF_LANES must be")]
    fn select_lanes_rejects_unknown_value() {
        select_lanes(Some("4"));
    }

    /// A small hand-built chain-with-fork graph (raw arrays, no Engine)
    /// so the pass implementations can be compared in isolation.
    struct Case {
        order: Vec<TaskId>,
        resources: Vec<u32>,
        csr_off: Vec<usize>,
        csr_dst: Vec<TaskId>,
        durs: Vec<f64>,
        n_res: usize,
    }

    fn chain_case() -> Case {
        // 0 → 1 → 2 → 3 on alternating resources, distinct durations per
        // lane so lanes genuinely diverge.
        let n = 4;
        let mut durs = vec![0.0; n * LANES];
        for (i, d) in durs.iter_mut().enumerate() {
            let (task, lane) = (i / LANES, i % LANES);
            *d = 0.25 + task as f64 * 0.5 + lane as f64 * 0.125;
        }
        Case {
            order: vec![0, 1, 2, 3],
            resources: vec![0, 1, 0, 1],
            csr_off: vec![0, 1, 2, 3, 3],
            csr_dst: vec![1, 2, 3],
            durs,
            n_res: 2,
        }
    }

    fn run_case(kind: KernelKind, c: &Case) -> Option<(Vec<f64>, [f64; LANES])> {
        let n = c.resources.len();
        let mut ready = vec![0.0; n * LANES];
        let mut free = vec![0.0; c.n_res * LANES];
        let mut finish = vec![f64::NAN; n * LANES];
        let mut mk = [0.0f64; LANES];
        let ok = replay(
            kind,
            &mut LanePass {
                order: &c.order,
                resources: &c.resources,
                csr_off: &c.csr_off,
                csr_dst: &c.csr_dst,
                durs: &c.durs,
                ready: &mut ready,
                free: &mut free,
                finish: &mut finish,
                makespan: &mut mk,
            },
        );
        ok.then_some((finish, mk))
    }

    #[test]
    fn scalar_lane_pass_matches_per_lane_chain_arithmetic() {
        let c = chain_case();
        let (finish, mk) = run_case(KernelKind::Scalar, &c).expect("valid chain order");
        for m in 0..LANES {
            let mut t = 0.0f64;
            for task in 0..4usize {
                t += c.durs[task * LANES + m];
                assert_eq!(finish[task * LANES + m].to_bits(), t.to_bits(), "lane {m} task {task}");
            }
            assert_eq!(mk[m].to_bits(), t.to_bits(), "lane {m} makespan");
        }
    }

    #[test]
    fn avx2_lane_pass_matches_scalar_bitwise_when_supported() {
        if !kernels::available(KernelKind::Avx2) {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        let c = chain_case();
        let (fs, ms) = run_case(KernelKind::Scalar, &c).expect("scalar pass valid");
        let (fv, mv) = run_case(KernelKind::Avx2, &c).expect("avx2 pass valid");
        for (i, (a, b)) in fs.iter().zip(&fv).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "finish slot {i}");
        }
        for m in 0..LANES {
            assert_eq!(ms[m].to_bits(), mv[m].to_bits(), "lane {m} makespan");
        }
    }

    #[test]
    fn stale_order_rejected_by_both_implementations() {
        // Two independent same-resource tasks recorded in the order
        // [1, 0]: task 0's (0.0, 0) does not exceed task 1's (0.0, 1)
        // lexicographically, so every implementation must reject.
        let c = Case {
            order: vec![1, 0],
            resources: vec![0, 0],
            csr_off: vec![0, 0, 0],
            csr_dst: vec![],
            durs: vec![1.0; 2 * LANES],
            n_res: 1,
        };
        assert!(run_case(KernelKind::Scalar, &c).is_none(), "scalar accepted a stale order");
        if kernels::available(KernelKind::Avx2) {
            assert!(run_case(KernelKind::Avx2, &c).is_none(), "avx2 accepted a stale order");
        }
    }

    #[test]
    fn fold_max_tasks_picks_lane_maxima() {
        // finish for 3 tasks × LANES lanes; fold over tasks {0, 2}.
        let mut finish = vec![0.0; 3 * LANES];
        for (i, f) in finish.iter_mut().enumerate() {
            let (task, lane) = (i / LANES, i % LANES);
            *f = (task * 10 + lane) as f64;
        }
        let tasks: Vec<TaskId> = vec![0, 2];
        let mut out = [0.0f64; LANES];
        fold_max_tasks(KernelKind::Scalar, &finish, LANES, &tasks, &mut out);
        for (m, &v) in out.iter().enumerate() {
            assert_eq!(v, (20 + m) as f64, "lane {m}");
        }
        if kernels::available(KernelKind::Avx2) {
            let mut out_v = [0.0f64; LANES];
            fold_max_tasks(KernelKind::Avx2, &finish, LANES, &tasks, &mut out_v);
            for m in 0..LANES {
                assert_eq!(out[m].to_bits(), out_v[m].to_bits(), "lane {m}");
            }
        }
    }
}
