//! Trace export: per-node timelines of a simulated Algorithm-2 iteration.
//!
//! Produces [Chrome trace-event format](https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU)
//! JSON (open in `chrome://tracing` or [Perfetto](https://ui.perfetto.dev))
//! — every broadcast send, Map+fold, reduce hop and master fold appears as
//! a duration event on its node's row, making stragglers, tree pipelining
//! and the master bottleneck visible at a glance.

use std::fmt::Write as _;

use crate::simulator::cluster::{simulate_iteration_full, CostProvider, SimParams};
use crate::simulator::engine::Engine;
use crate::util::Rng;

/// One executed task on a node's timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Phase label (`bcast`, `map+fold`, `reduce-send`, …).
    pub label: &'static str,
    /// Node id (0 = master; `masters..` = workers).
    pub resource: u32,
    /// Start time (seconds).
    pub start: f64,
    /// Duration (seconds).
    pub duration: f64,
}

/// A full iteration trace.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Events sorted by (resource, start).
    pub events: Vec<TraceEvent>,
    /// Makespan (seconds).
    pub total: f64,
}

impl Trace {
    /// Extract the trace from an executed engine.
    pub fn from_engine(eng: &Engine, finish: &[f64]) -> Trace {
        let mut events: Vec<TraceEvent> = eng
            .labels()
            .iter()
            .zip(finish)
            .enumerate()
            .map(|(id, (&label, &end))| (eng.spec(id as crate::simulator::TaskId), label, end))
            .filter(|(spec, label, _)| spec.duration > 0.0 || !label.is_empty())
            .map(|(spec, label, end)| TraceEvent {
                label: if label.is_empty() { "task" } else { label },
                resource: spec.resource,
                start: end - spec.duration,
                duration: spec.duration,
            })
            .collect();
        events.sort_by(|a, b| {
            (a.resource, a.start)
                .partial_cmp(&(b.resource, b.start))
                .expect("finite times")
        });
        Trace { events, total: Engine::makespan(finish) }
    }

    /// Busy fraction of a node (time occupied / makespan).
    pub fn utilization(&self, resource: u32) -> f64 {
        if self.total == 0.0 {
            return 0.0;
        }
        let busy: f64 = self
            .events
            .iter()
            .filter(|e| e.resource == resource)
            .map(|e| e.duration)
            .sum();
        busy / self.total
    }

    /// Serialize as Chrome trace-event JSON (times in µs, as the format
    /// expects).
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[\n");
        for (i, e) in self.events.iter().enumerate() {
            let name = if e.resource == 0 {
                "master".to_string()
            } else {
                format!("worker {}", e.resource)
            };
            let _ = write!(
                out,
                "{{\"name\":\"{}\",\"cat\":\"bsf\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
                 \"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"node\":\"{}\"}}}}",
                e.label,
                e.resource,
                e.start * 1e6,
                e.duration * 1e6,
                name
            );
            out.push_str(if i + 1 < self.events.len() { ",\n" } else { "\n" });
        }
        out.push_str("]}\n");
        out
    }

    /// Write the Chrome JSON to a file.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_chrome_json())
    }
}

/// Simulate one iteration and capture its trace.
pub fn trace_iteration(
    k: usize,
    l: usize,
    params: &SimParams,
    provider: &mut dyn CostProvider,
    rng: &mut Rng,
) -> (crate::simulator::IterationTiming, Trace) {
    let (timing, eng, finish) = simulate_iteration_full(k, l, params, provider, rng);
    let trace = Trace::from_engine(&eng, &finish);
    (timing, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::AnalyticCost;

    fn traced(k: usize) -> (crate::simulator::IterationTiming, Trace) {
        let l = 1024;
        let mut prov = AnalyticCost { t_map_full: 0.1, l, t_a: 1e-6, t_p: 1e-4 };
        let params = SimParams::new(l, l);
        trace_iteration(k, l, &params, &mut prov, &mut Rng::new(1))
    }

    #[test]
    fn trace_covers_all_phases() {
        let (_t, trace) = traced(8);
        let labels: std::collections::HashSet<&str> =
            trace.events.iter().map(|e| e.label).collect();
        for want in ["bcast", "map+fold", "reduce-send", "master-fold", "post"] {
            assert!(labels.contains(want), "missing {want}: {labels:?}");
        }
    }

    #[test]
    fn events_fit_in_makespan_and_dont_overlap_per_node() {
        let (t, trace) = traced(16);
        assert!(trace.total > 0.0);
        assert!((trace.total - t.total).abs() < 1e-15);
        let mut last_end: std::collections::HashMap<u32, f64> = Default::default();
        for e in &trace.events {
            assert!(e.start >= -1e-12, "negative start");
            assert!(e.start + e.duration <= trace.total + 1e-12);
            let prev = last_end.entry(e.resource).or_insert(0.0);
            assert!(e.start >= *prev - 1e-12, "overlap on node {}", e.resource);
            *prev = e.start + e.duration;
        }
    }

    #[test]
    fn worker_utilization_reasonable() {
        let (_t, trace) = traced(4);
        // Each of the 4 workers computes ~l/4 of a 0.1 s map: utilization
        // should be dominated by compute and bounded by 1.
        for w in 1..=4u32 {
            let u = trace.utilization(w);
            assert!(u > 0.5 && u <= 1.0, "worker {w}: {u}");
        }
        assert!(trace.utilization(99) == 0.0);
    }

    #[test]
    fn chrome_json_is_parseable() {
        let (_t, trace) = traced(3);
        let json = trace.to_chrome_json();
        let parsed = crate::util::Json::parse(&json).expect("valid json");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), trace.events.len());
        let first = &events[0];
        assert_eq!(first.get("ph").unwrap().as_str(), Some("X"));
        assert!(first.get("ts").unwrap().as_f64().is_some());
    }

    #[test]
    fn save_roundtrip() {
        let dir = std::env::temp_dir().join("bsf_trace_test");
        let path = dir.join("t.json");
        let (_t, trace) = traced(2);
        trace.save(&path).unwrap();
        let src = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::Json::parse(&src).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
