//! Algorithm-2 timeline simulation on the task-graph engine.
//!
//! One simulated iteration reproduces the exact structure of the paper's
//! Algorithm 2:
//!
//! 1. master broadcasts the current approximation (tree or linear);
//! 2. every worker executes Map over its sublist and folds it locally
//!    (`chunk` map applications + `chunk − 1` applications of `⊕`);
//! 3. the partial foldings are reduced back to the master (in-tree folding,
//!    like `MPI_Reduce`, or gather-then-fold, like the cost metric's
//!    `(K−1)·t_a` term assumes — an explicit [`ReduceMode`]);
//! 4. the master post-processes (`Compute` + `StopCond`, cost `t_p`) and
//!    broadcasts the exit flag (latency-only payload).
//!
//! Node compute/communication steps occupy their node's serial resource, so
//! e.g. a binomial-tree root that must send to `log K` children pays for
//! each send — the engine captures pipelining and stragglers that the
//! closed-form eq. (8) averages away.
//!
//! ## Hot-path structure
//!
//! For a fixed `(K, l, params)` the task *graph* is iteration-invariant;
//! only durations change (provider samples × jitter). The sweep hot path
//! therefore builds an [`IterationTemplate`] once and
//! [`IterationTemplate::replay`]s it per iteration: the graph, CSR edges
//! and engine scratch are all reused, so a replay performs zero heap
//! allocations. Durations are re-derived from a kind-grouped SoA table
//! (tag column in task-id order + dense per-kind payload columns), and
//! the engine serves repeat replays through its order-cached linear path
//! when the pop order is unchanged — no event queue at all (see
//! `engine.rs`; [`IterationTemplate::reset_to`] invalidates the cache
//! with the graph). When the configuration is fully deterministic (zero
//! jitter and a [`CostProvider::is_deterministic`] provider) every
//! iteration is identical, and [`simulate_run`] simulates one iteration
//! and replicates the timing — a Fig.-6-style sweep then costs one
//! engine run per K. Under jitter that shortcut is unavailable, so
//! [`IterationTemplate::run_into`] instead groups its replays into
//! lane-width batches: up to [`Engine::dispatch_width`] independent
//! duration sets (4 with AVX2, 8 with AVX-512 — see `lanes.rs`) execute
//! through one shared pass over the cached pop order (see `engine.rs`
//! "Lane-parallel replay"); remainder batches are padded with a
//! duplicated lane instead of running scalar — bitwise identical to
//! replaying one iteration at a time either way. Sweep cells whose
//! [`ShapeClass`] keys compare equal share one template across cell
//! boundaries too — even when their sizes, network costs, and jitter
//! differ, since those only set the duration *payload*
//! ([`IterationTemplate::bind_cell`] swaps it in place without touching
//! the graph or the order cache): [`IterationTemplate::run_group_into`]
//! rides a whole group of [`GroupCell`]s through shared lane batches.

use crate::linalg::kernels;
use crate::net::{CollectiveAlgo, CollectiveSchedule, NetworkParams};
use crate::simulator::engine::{Engine, SchedCounters, TaskId};
use crate::simulator::faults::RecoveryPolicy;
use crate::simulator::lanes::{self, LANES_MAX};
use crate::util::Rng;

/// How partial foldings travel back to the master.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceMode {
    /// The configuration eq. (8) models and the BSF-skeleton implements:
    /// partials relay to the master over a binomial tree (depth
    /// `⌈log2(K+1)⌉`, constant message size — the paper's simplification),
    /// then the master applies `⊕` K−1 times (the `(K−1)·t_a` term).
    TreeMasterFold,
    /// `MPI_Reduce`: tree schedule, each merge folds at the receiving node
    /// — only ~log K fold applications on the critical path, so the
    /// speedup peaks *later* than eq. (8) predicts (ablation ABL1).
    InTree,
    /// Flat `MPI_Gather` + master-side fold: K messages serialising at the
    /// master NIC then K−1 folds — linear communication, the pessimistic
    /// extreme (ablation ABL1).
    GatherThenFold,
}

/// Simulation parameters for one cluster configuration.
///
/// `PartialEq` is exact (every field, f64s bitwise via `==`). Only the
/// *structural* fields (`algo`, `reduce_mode`, `masters`) enter the
/// [`ShapeClass`] key; the network model, payload word counts and
/// jitter sigmas are duration payload that a shared template swaps per
/// cell via [`IterationTemplate::bind_cell`].
#[derive(Debug, Clone, PartialEq)]
pub struct SimParams {
    /// Interconnect cost model.
    pub net: NetworkParams,
    /// Collective schedule shape.
    pub algo: CollectiveAlgo,
    /// Reduce strategy.
    pub reduce_mode: ReduceMode,
    /// f64 words in the downlink broadcast payload (the approximation).
    pub words_down: usize,
    /// f64 words in each uplink partial folding.
    pub words_up: usize,
    /// Lognormal sigma for compute-time jitter (0 = deterministic).
    pub jitter_comp: f64,
    /// Lognormal sigma for per-message jitter (0 = deterministic).
    pub jitter_comm: f64,
    /// Number of master nodes (1 = the BSF model; ≥2 is the §7-Q5 ablation).
    pub masters: usize,
}

impl SimParams {
    /// Deterministic defaults on the paper's calibrated network.
    pub fn new(words_down: usize, words_up: usize) -> SimParams {
        SimParams {
            net: NetworkParams::tornado_susu(),
            algo: CollectiveAlgo::BinomialTree,
            reduce_mode: ReduceMode::TreeMasterFold,
            words_down,
            words_up,
            jitter_comp: 0.0,
            jitter_comm: 0.0,
            masters: 1,
        }
    }
}

/// Source of compute-step durations (the node "black box" of the model).
pub trait CostProvider {
    /// Time for one worker to Map a sublist of `chunk` elements
    /// (excluding the local fold).
    fn map_time(&mut self, worker: usize, chunk: usize) -> f64;
    /// Time for one application of `⊕` (the model's `t_a`).
    fn combine_time(&mut self) -> f64;
    /// Master post-processing time (the model's `t_p`).
    fn post_time(&mut self) -> f64;
    /// True when every call with the same arguments returns the same value
    /// (no internal sampling). Enables [`simulate_run`]'s
    /// simulate-once-replicate fast path for zero-jitter configurations.
    /// Defaults to `false` — stochastic unless a provider opts in.
    fn is_deterministic(&self) -> bool {
        false
    }
}

/// Instantiates per-stream [`CostProvider`]s for parallel sweeps.
///
/// A K-sweep evaluates many worker counts concurrently; threading one
/// `&mut CostProvider` through them serially would make results depend on
/// evaluation order. A factory instead derives an *independent* provider
/// per stream id (we key streams by K), so every K consumes its own
/// deterministic sample sequence and a parallel sweep is bitwise identical
/// to the serial one at any thread count (see `rust/tests/determinism.rs`).
pub trait CostFactory: Sync {
    /// Create the provider for stream `stream` (deterministic in
    /// `(self, stream)`).
    fn instance(&self, stream: u64) -> Box<dyn CostProvider + Send>;
}

/// Analytic provider: linear-in-chunk Map cost derived from the whole-list
/// time `t_map_full` — exactly the BSF cost metric's assumption.
#[derive(Debug, Clone)]
pub struct AnalyticCost {
    /// Time to Map the entire list on one node (the model's `t_Map`).
    pub t_map_full: f64,
    /// List length `l`.
    pub l: usize,
    /// One `⊕` application (the model's `t_a`).
    pub t_a: f64,
    /// Master post time (the model's `t_p`).
    pub t_p: f64,
}

impl CostProvider for AnalyticCost {
    fn map_time(&mut self, _worker: usize, chunk: usize) -> f64 {
        self.t_map_full * chunk as f64 / self.l as f64
    }
    fn combine_time(&mut self) -> f64 {
        self.t_a
    }
    fn post_time(&mut self) -> f64 {
        self.t_p
    }
    fn is_deterministic(&self) -> bool {
        true
    }
}

impl CostFactory for AnalyticCost {
    fn instance(&self, _stream: u64) -> Box<dyn CostProvider + Send> {
        Box::new(self.clone())
    }
}

/// Sampled provider: Map durations drawn from per-element samples measured
/// on this machine (live PJRT kernel executions) — the "hybrid" empirical
/// mode of DESIGN.md §4.
#[derive(Debug, Clone)]
pub struct SampledCost {
    /// Measured per-element Map times (seconds/element). Shared, so
    /// [`CostFactory::instance`] is O(1) per K-point instead of cloning
    /// the whole sample set per stream.
    pub per_elem: std::sync::Arc<Vec<f64>>,
    /// Measured `t_a`.
    pub t_a: f64,
    /// Measured `t_p`.
    pub t_p: f64,
    /// Private sample-selection stream.
    pub rng: Rng,
}

impl CostProvider for SampledCost {
    fn map_time(&mut self, _worker: usize, chunk: usize) -> f64 {
        let s = self.per_elem[self.rng.below(self.per_elem.len() as u64) as usize];
        s * chunk as f64
    }
    fn combine_time(&mut self) -> f64 {
        self.t_a
    }
    fn post_time(&mut self) -> f64 {
        self.t_p
    }
}

impl CostFactory for SampledCost {
    fn instance(&self, stream: u64) -> Box<dyn CostProvider + Send> {
        // Child stream derived from this provider's own rng state, without
        // advancing it: instance(s) is a pure function of (self, s).
        Box::new(SampledCost { rng: self.rng.split(stream), ..self.clone() })
    }
}

/// Timing breakdown of one simulated iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationTiming {
    /// When the last worker received the approximation.
    pub broadcast_done: f64,
    /// When the last worker finished Map + local fold.
    pub map_done: f64,
    /// When the master held the full folding.
    pub reduce_done: f64,
    /// When the master finished Compute + StopCond.
    pub post_done: f64,
    /// End of the exit-flag broadcast — the iteration period.
    pub total: f64,
}

/// How a non-message task's duration is (re)computed on each replay
/// (messages carry a [`CommRule`] instead — see [`DurTable::push_comm`]).
/// Compute durations defer to the per-replay [`CostProvider`] calls so
/// sampled providers redraw every iteration exactly like the
/// rebuild-per-iteration path did.
#[derive(Debug, Clone, Copy)]
enum DurKind {
    /// Constant duration (relays, placeholder zero tasks).
    Fixed(f64),
    /// Worker Map + local fold: `map_time(worker, chunk) +
    /// (chunk−1)·combine_time()`; × comp jitter.
    MapFold { worker: u32, chunk: u32 },
    /// `n` applications of `⊕` at one node; × comp jitter.
    FoldN(u32),
    /// Master post-processing (`post_time()`); × comp jitter.
    Post,
}

/// One-byte duration-kind tag, in task-id order (see [`DurTable`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
enum DurTag {
    Fixed,
    Comm,
    MapFold,
    FoldN,
    Post,
}

/// How a message task's base cost derives from a cell's [`SimParams`].
/// Stored alongside the evaluated base so [`IterationTemplate::bind_cell`]
/// can re-price every message for a new cell without rebuilding the graph;
/// both the build and every rebind price through [`comm_base`], so a
/// rebind to the original params is bitwise identical to the build.
#[derive(Debug, Clone, Copy)]
enum CommRule {
    /// Downlink payload: `p2p(words_down)`.
    Down,
    /// Uplink payload: `p2p(words_up)`.
    Up,
    /// Half an uplink transfer (the split send/recv halves of a gather).
    HalfUp,
    /// Fixed word count (e.g. the two-word redispatch range descriptor).
    Words(u32),
}

/// The single message-pricing function: evaluated at build time and
/// re-evaluated against each cell's params on every
/// [`IterationTemplate::bind_cell`]. `contenders` is the number of
/// transfers concurrently in flight in the message's collective round
/// (structural — it follows from the tree shapes, so a payload rebind
/// never changes it); under [`crate::net::LinkMode::Shared`] they split
/// the link bandwidth, under the default per-edge model the count is
/// ignored and the arithmetic is bitwise identical to the PR-6 constants.
fn comm_base(params: &SimParams, rule: CommRule, contenders: u32) -> f64 {
    match rule {
        CommRule::Down => params.net.p2p_contended(params.words_down, contenders),
        CommRule::Up => params.net.p2p_contended(params.words_up, contenders),
        CommRule::HalfUp => params.net.p2p_contended(params.words_up, contenders) / 2.0,
        CommRule::Words(w) => params.net.p2p_contended(w as usize, contenders),
    }
}

/// Kind-grouped SoA duration table: one 1-byte tag per task in task-id
/// order plus dense per-kind payload columns (`Comm` bases, `MapFold`
/// worker/chunk pairs, `FoldN` counts, `Fixed` values), each filled in
/// task-id order within its kind. The replay duration-refresh loop walks
/// the tag column once, pulling each kind's payload from its own cursor —
/// so the provider/rng **call sequence stays exactly task-id order** (the
/// bitwise determinism contract in PERF.md depends on draws staying in
/// task-id order) while the hot loop reads homogeneous dense columns
/// instead of a 24-byte tagged union per task.
#[derive(Debug, Default)]
struct DurTable {
    tag: Vec<DurTag>,
    fixed: Vec<f64>,
    comm_base: Vec<f64>,
    /// Pricing rule per `Comm` entry, parallel to `comm_base` — the
    /// re-pricing input of [`IterationTemplate::bind_cell`]. Cold during
    /// replays (refresh reads only the evaluated bases).
    comm_rule: Vec<CommRule>,
    /// Concurrent-transfer count per `Comm` entry, parallel to
    /// `comm_base` — the [`comm_base`] contention input. Structural (it
    /// follows from the collective round shapes), so `bind_cell` re-prices
    /// through it but never rewrites it.
    comm_contenders: Vec<u32>,
    mf_worker: Vec<u32>,
    mf_chunk: Vec<u32>,
    fold_n: Vec<u32>,
}

impl DurTable {
    /// Drop all entries, keeping every column's capacity (rebuilds reuse).
    fn clear(&mut self) {
        self.tag.clear();
        self.fixed.clear();
        self.comm_base.clear();
        self.comm_rule.clear();
        self.comm_contenders.clear();
        self.mf_worker.clear();
        self.mf_chunk.clear();
        self.fold_n.clear();
    }

    /// Compute one replay's duration per task — provider samples × jitter,
    /// drawn strictly **in task-id order** (the bitwise determinism
    /// contract) — handing each `(task id, duration)` to `sink`. One walk
    /// of the tag column with per-kind payload cursors; the sink decides
    /// where the value lands (the engine's duration column for a scalar
    /// replay, one lane of the lane matrix for a batched one). Generic
    /// over the sink so the trivial stores inline into this hot loop
    /// (two call sites — monomorphization cost is negligible).
    fn refresh<F: FnMut(usize, f64)>(
        &self,
        jitter_comp: f64,
        jitter_comm: f64,
        provider: &mut dyn CostProvider,
        rng: &mut Rng,
        mut sink: F,
    ) {
        let (mut fx, mut cm, mut mf, mut fo) = (0usize, 0usize, 0usize, 0usize);
        for (id, &tag) in self.tag.iter().enumerate() {
            let d = match tag {
                DurTag::Fixed => {
                    let v = self.fixed[fx];
                    fx += 1;
                    v
                }
                DurTag::Comm => {
                    let base = self.comm_base[cm];
                    cm += 1;
                    base * rng.jitter(jitter_comm)
                }
                DurTag::MapFold => {
                    let worker = self.mf_worker[mf] as usize;
                    let chunk = self.mf_chunk[mf] as usize;
                    mf += 1;
                    let map_t = provider.map_time(worker, chunk);
                    let folds = chunk.saturating_sub(1) as f64 * provider.combine_time();
                    (map_t + folds) * rng.jitter(jitter_comp)
                }
                DurTag::FoldN => {
                    let c = self.fold_n[fo];
                    fo += 1;
                    c as f64 * provider.combine_time() * rng.jitter(jitter_comp)
                }
                DurTag::Post => provider.post_time() * rng.jitter(jitter_comp),
            };
            sink(id, d);
        }
    }

    /// Append the next task's (task-id order) duration rule.
    fn push(&mut self, kind: DurKind) {
        match kind {
            DurKind::Fixed(v) => {
                self.tag.push(DurTag::Fixed);
                self.fixed.push(v);
            }
            DurKind::MapFold { worker, chunk } => {
                self.tag.push(DurTag::MapFold);
                self.mf_worker.push(worker);
                self.mf_chunk.push(chunk);
            }
            DurKind::FoldN(n) => {
                self.tag.push(DurTag::FoldN);
                self.fold_n.push(n);
            }
            DurKind::Post => self.tag.push(DurTag::Post),
        }
    }

    /// Append the next task as a message: the evaluated base cost plus
    /// the [`CommRule`] (and its round's contender count) that
    /// [`IterationTemplate::bind_cell`] re-evaluates when the template is
    /// bound to a different cell.
    fn push_comm(&mut self, base: f64, rule: CommRule, contenders: u32) {
        self.tag.push(DurTag::Comm);
        self.comm_base.push(base);
        self.comm_rule.push(rule);
        self.comm_contenders.push(contenders);
    }
}

/// Structural shape key of a clean (fault-free) iteration template.
///
/// The task *structure* a clean [`IterationTemplate::build`] produces —
/// task count, resource assignment, CSR edges, and the [`DurTable`]
/// kind/tag layout — is a pure function of `k` and the `SimParams`
/// fields captured here. Every other build input is duration *payload*:
/// the list size `l` only sets the `MapFold` chunk column (Algorithm 2
/// builds one Map task per worker either way), the network model and
/// word counts only the `Comm` base column, and the jitter sigmas only
/// the per-replay multipliers. Two cells whose keys compare equal
/// therefore share one graph build — [`IterationTemplate::bind_cell`]
/// swaps the payload columns in place without touching the graph or the
/// order cache — and that is the invariant
/// [`IterationTemplate::run_group_into`] batches on. The comparison is
/// exact field equality, never a hash or fingerprint: a missed match
/// only costs a batching opportunity, but a spurious match would replay
/// the wrong graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeClass {
    /// Worker count: per-worker broadcast/reduce trees, so cells with
    /// different `k` never share a shape.
    k: usize,
    /// Effective master count `masters.min(k)` (worker-group structure).
    m: usize,
    /// Collective schedule shape (broadcast + reduce trees).
    algo: CollectiveAlgo,
    /// Reduce strategy (the whole task layout of phase 3).
    reduce_mode: ReduceMode,
}

impl ShapeClass {
    /// The shape key of the graph `IterationTemplate::new(k, _, params)`
    /// would build (any list size — size is payload, not shape).
    pub fn of(k: usize, params: &SimParams) -> ShapeClass {
        ShapeClass {
            k,
            m: params.masters.min(k),
            algo: params.algo,
            reduce_mode: params.reduce_mode,
        }
    }
}

/// Structural fingerprint of a built template, for tests that pin the
/// [`ShapeClass`] contract: everything a clean build derives from the
/// shape key and nothing derived from the payload. Two templates with
/// equal [`ShapeClass`] must compare equal here even when their sizes,
/// network params and jitter all differ (see `rust/tests/properties.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GraphStructure {
    /// Resource id per task, task-id order.
    pub resources: Vec<u32>,
    /// Dependency edges in insertion order.
    pub edges: Vec<(TaskId, TaskId)>,
    /// Duration-kind tag per task (as raw bytes), task-id order.
    pub dur_tags: Vec<u8>,
    /// `MapFold` worker column (chunk sizes are payload, excluded).
    pub mf_workers: Vec<u32>,
    /// `FoldN` count column (fold counts are structural: they follow
    /// from the reduce tree, not from the cell's size).
    pub fold_counts: Vec<u32>,
    /// `Comm` contender column (contention counts are structural: they
    /// follow from the collective round shapes, not from the payload).
    pub comm_contenders: Vec<u32>,
}

/// One sweep cell of a shape-class batch group: the duration payload
/// (list size + full params) and sampling state (provider instance +
/// rng stream) for a cell whose [`ShapeClass`] equals the group's. The
/// shared template supplies the graph; [`IterationTemplate::bind_cell`]
/// swaps each cell's payload in; each cell keeps its own provider and
/// rng, exactly as the serial per-cell loop would.
pub struct GroupCell {
    /// The cell's cost provider (its own sample stream).
    pub provider: Box<dyn CostProvider + Send>,
    /// The cell's jitter/draw stream.
    pub rng: Rng,
    /// The cell's list size (sets the `MapFold` chunk column on bind).
    pub l: usize,
    /// The cell's full parameters. Structural fields must match the
    /// group's shared [`ShapeClass`] (asserted on bind); the rest is
    /// the payload this cell replays under.
    pub params: SimParams,
}

impl GroupCell {
    /// Bundle one cell's sampling state with its duration payload
    /// (`params` is cloned — `SimParams` is a small flat struct).
    pub fn new(
        provider: Box<dyn CostProvider + Send>,
        rng: Rng,
        l: usize,
        params: &SimParams,
    ) -> GroupCell {
        GroupCell { provider, rng, l, params: params.clone() }
    }
}

/// A reusable Algorithm-2 iteration for fixed `(K, l, params)`: the task
/// graph is built once, each [`IterationTemplate::replay`] refreshes the
/// durations (provider samples × jitter, drawn in task-id order) and
/// re-executes the graph in the engine's scratch buffers. For sweeps over
/// many `(K, l)` points, [`IterationTemplate::reset_to`] rebuilds the graph
/// in place — one engine (and its grown scratch) serves a whole worker
/// thread's share of the (experiment × size × K) work queue, and
/// [`IterationTemplate::reset_shape`] downgrades the rebuild to a
/// payload rebind whenever the new point's [`ShapeClass`] matches.
pub struct IterationTemplate {
    eng: Engine,
    durs: DurTable,
    /// Worker count of the current build (a shape field).
    k: usize,
    /// List size of the currently bound cell (payload).
    l: usize,
    /// Shape key of the current build — the bind-compatibility check.
    shape: ShapeClass,
    /// Built with a fault plan: recovery structure is baked into the
    /// graph, so the template is cell-specific and never bind-shared.
    faulty: bool,
    jitter_comp: f64,
    jitter_comm: f64,
    /// Last broadcast-completion task per worker (empty entries skipped).
    bcast_tasks: Vec<TaskId>,
    /// Map+fold task per worker.
    map_tasks: Vec<TaskId>,
    /// Task after which master 0 holds the full folding.
    final_fold: TaskId,
    /// Master post-processing task.
    post: TaskId,
}

/// Graph-construction helper: adds tasks with a placeholder duration and
/// records how to compute the real duration on replay. Borrows the
/// template's engine and duration table so rebuilds reuse their capacity.
struct Build<'p> {
    eng: &'p mut Engine,
    durs: &'p mut DurTable,
    params: &'p SimParams,
}

impl<'p> Build<'p> {
    fn push(&mut self, res: u32, kind: DurKind, label: &'static str) -> TaskId {
        let id = self.eng.task_labeled(res, 0.0, label);
        self.durs.push(kind);
        id
    }

    /// Lone message task priced by `rule` against the build params (and
    /// re-priced against each cell's on [`IterationTemplate::bind_cell`]).
    fn comm(&mut self, res: u32, rule: CommRule, label: &'static str) -> TaskId {
        self.comm_n(res, rule, 1, label)
    }

    /// Message task in a collective round of `contenders` concurrent
    /// transfers: under a shared link they split the bandwidth (see
    /// [`comm_base`]); per-edge pricing ignores the count.
    fn comm_n(&mut self, res: u32, rule: CommRule, contenders: u32, label: &'static str) -> TaskId {
        let id = self.eng.task_labeled(res, 0.0, label);
        self.durs.push_comm(comm_base(self.params, rule, contenders), rule, contenders);
        id
    }

    fn zero(&mut self, res: u32, label: &'static str) -> TaskId {
        self.push(res, DurKind::Fixed(0.0), label)
    }

    /// Build the reduce of a worker group into its master; returns the task
    /// after which the group master holds the folded partial.
    fn reduce_group(&mut self, master_res: u32, members: &[(u32, TaskId)]) -> TaskId {
        let kk = members.len();
        if kk == 0 {
            // Master with no workers: nothing to fold; synthesise a zero task.
            return self.zero(master_res, "");
        }
        match self.params.reduce_mode {
            ReduceMode::TreeMasterFold => {
                // Relay partials over the reduce tree (no intermediate folds —
                // the paper charges all K−1 folds at the master), then a single
                // master task of (kk−1)·t_a.
                let sched = CollectiveSchedule::reduce(self.params.algo, kk);
                let res_of = |node: usize| -> u32 {
                    if node == 0 {
                        master_res
                    } else {
                        members[node - 1].0
                    }
                };
                let mut holds: Vec<TaskId> = Vec::with_capacity(sched.size);
                holds.push(self.zero(master_res, ""));
                for &(_, ready) in members {
                    holds.push(ready);
                }
                for round in &sched.rounds {
                    let n = round.len() as u32;
                    for &(from, to) in round {
                        let send = self.comm_n(res_of(from), CommRule::Up, n, "reduce-send");
                        self.eng.dep(holds[from], send);
                        let relay = self.zero(res_of(to), "relay");
                        self.eng.dep(send, relay);
                        self.eng.dep(holds[to], relay);
                        holds[to] = relay;
                    }
                }
                let folds = kk.saturating_sub(1) as u32;
                let fold = self.push(master_res, DurKind::FoldN(folds), "master-fold");
                self.eng.dep(holds[0], fold);
                fold
            }
            ReduceMode::GatherThenFold => {
                // Each worker sends to the master (master NIC serialises
                // receives); master then folds kk-1 times. The transfer
                // cost is split into send/recv halves.
                let mut recvs: Vec<TaskId> = Vec::with_capacity(kk);
                // All kk gather transfers target the master at once — the
                // flat gather is the maximally contended round.
                let n = kk as u32;
                for &(res, ready) in members {
                    let send = self.comm_n(res, CommRule::HalfUp, n, "gather-send");
                    self.eng.dep(ready, send);
                    // receive occupies the master for the other half of the cost
                    let recv = self.comm_n(master_res, CommRule::HalfUp, n, "gather-recv");
                    self.eng.dep(send, recv);
                    recvs.push(recv);
                }
                let mut acc = recvs[0];
                for &r in &recvs[1..] {
                    let fold = self.push(master_res, DurKind::FoldN(1), "fold");
                    self.eng.dep(acc, fold);
                    self.eng.dep(r, fold);
                    acc = fold;
                }
                acc
            }
            ReduceMode::InTree => {
                // Tree reduce: schedule node 0 = master, node i = members[i-1].
                let sched = CollectiveSchedule::reduce(self.params.algo, kk);
                let res_of = |node: usize| -> u32 {
                    if node == 0 {
                        master_res
                    } else {
                        members[node - 1].0
                    }
                };
                // holds[i] = task after which node i's (partially folded)
                // value is ready.
                let mut holds: Vec<TaskId> = Vec::with_capacity(sched.size);
                holds.push(self.zero(master_res, "")); // master starts empty fold
                for &(_, ready) in members {
                    holds.push(ready);
                }
                for round in &sched.rounds {
                    let n = round.len() as u32;
                    for &(from, to) in round {
                        let send = self.comm_n(res_of(from), CommRule::Up, n, "reduce-send");
                        self.eng.dep(holds[from], send);
                        let fold = self.push(res_of(to), DurKind::FoldN(1), "fold");
                        self.eng.dep(send, fold);
                        self.eng.dep(holds[to], fold);
                        holds[to] = fold;
                    }
                }
                holds[0]
            }
        }
    }

    /// [`RecoveryPolicy::MasterRecompute`] for one dead chunk: the group
    /// master re-runs the dead worker's Map+fold itself *after* its group
    /// reduce completed (detection happens at the gather deadline — the
    /// live runner's degraded mode), then folds the result in. The
    /// recovery Map carries the [`crate::simulator::faults::MASTER_WORKER`]
    /// sentinel so a fault plan never slows it by the dead worker's
    /// multiplier. Returns the new group-partial task.
    fn recover_on_master(
        &mut self,
        master_res: u32,
        anchor: Option<TaskId>,
        after: TaskId,
        chunk: usize,
    ) -> TaskId {
        let t = self.push(
            master_res,
            DurKind::MapFold { worker: u32::MAX, chunk: chunk as u32 },
            "recover-map",
        );
        if let Some(a) = anchor {
            self.eng.dep(a, t);
        }
        self.eng.dep(after, t);
        let fold = self.push(master_res, DurKind::FoldN(1), "recover-fold");
        self.eng.dep(t, fold);
        fold
    }

    /// [`RecoveryPolicy::Redistribute`] for one dead chunk: the chunk is
    /// split evenly over the group's survivors `(worker, resource,
    /// recv-x task)`; each sub-chunk costs a re-dispatch message on the
    /// master, the survivor's extra Map+fold (serialised with its own Map
    /// on the survivor's resource, overlapping other nodes), an uplink of
    /// the extra partial, and one fold at the master chained after the
    /// group reduce. Dispatches depend only on the master holding `x`
    /// (they ride the scatter, like the live runner's `extra` ranges on
    /// the downlink), not on the gather — so redistribution overlaps where
    /// master recompute serialises. Returns the new group-partial task.
    fn recover_redistribute(
        &mut self,
        master_res: u32,
        anchor: Option<TaskId>,
        after: TaskId,
        chunk: usize,
        survivors: &[(u32, u32, Option<TaskId>)],
    ) -> TaskId {
        let sub = crate::lists::partition_even(chunk, survivors.len());
        let mut acc = after;
        for (i, &(worker, res, recv)) in survivors.iter().enumerate() {
            let c = sub.size(i);
            if c == 0 {
                continue;
            }
            // range descriptor (start, len): two words on the downlink
            let dispatch = self.comm(master_res, CommRule::Words(2), "redispatch");
            if let Some(a) = anchor {
                self.eng.dep(a, dispatch);
            }
            let t = self.push(res, DurKind::MapFold { worker, chunk: c as u32 }, "recover-map");
            self.eng.dep(dispatch, t);
            if let Some(r) = recv {
                self.eng.dep(r, t);
            }
            let send = self.comm(res, CommRule::Up, "recover-uplink");
            self.eng.dep(t, send);
            let fold = self.push(master_res, DurKind::FoldN(1), "recover-fold");
            self.eng.dep(send, fold);
            self.eng.dep(acc, fold);
            acc = fold;
        }
        acc
    }

    /// Fold the per-group partials held by masters `1..m` into master 0.
    fn reduce_masters(&mut self, master0_ready: TaskId, peers: &[(u32, TaskId)]) -> TaskId {
        let sched = CollectiveSchedule::reduce(self.params.algo, peers.len());
        let res_of = |node: usize| -> u32 { if node == 0 { 0 } else { peers[node - 1].0 } };
        let mut holds: Vec<TaskId> = Vec::with_capacity(sched.size);
        holds.push(master0_ready);
        for &(_, t) in peers {
            holds.push(t);
        }
        for round in &sched.rounds {
            let n = round.len() as u32;
            for &(from, to) in round {
                let send = self.comm_n(res_of(from), CommRule::Up, n, "reduce-send");
                self.eng.dep(holds[from], send);
                let fold = self.push(res_of(to), DurKind::FoldN(1), "fold");
                self.eng.dep(send, fold);
                self.eng.dep(holds[to], fold);
                holds[to] = fold;
            }
        }
        holds[0]
    }
}

impl IterationTemplate {
    /// Build the Algorithm-2 task graph for `k` workers over a list of
    /// length `l`. Pure structure — no provider or rng calls happen here.
    ///
    /// With `params.masters > 1`, workers are split evenly among the
    /// masters, each group runs its own broadcast/reduce, the group masters
    /// tree-reduce among themselves to master 0, which post-processes and
    /// broadcasts the exit flag back through the masters (the §7-Q5
    /// configuration the paper says admits no closed-form boundary).
    pub fn new(k: usize, l: usize, params: &SimParams) -> IterationTemplate {
        let mut tmpl = IterationTemplate {
            eng: Engine::new(),
            durs: DurTable::default(),
            k,
            l,
            shape: ShapeClass::of(k, params),
            faulty: false,
            jitter_comp: 0.0,
            jitter_comm: 0.0,
            bcast_tasks: Vec::new(),
            map_tasks: Vec::new(),
            final_fold: 0,
            post: 0,
        };
        tmpl.reset_to(k, l, params);
        tmpl
    }

    /// Rebuild the template for a new `(k, l, params)` point **in place**,
    /// reusing the engine (graph + scratch capacity, via [`Engine::reset`],
    /// which also invalidates the order cache along with the graph) and
    /// every template buffer. Produces a graph bitwise identical to a
    /// fresh [`IterationTemplate::new`] — pinned by the module tests — so
    /// pooled sweep workers can hold one template for their whole queue.
    pub fn reset_to(&mut self, k: usize, l: usize, params: &SimParams) {
        self.build(k, l, params, None, false);
    }

    /// Rebind the template to a new cell `(l, params)` of the **same**
    /// [`ShapeClass`] without rebuilding: swaps the [`DurTable`] payload
    /// columns in place — `MapFold` chunks from the new size's even
    /// partition, `Comm` bases re-priced through the recorded
    /// [`CommRule`]s, jitter sigmas replaced — while the graph, the CSR
    /// edges and the engine's order cache all survive untouched. Bitwise
    /// identical to [`IterationTemplate::reset_to`] for the same cell
    /// (pinned by the module tests); panics on a shape mismatch or on a
    /// faulty build, where a silent rebind would replay the wrong graph.
    pub fn bind_cell(&mut self, l: usize, params: &SimParams) {
        assert!(!self.faulty, "faulty templates are cell-specific; rebuild instead");
        assert!(
            ShapeClass::of(self.k, params) == self.shape,
            "bind_cell requires an equal ShapeClass (a spurious match would \
             replay the wrong graph)"
        );
        self.jitter_comp = params.jitter_comp;
        self.jitter_comm = params.jitter_comm;
        let durs = &mut self.durs;
        if l != self.l {
            // The even partition's sizes in closed form (remainder spread
            // to the front, exactly `partition_even`'s layout) — computed
            // inline so a size swap stays allocation-free on the
            // `run_group_into` hot path.
            let (base, extra) = (l / self.k, l % self.k);
            for i in 0..durs.mf_worker.len() {
                let w = durs.mf_worker[i] as usize;
                durs.mf_chunk[i] = (base + usize::from(w < extra)) as u32;
            }
            self.l = l;
        }
        for i in 0..durs.comm_rule.len() {
            durs.comm_base[i] = comm_base(params, durs.comm_rule[i], durs.comm_contenders[i]);
        }
        self.eng.note_shape_rebind();
    }

    /// Re-point the template at the sweep point `(k, l, params)` the
    /// cheapest correct way: a [`IterationTemplate::bind_cell`] payload
    /// rebind when the point's [`ShapeClass`] matches the current build
    /// (and the build is clean), a full [`IterationTemplate::reset_to`]
    /// rebuild otherwise. Returns `true` iff it rebuilt.
    pub fn reset_shape(&mut self, k: usize, l: usize, params: &SimParams) -> bool {
        if !self.faulty && ShapeClass::of(k, params) == self.shape {
            self.bind_cell(l, params);
            false
        } else {
            self.reset_to(k, l, params);
            true
        }
    }

    /// Rebuild the template for `(k, l, params)` with the given per-worker
    /// dead set: dead workers receive no broadcast and run no Map; each
    /// dead chunk is recovered per `policy` as extra Map tasks + comm
    /// edges, so the replayed makespan reflects the re-dispatch cost (see
    /// `faults.rs`). A group whose workers are *all* dead falls back to
    /// master recompute regardless of the policy (there is nobody left to
    /// redistribute to). With an all-alive dead set this runs the exact
    /// same build pass as [`IterationTemplate::reset_to`] — the graphs are
    /// identical, which the fault-plane bitwise tests pin.
    pub fn reset_to_faulty(
        &mut self,
        k: usize,
        l: usize,
        params: &SimParams,
        dead: &[bool],
        policy: RecoveryPolicy,
    ) {
        self.reset_to_faulty_ckpt(k, l, params, dead, policy, false);
    }

    /// [`IterationTemplate::reset_to_faulty`] with an explicit
    /// checkpoint-save flag: when `ckpt_save` is set, a fixed-duration
    /// state-save task (the master writing the approximation, priced as
    /// one downlink payload) is appended *after* `post`. Because every
    /// other task precedes `post`, the saved iteration's makespan is
    /// exactly the unsaved one plus the save cost — and because the save
    /// is a `Fixed` duration it draws no provider sample and no jitter,
    /// so the rest of the draw stream is bitwise untouched (the
    /// checkpoint-monotonicity test in `rust/tests/faults.rs` pins both).
    pub fn reset_to_faulty_ckpt(
        &mut self,
        k: usize,
        l: usize,
        params: &SimParams,
        dead: &[bool],
        policy: RecoveryPolicy,
        ckpt_save: bool,
    ) {
        assert_eq!(dead.len(), k, "dead set must cover every worker");
        self.build(k, l, params, Some((dead, policy)), ckpt_save);
    }

    fn build(
        &mut self,
        k: usize,
        l: usize,
        params: &SimParams,
        faults: Option<(&[bool], RecoveryPolicy)>,
        ckpt_save: bool,
    ) {
        assert!(k >= 1, "need at least one worker");
        assert!(params.masters >= 1);
        let is_dead = |j: usize| faults.is_some_and(|(d, _)| d[j]);
        self.k = k;
        self.l = l;
        self.shape = ShapeClass::of(k, params);
        self.faulty = faults.is_some();
        self.eng.reset();
        self.durs.clear();
        self.bcast_tasks.clear();
        self.map_tasks.clear();
        self.jitter_comp = params.jitter_comp;
        self.jitter_comm = params.jitter_comm;
        let m = params.masters.min(k); // no point in masters without workers
        let mut b = Build { eng: &mut self.eng, durs: &mut self.durs, params };

        // Resources: 0..m are masters, m..m+k are workers.
        let worker_res = |j: usize| (m + j) as u32; // j in 0..k
        let chunk_of = crate::lists::partition_even(l, k);

        // Split workers among masters evenly.
        let groups = crate::lists::partition_even(k, m);

        // Phase 1: per-group broadcast (payload = words_down).
        let mut recv_x: Vec<Option<TaskId>> = vec![None; k];
        // Master-0 forwards the approximation to other masters first (tree).
        let master_tree = CollectiveSchedule::broadcast(params.algo, m.saturating_sub(1));
        let mut master_recv: Vec<Option<TaskId>> = vec![None; m];
        if m > 1 {
            // node ids in the schedule: 0 = master 0, i = master i.
            let mut last_send_of: Vec<Option<TaskId>> = vec![None; m];
            for round in &master_tree.rounds {
                let n = round.len() as u32;
                for &(from, to) in round {
                    let send = b.comm_n(from as u32, CommRule::Down, n, "bcast-master");
                    if let Some(prev) = last_send_of[from] {
                        b.eng.dep(prev, send);
                    }
                    if let Some(r) = master_recv[from] {
                        b.eng.dep(r, send);
                    }
                    last_send_of[from] = Some(send);
                    master_recv[to] = Some(send);
                    last_send_of[to] = None;
                }
            }
        }

        for g in 0..m {
            // Dead workers take no part in the collective: the broadcast
            // tree spans the group's alive members only.
            let members: Vec<usize> = groups.range(g).filter(|&w| !is_dead(w)).collect();
            let sched = CollectiveSchedule::broadcast(params.algo, members.len());
            // Schedule node 0 = master g; node i = worker members[i-1].
            let res_of = |node: usize| -> u32 {
                if node == 0 {
                    g as u32
                } else {
                    worker_res(members[node - 1])
                }
            };
            let mut node_recv: Vec<Option<TaskId>> = vec![None; sched.size];
            let mut last_send_of: Vec<Option<TaskId>> = vec![None; sched.size];
            // Master g cannot start before it has the approximation.
            let anchor = master_recv[g];
            for round in &sched.rounds {
                let n = round.len() as u32;
                for &(from, to) in round {
                    let send = b.comm_n(res_of(from), CommRule::Down, n, "bcast");
                    if let Some(prev) = last_send_of[from] {
                        b.eng.dep(prev, send);
                    }
                    if let Some(r) = node_recv[from] {
                        b.eng.dep(r, send);
                    } else if from == 0 {
                        if let Some(a) = anchor {
                            b.eng.dep(a, send);
                        }
                    }
                    last_send_of[from] = Some(send);
                    node_recv[to] = Some(send);
                    last_send_of[to] = None;
                }
            }
            for (i, &w) in members.iter().enumerate() {
                // MPI_Bcast semantics: a rank leaves the collective only after
                // it has both received the payload *and* forwarded it to all of
                // its tree children — its compute must not preempt forwarding.
                recv_x[w] = last_send_of[i + 1].or(node_recv[i + 1]);
            }
        }

        // Phase 2: worker compute = Map(chunk) + (chunk-1) local folds.
        // Dead workers run nothing; their entry stays None.
        let mut partial_ready: Vec<Option<TaskId>> = Vec::with_capacity(k);
        for j in 0..k {
            if is_dead(j) {
                partial_ready.push(None);
                continue;
            }
            let chunk = chunk_of.size(j);
            let t = b.push(
                worker_res(j),
                DurKind::MapFold { worker: j as u32, chunk: chunk as u32 },
                "map+fold",
            );
            if let Some(r) = recv_x[j] {
                b.eng.dep(r, t);
            }
            partial_ready.push(Some(t));
        }

        // Phase 3: per-group reduce to the group master, then masters to 0.
        // Dead chunks are recovered here per the plan's policy, chained
        // onto the group partial so every recovered element reaches the
        // final fold — the makespan pays the full re-dispatch cost.
        let mut group_partial: Vec<TaskId> = Vec::with_capacity(m);
        for g in 0..m {
            let members: Vec<(u32, TaskId)> = groups
                .range(g)
                .filter_map(|w| partial_ready[w].map(|t| (worker_res(w), t)))
                .collect();
            let mut gp = b.reduce_group(g as u32, &members);
            if let Some((dead, policy)) = faults {
                let anchor = master_recv[g];
                let survivors: Vec<(u32, u32, Option<TaskId>)> = groups
                    .range(g)
                    .filter(|&w| !dead[w])
                    .map(|w| (w as u32, worker_res(w), recv_x[w]))
                    .collect();
                for w in groups.range(g) {
                    if !dead[w] {
                        continue;
                    }
                    let chunk = chunk_of.size(w);
                    if chunk == 0 {
                        continue;
                    }
                    gp = match policy {
                        RecoveryPolicy::Redistribute if !survivors.is_empty() => {
                            b.recover_redistribute(g as u32, anchor, gp, chunk, &survivors)
                        }
                        _ => b.recover_on_master(g as u32, anchor, gp, chunk),
                    };
                }
            }
            group_partial.push(gp);
        }
        // Masters fold to master 0 (tree over m nodes).
        let final_fold = if m > 1 {
            let peers: Vec<(u32, TaskId)> = (1..m).map(|g| (g as u32, group_partial[g])).collect();
            b.reduce_masters(group_partial[0], &peers)
        } else {
            group_partial[0]
        };

        // Phase 4: master post-processing. The exit flag of Algorithm 2
        // (step 10) is piggybacked on the next iteration's broadcast (a tagged
        // message), as real skeletons do — so the steady-state iteration
        // period is exactly the master's cycle: broadcast → … → post.
        let post = b.push(0, DurKind::Post, "post");
        b.eng.dep(final_fold, post);

        // Checkpoint save: the master persists the approximation after the
        // iteration completes. A `Fixed` duration (no provider call, no
        // jitter draw) priced as one uncontended downlink payload — so a
        // save-carrying iteration costs exactly `clean total + save cost`
        // and the draw stream is untouched.
        if ckpt_save {
            let save = b.push(
                0,
                DurKind::Fixed(comm_base(params, CommRule::Down, 1)),
                "ckpt-save",
            );
            b.eng.dep(post, save);
        }

        self.bcast_tasks.extend(recv_x.iter().flatten().copied());
        self.map_tasks.extend(partial_ready.iter().flatten().copied());
        self.final_fold = final_fold;
        self.post = post;
    }

    /// Number of tasks in the iteration graph.
    pub fn task_count(&self) -> usize {
        self.eng.len()
    }

    /// Scheduler telemetry of the underlying engine (order-cache hits,
    /// fallbacks, lane batches) — lets tests assert that the fault plane's
    /// clean path still replays through the cache.
    pub fn sched_counters(&self) -> SchedCounters {
        self.eng.sched_counters()
    }

    /// The [`ShapeClass`] of the current build — cells whose keys equal
    /// it can be swapped in via [`IterationTemplate::bind_cell`] and
    /// batched via [`IterationTemplate::run_group_into`].
    pub fn shape_class(&self) -> ShapeClass {
        self.shape
    }

    /// Snapshot the structural fingerprint of the current build (see
    /// [`GraphStructure`]) — test support for the shape-class contract.
    pub fn structure(&self) -> GraphStructure {
        GraphStructure {
            resources: (0..self.eng.len())
                .map(|i| self.eng.spec(i as TaskId).resource)
                .collect(),
            edges: (0..self.eng.edge_count()).map(|i| self.eng.edge(i)).collect(),
            dur_tags: self.durs.tag.iter().map(|&t| t as u8).collect(),
            mf_workers: self.durs.mf_worker.clone(),
            fold_counts: self.durs.fold_n.clone(),
            comm_contenders: self.durs.comm_contenders.clone(),
        }
    }

    /// Per-instance lane-replay override, forwarded to the engine (see
    /// [`Engine::set_lane_mode`]) — lets grouped-vs-per-cell races pin
    /// the batching mode without touching process env.
    pub fn set_lane_mode(&mut self, on: Option<bool>) {
        self.eng.set_lane_mode(on);
    }

    /// Per-instance lane-width override, forwarded to the engine (see
    /// [`Engine::set_lane_width`]).
    pub fn set_lane_width(&mut self, width: Option<usize>) {
        self.eng.set_lane_width(width);
    }

    /// Simulate one iteration: refresh every task's duration (provider
    /// samples and jitter draws, in task-id order — deterministic for a
    /// given provider/rng state) and re-execute the graph in place. The
    /// refresh is one pass over the [`DurTable`] tag column with per-kind
    /// payload cursors; the execution dispatches through the engine's
    /// order cache (deterministic configs validate always, jittered
    /// configs almost always — stale orders fall back to the calendar,
    /// bitwise-identically).
    pub fn replay(&mut self, provider: &mut dyn CostProvider, rng: &mut Rng) -> IterationTiming {
        let eng = &mut self.eng;
        self.durs.refresh(self.jitter_comp, self.jitter_comm, provider, rng, |id, d| {
            eng.set_duration(id as TaskId, d);
        });
        eng.run_reuse();
        let total = eng.last_makespan(); // fused max fold — no finish re-walk
        let finish = eng.last_finish();
        let broadcast_done =
            self.bcast_tasks.iter().map(|&t| finish[t as usize]).fold(0.0, f64::max);
        let map_done = self.map_tasks.iter().map(|&t| finish[t as usize]).fold(0.0, f64::max);
        IterationTiming {
            broadcast_done,
            map_done,
            reduce_done: finish[self.final_fold as usize],
            post_done: finish[self.post as usize],
            total,
        }
    }

    /// Simulate `lanes` jittered iterations in **one lane-batched engine
    /// pass** (see `engine.rs` "Lane-parallel replay"), appending their
    /// timings to `out` in lane order. Duration draws fill the lane
    /// matrix replay-by-replay — provider/rng draws stay in task-id order
    /// within each replay, replays drawn in sequence, so the draw stream
    /// is untouched — and the per-replay timing extraction (the
    /// `broadcast_done`/`map_done` folds and the makespan) vectorizes
    /// across lanes. Bitwise identical to `lanes` successive
    /// [`IterationTemplate::replay`] calls, vector hit or per-lane
    /// fallback alike (the engine owns that contract).
    fn replay_lanes_into(
        &mut self,
        lanes: usize,
        provider: &mut dyn CostProvider,
        rng: &mut Rng,
        out: &mut Vec<IterationTiming>,
    ) {
        let eng = &mut self.eng;
        let (jc, jm) = (self.jitter_comp, self.jitter_comm);
        let mat = eng.lane_durations_mut(lanes);
        for lane in 0..lanes {
            self.durs.refresh(jc, jm, provider, rng, |id, d| {
                mat[id * lanes + lane] = d;
            });
        }
        eng.run_lanes(lanes);
        self.push_lane_timings(lanes, out);
    }

    /// Extract per-lane [`IterationTiming`]s from the engine's lane state
    /// after a `run_lanes(lanes)` pass, appending them to `out` in lane
    /// order. The `broadcast_done`/`map_done` folds vectorize across lanes
    /// ([`lanes::fold_max_tasks`]); the remaining fields are strided reads.
    fn push_lane_timings(&self, lanes: usize, out: &mut Vec<IterationTiming>) {
        let kind = kernels::active();
        let finish = self.eng.lane_finish();
        let mut bcast = [0.0f64; LANES_MAX];
        let mut mapd = [0.0f64; LANES_MAX];
        lanes::fold_max_tasks(kind, finish, lanes, &self.bcast_tasks, &mut bcast);
        lanes::fold_max_tasks(kind, finish, lanes, &self.map_tasks, &mut mapd);
        let mks = self.eng.lane_makespans();
        for m in 0..lanes {
            out.push(IterationTiming {
                broadcast_done: bcast[m],
                map_done: mapd[m],
                reduce_done: finish[self.final_fold as usize * lanes + m],
                post_done: finish[self.post as usize * lanes + m],
                total: mks[m],
            });
        }
    }

    /// Simulate `iters` iterations into `out` (cleared first). With zero
    /// jitter and a deterministic provider every iteration is identical, so
    /// one replay is simulated and its timing replicated — bitwise equal to
    /// the naive loop (and to [`simulate_run`] on a fresh template).
    /// Stochastic configurations group their replays into batches of the
    /// engine's dispatched lane width ([`Engine::dispatch_width`]: 8 with
    /// AVX-512, else 4) via [`IterationTemplate::replay_lanes_into`]; a
    /// final partial batch rides the same lane pass with discarded pad
    /// lanes (no scalar remainder). Bitwise identical to the one-at-a-time
    /// loop (pinned by `rust/tests/determinism.rs`).
    pub fn run_into(
        &mut self,
        iters: usize,
        provider: &mut dyn CostProvider,
        rng: &mut Rng,
        out: &mut Vec<IterationTiming>,
    ) {
        out.clear();
        if iters == 0 {
            return;
        }
        let deterministic =
            self.jitter_comp == 0.0 && self.jitter_comm == 0.0 && provider.is_deterministic();
        if deterministic {
            let t = self.replay(provider, rng);
            out.resize(iters, t);
        } else {
            let width = self.eng.dispatch_width();
            let mut left = iters;
            while left > 0 {
                let lanes = left.min(width);
                self.replay_lanes_into(lanes, provider, rng, out);
                left -= lanes;
            }
        }
    }

    /// Simulate `iters` iterations for **each** of `cells.len()` sweep
    /// cells whose [`ShapeClass`] equals this template's, appending
    /// `cells.len() * iters` timings to `out` in cell-major order (all of
    /// cell 0's iterations, then cell 1's, …) — exactly the order a serial
    /// per-cell bind + [`IterationTemplate::run_into`] loop would produce.
    /// Each cell's payload (size, cost params, jitter) is swapped in via
    /// [`IterationTemplate::bind_cell`]; the graph and the engine's order
    /// cache survive every switch.
    ///
    /// Jittered replays are indexed flat (`r = cell * iters + iter`) and
    /// batched into lane passes of the dispatched width, so batches *span
    /// cell boundaries*: with width 8 and 7 iterations per cell, lanes
    /// 0..7 of the first pass carry cell 0's seven replays plus cell 1's
    /// first — even when the two cells simulate different list sizes.
    /// Each lane is refreshed from **its own cell's** bound payload,
    /// provider and rng, in flat order — each cell's draw stream advances
    /// exactly as its serial loop would (streams are independent, so
    /// interleaving cells within a batch is bitwise-irrelevant). Pinned
    /// against the per-cell loop by `rust/tests/determinism.rs`.
    ///
    /// Deterministic cells (zero jitter, deterministic provider) take the
    /// same one-replay replication shortcut as
    /// [`IterationTemplate::run_into`]; mixed groups replicate those and
    /// lane-batch maximal runs of the jittered rest.
    pub fn run_group_into(
        &mut self,
        cells: &mut [GroupCell],
        iters: usize,
        out: &mut Vec<IterationTiming>,
    ) {
        out.clear();
        if iters == 0 || cells.is_empty() {
            return;
        }
        let det = |c: &GroupCell| {
            c.params.jitter_comp == 0.0
                && c.params.jitter_comm == 0.0
                && c.provider.is_deterministic()
        };
        let mut c0 = 0;
        while c0 < cells.len() {
            if det(&cells[c0]) {
                let cell = &mut cells[c0];
                self.bind_cell(cell.l, &cell.params);
                let t = self.replay(cell.provider.as_mut(), &mut cell.rng);
                out.extend(std::iter::repeat(t).take(iters));
                c0 += 1;
            } else {
                let mut c1 = c0 + 1;
                while c1 < cells.len() && !det(&cells[c1]) {
                    c1 += 1;
                }
                self.run_group_lanes(&mut cells[c0..c1], iters, out);
                c0 = c1;
            }
        }
    }

    /// Lane-batch a maximal run of jittered cells (the non-deterministic
    /// arm of [`IterationTemplate::run_group_into`]): flat replay index
    /// `r = cell * iters + iter`, batches of the dispatched width, each
    /// lane refreshed under its own cell's bound payload. A cell switch
    /// mid-batch is a [`IterationTemplate::bind_cell`] payload rebind;
    /// per-batch telemetry lands in [`SchedCounters::group_batches`] and
    /// [`SchedCounters::group_spanned_cells`].
    fn run_group_lanes(
        &mut self,
        cells: &mut [GroupCell],
        iters: usize,
        out: &mut Vec<IterationTiming>,
    ) {
        let width = self.eng.dispatch_width();
        let total = cells.len() * iters;
        let mut done = 0;
        let mut bound = usize::MAX;
        while done < total {
            let lanes = width.min(total - done);
            for lane in 0..lanes {
                let ci = (done + lane) / iters;
                if ci != bound {
                    self.bind_cell(cells[ci].l, &cells[ci].params);
                    bound = ci;
                }
                let (jc, jm) = (self.jitter_comp, self.jitter_comm);
                let cell = &mut cells[ci];
                let eng = &mut self.eng;
                let mat = eng.lane_durations_mut(lanes);
                self.durs.refresh(jc, jm, cell.provider.as_mut(), &mut cell.rng, |id, d| {
                    mat[id * lanes + lane] = d;
                });
            }
            // Distinct cells in this batch, minus one: flat indexing keeps
            // a batch's cells contiguous, so last − first counts them.
            let spanned = ((done + lanes - 1) / iters - done / iters) as u64;
            self.eng.run_lanes(lanes);
            self.eng.note_group_batch(spanned);
            self.push_lane_timings(lanes, out);
            done += lanes;
        }
    }

    /// Consume the template, returning the executed engine and the finish
    /// times of the last replay (for trace export).
    fn into_engine(self) -> (Engine, Vec<f64>) {
        let finish = self.eng.last_finish().to_vec();
        (self.eng, finish)
    }
}

/// Simulate one iteration of Algorithm 2 with `k` workers over a list of
/// length `l`. Returns the timing breakdown.
///
/// One-shot convenience (builds a fresh [`IterationTemplate`]); sweep hot
/// paths should build the template once and [`IterationTemplate::replay`].
pub fn simulate_iteration(
    k: usize,
    l: usize,
    params: &SimParams,
    provider: &mut dyn CostProvider,
    rng: &mut Rng,
) -> IterationTiming {
    IterationTemplate::new(k, l, params).replay(provider, rng)
}

/// Like [`simulate_iteration`], also returning the executed task graph and
/// per-task finish times (for trace export — see [`crate::simulator::trace`]).
pub fn simulate_iteration_full(
    k: usize,
    l: usize,
    params: &SimParams,
    provider: &mut dyn CostProvider,
    rng: &mut Rng,
) -> (IterationTiming, Engine, Vec<f64>) {
    let mut tmpl = IterationTemplate::new(k, l, params);
    let timing = tmpl.replay(provider, rng);
    let (eng, finish) = tmpl.into_engine();
    (timing, eng, finish)
}

/// Simulate `iters` iterations; returns per-iteration timings.
///
/// Builds the task graph once and replays it per iteration. When the
/// configuration is fully deterministic (zero jitter, deterministic
/// provider) every iteration is identical, so one iteration is simulated
/// and its timing replicated `iters` times — bitwise equal to the naive
/// loop (asserted in `rust/tests/determinism.rs`).
pub fn simulate_run(
    k: usize,
    l: usize,
    iters: usize,
    params: &SimParams,
    provider: &mut dyn CostProvider,
    rng: &mut Rng,
) -> Vec<IterationTiming> {
    let mut tmpl = IterationTemplate::new(k, l, params);
    let mut out = Vec::new();
    tmpl.run_into(iters, provider, rng, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn analytic(l: usize) -> AnalyticCost {
        AnalyticCost { t_map_full: 1.0, l, t_a: 1e-4, t_p: 1e-3 }
    }

    fn params() -> SimParams {
        SimParams::new(1000, 1000)
    }

    #[test]
    fn single_worker_matches_eq7_shape() {
        // T_1 = t_p + t_c + t_Map + t_Rdc (eq. 7), modulo the exit flag.
        let l = 1000;
        let mut prov = analytic(l);
        let mut rng = Rng::new(1);
        let t = simulate_iteration(1, l, &params(), &mut prov, &mut rng);
        let p = params();
        let t_c = p.net.t_c(p.words_down, p.words_up);
        let t_rdc = (l - 1) as f64 * 1e-4;
        let expect = 1e-3 + t_c + 1.0 + t_rdc;
        // exit flag adds one latency; in-tree fold adds one t_a at master
        assert!((t.total - expect).abs() / expect < 0.01, "sim={} expect~{}", t.total, expect);
    }

    #[test]
    fn phases_are_ordered() {
        let mut prov = analytic(1024);
        let mut rng = Rng::new(2);
        let t = simulate_iteration(8, 1024, &params(), &mut prov, &mut rng);
        assert!(t.broadcast_done > 0.0);
        assert!(t.map_done >= t.broadcast_done);
        assert!(t.reduce_done >= t.map_done);
        assert!(t.post_done >= t.reduce_done);
        assert!(t.total >= t.post_done);
    }

    #[test]
    fn more_workers_speed_up_compute_bound() {
        let l = 4096;
        let mut prov = analytic(l);
        let mut rng = Rng::new(3);
        let t1 = simulate_iteration(1, l, &params(), &mut prov, &mut rng).total;
        let t8 = simulate_iteration(8, l, &params(), &mut prov, &mut rng).total;
        let t64 = simulate_iteration(64, l, &params(), &mut prov, &mut rng).total;
        assert!(t8 < t1 / 4.0, "t1={t1} t8={t8}");
        assert!(t64 < t8, "t8={t8} t64={t64}");
    }

    #[test]
    fn speedup_eventually_degrades() {
        // tiny compute, big payload: communication dominates, so large K
        // must be slower than small K.
        let l = 256;
        let mut prov = AnalyticCost { t_map_full: 1e-4, l, t_a: 1e-8, t_p: 1e-6 };
        let mut rng = Rng::new(4);
        let t2 = simulate_iteration(2, l, &params(), &mut prov, &mut rng).total;
        let t128 = simulate_iteration(128, l, &params(), &mut prov, &mut rng).total;
        assert!(t128 > t2, "t2={t2} t128={t128}");
    }

    #[test]
    fn deterministic_without_jitter() {
        let l = 512;
        let mut prov = analytic(l);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(99);
        let a = simulate_iteration(16, l, &params(), &mut prov, &mut r1);
        let b = simulate_iteration(16, l, &params(), &mut prov, &mut r2);
        assert_eq!(a, b, "zero jitter must be rng-independent");
    }

    #[test]
    fn jitter_perturbs_and_is_seed_deterministic() {
        let l = 512;
        let mut p = params();
        p.jitter_comp = 0.1;
        p.jitter_comm = 0.1;
        let mut prov = analytic(l);
        let a = simulate_iteration(16, l, &p, &mut prov, &mut Rng::new(5));
        let b = simulate_iteration(16, l, &p, &mut prov, &mut Rng::new(5));
        let c = simulate_iteration(16, l, &p, &mut prov, &mut Rng::new(6));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn gather_mode_slower_than_tree_at_scale() {
        let l = 4096;
        let mut tree = params();
        tree.reduce_mode = ReduceMode::InTree;
        let mut gather = params();
        gather.reduce_mode = ReduceMode::GatherThenFold;
        let mut prov = analytic(l);
        let mut rng = Rng::new(8);
        let t_tree = simulate_iteration(128, l, &tree, &mut prov, &mut rng).total;
        let t_gather = simulate_iteration(128, l, &gather, &mut prov, &mut rng).total;
        assert!(t_gather > t_tree, "tree={t_tree} gather={t_gather}");
    }

    #[test]
    fn linear_collective_slower_than_tree_at_scale() {
        let l = 4096;
        let mut lin = params();
        lin.algo = CollectiveAlgo::Linear;
        let mut prov = analytic(l);
        let mut rng = Rng::new(9);
        let t_lin = simulate_iteration(128, l, &lin, &mut prov, &mut rng).total;
        let t_tree = simulate_iteration(128, l, &params(), &mut prov, &mut rng).total;
        assert!(t_lin > t_tree, "lin={t_lin} tree={t_tree}");
    }

    #[test]
    fn two_masters_runs_and_orders_phases() {
        let l = 2048;
        let mut p = params();
        p.masters = 2;
        let mut prov = analytic(l);
        let mut rng = Rng::new(10);
        let t = simulate_iteration(16, l, &p, &mut prov, &mut rng);
        assert!(t.total > 0.0);
        assert!(t.reduce_done >= t.map_done);
    }

    #[test]
    fn sampled_cost_draws_from_samples() {
        let mut prov = SampledCost {
            per_elem: std::sync::Arc::new(vec![1e-6, 2e-6]),
            t_a: 1e-7,
            t_p: 1e-6,
            rng: Rng::new(11),
        };
        let t = prov.map_time(0, 1000);
        assert!(t == 1e-3 || t == 2e-3, "t={t}");
        assert!(!prov.is_deterministic());
    }

    #[test]
    fn simulate_run_length() {
        let l = 256;
        let mut prov = analytic(l);
        let mut rng = Rng::new(12);
        let runs = simulate_run(4, l, 5, &params(), &mut prov, &mut rng);
        assert_eq!(runs.len(), 5);
    }

    #[test]
    fn template_replay_matches_fresh_build() {
        // Replaying one template must be bitwise identical to rebuilding
        // the graph per iteration, jittered or not.
        let l = 1024;
        let mut p = params();
        p.jitter_comp = 0.08;
        p.jitter_comm = 0.05;
        let mut prov = analytic(l);
        let mut tmpl = IterationTemplate::new(24, l, &p);
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        for _ in 0..4 {
            let reused = tmpl.replay(&mut prov, &mut r1);
            let fresh = simulate_iteration(24, l, &p, &mut prov, &mut r2);
            assert_eq!(reused, fresh);
        }
    }

    #[test]
    fn reset_to_matches_fresh_template() {
        // Rebuilding a template in place for a new (K, l, params) must be
        // bitwise identical to constructing it from scratch — the pooled
        // sweep's one-engine-per-worker reuse depends on it.
        let mut p = params();
        p.jitter_comp = 0.07;
        let mut prov = analytic(2048);
        let mut tmpl = IterationTemplate::new(8, 512, &params());
        tmpl.replay(&mut prov, &mut Rng::new(1));
        for (k, l) in [(24usize, 2048usize), (3, 100), (24, 2048)] {
            tmpl.reset_to(k, l, &p);
            let mut fresh = IterationTemplate::new(k, l, &p);
            assert_eq!(tmpl.task_count(), fresh.task_count(), "K={k} l={l}");
            let mut prov_a = analytic(l);
            let mut prov_b = analytic(l);
            let a = tmpl.replay(&mut prov_a, &mut Rng::new(42));
            let b = fresh.replay(&mut prov_b, &mut Rng::new(42));
            assert_eq!(a, b, "K={k} l={l}");
        }
    }

    #[test]
    fn run_into_matches_simulate_run() {
        let l = 1024;
        let mut p = params();
        p.jitter_comp = 0.05;
        let mut prov = analytic(l);
        let expect = simulate_run(12, l, 5, &p, &mut prov, &mut Rng::new(9));
        let mut tmpl = IterationTemplate::new(12, l, &p);
        let mut got = Vec::new();
        tmpl.run_into(5, &mut prov, &mut Rng::new(9), &mut got);
        assert_eq!(expect, got);
    }

    #[test]
    fn all_alive_faulty_build_matches_clean_build() {
        // reset_to_faulty with nobody dead must produce the exact graph
        // reset_to does — the empty-plan bitwise contract rests on it.
        let mut p = params();
        p.jitter_comp = 0.05;
        p.jitter_comm = 0.03;
        for (k, l, m) in [(1usize, 64usize, 1usize), (8, 1024, 1), (24, 2048, 3)] {
            p.masters = m;
            let dead = vec![false; k];
            let mut faulty = IterationTemplate::new(k, l, &p);
            faulty.reset_to_faulty(k, l, &p, &dead, RecoveryPolicy::Redistribute);
            let mut clean = IterationTemplate::new(k, l, &p);
            assert_eq!(faulty.task_count(), clean.task_count(), "K={k} l={l} m={m}");
            let a = faulty.replay(&mut analytic(l), &mut Rng::new(42));
            let b = clean.replay(&mut analytic(l), &mut Rng::new(42));
            assert_eq!(a, b, "K={k} l={l} m={m}");
        }
    }

    #[test]
    fn dead_worker_adds_recovery_tasks() {
        let p = params();
        let (k, l) = (8usize, 1024usize);
        let mut dead = vec![false; k];
        dead[3] = true;
        let mut counts = Vec::new();
        for policy in [RecoveryPolicy::MasterRecompute, RecoveryPolicy::Redistribute] {
            let mut tmpl = IterationTemplate::new(k, l, &p);
            tmpl.reset_to_faulty(k, l, &p, &dead, policy);
            counts.push(tmpl.task_count());
            let t = tmpl.replay(&mut analytic(l), &mut Rng::new(3));
            assert!(t.total > 0.0);
            assert!(t.reduce_done >= t.map_done);
            assert!(t.post_done >= t.reduce_done);
        }
        // Redistribute fans the dead chunk over 7 survivors (dispatch +
        // map + uplink + fold each) where master recompute adds only a
        // serial map + fold — graph sizes must reflect that.
        assert!(counts[1] > counts[0], "redistribute={} master={}", counts[1], counts[0]);
    }

    #[test]
    fn all_workers_dead_still_builds_and_runs() {
        // Degenerate case: every worker dead — the master recomputes the
        // whole list regardless of policy (no survivors to redistribute to).
        let p = params();
        let (k, l) = (4usize, 256usize);
        let dead = vec![true; k];
        for policy in [RecoveryPolicy::MasterRecompute, RecoveryPolicy::Redistribute] {
            let mut tmpl = IterationTemplate::new(k, l, &p);
            tmpl.reset_to_faulty(k, l, &p, &dead, policy);
            let t = tmpl.replay(&mut analytic(l), &mut Rng::new(6));
            // the master alone pays at least the whole Map
            assert!(t.total >= 1.0, "{policy:?}: total={}", t.total);
        }
    }

    #[test]
    fn shared_link_slows_collectives_and_k1_stays_bitwise() {
        // Multi-transfer collective rounds split bandwidth under a shared
        // link, so the iteration must slow down; a single worker's rounds
        // all have one transfer, so shared pricing is bitwise per-edge.
        let per_edge = params();
        let mut shared = params();
        shared.net.link = crate::net::LinkMode::Shared;
        let l = 2048;
        for k in [16usize, 24] {
            let a = IterationTemplate::new(k, l, &per_edge)
                .replay(&mut analytic(l), &mut Rng::new(7));
            let b = IterationTemplate::new(k, l, &shared)
                .replay(&mut analytic(l), &mut Rng::new(7));
            assert!(b.total > a.total, "K={k}: shared={} per-edge={}", b.total, a.total);
        }
        let a = IterationTemplate::new(1, l, &per_edge).replay(&mut analytic(l), &mut Rng::new(7));
        let b = IterationTemplate::new(1, l, &shared).replay(&mut analytic(l), &mut Rng::new(7));
        assert_eq!(a, b, "K=1 has no concurrent transfers to contend");
    }

    #[test]
    fn bind_cell_reprices_shared_link_round_trip() {
        // Rebinding per-edge → shared → per-edge must route contention
        // through the recorded contender column and return bitwise to the
        // original pricing.
        let per_edge = params();
        let mut shared = params();
        shared.net.link = crate::net::LinkMode::Shared;
        let (k, l) = (12usize, 1024usize);
        let mut tmpl = IterationTemplate::new(k, l, &per_edge);
        let want = tmpl.replay(&mut analytic(l), &mut Rng::new(5));
        let mut fresh_shared = IterationTemplate::new(k, l, &shared);
        let want_shared = fresh_shared.replay(&mut analytic(l), &mut Rng::new(5));
        tmpl.bind_cell(l, &shared);
        let got_shared = tmpl.replay(&mut analytic(l), &mut Rng::new(5));
        assert_eq!(got_shared, want_shared, "rebind must price like a fresh shared build");
        tmpl.bind_cell(l, &per_edge);
        let got = tmpl.replay(&mut analytic(l), &mut Rng::new(5));
        assert_eq!(got, want, "round-trip rebind must restore per-edge pricing");
    }

    #[test]
    fn ckpt_save_adds_exactly_the_fixed_save_cost() {
        // The save task is appended after `post` with a Fixed duration, so
        // a save-carrying build's makespan is bitwise `clean + save_cost`
        // and no provider/rng draw moves.
        let mut p = params();
        p.jitter_comp = 0.04;
        p.jitter_comm = 0.02;
        let (k, l) = (8usize, 1024usize);
        let dead = vec![false; k];
        let policy = RecoveryPolicy::Checkpoint { interval: 4 };
        let mut plain = IterationTemplate::new(k, l, &p);
        plain.reset_to_faulty_ckpt(k, l, &p, &dead, policy, false);
        let a = plain.replay(&mut analytic(l), &mut Rng::new(11));
        let mut saving = IterationTemplate::new(k, l, &p);
        saving.reset_to_faulty_ckpt(k, l, &p, &dead, policy, true);
        let b = saving.replay(&mut analytic(l), &mut Rng::new(11));
        assert_eq!(saving.task_count(), plain.task_count() + 1);
        assert_eq!(b.post_done.to_bits(), a.post_done.to_bits());
        let save_cost = p.net.p2p(p.words_down);
        assert_eq!(b.total.to_bits(), (a.total + save_cost).to_bits());
    }

    #[test]
    fn shape_class_splits_on_structure_only() {
        let p = params();
        // Payload-only differences keep the key equal: size is not even
        // an input, and jitter / word counts / network model are bound
        // per cell.
        let mut q = params();
        q.jitter_comp = 0.05;
        q.jitter_comm = 0.02;
        q.words_down = 17;
        q.words_up = 3;
        q.net = NetworkParams::fast_fabric();
        assert_eq!(ShapeClass::of(12, &p), ShapeClass::of(12, &q));
        // Structural differences split it.
        assert_ne!(ShapeClass::of(12, &p), ShapeClass::of(13, &p));
        let mut alg = params();
        alg.algo = CollectiveAlgo::Linear;
        assert_ne!(ShapeClass::of(12, &p), ShapeClass::of(12, &alg));
        let mut red = params();
        red.reduce_mode = ReduceMode::InTree;
        assert_ne!(ShapeClass::of(12, &p), ShapeClass::of(12, &red));
        let mut mm = params();
        mm.masters = 3;
        assert_ne!(ShapeClass::of(12, &p), ShapeClass::of(12, &mm));
        // Only the *effective* master count is structural: masters 5 and
        // 9 saturate to the same shape when k = 4.
        let mut m5 = params();
        m5.masters = 5;
        let mut m9 = params();
        m9.masters = 9;
        assert_eq!(ShapeClass::of(4, &m5), ShapeClass::of(4, &m9));
        assert_eq!(IterationTemplate::new(12, 1024, &p).shape_class(), ShapeClass::of(12, &p));
    }

    #[test]
    fn bind_cell_matches_fresh_build_bitwise() {
        // Rebinding a shared-shape template to a new cell's payload
        // (size, word counts, network, jitter) must replay bitwise
        // identically to a template freshly built for that cell.
        let p = params();
        let mut tmpl = IterationTemplate::new(16, 1024, &p);
        tmpl.replay(&mut analytic(1024), &mut Rng::new(1));
        let mut q = params();
        q.words_down = 4096;
        q.words_up = 16;
        q.jitter_comp = 0.06;
        q.jitter_comm = 0.04;
        q.net = NetworkParams::fast_fabric();
        for l in [2048usize, 100, 2048] {
            tmpl.bind_cell(l, &q);
            let mut fresh = IterationTemplate::new(16, l, &q);
            assert_eq!(tmpl.task_count(), fresh.task_count(), "l={l}");
            assert_eq!(tmpl.structure(), fresh.structure(), "l={l}");
            let a = tmpl.replay(&mut analytic(l), &mut Rng::new(42));
            let b = fresh.replay(&mut analytic(l), &mut Rng::new(42));
            assert_eq!(a, b, "l={l}");
        }
        assert_eq!(tmpl.sched_counters().shape_rebinds, 3);
    }

    #[test]
    fn reset_shape_rebinds_on_equal_shape_and_rebuilds_otherwise() {
        let p = params();
        let mut tmpl = IterationTemplate::new(8, 512, &p);
        let mut q = params();
        q.words_up = 9;
        assert!(!tmpl.reset_shape(8, 4096, &q), "equal shape must rebind");
        let mut r = params();
        r.reduce_mode = ReduceMode::GatherThenFold;
        assert!(tmpl.reset_shape(9, 4096, &q), "new k must rebuild");
        assert!(tmpl.reset_shape(9, 4096, &r), "new reduce mode must rebuild");
        let a = tmpl.replay(&mut analytic(4096), &mut Rng::new(7));
        let b = IterationTemplate::new(9, 4096, &r).replay(&mut analytic(4096), &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "equal ShapeClass")]
    fn bind_cell_rejects_shape_mismatch() {
        let mut tmpl = IterationTemplate::new(8, 512, &params());
        let mut q = params();
        q.reduce_mode = ReduceMode::InTree;
        tmpl.bind_cell(512, &q);
    }

    #[test]
    fn run_group_into_matches_per_cell_run_into_bitwise() {
        // K-adjacent batching contract: one shared template driving N
        // cells' replays through flat lane batches (which span cell
        // boundaries) must be bitwise identical to a serial per-cell
        // run_into loop, in cell-major order.
        let l = 1024;
        let mut p = params();
        p.jitter_comp = 0.06;
        p.jitter_comm = 0.04;
        let (k, iters, n_cells) = (12usize, 7usize, 3usize);
        let root = Rng::new(0x5EED);
        let mut expect = Vec::new();
        for c in 0..n_cells {
            let mut tmpl = IterationTemplate::new(k, l, &p);
            let mut prov = analytic(l);
            let mut rng = root.split(c as u64);
            let mut out = Vec::new();
            tmpl.run_into(iters, &mut prov, &mut rng, &mut out);
            expect.extend(out);
        }
        let mut tmpl = IterationTemplate::new(k, l, &p);
        let mut cells: Vec<GroupCell> = (0..n_cells)
            .map(|c| GroupCell::new(Box::new(analytic(l)), root.split(c as u64), l, &p))
            .collect();
        let mut got = Vec::new();
        tmpl.run_group_into(&mut cells, iters, &mut got);
        assert_eq!(expect, got);
        let c = tmpl.sched_counters();
        assert!(c.lane_hits > 0 || c.lane_fallbacks > 0, "group run never batched: {c:?}");
        assert!(c.group_batches > 0, "{c:?}");
        assert!(c.group_spanned_cells > 0, "3 cells × 7 iters must span: {c:?}");
    }

    #[test]
    fn run_group_into_mixed_sizes_matches_per_cell_loop() {
        // The shape-class contract end to end: four *different sizes* of
        // one K share one template; grouped lane batches spanning the
        // size cells must be bitwise identical to a serial per-cell
        // run_into loop over per-size templates.
        let mut p = params();
        p.jitter_comp = 0.06;
        p.jitter_comm = 0.04;
        let (k, iters) = (12usize, 7usize);
        let sizes = [512usize, 1024, 4096, 16384];
        let root = Rng::new(0xBAD_5EED);
        let mut expect = Vec::new();
        for (c, &l) in sizes.iter().enumerate() {
            let mut tmpl = IterationTemplate::new(k, l, &p);
            let mut prov = analytic(l);
            let mut rng = root.split(c as u64);
            let mut out = Vec::new();
            tmpl.run_into(iters, &mut prov, &mut rng, &mut out);
            expect.extend(out);
        }
        let mut tmpl = IterationTemplate::new(k, sizes[0], &p);
        let mut cells: Vec<GroupCell> = sizes
            .iter()
            .enumerate()
            .map(|(c, &l)| GroupCell::new(Box::new(analytic(l)), root.split(c as u64), l, &p))
            .collect();
        let mut got = Vec::new();
        tmpl.run_group_into(&mut cells, iters, &mut got);
        assert_eq!(expect, got);
        let c = tmpl.sched_counters();
        assert!(c.group_spanned_cells > 0, "size cells must share batches: {c:?}");
        assert!(c.shape_rebinds >= sizes.len() as u64 - 1, "{c:?}");
    }

    #[test]
    fn run_group_into_mixed_determinism_matches_per_cell_loop() {
        // A group mixing deterministic and jittered cells replicates the
        // former and lane-batches maximal runs of the latter — still in
        // cell-major order, still bitwise equal to the serial loop.
        let (k, iters) = (8usize, 5usize);
        let det_p = params();
        let mut jit_p = params();
        jit_p.jitter_comp = 0.08;
        let specs = [(512usize, &det_p), (1024, &jit_p), (2048, &det_p), (4096, &jit_p)];
        let root = Rng::new(0xF00D);
        let mut expect = Vec::new();
        for (c, &(l, pp)) in specs.iter().enumerate() {
            let mut tmpl = IterationTemplate::new(k, l, pp);
            let mut prov = analytic(l);
            let mut rng = root.split(c as u64);
            let mut out = Vec::new();
            tmpl.run_into(iters, &mut prov, &mut rng, &mut out);
            expect.extend(out);
        }
        let mut tmpl = IterationTemplate::new(k, 512, &det_p);
        let mut cells: Vec<GroupCell> = specs
            .iter()
            .enumerate()
            .map(|(c, &(l, pp))| {
                GroupCell::new(Box::new(analytic(l)), root.split(c as u64), l, pp)
            })
            .collect();
        let mut got = Vec::new();
        tmpl.run_group_into(&mut cells, iters, &mut got);
        assert_eq!(expect, got);
    }

    #[test]
    fn run_group_into_deterministic_replicates_per_cell() {
        // Fully deterministic groups take the replication shortcut: one
        // replay per cell, timings replicated — same as run_into's.
        let l = 512;
        let p = params();
        let mut tmpl = IterationTemplate::new(8, l, &p);
        let mut cells: Vec<GroupCell> = (0..2)
            .map(|c| GroupCell::new(Box::new(analytic(l)), Rng::new(c as u64), l, &p))
            .collect();
        let mut got = Vec::new();
        tmpl.run_group_into(&mut cells, 5, &mut got);
        assert_eq!(got.len(), 10);
        let one = simulate_iteration(8, l, &p, &mut analytic(l), &mut Rng::new(99));
        for t in &got {
            assert_eq!(*t, one);
        }
    }

    #[test]
    fn deterministic_run_replicates_single_iteration() {
        let l = 2048;
        let mut prov = analytic(l);
        let mut rng = Rng::new(13);
        let runs = simulate_run(16, l, 7, &params(), &mut prov, &mut rng);
        assert_eq!(runs.len(), 7);
        let one = simulate_iteration(16, l, &params(), &mut prov, &mut Rng::new(99));
        for t in &runs {
            assert_eq!(*t, one);
        }
    }
}
