//! Discrete-event cluster simulator — the stand-in for the paper's 480-node
//! "Tornado SUSU" cluster.
//!
//! The simulator executes the *actual* Algorithm-2 timeline (binomial-tree
//! broadcast, per-worker Map + local Reduce, tree reduce with in-tree
//! folding, master post-processing) as a resource-constrained task graph:
//! every processor node is a serial resource, every message and compute
//! step is a task with explicit dependencies. Eq. (8) of the paper is a
//! closed-form *approximation* of this timeline, so predicted-vs-simulated
//! error is a meaningful analogue of the paper's predicted-vs-measured
//! error.
//!
//! Compute durations come from a pluggable [`CostProvider`] — analytic
//! per-op costs for pure model studies, or samples measured on this machine
//! (real PJRT kernel executions) for the hybrid "empirical" mode.
//! Multiplicative lognormal jitter (calibrated from live-run variance)
//! models OS/MPI noise.

mod cluster;
mod engine;
mod faults;
mod lanes;
pub mod trace;

pub use cluster::{
    simulate_iteration, simulate_iteration_full, simulate_run, AnalyticCost, CostFactory,
    CostProvider, GraphStructure, GroupCell, IterationTemplate, IterationTiming, ReduceMode,
    SampledCost, ShapeClass, SimParams,
};
pub use faults::{
    faults_audit, run_faulty_into, FailureWindow, FaultPlan, FaultScratch, FaultSpec, FaultyCost,
    RecoveryPolicy, MASTER_WORKER,
};
pub use trace::{trace_iteration, Trace, TraceEvent};
pub use engine::{
    sched_mode, Engine, ReferenceScheduler, SchedCounters, SchedMode, TaskId, TaskSpec,
};
pub use lanes::{group_enabled, lane_width, lanes_enabled, LANES_MAX};
