//! Resource-constrained task-graph execution engine (the DES core).
//!
//! A simulation is a DAG of tasks; each task has a duration and runs on one
//! *resource* (a processor node's CPU or NIC), and resources execute one
//! task at a time in the order they become ready (list scheduling). The
//! engine computes every task's start/finish time with a binary-heap event
//! queue — `O((T + E) log T)` for `T` tasks and `E` dependency edges.
//!
//! This is the hot path of every speedup-curve experiment (a Fig.-6 sweep
//! executes millions of tasks), so the representation is flat `Vec`s and
//! the heap holds plain `(f64, u32)` pairs.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Identifier of a task within one [`Engine`] run.
pub type TaskId = u32;

/// Specification of one task.
#[derive(Debug, Clone, Copy)]
pub struct TaskSpec {
    /// Resource (e.g. node id) the task occupies; tasks on one resource
    /// serialise.
    pub resource: u32,
    /// Duration in seconds.
    pub duration: f64,
}

/// Min-heap entry ordered by time (total order; times are finite).
#[derive(Debug, PartialEq)]
struct Ready(f64, TaskId);

impl Eq for Ready {}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; tie-break on id for determinism.
        other
            .0
            .partial_cmp(&self.0)
            .expect("non-finite task time")
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Task-graph builder + executor.
#[derive(Debug, Default)]
pub struct Engine {
    specs: Vec<TaskSpec>,
    /// Adjacency: edges[i] lists tasks that depend on task i.
    edges: Vec<Vec<TaskId>>,
    /// Number of unmet dependencies per task.
    pending: Vec<u32>,
    /// Earliest start implied by completed deps.
    ready_at: Vec<f64>,
    /// Optional phase labels (static strings — no hot-path allocation).
    labels: Vec<&'static str>,
}

impl Engine {
    /// Create an empty engine.
    pub fn new() -> Engine {
        Engine::default()
    }

    /// Add a task; returns its id.
    pub fn task(&mut self, resource: u32, duration: f64) -> TaskId {
        self.task_labeled(resource, duration, "")
    }

    /// Add a labelled task (label shows up in exported traces).
    pub fn task_labeled(&mut self, resource: u32, duration: f64, label: &'static str) -> TaskId {
        debug_assert!(duration >= 0.0, "negative duration");
        let id = self.specs.len() as TaskId;
        self.specs.push(TaskSpec { resource, duration });
        self.edges.push(Vec::new());
        self.pending.push(0);
        self.ready_at.push(0.0);
        self.labels.push(label);
        id
    }

    /// Per-task specs (read-only; used by trace export).
    pub fn specs(&self) -> &[TaskSpec] {
        &self.specs
    }

    /// Per-task labels.
    pub fn labels(&self) -> &[&'static str] {
        &self.labels
    }

    /// Declare that `after` cannot start before `before` finishes.
    pub fn dep(&mut self, before: TaskId, after: TaskId) {
        self.edges[before as usize].push(after);
        self.pending[after as usize] += 1;
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True when no tasks have been added.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Execute the graph; returns per-task finish times.
    ///
    /// Panics if the dependency graph is cyclic (some task never becomes
    /// ready).
    pub fn run(&mut self) -> Vec<f64> {
        let n = self.specs.len();
        let max_resource = self
            .specs
            .iter()
            .map(|s| s.resource)
            .max()
            .map(|r| r as usize + 1)
            .unwrap_or(0);
        let mut resource_free = vec![0.0f64; max_resource];
        let mut finish = vec![f64::NAN; n];
        let mut heap: BinaryHeap<Ready> = BinaryHeap::with_capacity(n);
        for (i, &p) in self.pending.iter().enumerate() {
            if p == 0 {
                heap.push(Ready(self.ready_at[i], i as TaskId));
            }
        }
        let mut done = 0usize;
        while let Some(Ready(ready, id)) = heap.pop() {
            let spec = self.specs[id as usize];
            let start = ready.max(resource_free[spec.resource as usize]);
            let end = start + spec.duration;
            resource_free[spec.resource as usize] = end;
            finish[id as usize] = end;
            done += 1;
            // `edges` is only read here; split borrow via index loop.
            for e in 0..self.edges[id as usize].len() {
                let succ = self.edges[id as usize][e] as usize;
                if self.ready_at[succ] < end {
                    self.ready_at[succ] = end;
                }
                self.pending[succ] -= 1;
                if self.pending[succ] == 0 {
                    heap.push(Ready(self.ready_at[succ], succ as TaskId));
                }
            }
        }
        assert_eq!(done, n, "cyclic dependency graph: {} tasks never ran", n - done);
        finish
    }

    /// Makespan of the last `run`'s schedule (max finish time).
    pub fn makespan(finish: &[f64]) -> f64 {
        finish.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_accumulates() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 2.0);
        let c = e.task(0, 3.0);
        e.dep(a, b);
        e.dep(b, c);
        let f = e.run();
        assert_eq!(f, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn parallel_resources_overlap() {
        let mut e = Engine::new();
        let a = e.task(0, 5.0);
        let b = e.task(1, 5.0);
        let f = e.run();
        assert_eq!(f[a as usize], 5.0);
        assert_eq!(f[b as usize], 5.0);
        assert_eq!(Engine::makespan(&f), 5.0);
    }

    #[test]
    fn same_resource_serialises() {
        let mut e = Engine::new();
        let _a = e.task(0, 5.0);
        let b = e.task(0, 5.0);
        let f = e.run();
        assert_eq!(f[b as usize], 10.0);
    }

    #[test]
    fn join_waits_for_slowest() {
        let mut e = Engine::new();
        let fast = e.task(0, 1.0);
        let slow = e.task(1, 9.0);
        let join = e.task(2, 0.5);
        e.dep(fast, join);
        e.dep(slow, join);
        let f = e.run();
        assert_eq!(f[join as usize], 9.5);
    }

    #[test]
    fn fork_join_diamond() {
        let mut e = Engine::new();
        let src = e.task(0, 1.0);
        let l = e.task(1, 2.0);
        let r = e.task(2, 3.0);
        let sink = e.task(0, 1.0);
        e.dep(src, l);
        e.dep(src, r);
        e.dep(l, sink);
        e.dep(r, sink);
        let f = e.run();
        assert_eq!(f[sink as usize], 5.0);
    }

    #[test]
    fn ready_order_respects_resource_contention() {
        // Two tasks ready at t=0 on one resource: deterministic order by id.
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        let f = e.run();
        assert_eq!(f[a as usize], 1.0);
        assert_eq!(f[b as usize], 2.0);
    }

    #[test]
    #[should_panic(expected = "cyclic")]
    fn cycle_detected() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        e.dep(a, b);
        e.dep(b, a);
        e.run();
    }

    #[test]
    fn zero_duration_tasks_ok() {
        let mut e = Engine::new();
        let a = e.task(0, 0.0);
        let b = e.task(0, 0.0);
        e.dep(a, b);
        let f = e.run();
        assert_eq!(f, vec![0.0, 0.0]);
    }

    #[test]
    fn empty_graph() {
        let mut e = Engine::new();
        let f = e.run();
        assert!(f.is_empty());
        assert!(e.is_empty());
        assert_eq!(Engine::makespan(&f), 0.0);
    }
}
