//! Resource-constrained task-graph execution engine (the DES core).
//!
//! A simulation is a DAG of tasks; each task has a duration and runs on one
//! *resource* (a processor node's CPU or NIC), and resources execute one
//! task at a time in the order they become ready (list scheduling). The
//! engine computes every task's start/finish time with a binary-heap event
//! queue — `O((T + E) log T)` for `T` tasks and `E` dependency edges.
//!
//! This is the hot path of every speedup-curve experiment (a Fig.-6 sweep
//! executes millions of tasks), so the representation is allocation-free on
//! replay: edges live in a CSR-style flat array (`csr_off`/`csr_dst`, built
//! once per graph), every per-run working set (`pending`, `ready_at`,
//! `finish`, `resource_free`, the heap) is a reusable scratch buffer, and
//! [`Engine::set_duration`] + [`Engine::run_reuse`] replay the same graph
//! with new durations without touching the allocator. After the first
//! `run_reuse` call on a graph, subsequent replays perform **zero** heap
//! allocations (asserted by `rust/benches/simulator_hotpath.rs` with a
//! counting allocator).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Identifier of a task within one [`Engine`] run.
pub type TaskId = u32;

/// Specification of one task.
#[derive(Debug, Clone, Copy)]
pub struct TaskSpec {
    /// Resource (e.g. node id) the task occupies; tasks on one resource
    /// serialise.
    pub resource: u32,
    /// Duration in seconds.
    pub duration: f64,
}

/// Min-heap entry ordered by time (total order; times are finite).
#[derive(Debug, PartialEq)]
struct Ready(f64, TaskId);

impl Eq for Ready {}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; tie-break on id for determinism.
        other
            .0
            .partial_cmp(&self.0)
            .expect("non-finite task time")
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Task-graph builder + executor.
///
/// The graph (tasks + dependencies) and the execution scratch are both
/// owned by the engine, so a graph can be built once and replayed many
/// times: mutate durations with [`Engine::set_duration`], execute with
/// [`Engine::run_reuse`], and start a new graph without releasing buffer
/// capacity with [`Engine::reset`].
#[derive(Debug, Default)]
pub struct Engine {
    specs: Vec<TaskSpec>,
    /// Optional phase labels (static strings — no hot-path allocation).
    labels: Vec<&'static str>,
    /// Edge list in insertion order; finalised into CSR before execution.
    edge_from: Vec<TaskId>,
    edge_to: Vec<TaskId>,
    /// Number of dependencies per task (static; copied into `pending` per run).
    indegree: Vec<u32>,
    /// CSR adjacency: successors of task `i` are
    /// `csr_dst[csr_off[i]..csr_off[i+1]]`, in `dep` insertion order.
    csr_off: Vec<usize>,
    csr_dst: Vec<TaskId>,
    csr_valid: bool,
    /// Number of distinct resources (max resource id + 1).
    max_res: usize,
    // --- per-run scratch, reused across run_reuse calls ---
    pending: Vec<u32>,
    ready_at: Vec<f64>,
    finish: Vec<f64>,
    resource_free: Vec<f64>,
    heap: BinaryHeap<Ready>,
}

impl Engine {
    /// Create an empty engine.
    pub fn new() -> Engine {
        Engine::default()
    }

    /// Add a task; returns its id.
    pub fn task(&mut self, resource: u32, duration: f64) -> TaskId {
        self.task_labeled(resource, duration, "")
    }

    /// Add a labelled task (label shows up in exported traces).
    pub fn task_labeled(&mut self, resource: u32, duration: f64, label: &'static str) -> TaskId {
        debug_assert!(duration >= 0.0, "negative duration");
        let id = self.specs.len() as TaskId;
        self.specs.push(TaskSpec { resource, duration });
        self.labels.push(label);
        self.indegree.push(0);
        self.max_res = self.max_res.max(resource as usize + 1);
        self.csr_valid = false;
        id
    }

    /// Per-task specs (read-only; used by trace export).
    pub fn specs(&self) -> &[TaskSpec] {
        &self.specs
    }

    /// Per-task labels.
    pub fn labels(&self) -> &[&'static str] {
        &self.labels
    }

    /// Declare that `after` cannot start before `before` finishes.
    pub fn dep(&mut self, before: TaskId, after: TaskId) {
        self.edge_from.push(before);
        self.edge_to.push(after);
        self.indegree[after as usize] += 1;
        self.csr_valid = false;
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// Number of dependency edges.
    pub fn edge_count(&self) -> usize {
        self.edge_from.len()
    }

    /// True when no tasks have been added.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Overwrite a task's duration (graph structure unchanged) — the replay
    /// API: build the graph once, then per iteration set new durations and
    /// call [`Engine::run_reuse`].
    pub fn set_duration(&mut self, id: TaskId, duration: f64) {
        debug_assert!(duration >= 0.0, "negative duration");
        self.specs[id as usize].duration = duration;
    }

    /// Clear the graph (tasks, labels, edges) while keeping the capacity of
    /// every internal buffer — start building the next graph without
    /// releasing memory.
    pub fn reset(&mut self) {
        self.specs.clear();
        self.labels.clear();
        self.edge_from.clear();
        self.edge_to.clear();
        self.indegree.clear();
        self.csr_valid = false;
        self.max_res = 0;
    }

    /// Per-task finish times of the most recent run (empty before any run).
    pub fn last_finish(&self) -> &[f64] {
        &self.finish
    }

    /// Build the CSR adjacency from the edge list (counting sort by source;
    /// stable, so per-source successor order equals `dep` insertion order —
    /// this keeps heap insertion order, and therefore tie-breaking, bitwise
    /// reproducible).
    fn finalize(&mut self) {
        let n = self.specs.len();
        self.csr_off.clear();
        self.csr_off.resize(n + 1, 0);
        for &f in &self.edge_from {
            self.csr_off[f as usize + 1] += 1;
        }
        for i in 0..n {
            self.csr_off[i + 1] += self.csr_off[i];
        }
        self.csr_dst.clear();
        self.csr_dst.resize(self.edge_from.len(), 0);
        let mut cursor = self.csr_off.clone();
        for (&f, &t) in self.edge_from.iter().zip(&self.edge_to) {
            self.csr_dst[cursor[f as usize]] = t;
            cursor[f as usize] += 1;
        }
        self.csr_valid = true;
    }

    /// Execute the graph; returns per-task finish times as a fresh vector.
    ///
    /// Panics if the dependency graph is cyclic (some task never becomes
    /// ready). Convenience wrapper over [`Engine::run_reuse`] for one-shot
    /// callers; hot loops should use `run_reuse` to avoid the copy.
    pub fn run(&mut self) -> Vec<f64> {
        self.run_reuse().to_vec()
    }

    /// Execute the graph into the engine's reusable scratch buffers and
    /// return the per-task finish times as a borrowed slice. Zero heap
    /// allocations once the scratch has grown to the graph's size.
    pub fn run_reuse(&mut self) -> &[f64] {
        if !self.csr_valid {
            self.finalize();
        }
        let n = self.specs.len();
        self.pending.clear();
        self.pending.extend_from_slice(&self.indegree);
        self.ready_at.clear();
        self.ready_at.resize(n, 0.0);
        self.finish.clear();
        self.finish.resize(n, f64::NAN);
        self.resource_free.clear();
        self.resource_free.resize(self.max_res, 0.0);
        self.heap.clear();
        for (i, &p) in self.pending.iter().enumerate() {
            if p == 0 {
                self.heap.push(Ready(0.0, i as TaskId));
            }
        }
        let mut done = 0usize;
        while let Some(Ready(ready, id)) = self.heap.pop() {
            let spec = self.specs[id as usize];
            let start = ready.max(self.resource_free[spec.resource as usize]);
            let end = start + spec.duration;
            self.resource_free[spec.resource as usize] = end;
            self.finish[id as usize] = end;
            done += 1;
            let lo = self.csr_off[id as usize];
            let hi = self.csr_off[id as usize + 1];
            for e in lo..hi {
                let succ = self.csr_dst[e] as usize;
                if self.ready_at[succ] < end {
                    self.ready_at[succ] = end;
                }
                self.pending[succ] -= 1;
                if self.pending[succ] == 0 {
                    let at = self.ready_at[succ];
                    self.heap.push(Ready(at, succ as TaskId));
                }
            }
        }
        assert_eq!(done, n, "cyclic dependency graph: {} tasks never ran", n - done);
        &self.finish
    }

    /// Makespan of the last `run`'s schedule (max finish time).
    pub fn makespan(finish: &[f64]) -> f64 {
        finish.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_accumulates() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 2.0);
        let c = e.task(0, 3.0);
        e.dep(a, b);
        e.dep(b, c);
        let f = e.run();
        assert_eq!(f, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn parallel_resources_overlap() {
        let mut e = Engine::new();
        let a = e.task(0, 5.0);
        let b = e.task(1, 5.0);
        let f = e.run();
        assert_eq!(f[a as usize], 5.0);
        assert_eq!(f[b as usize], 5.0);
        assert_eq!(Engine::makespan(&f), 5.0);
    }

    #[test]
    fn same_resource_serialises() {
        let mut e = Engine::new();
        let _a = e.task(0, 5.0);
        let b = e.task(0, 5.0);
        let f = e.run();
        assert_eq!(f[b as usize], 10.0);
    }

    #[test]
    fn join_waits_for_slowest() {
        let mut e = Engine::new();
        let fast = e.task(0, 1.0);
        let slow = e.task(1, 9.0);
        let join = e.task(2, 0.5);
        e.dep(fast, join);
        e.dep(slow, join);
        let f = e.run();
        assert_eq!(f[join as usize], 9.5);
    }

    #[test]
    fn fork_join_diamond() {
        let mut e = Engine::new();
        let src = e.task(0, 1.0);
        let l = e.task(1, 2.0);
        let r = e.task(2, 3.0);
        let sink = e.task(0, 1.0);
        e.dep(src, l);
        e.dep(src, r);
        e.dep(l, sink);
        e.dep(r, sink);
        let f = e.run();
        assert_eq!(f[sink as usize], 5.0);
    }

    #[test]
    fn ready_order_respects_resource_contention() {
        // Two tasks ready at t=0 on one resource: deterministic order by id.
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        let f = e.run();
        assert_eq!(f[a as usize], 1.0);
        assert_eq!(f[b as usize], 2.0);
    }

    #[test]
    #[should_panic(expected = "cyclic")]
    fn cycle_detected() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        e.dep(a, b);
        e.dep(b, a);
        e.run();
    }

    #[test]
    fn zero_duration_tasks_ok() {
        let mut e = Engine::new();
        let a = e.task(0, 0.0);
        let b = e.task(0, 0.0);
        e.dep(a, b);
        let f = e.run();
        assert_eq!(f, vec![0.0, 0.0]);
    }

    #[test]
    fn empty_graph() {
        let mut e = Engine::new();
        let f = e.run();
        assert!(f.is_empty());
        assert!(e.is_empty());
        assert_eq!(Engine::makespan(&f), 0.0);
    }

    #[test]
    fn replay_is_bitwise_stable() {
        // Same graph, same durations: every replay must be bit-identical.
        let mut e = Engine::new();
        let src = e.task(0, 0.3);
        let mid = e.task(1, 0.7);
        let sink = e.task(0, 0.1);
        e.dep(src, mid);
        e.dep(mid, sink);
        let first = e.run();
        for _ in 0..3 {
            assert_eq!(e.run_reuse(), &first[..]);
        }
    }

    #[test]
    fn set_duration_replays_new_schedule() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 2.0);
        e.dep(a, b);
        assert_eq!(e.run(), vec![1.0, 3.0]);
        e.set_duration(a, 10.0);
        assert_eq!(e.run(), vec![10.0, 12.0]);
    }

    #[test]
    fn reset_reuses_buffers_for_new_graph() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(1, 2.0);
        e.dep(a, b);
        assert_eq!(e.run(), vec![1.0, 3.0]);
        e.reset();
        assert!(e.is_empty());
        assert_eq!(e.edge_count(), 0);
        let a = e.task(0, 4.0);
        let b = e.task(0, 5.0);
        e.dep(a, b);
        assert_eq!(e.run(), vec![4.0, 9.0]);
    }

    #[test]
    fn dep_after_first_run_rebuilds_csr() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        let f = e.run();
        assert_eq!(f, vec![1.0, 2.0]);
        let c = e.task(1, 1.0);
        e.dep(a, c);
        e.dep(b, c);
        let f = e.run();
        assert_eq!(f[c as usize], 3.0);
    }
}
