//! Resource-constrained task-graph execution engine (the DES core).
//!
//! A simulation is a DAG of tasks; each task has a duration and runs on one
//! *resource* (a processor node's CPU or NIC), and resources execute one
//! task at a time in the order they become ready (list scheduling). The
//! engine computes every task's start/finish time with a calendar (bucket)
//! event queue — amortised `O(T + E)` for `T` tasks and `E` dependency
//! edges on the event distributions real iteration graphs produce.
//!
//! This is the hot path of every speedup-curve experiment (a Fig.-6 sweep
//! executes millions of tasks), so the representation is allocation-free on
//! replay: the task table is SoA (`resources`/`durations` parallel
//! columns), edges live in a CSR-style flat array (`csr_off`/`csr_dst`,
//! built once per graph), every per-run working set (`pending`, `ready_at`,
//! `finish`, `resource_free`, the calendar's bucket lists) is a reusable
//! scratch buffer, and [`Engine::set_duration`] + [`Engine::run_reuse`]
//! replay the same graph with new durations without touching the
//! allocator. After the first `run_reuse` call on a graph, subsequent
//! replays perform **zero** heap allocations (asserted by
//! `rust/benches/simulator_hotpath.rs` with a counting allocator).
//!
//! ## Event-queue schedule contract
//!
//! The calendar queue pops events in ascending `(ready_time, task id)`
//! order — exactly the order the previous `BinaryHeap` implementation
//! produced (min time, ties broken by the smaller id). This keeps every
//! schedule bitwise identical across the queue swap; the equivalence is
//! pinned by `rust/tests/determinism.rs` and the random-DAG property test
//! in `rust/tests/properties.rs`, which compares against a reference heap
//! implementation including time ties.
//!
//! ## Order-cached linear replay
//!
//! A sweep replays one graph thousands of times with slightly different
//! durations, and list scheduling almost never changes its pop order
//! under small perturbations. The engine therefore retains the pop order
//! of the last full calendar run as a permutation; when the order cache
//! is valid, [`Engine::run_reuse`] executes a single linear pass over it
//! (`start = max(ready_at, resource_free)`, successors' `ready_at`
//! updated in place — no queue, no bucket scans) guarded by an exact
//! O(T) **validity check**: the sequence `(ready_at_at_pop, id)` along
//! the cached permutation must be lexicographically *strictly*
//! increasing. Because predecessors precede successors in any recorded
//! pop order, every `ready_at` is final when its task is reached, and a
//! strictly increasing sequence means each task is the unique
//! `(time, id)` minimum of the event queue at its turn — i.e. the
//! calendar/heap would have popped exactly this order, so the linear
//! pass reproduces the calendar schedule **bitwise by construction**.
//! On violation the pass aborts and a full calendar run executes,
//! refreshing the cache — results stay bitwise identical to
//! [`ReferenceScheduler`] in both branches (the check is conservative:
//! it may reject a still-valid order in exotic zero-duration tie cases,
//! which only costs a fallback, never correctness).
//!
//! Dispatch mirrors `BSF_KERNEL`: `BSF_SCHED=calendar|cached` overrides
//! **once per process** (unset = `cached`, the auto default; any other
//! value panics), read by [`sched_mode`]. [`Engine::set_sched_mode`] is
//! the explicit per-instance override (like `kernels::dot_with`) used by
//! the test suites and `simulator_hotpath` to race both paths inside one
//! process. Cache hits/fallbacks are counted per engine
//! ([`Engine::sched_counters`]) and land in `BENCH_ci.json`.
//!
//! ## Adaptive calendar width
//!
//! A fallback calendar run tracks its bucket min-scan lengths (mean and
//! max) and overflow rebases; when occupancy sits far from the O(√R)
//! sizing target, the next `Calendar::prime` applies a corrected
//! width à la Brown's calendar queue (`Calendar::adapt`). Pop order is
//! width-independent — every bucket holds a time-disjoint slice and the
//! min-scan returns the global `(time, id)` minimum for any width — so
//! resizing is bitwise-neutral, pinned by the reference-heap property
//! test and the `adaptive_resize_is_bitwise_neutral` unit test.
//!
//! ## Lane-parallel replay
//!
//! Under jitter the deterministic-replication shortcut is unavailable and
//! every replay runs separately — but the order-cached linear pass is just
//! `max`/`+` per task, both exact IEEE-754 operations, so a batch of
//! *independent* duration sets replays through one shared pass at one
//! replay per lane. The lane width is chosen at runtime
//! ([`super::lanes::lane_width`], up to [`super::lanes::LANES_MAX`]):
//! AVX2 carries four f64 lanes, AVX-512 eight on hosts reporting
//! `avx512f`, and a width-generic scalar twin covers every other
//! (kernel, width) combination bitwise-identically.
//! [`Engine::run_lanes`] executes a lane batch: fill the lane-strided
//! duration matrix via [`Engine::lane_durations_mut`] (`[task][lane]`,
//! one task's lanes contiguous for a single vector load), then the
//! vectorized pass carries the per-lane validity check alongside the
//! timeline; any failing lane aborts the batch to a sequential scalar
//! re-run *in lane order* (each lane's [`Engine::run_reuse`] performing
//! its own cached-check / calendar-fallback with cache refreshes), so
//! hit and fallback results are both bitwise identical to replaying the
//! lanes one at a time. Batches narrower than the dispatch width are
//! **padded**: the missing lanes duplicate the last real lane's
//! durations (copied, never drawn — the jitter draw stream is untouched)
//! and their results are discarded, so a 3-replay remainder still rides
//! one vector pass instead of falling back to the scalar loop
//! (`SchedCounters::lane_pad_replays` counts the discarded lanes). The
//! implementation set dispatches through the existing `BSF_KERNEL`
//! mechanism plus `BSF_LANE_WIDTH=4|8` (per-instance:
//! [`Engine::set_lane_width`]); `BSF_LANES=on|off` (unset = `on`) gates
//! the vector pass process-wide, with [`Engine::set_lane_mode`] as the
//! per-instance override. See `simulator/lanes.rs`.
//!
//! After a lane batch the scalar accessors ([`Engine::last_finish`],
//! [`Engine::last_makespan`], [`Engine::durations`]) are unspecified and
//! **poisoned**: reading one before the next scalar run trips a
//! `debug_assert`, so misuse fails loudly in tests instead of silently
//! reading stale lane-0 data.

use crate::linalg::kernels;
use crate::simulator::lanes;

/// Identifier of a task within one [`Engine`] run.
pub type TaskId = u32;

/// One task's `(resource, duration)` pair — an assembled view over the
/// engine's SoA columns (see [`Engine::spec`]).
#[derive(Debug, Clone, Copy)]
pub struct TaskSpec {
    /// Resource (e.g. node id) the task occupies; tasks on one resource
    /// serialise.
    pub resource: u32,
    /// Duration in seconds.
    pub duration: f64,
}

/// Which replay scheduler [`Engine::run_reuse`] uses (see the module docs'
/// "Order-cached linear replay" section).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// Always run the full calendar event queue (the reference hot path).
    Calendar,
    /// Replay the cached pop order linearly when valid; fall back to the
    /// calendar (refreshing the cache) when the validity check rejects.
    Cached,
}

impl SchedMode {
    /// Human-readable name (reports, BENCH_ci.json).
    pub fn name(&self) -> &'static str {
        match self {
            SchedMode::Calendar => "calendar",
            SchedMode::Cached => "cached",
        }
    }
}

static ACTIVE_SCHED: std::sync::OnceLock<SchedMode> = std::sync::OnceLock::new();

/// The scheduler selected for this process (reads `BSF_SCHED` once).
/// Engines without a [`Engine::set_sched_mode`] override dispatch through
/// this, so CI can run the whole suite under either scheduler.
pub fn sched_mode() -> SchedMode {
    *ACTIVE_SCHED.get_or_init(|| select_sched(std::env::var("BSF_SCHED").ok().as_deref()))
}

/// Pure selection logic (unit-tested separately from process env state).
/// Requesting anything but `calendar`/`cached` panics loudly rather than
/// silently falling back — an override that does nothing would invalidate
/// any benchmark run on top of it.
fn select_sched(request: Option<&str>) -> SchedMode {
    match request {
        Some("calendar") => SchedMode::Calendar,
        Some("cached") => SchedMode::Cached,
        Some(other) => panic!("BSF_SCHED must be 'calendar' or 'cached', got '{other}'"),
        None => SchedMode::Cached,
    }
}

/// Scheduler-path counters for one [`Engine`] (cache telemetry — the
/// benches record hit-rate and fallback counts into `BENCH_ci.json`).
/// Counters accumulate for the life of the engine, across
/// [`Engine::reset`] calls.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SchedCounters {
    /// Replays served entirely by the order-cached linear pass.
    pub cached_hits: u64,
    /// Cached replays rejected by the validity check (stale pop order).
    pub fallbacks: u64,
    /// Full calendar runs (first runs, forced-calendar runs, fallbacks).
    pub calendar_runs: u64,
    /// Replays served by the vectorized lane-batched pass (counted per
    /// lane, i.e. per replay — see [`Engine::run_lanes`]).
    pub lane_hits: u64,
    /// Lane batches whose vector pass aborted (some lane failed the
    /// validity check) and re-ran through the sequential scalar path;
    /// those replays land in the ordinary counters above.
    pub lane_fallbacks: u64,
    /// Widest lane pass this engine has *dispatched* (0 = never
    /// batched): the runtime-selected vector width for batches served by
    /// the lane pass (padded remainders included), the batch size for
    /// sequential-path batches.
    pub lane_width: u64,
    /// Discarded pad-lane replays: a batch narrower than the dispatch
    /// width is padded with duplicates of its last real lane, and those
    /// lanes' results are thrown away. `lane_hits` counts real lanes
    /// only, so `lane_hits + lane_pad_replays` is the total lane-pass
    /// throughput the hardware actually executed.
    pub lane_pad_replays: u64,
    /// Cross-cell lane batches issued by a shape-class group run
    /// (`IterationTemplate::run_group_into`'s jittered path) — every
    /// batch counted, whether or not it crossed a cell boundary.
    pub group_batches: u64,
    /// Sum over group batches of `(distinct cells in the batch − 1)`:
    /// strictly positive iff some lane batch genuinely carried replays
    /// of more than one sweep cell — the figure the grouped benches
    /// assert on.
    pub group_spanned_cells: u64,
    /// Duration-payload rebinds (`IterationTemplate::bind_cell`): cell
    /// switches served by swapping the `DurTable` payload columns in
    /// place instead of rebuilding the graph (the order cache survives).
    pub shape_rebinds: u64,
}

/// Sentinel for "no entry" in the calendar's intrusive linked lists.
const NONE: u32 = u32::MAX;

/// Calendar (bucket) event queue over task ids.
///
/// Events are bucketed by ready time into a sliding window of
/// equal-width buckets; events beyond the window park on an overflow list
/// and are redistributed when the window advances ([`Calendar::rebase`]).
/// Every list is intrusive over a preallocated `next` array (each task
/// enters the queue exactly once), so the queue allocates nothing after
/// [`Calendar::prime`] has grown its two arrays to the graph size.
///
/// Pops return the minimum `(time, id)` event. Correctness relies on the
/// engine's monotonicity: an event inserted while processing a pop at time
/// `t` is never earlier than `t`, so insertions always land in the current
/// bucket or later and a linear min-scan of the current bucket yields the
/// global minimum. Worst case (all events tied in one bucket) degrades to
/// `O(queue²)`; iteration graphs keep bucket occupancy near the
/// [`Calendar::prime`] sizing target.
#[derive(Debug)]
struct Calendar {
    /// Head of each bucket's list (`NONE` = empty).
    heads: Vec<u32>,
    /// Intrusive next pointer per task id.
    next: Vec<u32>,
    /// Absolute time at the start of bucket 0 of the current window.
    base: f64,
    /// Width of one bucket (seconds).
    width: f64,
    /// Cursor: buckets before `cur` are empty for the rest of the run.
    cur: usize,
    /// Head of the beyond-the-window overflow list.
    overflow: u32,
    /// Queued events (buckets + overflow).
    len: usize,
    /// Adaptive width correction carried between runs (see
    /// [`Calendar::adapt`]); 1.0 = the static heuristic of
    /// [`Calendar::prime`] unchanged.
    width_scale: f64,
    // --- per-run occupancy stats, reset by `prime` ---
    /// Total elements examined across all bucket min-scans.
    scan_len: u64,
    /// Number of pops that scanned a bucket.
    scan_pops: u64,
    /// Longest single bucket min-scan.
    max_scan: u32,
    /// Overflow redistributions ([`Calendar::rebase`] calls).
    rebases: u32,
}

impl Default for Calendar {
    fn default() -> Calendar {
        Calendar {
            heads: Vec::new(),
            next: Vec::new(),
            base: 0.0,
            width: 1.0,
            cur: 0,
            overflow: NONE,
            len: 0,
            width_scale: 1.0,
            scan_len: 0,
            scan_pops: 0,
            max_scan: 0,
            rebases: 0,
        }
    }
}

impl Calendar {
    /// Prepare for a run of `n` tasks whose durations sum to `total` over
    /// `max_res` resources: clears all lists and sizes the bucket width to
    /// the geometric mean of the two makespan extremes (`total` when fully
    /// serial, `total / max_res` when perfectly parallel) divided by `n`.
    /// Serial schedules then cross O(√R) windows of cheap empty-bucket
    /// hops, while parallel schedules keep bucket occupancy at O(√R)
    /// events instead of piling the whole makespan into a few buckets.
    fn prime(&mut self, n: usize, total: f64, max_res: usize) {
        let nb = n / 4 + 1;
        self.heads.clear();
        self.heads.resize(nb, NONE);
        self.next.clear();
        self.next.resize(n, NONE);
        let w = total * self.width_scale / (n.max(1) as f64 * (max_res.max(1) as f64).sqrt());
        self.width = if w.is_finite() && w > 0.0 { w } else { 1.0 };
        self.base = 0.0;
        self.cur = 0;
        self.overflow = NONE;
        self.len = 0;
        self.scan_len = 0;
        self.scan_pops = 0;
        self.max_scan = 0;
        self.rebases = 0;
    }

    /// Insert task `id` ready at time `t` (`t` must be ≥ the time of the
    /// most recent pop — guaranteed because successor ready times are
    /// finish times of already-popped tasks). Finiteness is debug-asserted
    /// where durations are set and here (this is the hottest store in the
    /// event loop); in release builds a non-finite time parks on the
    /// overflow list and trips the hard assert in the cold
    /// [`Calendar::rebase`] path instead of spinning.
    fn push(&mut self, t: f64, id: TaskId) {
        debug_assert!(t.is_finite(), "non-finite task time");
        let d = (t - self.base) / self.width;
        if d < self.heads.len() as f64 {
            let b = d as usize;
            self.next[id as usize] = self.heads[b];
            self.heads[b] = id;
        } else {
            self.next[id as usize] = self.overflow;
            self.overflow = id;
        }
        self.len += 1;
    }

    /// Remove and return the event minimising `(time_of[id], id)`.
    fn pop(&mut self, time_of: &[f64]) -> Option<TaskId> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.cur == self.heads.len() {
                self.rebase(time_of);
            }
            let head = self.heads[self.cur];
            if head == NONE {
                self.cur += 1;
                continue;
            }
            // Linear min-scan of the bucket; ties break on the smaller id,
            // matching the retired heap's ordering bit for bit. The running
            // minimum's time is kept in a register instead of re-loaded
            // from `time_of` per comparison.
            let mut best = head;
            let mut best_t = time_of[head as usize];
            let mut best_prev = NONE;
            let mut prev = head;
            let mut at = self.next[head as usize];
            let mut scanned = 1u32;
            while at != NONE {
                let t = time_of[at as usize];
                if t < best_t || (t == best_t && at < best) {
                    best = at;
                    best_t = t;
                    best_prev = prev;
                }
                prev = at;
                at = self.next[at as usize];
                scanned += 1;
            }
            self.scan_len += scanned as u64;
            self.scan_pops += 1;
            self.max_scan = self.max_scan.max(scanned);
            if best == head {
                self.heads[self.cur] = self.next[best as usize];
            } else {
                self.next[best_prev as usize] = self.next[best as usize];
            }
            self.len -= 1;
            return Some(best);
        }
    }

    /// Advance the window to the earliest overflow event and redistribute
    /// the overflow list. Only reached when every bucket is empty, so all
    /// queued events live on the overflow list.
    fn rebase(&mut self, time_of: &[f64]) {
        debug_assert!(self.overflow != NONE, "rebase with events still queued");
        self.rebases += 1;
        let mut t_min = f64::INFINITY;
        let mut at = self.overflow;
        while at != NONE {
            t_min = t_min.min(time_of[at as usize]);
            at = self.next[at as usize];
        }
        // Hard assert (cold path — once per window, never per event): a
        // non-finite event time would otherwise cycle on the overflow
        // list forever. This is where release builds catch what the hot
        // `push` only debug-asserts.
        assert!(t_min.is_finite(), "non-finite task time");
        self.base = t_min;
        self.cur = 0;
        let nb = self.heads.len() as f64;
        let mut at = self.overflow;
        self.overflow = NONE;
        while at != NONE {
            let nx = self.next[at as usize];
            let d = (time_of[at as usize] - self.base) / self.width;
            if d < nb {
                let b = d as usize;
                self.next[at as usize] = self.heads[b];
                self.heads[b] = at;
            } else {
                self.next[at as usize] = self.overflow;
                self.overflow = at;
            }
            at = nx;
        }
    }

    /// Adaptive width correction à la Brown's calendar queue, applied
    /// after a completed run: when the observed bucket min-scan lengths
    /// sit far above the O(√R) occupancy the static [`Calendar::prime`]
    /// heuristic targets, narrow the buckets for the next run; when a run
    /// spent its time redistributing the overflow list instead, widen
    /// them. Only `width_scale` changes — pop order is width-independent
    /// (each bucket holds a time-disjoint slice and the min-scan returns
    /// the global `(time, id)` minimum for any width), so this is
    /// bitwise-neutral, pinned by the reference-heap property test.
    fn adapt(&mut self, max_res: usize) {
        if self.scan_pops == 0 {
            return;
        }
        let target = (max_res.max(1) as f64).sqrt().max(1.0);
        let mean = self.scan_len as f64 / self.scan_pops as f64;
        // Blend mean and max so one pathological bucket (a tie cluster)
        // also registers as crowding.
        let crowd = mean.max(self.max_scan as f64 / 8.0);
        if crowd > 4.0 * target {
            let shrink = (target / crowd).max(1.0 / 64.0);
            self.width_scale = (self.width_scale * shrink).max(1e-3);
        } else if f64::from(self.rebases) > 8.0 * target && mean < 1.0 + target / 4.0 {
            self.width_scale = (self.width_scale * 4.0).min(1e3);
        }
    }
}

/// Task-graph builder + executor.
///
/// The graph (tasks + dependencies) and the execution scratch are both
/// owned by the engine, so a graph can be built once and replayed many
/// times: mutate durations with [`Engine::set_duration`], execute with
/// [`Engine::run_reuse`], and start a new graph without releasing buffer
/// capacity with [`Engine::reset`].
#[derive(Debug, Default)]
pub struct Engine {
    /// SoA task table: resource column.
    resources: Vec<u32>,
    /// SoA task table: duration column.
    durations: Vec<f64>,
    /// Optional phase labels (static strings — no hot-path allocation).
    labels: Vec<&'static str>,
    /// Edge list in insertion order; finalised into CSR before execution.
    edge_from: Vec<TaskId>,
    edge_to: Vec<TaskId>,
    /// Number of dependencies per task (static; copied into `pending` per run).
    indegree: Vec<u32>,
    /// CSR adjacency: successors of task `i` are
    /// `csr_dst[csr_off[i]..csr_off[i+1]]`, in `dep` insertion order.
    csr_off: Vec<usize>,
    csr_dst: Vec<TaskId>,
    csr_valid: bool,
    /// Number of distinct resources (max resource id + 1).
    max_res: usize,
    // --- per-run scratch, reused across run_reuse calls ---
    pending: Vec<u32>,
    ready_at: Vec<f64>,
    finish: Vec<f64>,
    resource_free: Vec<f64>,
    queue: Calendar,
    // --- order cache (see module docs "Order-cached linear replay") ---
    /// Pop order of the last recorded calendar run (a permutation of all
    /// task ids; predecessors precede successors).
    order: Vec<TaskId>,
    /// True while `order` matches the current graph structure.
    order_ok: bool,
    /// Per-instance scheduler override; `None` defers to [`sched_mode`].
    mode_override: Option<SchedMode>,
    /// Cache hit/fallback telemetry.
    stats: SchedCounters,
    // --- lane-parallel replay state (see module docs + simulator/lanes) ---
    /// Lane-strided duration matrix `[task][lane]` for the next lane batch
    /// (filled through [`Engine::lane_durations_mut`]).
    lane_durs: Vec<f64>,
    /// Lane-strided ready-time scratch.
    lane_ready: Vec<f64>,
    /// Lane-strided per-resource free-time scratch.
    lane_free: Vec<f64>,
    /// Lane-strided finish times of the last [`Engine::run_lanes`] batch.
    lane_finish: Vec<f64>,
    /// Widened duration matrix for padded remainder batches (pad lanes
    /// duplicate the last real lane; `lane_durs` stays untouched so a
    /// validity fallback replays the caller's original matrix).
    lane_pad: Vec<f64>,
    /// Per-lane makespans of the last batch (fused fold, see
    /// [`Engine::lane_makespans`]).
    lane_makespan: [f64; lanes::LANES_MAX],
    /// Per-instance lane-pass override; `None` defers to
    /// [`lanes::lanes_enabled`].
    lane_override: Option<bool>,
    /// Per-instance lane-width override; `None` defers to
    /// [`lanes::lane_width`].
    lane_width_override: Option<usize>,
    /// Set by [`Engine::run_lanes`], cleared by the next scalar run: the
    /// scalar accessors are unspecified while a lane batch is the most
    /// recent execution (see the module docs).
    scalar_state_stale: bool,
    /// Running Σ durations — sizes the fallback calendar without the
    /// per-run O(T) re-sum. Incremental drift only perturbs the bucket
    /// width, which never affects pop order (bitwise-neutral).
    total_work: f64,
    /// Makespan of the most recent run (the `max` fold fused into the
    /// replay/calendar pass — see [`Engine::last_makespan`]).
    last_makespan: f64,
}

impl Engine {
    /// Create an empty engine.
    pub fn new() -> Engine {
        Engine::default()
    }

    /// Add a task; returns its id.
    pub fn task(&mut self, resource: u32, duration: f64) -> TaskId {
        self.task_labeled(resource, duration, "")
    }

    /// Add a labelled task (label shows up in exported traces).
    pub fn task_labeled(&mut self, resource: u32, duration: f64, label: &'static str) -> TaskId {
        debug_assert!(duration.is_finite() && duration >= 0.0, "negative or non-finite duration");
        let id = self.resources.len() as TaskId;
        self.resources.push(resource);
        self.durations.push(duration);
        self.total_work += duration;
        self.labels.push(label);
        self.indegree.push(0);
        self.max_res = self.max_res.max(resource as usize + 1);
        self.csr_valid = false;
        id
    }

    /// Task `id`'s `(resource, duration)`, assembled from the SoA columns.
    pub fn spec(&self, id: TaskId) -> TaskSpec {
        TaskSpec { resource: self.resources[id as usize], duration: self.durations[id as usize] }
    }

    /// Per-task durations (read-only column view).
    ///
    /// Unspecified after a lane batch (debug-asserted — see the module
    /// docs' poisoning contract): the batch's duration sets live in the
    /// lane matrix, and the scalar column holds whatever the last
    /// sequential-path lane (or the pre-batch state) left behind.
    pub fn durations(&self) -> &[f64] {
        debug_assert!(
            !self.scalar_state_stale,
            "durations() after run_lanes is unspecified — set new durations or run_reuse first"
        );
        &self.durations
    }

    /// Per-task labels.
    pub fn labels(&self) -> &[&'static str] {
        &self.labels
    }

    /// Declare that `after` cannot start before `before` finishes.
    pub fn dep(&mut self, before: TaskId, after: TaskId) {
        self.edge_from.push(before);
        self.edge_to.push(after);
        self.indegree[after as usize] += 1;
        self.csr_valid = false;
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.resources.len()
    }

    /// Number of dependency edges.
    pub fn edge_count(&self) -> usize {
        self.edge_from.len()
    }

    /// Dependency edge `i` as `(before, after)`, in insertion order.
    pub fn edge(&self, i: usize) -> (TaskId, TaskId) {
        (self.edge_from[i], self.edge_to[i])
    }

    /// True when no tasks have been added.
    pub fn is_empty(&self) -> bool {
        self.resources.is_empty()
    }

    /// Overwrite a task's duration (graph structure unchanged) — the replay
    /// API: build the graph once, then per iteration set new durations and
    /// call [`Engine::run_reuse`]. The order cache survives (the validity
    /// check, not the setter, decides whether the new durations preserve
    /// the pop order).
    pub fn set_duration(&mut self, id: TaskId, duration: f64) {
        debug_assert!(duration.is_finite() && duration >= 0.0, "negative or non-finite duration");
        // Keep the running total in step so a calendar fallback can size
        // its buckets without re-summing all T durations. Incremental
        // rounding drift only nudges the bucket width, which never
        // affects pop order (the width-independence contract in PERF.md).
        self.total_work += duration - self.durations[id as usize];
        self.durations[id as usize] = duration;
    }

    /// Per-instance scheduler override (`None` = the process-wide
    /// [`sched_mode`]). The explicit-mode hook, mirroring
    /// `kernels::dot_with`: the test suites and `simulator_hotpath` use it
    /// to race the calendar and order-cached paths inside one process.
    pub fn set_sched_mode(&mut self, mode: Option<SchedMode>) {
        self.mode_override = mode;
    }

    /// Order-cache telemetry (hits/fallbacks/calendar runs) accumulated
    /// over this engine's lifetime.
    pub fn sched_counters(&self) -> SchedCounters {
        self.stats
    }

    /// Record one cross-cell group lane batch that carried `spanned + 1`
    /// distinct sweep cells (telemetry hook for
    /// `IterationTemplate::run_group_into`).
    pub(crate) fn note_group_batch(&mut self, spanned: u64) {
        self.stats.group_batches += 1;
        self.stats.group_spanned_cells += spanned;
    }

    /// Record one duration-payload rebind (telemetry hook for
    /// `IterationTemplate::bind_cell`).
    pub(crate) fn note_shape_rebind(&mut self) {
        self.stats.shape_rebinds += 1;
    }

    /// Clear the graph (tasks, labels, edges) while keeping the capacity of
    /// every internal buffer — start building the next graph without
    /// releasing memory.
    pub fn reset(&mut self) {
        self.resources.clear();
        self.durations.clear();
        self.labels.clear();
        self.edge_from.clear();
        self.edge_to.clear();
        self.indegree.clear();
        self.csr_valid = false;
        self.max_res = 0;
        self.order_ok = false;
        self.total_work = 0.0;
        self.last_makespan = 0.0;
        self.scalar_state_stale = false;
    }

    /// Per-task finish times of the most recent run (empty before any run).
    ///
    /// Unspecified after a lane batch (debug-asserted — see the module
    /// docs' poisoning contract): read [`Engine::lane_finish`] instead.
    pub fn last_finish(&self) -> &[f64] {
        debug_assert!(
            !self.scalar_state_stale,
            "last_finish() after run_lanes is unspecified — read lane_finish() instead"
        );
        &self.finish
    }

    /// Build the CSR adjacency from the edge list (counting sort by source;
    /// stable, so per-source successor order equals `dep` insertion order —
    /// this keeps event insertion order, and therefore tie-breaking, bitwise
    /// reproducible).
    fn finalize(&mut self) {
        let n = self.resources.len();
        self.csr_off.clear();
        self.csr_off.resize(n + 1, 0);
        for &f in &self.edge_from {
            self.csr_off[f as usize + 1] += 1;
        }
        for i in 0..n {
            self.csr_off[i + 1] += self.csr_off[i];
        }
        self.csr_dst.clear();
        self.csr_dst.resize(self.edge_from.len(), 0);
        let mut cursor = self.csr_off.clone();
        for (&f, &t) in self.edge_from.iter().zip(&self.edge_to) {
            self.csr_dst[cursor[f as usize]] = t;
            cursor[f as usize] += 1;
        }
        self.csr_valid = true;
        // The graph changed structurally — the cached pop order is for a
        // different task/edge set and must never be consulted again.
        self.order_ok = false;
    }

    /// Execute the graph; returns per-task finish times as a fresh vector.
    ///
    /// Panics if the dependency graph is cyclic (some task never becomes
    /// ready). Convenience wrapper over [`Engine::run_reuse`] for one-shot
    /// callers; hot loops should use `run_reuse` to avoid the copy.
    pub fn run(&mut self) -> Vec<f64> {
        self.run_reuse().to_vec()
    }

    /// Execute the graph into the engine's reusable scratch buffers and
    /// return the per-task finish times as a borrowed slice. Zero heap
    /// allocations once the scratch has grown to the graph's size.
    ///
    /// Under [`SchedMode::Cached`] (the default) a valid order cache is
    /// replayed linearly — no event queue at all; the calendar runs on
    /// the first execution, after graph changes, and when the validity
    /// check rejects a stale order. Both branches produce the identical
    /// bitwise schedule (see the module docs).
    pub fn run_reuse(&mut self) -> &[f64] {
        // A scalar run re-establishes every scalar accessor (finish,
        // makespan, durations) — lift the post-lane-batch poisoning.
        self.scalar_state_stale = false;
        if !self.csr_valid {
            self.finalize();
        }
        let want_cached = self.mode_override.unwrap_or_else(sched_mode) == SchedMode::Cached;
        if want_cached && self.order_ok {
            if self.replay_cached() {
                self.stats.cached_hits += 1;
                return &self.finish;
            }
            self.stats.fallbacks += 1;
            self.order_ok = false;
        }
        self.run_calendar(want_cached)
    }

    /// Linear pass over the cached pop order. Returns `false` (leaving
    /// scratch in an undefined state for the calendar fallback to
    /// reinitialise) as soon as the `(ready_at, id)` sequence fails to be
    /// lexicographically strictly increasing; returns `true` with `finish`
    /// holding the exact calendar schedule otherwise. Zero allocations.
    fn replay_cached(&mut self) -> bool {
        let n = self.resources.len();
        debug_assert_eq!(self.order.len(), n, "order cache out of sync with graph");
        self.ready_at.clear();
        self.ready_at.resize(n, 0.0);
        self.finish.clear();
        self.finish.resize(n, f64::NAN);
        self.resource_free.clear();
        self.resource_free.resize(self.max_res, 0.0);
        let mut prev_t = f64::NEG_INFINITY;
        let mut prev_id: TaskId = 0;
        let mut mk = 0.0f64;
        for &id in &self.order {
            let i = id as usize;
            // Predecessors precede `id` in any recorded pop order, so
            // `ready_at[i]` is final here — the value the calendar would
            // have popped this task at.
            let ready = self.ready_at[i];
            // Strictly increasing (ready, id), or the cache is stale. NaN
            // ready times (only reachable via unchecked non-finite
            // durations in release builds) compare false and reject.
            let ok = ready > prev_t || (ready == prev_t && id > prev_id);
            if !ok {
                return false;
            }
            prev_t = ready;
            prev_id = id;
            let res = self.resources[i] as usize;
            // Same float ops as the calendar loop, for bitwise identity.
            let start = ready.max(self.resource_free[res]);
            let end = start + self.durations[i];
            self.resource_free[res] = end;
            self.finish[i] = end;
            // Fused makespan fold: `max` is exact, so tracking the running
            // maximum here is bitwise identical to re-walking `finish`.
            mk = mk.max(end);
            let lo = self.csr_off[i];
            let hi = self.csr_off[i + 1];
            for e in lo..hi {
                let succ = self.csr_dst[e] as usize;
                if self.ready_at[succ] < end {
                    self.ready_at[succ] = end;
                }
            }
        }
        self.last_makespan = mk;
        true
    }

    /// Full calendar-queue run. With `record`, the pop order is captured
    /// into the order cache for subsequent linear replays.
    fn run_calendar(&mut self, record: bool) -> &[f64] {
        let n = self.resources.len();
        self.pending.clear();
        self.pending.extend_from_slice(&self.indegree);
        self.ready_at.clear();
        self.ready_at.resize(n, 0.0);
        self.finish.clear();
        self.finish.resize(n, f64::NAN);
        self.resource_free.clear();
        self.resource_free.resize(self.max_res, 0.0);
        if record {
            self.order.clear();
        }
        // Total work bounds every event time (each finish is a sum of a
        // chain of distinct task durations), so it sizes the calendar.
        // Maintained incrementally by `task`/`set_duration` — a fallback
        // no longer re-sums all T durations just to pick a bucket width.
        self.queue.prime(n, self.total_work, self.max_res);
        for (i, &p) in self.pending.iter().enumerate() {
            if p == 0 {
                self.queue.push(0.0, i as TaskId);
            }
        }
        let mut done = 0usize;
        let mut mk = 0.0f64;
        while let Some(id) = self.queue.pop(&self.ready_at) {
            let i = id as usize;
            if record {
                self.order.push(id);
            }
            let res = self.resources[i] as usize;
            let start = self.ready_at[i].max(self.resource_free[res]);
            let end = start + self.durations[i];
            self.resource_free[res] = end;
            self.finish[i] = end;
            mk = mk.max(end);
            done += 1;
            let lo = self.csr_off[i];
            let hi = self.csr_off[i + 1];
            for e in lo..hi {
                let succ = self.csr_dst[e] as usize;
                if self.ready_at[succ] < end {
                    self.ready_at[succ] = end;
                }
                self.pending[succ] -= 1;
                if self.pending[succ] == 0 {
                    self.queue.push(self.ready_at[succ], succ as TaskId);
                }
            }
        }
        assert_eq!(done, n, "cyclic dependency graph: {} tasks never ran", n - done);
        self.queue.adapt(self.max_res);
        self.stats.calendar_runs += 1;
        self.last_makespan = mk;
        if record {
            self.order_ok = true;
        }
        &self.finish
    }

    /// Makespan of the last `run`'s schedule (max finish time).
    pub fn makespan(finish: &[f64]) -> f64 {
        finish.iter().copied().fold(0.0, f64::max)
    }

    /// Makespan of the most recent run — the `max` fold fused into the
    /// replay/calendar pass itself (`max` is exact, so this is bitwise
    /// [`Engine::makespan`] of [`Engine::last_finish`] without the extra
    /// O(T) walk). `0.0` before any run.
    ///
    /// Unspecified after a lane batch (debug-asserted — see the module
    /// docs' poisoning contract): read [`Engine::lane_makespans`] instead.
    pub fn last_makespan(&self) -> f64 {
        debug_assert!(
            !self.scalar_state_stale,
            "last_makespan() after run_lanes is unspecified — read lane_makespans() instead"
        );
        self.last_makespan
    }

    /// Per-instance lane-pass override (`None` = the process-wide
    /// `BSF_LANES` selection): `Some(true)` forces the vectorized lane
    /// batch on, `Some(false)` forces every batch through the sequential
    /// scalar path. The test suites and `simulator_hotpath` use it to
    /// race both paths inside one process, like [`Engine::set_sched_mode`].
    pub fn set_lane_mode(&mut self, on: Option<bool>) {
        self.lane_override = on;
    }

    /// Per-instance lane-width override (`None` = the process-wide
    /// `BSF_LANE_WIDTH` selection). Unlike the env override, requesting
    /// width 8 on a host without `avx512f` is allowed here: the lane
    /// pass falls back to the width-generic scalar twin (bitwise
    /// identical), which is what lets the test suites race widths on any
    /// hardware without touching process env.
    pub fn set_lane_width(&mut self, width: Option<usize>) {
        if let Some(w) = width {
            assert!(w == 4 || w == 8, "lane width must be 4 or 8, got {w}");
        }
        self.lane_width_override = width;
    }

    /// The lane width [`Engine::run_lanes`] dispatches at: the
    /// per-instance override if set, else the process-wide
    /// [`lanes::lane_width`]. Callers batching replays should cut their
    /// batches to this width (narrower batches are padded).
    pub fn dispatch_width(&self) -> usize {
        self.lane_width_override.unwrap_or_else(lanes::lane_width)
    }

    /// The lane-strided duration matrix for the next [`Engine::run_lanes`]
    /// batch of `lanes` independent replays: entry `[task][lane]` lives at
    /// `task * lanes + lane`. Sized here — the caller must fill **every**
    /// slot (only newly grown tail slots are initialised; a resize never
    /// memsets the whole matrix, this is the hot path). No allocation
    /// once the matrix has grown to the graph.
    pub fn lane_durations_mut(&mut self, lanes: usize) -> &mut [f64] {
        assert!((1..=lanes::LANES_MAX).contains(&lanes), "1..={} lanes", lanes::LANES_MAX);
        let n = self.resources.len();
        self.lane_durs.resize(n * lanes, 0.0);
        &mut self.lane_durs
    }

    /// Execute `lanes` independent replays whose duration sets occupy the
    /// lane matrix (fill [`Engine::lane_durations_mut`] first). Lane `m`'s
    /// finish times land at `task * lanes + m` of [`Engine::lane_finish`],
    /// its makespan in [`Engine::lane_makespans`]. **Bitwise contract:**
    /// hit or fallback, the results equal running each lane's durations
    /// through [`Engine::set_duration`] + [`Engine::run_reuse`] in lane
    /// order — a batch with a valid order cache goes through the lane
    /// pass at the dispatch width ([`Engine::dispatch_width`]), padding
    /// narrower batches with duplicates of their last real lane (copied
    /// durations — the caller's draw stream is never consulted — results
    /// discarded, counted in `lane_pad_replays`); the all-lane validity
    /// check covers pad lanes too (they replay a real lane's durations,
    /// so they can only fail together with it), and any failing lane
    /// aborts to the sequential path, because its calendar fallback
    /// would refresh the cache the later lanes are checked against.
    /// Everything else runs the sequential loop directly. Zero heap
    /// allocations once the lane scratch is warm.
    ///
    /// The batch's outputs are [`Engine::lane_finish`] and
    /// [`Engine::lane_makespans`] **only**: after a lane batch the scalar
    /// accessors ([`Engine::last_finish`], [`Engine::last_makespan`],
    /// [`Engine::durations`]) are unspecified — a vector hit leaves them
    /// at their pre-batch values while the sequential path leaves them at
    /// the last lane's replay. (Normalising them would cost a full copy
    /// per hit; the lane accessors are bitwise identical either way.)
    /// Reading one before the next scalar run trips a `debug_assert`.
    pub fn run_lanes(&mut self, lanes: usize) -> &[f64] {
        assert!((1..=lanes::LANES_MAX).contains(&lanes), "1..={} lanes", lanes::LANES_MAX);
        if !self.csr_valid {
            self.finalize();
        }
        let n = self.resources.len();
        assert_eq!(self.lane_durs.len(), n * lanes, "fill lane_durations_mut({lanes}) first");
        let want_cached = self.mode_override.unwrap_or_else(sched_mode) == SchedMode::Cached;
        let lanes_on = self.lane_override.unwrap_or_else(lanes::lanes_enabled);
        let width = self.dispatch_width();
        if lanes_on && lanes <= width && want_cached && self.order_ok {
            // Remainder batch: widen the duration matrix into separate
            // pad scratch (lane_durs stays untouched at its `lanes`
            // stride, so a validity fallback below replays the caller's
            // original matrix). Pad lanes duplicate the last real lane.
            let pad = lanes < width;
            if pad {
                self.lane_pad.resize(n * width, 0.0);
                for i in 0..n {
                    let row = i * lanes;
                    for m in 0..width {
                        self.lane_pad[i * width + m] = self.lane_durs[row + m.min(lanes - 1)];
                    }
                }
            }
            // ready/free genuinely need a zeroed start; finish is fully
            // overwritten by a successful pass (every task appears in the
            // valid order) or by the fallback below, so it is only sized.
            self.lane_ready.clear();
            self.lane_ready.resize(n * width, 0.0);
            self.lane_free.clear();
            self.lane_free.resize(self.max_res * width, 0.0);
            self.lane_finish.resize(n * width, f64::NAN);
            let durs: &[f64] = if pad { &self.lane_pad } else { &self.lane_durs };
            let mut pass = lanes::LanePass {
                order: &self.order,
                resources: &self.resources,
                csr_off: &self.csr_off,
                csr_dst: &self.csr_dst,
                durs,
                ready: &mut self.lane_ready,
                free: &mut self.lane_free,
                finish: &mut self.lane_finish,
                makespan: &mut self.lane_makespan[..],
                width,
            };
            if lanes::replay(kernels::active(), &mut pass) {
                if pad {
                    // Discard the pad lanes: compact finish from stride
                    // `width` to stride `lanes` in place. Forward order is
                    // safe — the destination index never passes the next
                    // unread source (`i*lanes + m <= i*width + m`, equal
                    // only at i == 0 where it is a self-copy). The real
                    // lanes' makespans already sit at slots 0..lanes.
                    for i in 0..n {
                        for m in 0..lanes {
                            self.lane_finish[i * lanes + m] = self.lane_finish[i * width + m];
                        }
                    }
                    self.lane_finish.truncate(n * lanes);
                    self.stats.lane_pad_replays += (width - lanes) as u64;
                }
                self.stats.lane_hits += lanes as u64;
                self.stats.lane_width = self.stats.lane_width.max(width as u64);
                self.scalar_state_stale = true;
                return &self.lane_finish;
            }
            self.stats.lane_fallbacks += 1;
        }
        // Sequential path: exactly the one-at-a-time loop the lane pass
        // replaces — each lane's run_reuse does its own cached-check /
        // calendar-fallback (with cache refreshes), in lane order. The
        // copy loop below overwrites every slot, so finish is only sized.
        self.stats.lane_width = self.stats.lane_width.max(lanes as u64);
        self.lane_finish.resize(n * lanes, f64::NAN);
        for m in 0..lanes {
            for i in 0..n {
                let d = self.lane_durs[i * lanes + m];
                self.set_duration(i as TaskId, d);
            }
            self.run_reuse();
            for i in 0..n {
                self.lane_finish[i * lanes + m] = self.finish[i];
            }
            self.lane_makespan[m] = self.last_makespan;
        }
        self.scalar_state_stale = true;
        &self.lane_finish
    }

    /// Lane-strided finish times of the most recent [`Engine::run_lanes`]
    /// batch (lane `m` of task `t` at `t * lanes + m`).
    pub fn lane_finish(&self) -> &[f64] {
        &self.lane_finish
    }

    /// Per-lane makespans of the most recent [`Engine::run_lanes`] batch
    /// (the fused `max` fold; only the first `lanes` entries meaningful —
    /// pad lanes' slots are discarded state).
    pub fn lane_makespans(&self) -> &[f64] {
        &self.lane_makespan
    }
}

/// Min-heap entry ordered by `(time, id)` for [`ReferenceScheduler`].
#[derive(Debug, PartialEq)]
struct Ready(f64, TaskId);

impl Eq for Ready {}

impl PartialOrd for Ready {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ready {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse for min-heap; tie-break on id for determinism.
        other
            .0
            .partial_cmp(&self.0)
            .expect("non-finite task time")
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// The engine's scheduling contract as an executable specification: the
/// retired `BinaryHeap` event loop, kept as the single ground truth the
/// calendar queue is checked against. `rust/tests/properties.rs` pins
/// bitwise schedule equality on random tie-heavy DAGs, and
/// `rust/benches/simulator_hotpath.rs` races it against
/// [`Engine::run_reuse`] on the K=270 iteration graph. Not a hot path —
/// do not use it for simulation.
#[derive(Debug, Default)]
pub struct ReferenceScheduler {
    resources: Vec<u32>,
    durations: Vec<f64>,
    succs: Vec<Vec<TaskId>>,
    indegree: Vec<u32>,
    max_res: usize,
    /// Record per-resource pop order during runs. Off by default so the
    /// benchmark's timed replays measure only the heap event loop, exactly
    /// like [`Engine::run_reuse`] measures only the calendar.
    record_order: bool,
    // per-run scratch (reused so benchmark replays match run_reuse's
    // steady state)
    pending: Vec<u32>,
    ready_at: Vec<f64>,
    finish: Vec<f64>,
    free: Vec<f64>,
    order: Vec<Vec<TaskId>>,
    heap: std::collections::BinaryHeap<Ready>,
}

impl ReferenceScheduler {
    /// Build from raw SoA columns + an edge list.
    pub fn new(
        resources: Vec<u32>,
        durations: Vec<f64>,
        edges: &[(TaskId, TaskId)],
    ) -> ReferenceScheduler {
        assert_eq!(resources.len(), durations.len());
        let n = resources.len();
        let mut succs: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        let mut indegree = vec![0u32; n];
        for &(from, to) in edges {
            succs[from as usize].push(to);
            indegree[to as usize] += 1;
        }
        let max_res = resources.iter().map(|&r| r as usize + 1).max().unwrap_or(0);
        ReferenceScheduler {
            resources,
            durations,
            succs,
            indegree,
            max_res,
            ..ReferenceScheduler::default()
        }
    }

    /// Copy an engine's graph (tasks + edges) into a reference scheduler.
    pub fn from_engine(eng: &Engine) -> ReferenceScheduler {
        let edges: Vec<(TaskId, TaskId)> = (0..eng.edge_count()).map(|i| eng.edge(i)).collect();
        let (resources, durations): (Vec<u32>, Vec<f64>) =
            (0..eng.len()).map(|i| eng.spec(i as TaskId)).map(|s| (s.resource, s.duration)).unzip();
        ReferenceScheduler::new(resources, durations, &edges)
    }

    /// Record per-resource pop order on subsequent [`Self::run`]s (see
    /// [`Self::resource_order`]).
    pub fn record_order(&mut self, on: bool) {
        self.record_order = on;
    }

    /// Execute the graph with the heap event loop; returns per-task finish
    /// times. Panics on cyclic graphs, like [`Engine::run_reuse`].
    pub fn run(&mut self) -> &[f64] {
        let n = self.resources.len();
        self.pending.clear();
        self.pending.extend_from_slice(&self.indegree);
        self.ready_at.clear();
        self.ready_at.resize(n, 0.0);
        self.finish.clear();
        self.finish.resize(n, f64::NAN);
        self.free.clear();
        self.free.resize(self.max_res, 0.0);
        // Truncate (not drop) the inner order buffers so repeated runs
        // reuse their capacity.
        self.order.resize(self.max_res, Vec::new());
        for o in &mut self.order {
            o.clear();
        }
        self.heap.clear();
        for (i, &p) in self.pending.iter().enumerate() {
            if p == 0 {
                self.heap.push(Ready(0.0, i as TaskId));
            }
        }
        let mut done = 0usize;
        while let Some(Ready(ready, id)) = self.heap.pop() {
            let i = id as usize;
            let res = self.resources[i] as usize;
            let start = ready.max(self.free[res]);
            let end = start + self.durations[i];
            self.free[res] = end;
            self.finish[i] = end;
            if self.record_order {
                self.order[res].push(id);
            }
            done += 1;
            for &succ_id in &self.succs[i] {
                let succ = succ_id as usize;
                if self.ready_at[succ] < end {
                    self.ready_at[succ] = end;
                }
                self.pending[succ] -= 1;
                if self.pending[succ] == 0 {
                    self.heap.push(Ready(self.ready_at[succ], succ_id));
                }
            }
        }
        assert_eq!(done, n, "cyclic dependency graph: {} tasks never ran", n - done);
        &self.finish
    }

    /// Execution order per resource of the most recent [`Self::run`]
    /// (empty unless [`Self::record_order`] was enabled).
    pub fn resource_order(&self) -> &[Vec<TaskId>] {
        &self.order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_accumulates() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 2.0);
        let c = e.task(0, 3.0);
        e.dep(a, b);
        e.dep(b, c);
        let f = e.run();
        assert_eq!(f, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn parallel_resources_overlap() {
        let mut e = Engine::new();
        let a = e.task(0, 5.0);
        let b = e.task(1, 5.0);
        let f = e.run();
        assert_eq!(f[a as usize], 5.0);
        assert_eq!(f[b as usize], 5.0);
        assert_eq!(Engine::makespan(&f), 5.0);
    }

    #[test]
    fn same_resource_serialises() {
        let mut e = Engine::new();
        let _a = e.task(0, 5.0);
        let b = e.task(0, 5.0);
        let f = e.run();
        assert_eq!(f[b as usize], 10.0);
    }

    #[test]
    fn join_waits_for_slowest() {
        let mut e = Engine::new();
        let fast = e.task(0, 1.0);
        let slow = e.task(1, 9.0);
        let join = e.task(2, 0.5);
        e.dep(fast, join);
        e.dep(slow, join);
        let f = e.run();
        assert_eq!(f[join as usize], 9.5);
    }

    #[test]
    fn fork_join_diamond() {
        let mut e = Engine::new();
        let src = e.task(0, 1.0);
        let l = e.task(1, 2.0);
        let r = e.task(2, 3.0);
        let sink = e.task(0, 1.0);
        e.dep(src, l);
        e.dep(src, r);
        e.dep(l, sink);
        e.dep(r, sink);
        let f = e.run();
        assert_eq!(f[sink as usize], 5.0);
    }

    #[test]
    fn ready_order_respects_resource_contention() {
        // Two tasks ready at t=0 on one resource: deterministic order by id.
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        let f = e.run();
        assert_eq!(f[a as usize], 1.0);
        assert_eq!(f[b as usize], 2.0);
    }

    #[test]
    fn tied_ready_times_pop_in_id_order_across_many_tasks() {
        // Many tasks tied at t=0 on one resource: the calendar's bucket
        // min-scan must reproduce the heap's ascending-id order exactly.
        let mut e = Engine::new();
        let ids: Vec<TaskId> = (0..17).map(|_| e.task(0, 1.0)).collect();
        let f = e.run();
        for (i, &id) in ids.iter().enumerate() {
            assert_eq!(f[id as usize], (i + 1) as f64, "task {id}");
        }
    }

    #[test]
    #[should_panic(expected = "cyclic")]
    fn cycle_detected() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        e.dep(a, b);
        e.dep(b, a);
        e.run();
    }

    #[test]
    fn zero_duration_tasks_ok() {
        let mut e = Engine::new();
        let a = e.task(0, 0.0);
        let b = e.task(0, 0.0);
        e.dep(a, b);
        let f = e.run();
        assert_eq!(f, vec![0.0, 0.0]);
    }

    #[test]
    fn empty_graph() {
        let mut e = Engine::new();
        let f = e.run();
        assert!(f.is_empty());
        assert!(e.is_empty());
        assert_eq!(Engine::makespan(&f), 0.0);
    }

    #[test]
    fn long_chain_crosses_calendar_windows() {
        // A serial chain's makespan equals the total work, so its events
        // sweep through every calendar window (~4 rebases) — exercises the
        // overflow/rebase path end to end.
        let n = 512;
        let mut e = Engine::new();
        let mut prev = e.task(0, 1.0);
        for _ in 1..n {
            let t = e.task(0, 1.0);
            e.dep(prev, t);
            prev = t;
        }
        let f = e.run();
        assert_eq!(f[prev as usize], n as f64);
        for (i, &v) in f.iter().enumerate() {
            assert_eq!(v, (i + 1) as f64);
        }
    }

    #[test]
    fn replay_is_bitwise_stable() {
        // Same graph, same durations: every replay must be bit-identical.
        let mut e = Engine::new();
        let src = e.task(0, 0.3);
        let mid = e.task(1, 0.7);
        let sink = e.task(0, 0.1);
        e.dep(src, mid);
        e.dep(mid, sink);
        let first = e.run();
        for _ in 0..3 {
            assert_eq!(e.run_reuse(), &first[..]);
        }
    }

    #[test]
    fn set_duration_replays_new_schedule() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 2.0);
        e.dep(a, b);
        assert_eq!(e.run(), vec![1.0, 3.0]);
        e.set_duration(a, 10.0);
        assert_eq!(e.run(), vec![10.0, 12.0]);
    }

    #[test]
    fn reset_reuses_buffers_for_new_graph() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(1, 2.0);
        e.dep(a, b);
        assert_eq!(e.run(), vec![1.0, 3.0]);
        e.reset();
        assert!(e.is_empty());
        assert_eq!(e.edge_count(), 0);
        let a = e.task(0, 4.0);
        let b = e.task(0, 5.0);
        e.dep(a, b);
        assert_eq!(e.run(), vec![4.0, 9.0]);
    }

    #[test]
    fn dep_after_first_run_rebuilds_csr() {
        let mut e = Engine::new();
        let a = e.task(0, 1.0);
        let b = e.task(0, 1.0);
        let f = e.run();
        assert_eq!(f, vec![1.0, 2.0]);
        let c = e.task(1, 1.0);
        e.dep(a, c);
        e.dep(b, c);
        let f = e.run();
        assert_eq!(f[c as usize], 3.0);
    }

    #[test]
    fn select_sched_parses_overrides() {
        assert_eq!(select_sched(Some("calendar")), SchedMode::Calendar);
        assert_eq!(select_sched(Some("cached")), SchedMode::Cached);
        assert_eq!(select_sched(None), SchedMode::Cached);
        assert_eq!(SchedMode::Calendar.name(), "calendar");
        assert_eq!(SchedMode::Cached.name(), "cached");
    }

    #[test]
    #[should_panic(expected = "BSF_SCHED must be")]
    fn select_sched_rejects_unknown_scheduler() {
        select_sched(Some("fifo"));
    }

    /// A small fork-join graph with all five structural elements (sources,
    /// chain, contention, join) for the order-cache tests.
    fn fork_join_engine() -> Engine {
        let mut e = Engine::new();
        let src = e.task(0, 1.0);
        let l = e.task(1, 2.0);
        let r = e.task(2, 3.0);
        let r2 = e.task(2, 0.5);
        let sink = e.task(0, 1.0);
        e.dep(src, l);
        e.dep(src, r);
        e.dep(src, r2);
        e.dep(l, sink);
        e.dep(r, sink);
        e.dep(r2, sink);
        e
    }

    #[test]
    fn order_cached_replay_hits_and_matches_after_first_run() {
        let mut e = fork_join_engine();
        e.set_sched_mode(Some(SchedMode::Cached));
        let first = e.run();
        assert_eq!(e.sched_counters(), SchedCounters { calendar_runs: 1, ..Default::default() });
        for round in 1..=3u64 {
            let got = e.run_reuse();
            assert_eq!(got, &first[..], "round {round}");
            let c = e.sched_counters();
            assert_eq!(c.cached_hits, round, "round {round}");
            assert_eq!(c.calendar_runs, 1, "round {round}: cached replay hit the calendar");
            assert_eq!(c.fallbacks, 0, "round {round}");
        }
    }

    #[test]
    fn cached_replay_tracks_duration_changes_bitwise() {
        // Perturbed durations that keep the pop order valid must replay
        // through the cache and still match a from-scratch reference.
        let mut e = fork_join_engine();
        e.set_sched_mode(Some(SchedMode::Cached));
        e.run();
        for (id, d) in [(0u32, 1.5), (1, 2.25), (2, 3.5), (3, 0.75), (4, 0.5)] {
            e.set_duration(id, d);
        }
        let mut reference = ReferenceScheduler::from_engine(&e);
        let want = reference.run().to_vec();
        let got = e.run_reuse();
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "task {i}");
        }
        assert_eq!(e.sched_counters().cached_hits, 1);
        assert_eq!(e.sched_counters().fallbacks, 0);
    }

    #[test]
    fn stale_order_cache_rejected_on_ready_order_swap() {
        // Two same-resource tasks whose ready order flips between runs:
        // the validity check must reject the stale permutation and fall
        // back to a full calendar run (which re-records the cache).
        let mut e = Engine::new();
        e.set_sched_mode(Some(SchedMode::Cached));
        let a = e.task(0, 1.0);
        let b = e.task(1, 2.0);
        let c = e.task(2, 0.5);
        let d = e.task(2, 0.5);
        e.dep(a, c);
        e.dep(b, d);
        let first = e.run();
        assert_eq!(first[c as usize], 1.5);
        assert_eq!(first[d as usize], 2.5);
        // Swap the ready order of c and d on resource 2: c now ready at
        // 3.0, d still at 2.0 — the cached order (… c before d) is stale.
        e.set_duration(a, 3.0);
        let mut reference = ReferenceScheduler::from_engine(&e);
        let want = reference.run().to_vec();
        let got = e.run_reuse().to_vec();
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "task {i}");
        }
        assert_eq!(got[d as usize], 2.5, "d must now run first on resource 2");
        assert_eq!(got[c as usize], 3.5);
        let counters = e.sched_counters();
        assert_eq!(counters.fallbacks, 1, "stale cache must be rejected");
        assert_eq!(counters.cached_hits, 0);
        assert_eq!(counters.calendar_runs, 2);
        // The fallback refreshed the cache: an unchanged replay hits again.
        assert_eq!(e.run_reuse(), &got[..]);
        assert_eq!(e.sched_counters().cached_hits, 1);
    }

    #[test]
    fn forced_calendar_mode_never_consults_the_cache() {
        let mut e = fork_join_engine();
        e.set_sched_mode(Some(SchedMode::Calendar));
        let first = e.run();
        for _ in 0..3 {
            assert_eq!(e.run_reuse(), &first[..]);
        }
        let c = e.sched_counters();
        assert_eq!(c.cached_hits, 0);
        assert_eq!(c.fallbacks, 0);
        assert_eq!(c.calendar_runs, 4);
    }

    #[test]
    fn graph_edits_invalidate_the_order_cache() {
        let mut e = fork_join_engine();
        e.set_sched_mode(Some(SchedMode::Cached));
        e.run();
        e.run_reuse();
        assert_eq!(e.sched_counters().cached_hits, 1);
        // Adding a task + edge rebuilds the CSR and must force a calendar
        // run, not a cached replay of the old permutation.
        let extra = e.task(1, 0.25);
        e.dep(0, extra);
        e.run_reuse();
        let c = e.sched_counters();
        assert_eq!(c.calendar_runs, 2, "edited graph must re-run the calendar");
        assert_eq!(c.fallbacks, 0, "structural invalidation, not a validity fallback");
    }

    #[test]
    fn adaptive_resize_is_bitwise_neutral() {
        // Hundreds of exactly-tied events pile into one bucket and trip
        // the adaptive width correction after the first run; replays under
        // the corrected width must stay bitwise identical (pop order is
        // width-independent). Forced calendar mode so every run actually
        // exercises the bucket scan.
        let mut e = Engine::new();
        e.set_sched_mode(Some(SchedMode::Calendar));
        let n = 400u32;
        for i in 0..n {
            e.task(i % 2, 0.125);
        }
        let first = e.run();
        for round in 0..3 {
            assert_eq!(e.run_reuse(), &first[..], "round {round}");
        }
        // And a spread-out chain workload on the same engine (reset keeps
        // the adapted width): still bitwise stable across replays.
        e.reset();
        let mut prev = e.task(0, 1.0);
        for i in 1..256u32 {
            let t = e.task(i % 4, 1.0);
            e.dep(prev, t);
            prev = t;
        }
        let first = e.run();
        for round in 0..3 {
            assert_eq!(e.run_reuse(), &first[..], "chain round {round}");
        }
    }

    #[test]
    fn last_makespan_matches_finish_fold() {
        // Fused fold == re-walk, on both the calendar and cached paths.
        let mut e = fork_join_engine();
        e.set_sched_mode(Some(SchedMode::Cached));
        let first = e.run(); // calendar path
        assert_eq!(e.last_makespan().to_bits(), Engine::makespan(&first).to_bits());
        e.set_duration(2, 3.25);
        let replay = e.run_reuse().to_vec(); // cached path
        assert_eq!(e.sched_counters().cached_hits, 1);
        assert_eq!(e.last_makespan().to_bits(), Engine::makespan(&replay).to_bits());
    }

    /// Fill engine `a`'s lane matrix and engine `b` sequentially with the
    /// same duration sets, then assert `run_lanes` equals the
    /// one-at-a-time `run_reuse` loop bitwise, lane by lane.
    fn assert_lanes_match_sequential(a: &mut Engine, b: &mut Engine, sets: &[Vec<f64>]) {
        let lanes = sets.len();
        let n = b.len();
        let mat = a.lane_durations_mut(lanes);
        for (m, set) in sets.iter().enumerate() {
            for (i, &d) in set.iter().enumerate() {
                mat[i * lanes + m] = d;
            }
        }
        a.run_lanes(lanes);
        for (m, set) in sets.iter().enumerate() {
            for (i, &d) in set.iter().enumerate() {
                b.set_duration(i as TaskId, d);
            }
            let want = b.run_reuse().to_vec();
            let got = a.lane_finish();
            for (i, w) in want.iter().enumerate() {
                assert_eq!(w.to_bits(), got[i * lanes + m].to_bits(), "lane {m} task {i}");
            }
            assert_eq!(
                b.last_makespan().to_bits(),
                a.lane_makespans()[m].to_bits(),
                "lane {m} makespan"
            );
            assert_eq!(n, want.len());
        }
    }

    #[test]
    fn lane_batch_hit_matches_sequential_replays_bitwise() {
        for width in [4usize, 8] {
            let mut a = fork_join_engine();
            let mut b = fork_join_engine();
            a.set_sched_mode(Some(SchedMode::Cached));
            a.set_lane_mode(Some(true));
            a.set_lane_width(Some(width));
            b.set_sched_mode(Some(SchedMode::Cached));
            a.run();
            b.run();
            // Gently perturbed per-lane duration sets: the pop order stays
            // valid in every lane, so the lane pass serves the whole batch
            // (width 8 takes AVX-512 or its scalar twin depending on host —
            // bitwise identical either way).
            let base: Vec<f64> = b.durations().to_vec();
            let sets: Vec<Vec<f64>> = (0..width)
                .map(|m| base.iter().map(|d| d * (1.0 + (m as f64 + 1.0) * 0.01)).collect())
                .collect();
            assert_lanes_match_sequential(&mut a, &mut b, &sets);
            let c = a.sched_counters();
            assert_eq!(c.lane_hits, width as u64, "all lanes must hit the lane pass");
            assert_eq!(c.lane_fallbacks, 0, "width {width}");
            assert_eq!(c.lane_width, width as u64, "width {width}");
            assert_eq!(c.lane_pad_replays, 0, "full-width batch needs no padding");
            assert_eq!(c.cached_hits, 0, "a lane hit must not touch the scalar counters");
        }
    }

    #[test]
    fn lane_batch_stale_lane_falls_back_in_lane_order() {
        // The stale-cache scenario of `stale_order_cache_rejected_…`, but
        // smuggled into lane 2 of a batch: the vector pass must abort and
        // the sequential re-run (lane order, cache refreshes included)
        // must still match the one-at-a-time loop bitwise.
        fn graph() -> Engine {
            let mut e = Engine::new();
            let a = e.task(0, 1.0);
            let b = e.task(1, 2.0);
            let c = e.task(2, 0.5);
            let d = e.task(2, 0.5);
            e.dep(a, c);
            e.dep(b, d);
            e
        }
        let mut a = graph();
        let mut b = graph();
        a.set_sched_mode(Some(SchedMode::Cached));
        a.set_lane_mode(Some(true));
        a.set_lane_width(Some(4));
        b.set_sched_mode(Some(SchedMode::Cached));
        a.run();
        b.run();
        let base: Vec<f64> = b.durations().to_vec();
        let mut sets: Vec<Vec<f64>> = vec![base.clone(); 4];
        // Lane 2 flips the ready order of the two resource-2 tasks.
        sets[2][0] = 3.0;
        assert_lanes_match_sequential(&mut a, &mut b, &sets);
        let c = a.sched_counters();
        assert_eq!(c.lane_fallbacks, 1, "the stale lane must abort the vector pass");
        assert_eq!(c.lane_hits, 0);
        // The sequential re-run mirrors the twin engine's counters: same
        // hit/fallback/calendar pattern, because it IS the same loop.
        let cb = b.sched_counters();
        assert_eq!(c.cached_hits, cb.cached_hits);
        assert_eq!(c.fallbacks, cb.fallbacks);
        assert_eq!(c.calendar_runs, cb.calendar_runs);
    }

    #[test]
    fn lane_mode_off_takes_the_sequential_path_bitwise() {
        let mut a = fork_join_engine();
        let mut b = fork_join_engine();
        a.set_sched_mode(Some(SchedMode::Cached));
        a.set_lane_mode(Some(false));
        a.set_lane_width(Some(4));
        b.set_sched_mode(Some(SchedMode::Cached));
        a.run();
        b.run();
        let base: Vec<f64> = b.durations().to_vec();
        let sets: Vec<Vec<f64>> = (0..4)
            .map(|m| base.iter().map(|d| d * (1.0 + m as f64 * 0.02)).collect())
            .collect();
        assert_lanes_match_sequential(&mut a, &mut b, &sets);
        let c = a.sched_counters();
        assert_eq!(c.lane_hits, 0, "lanes forced off must never vectorize");
        assert_eq!(c.lane_fallbacks, 0, "a skipped vector pass is not a fallback");
        assert_eq!(c.lane_width, 4);
    }

    #[test]
    fn padded_remainder_batch_rides_the_lane_pass_bitwise() {
        // A 2-replay batch at dispatch width 4 pads two duplicate lanes,
        // rides one lane pass, and discards the pad results — bitwise
        // equal to the one-at-a-time loop, with the padding visible only
        // in the counters. Repeat at width 8 (scalar twin on hosts
        // without avx512f) with a 3-replay batch.
        for (width, batch) in [(4usize, 2usize), (8, 3)] {
            let mut a = fork_join_engine();
            let mut b = fork_join_engine();
            a.set_sched_mode(Some(SchedMode::Cached));
            a.set_lane_mode(Some(true));
            a.set_lane_width(Some(width));
            b.set_sched_mode(Some(SchedMode::Cached));
            a.run();
            b.run();
            let base: Vec<f64> = b.durations().to_vec();
            let sets: Vec<Vec<f64>> = (0..batch)
                .map(|m| base.iter().map(|d| d * (1.1 + m as f64 * 0.1)).collect())
                .collect();
            assert_lanes_match_sequential(&mut a, &mut b, &sets);
            let c = a.sched_counters();
            assert_eq!(c.lane_hits, batch as u64, "real lanes hit the lane pass");
            assert_eq!(c.lane_fallbacks, 0, "width {width}");
            assert_eq!(c.lane_pad_replays, (width - batch) as u64, "width {width}");
            assert_eq!(c.lane_width, width as u64, "padded batches dispatch at full width");
            assert_eq!(c.cached_hits, 0, "padding must not touch the scalar counters");
        }
    }

    #[test]
    fn padded_batch_with_stale_pad_source_falls_back_like_its_real_lane() {
        // The pad lanes duplicate the LAST real lane; if that lane's
        // durations invalidate the cached order, the pad lanes fail the
        // validity check with it and the whole batch falls back — results
        // must still equal the one-at-a-time loop (which never saw a pad
        // lane at all).
        let mut a = Engine::new();
        let mut b = Engine::new();
        for e in [&mut a, &mut b] {
            let w = e.task(0, 1.0);
            let x = e.task(1, 2.0);
            let y = e.task(2, 0.5);
            let z = e.task(2, 0.5);
            e.dep(w, y);
            e.dep(x, z);
        }
        a.set_sched_mode(Some(SchedMode::Cached));
        a.set_lane_mode(Some(true));
        a.set_lane_width(Some(4));
        b.set_sched_mode(Some(SchedMode::Cached));
        a.run();
        b.run();
        let base: Vec<f64> = b.durations().to_vec();
        let mut sets: Vec<Vec<f64>> = vec![base.clone(); 2];
        // The last real lane (lane 1, the pad source) goes stale.
        sets[1][0] = 3.0;
        assert_lanes_match_sequential(&mut a, &mut b, &sets);
        let c = a.sched_counters();
        assert_eq!(c.lane_fallbacks, 1, "the stale pad-source lane must abort the pass");
        assert_eq!(c.lane_hits, 0);
        assert_eq!(c.lane_pad_replays, 0, "an aborted pass discards nothing");
        let cb = b.sched_counters();
        assert_eq!(c.cached_hits, cb.cached_hits);
        assert_eq!(c.fallbacks, cb.fallbacks);
        assert_eq!(c.calendar_runs, cb.calendar_runs);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "after run_lanes is unspecified")]
    fn scalar_accessors_are_poisoned_after_a_lane_batch() {
        let mut e = fork_join_engine();
        e.set_sched_mode(Some(SchedMode::Cached));
        e.set_lane_width(Some(4));
        e.run();
        let base: Vec<f64> = e.durations().to_vec();
        let mat = e.lane_durations_mut(4);
        for (i, &d) in base.iter().enumerate() {
            for m in 0..4 {
                mat[i * 4 + m] = d * (1.0 + m as f64 * 0.01);
            }
        }
        e.run_lanes(4);
        // Poisoned: the batch's outputs are the lane accessors only.
        let _ = e.last_makespan();
    }

    #[test]
    fn scalar_poisoning_clears_on_the_next_scalar_run() {
        let mut e = fork_join_engine();
        e.set_sched_mode(Some(SchedMode::Cached));
        e.set_lane_width(Some(4));
        let first = e.run();
        let base: Vec<f64> = e.durations().to_vec();
        let mat = e.lane_durations_mut(4);
        for (i, &d) in base.iter().enumerate() {
            for m in 0..4 {
                mat[i * 4 + m] = d;
            }
        }
        e.run_lanes(4);
        // A scalar replay re-establishes (and un-poisons) the scalar
        // accessors, whatever path the lane batch took.
        for (i, &d) in base.iter().enumerate() {
            e.set_duration(i as TaskId, d);
        }
        let again = e.run_reuse().to_vec();
        assert_eq!(again, first);
        assert_eq!(e.last_makespan().to_bits(), Engine::makespan(&again).to_bits());
        assert_eq!(e.durations(), &base[..]);
    }

    #[test]
    fn spec_and_edge_accessors() {
        let mut e = Engine::new();
        let a = e.task(3, 1.5);
        let b = e.task(1, 2.5);
        e.dep(a, b);
        let s = e.spec(a);
        assert_eq!(s.resource, 3);
        assert_eq!(s.duration, 1.5);
        assert_eq!(e.edge(0), (a, b));
        assert_eq!(e.durations(), &[1.5, 2.5]);
    }
}
