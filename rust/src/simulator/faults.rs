//! Faulty-cluster simulation plane: heterogeneous node speeds, heavy-tail
//! stragglers, and a worker-failure schedule, replayed deterministically on
//! top of the Algorithm-2 task graph.
//!
//! A [`FaultPlan`] is fixed *at construction* from split [`Rng`] streams:
//! per-worker speed multipliers, a failure schedule ("worker `w` dies at
//! iteration `i`, recovers after `r`"), and a straggler draw that is a
//! **pure function of `(worker, iteration)`** — no mutable state, so one
//! plan can be shared by reference and a pooled faulty sweep is bitwise
//! identical to the serial one at any thread count (the same contract the
//! clean sweep's `Rng::split`-per-K streams provide; see
//! `rust/tests/faults.rs`).
//!
//! Recovery is *modeled in the graph*, not hand-waved into the cost
//! formula: [`IterationTemplate::reset_to_faulty`] adds the recovery
//! policy's extra Map tasks and comm edges for each dead chunk, so the
//! replayed makespan reflects re-dispatch cost, straggler overlap, and the
//! serialisation the policy implies (master recompute serialises after the
//! reduce; redistribution overlaps with the survivors' own Map).
//!
//! ## Bitwise contracts (pinned by tests, see PERF.md "Fault plane")
//!
//! * **Empty plan = clean engine.** `run_faulty_into` with an empty plan
//!   (no failure windows, no stragglers, all speeds exactly 1.0) delegates
//!   to the untouched clean path — bitwise identical timings, identical
//!   scheduler counters, so the `BSF_SCHED`/`BSF_LANES` caches keep
//!   working unchanged.
//! * **Deterministic fault draws.** Speeds and the failure schedule are
//!   drawn once at plan construction; straggler multipliers come from
//!   `split(iteration << 32 | worker)` child streams — evaluation order
//!   and thread count cannot change any draw.
//! * **`BSF_FAULTS=audit`** routes even empty plans through the faulty
//!   machinery (the wrapped provider + the recovery-aware build pass),
//!   which must still be bitwise identical — CI runs the whole suite in
//!   that cell so the identity is checked under every kernel/scheduler/
//!   lane combination.

use std::sync::OnceLock;

use crate::simulator::cluster::{
    CostProvider, IterationTemplate, IterationTiming, SimParams,
};
use crate::util::Rng;

/// How a dead worker's chunk is recovered, both in the DES graph
/// ([`IterationTemplate::reset_to_faulty`]) and in the live runner
/// (`LiveRunner::recovery`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// The master recomputes the dead chunk itself after the gather —
    /// today's degraded mode: detection at the gather deadline, then a
    /// serial Map+fold on the master's own resource.
    #[default]
    MasterRecompute,
    /// The dead chunk is split over the group's surviving workers: a
    /// re-dispatch message per survivor, the survivor's extra Map+fold
    /// (overlapping its own), an uplink of the extra partial, and one fold
    /// at the master. Falls back to [`RecoveryPolicy::MasterRecompute`]
    /// when a group has no survivors left.
    Redistribute,
    /// Periodic checkpoint/restart: every `interval` iterations the master
    /// saves the current approximation (modeled in the DES graph as a
    /// state-save task appended after `post`; in the live runner as a
    /// master-side snapshot of `x`). On a worker death the computation
    /// rolls back to the last checkpoint and re-executes the lost
    /// iterations; dead chunks themselves are recomputed on the master
    /// (detection still happens at the gather deadline). The knob trades
    /// steady-state save overhead against rollback re-execution — see
    /// `model::bsf::optimal_checkpoint_interval` for the analytic optimum.
    Checkpoint {
        /// Iterations between state saves (min 1; a save fires at every
        /// iteration `i` with `i % interval == 0`).
        interval: u64,
    },
}

/// Generator configuration for [`FaultPlan::generate`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Lognormal sigma of the static per-worker speed multiplier
    /// (0 = homogeneous: every speed is exactly 1.0).
    pub speed_sigma: f64,
    /// Per-(worker, iteration) probability of a straggler event.
    pub straggler_prob: f64,
    /// Map-time multiplier applied when a straggler event fires (the
    /// heavy-tail factor; 1.0 = stragglers change nothing).
    pub straggler_factor: f64,
    /// Per-(worker, iteration) probability that the worker dies.
    pub fail_prob: f64,
    /// Iterations a dead worker stays down before it recovers (min 1).
    pub downtime: u64,
    /// Recovery policy modeled for dead chunks.
    pub policy: RecoveryPolicy,
    /// Lognormal sigma of the per-worker *speed drift trend* (0 = stationary).
    /// Each worker draws one trend slope `τ_w` at plan construction (from a
    /// dedicated split stream, so zero-drift plans draw nothing extra) and
    /// its Map-time multiplier becomes `speed_w · exp(τ_w · iter)` — speeds
    /// that wander mid-run instead of being fixed at iteration 0.
    pub speed_drift: f64,
    /// Exponential failure-hazard growth over the horizon (0 = stationary).
    /// The per-iteration death probability becomes
    /// `fail_prob · exp(hazard_drift · i / horizon)` — a cluster whose
    /// failure rate rises (positive) or burns in (negative) as the job ages.
    pub hazard_drift: f64,
}

impl FaultSpec {
    /// The no-fault spec: generates an empty plan (all speeds 1.0).
    pub fn clean() -> FaultSpec {
        FaultSpec {
            speed_sigma: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            fail_prob: 0.0,
            downtime: 1,
            policy: RecoveryPolicy::MasterRecompute,
            speed_drift: 0.0,
            hazard_drift: 0.0,
        }
    }
}

/// One failure episode: `worker` is down for iterations `from..until`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailureWindow {
    /// Worker index in `0..k`.
    pub worker: usize,
    /// First iteration (inclusive) the worker is dead.
    pub from: u64,
    /// First iteration the worker is back up (exclusive end).
    pub until: u64,
}

/// `worker` value of the synthetic Map tasks a master runs when it
/// recomputes a dead chunk itself ([`RecoveryPolicy::MasterRecompute`]):
/// out of range of any real worker, so [`FaultPlan::mult`] never slows a
/// master's recovery compute by the dead worker's multiplier.
pub const MASTER_WORKER: usize = u32::MAX as usize;

// Plan-local stream tags, disjoint in the high bits from each other and
// from any worker index.
const SPEED_STREAM: u64 = 0x5BEE_D000 << 32;
const FAIL_STREAM: u64 = 0xFA11_0000 << 32;
const STRAGGLER_STREAM: u64 = 0x51AC_0000 << 32;
const DRIFT_STREAM: u64 = 0xD21F_0000 << 32;

/// A deterministic fault schedule for `k` workers over a finite horizon.
///
/// All randomness is resolved at construction ([`FaultPlan::generate`]) or
/// through pure `split` streams ([`FaultPlan::mult`]); the plan itself is
/// immutable and can be shared by `&` across replay loops and threads.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    k: usize,
    /// Static per-worker Map-time multiplier (1.0 = nominal speed).
    speeds: Vec<f64>,
    /// Per-worker drift trend slope `τ_w` (empty = stationary speeds).
    /// The iteration-`i` multiplier is `speeds[w] · exp(drift[w] · i)`.
    drift: Vec<f64>,
    windows: Vec<FailureWindow>,
    straggler_prob: f64,
    straggler_factor: f64,
    policy: RecoveryPolicy,
    /// Root of the pure per-(worker, iteration) straggler streams.
    straggler_root: Rng,
}

impl FaultPlan {
    /// The empty plan: no failures, no stragglers, all speeds exactly 1.0.
    pub fn clean(k: usize) -> FaultPlan {
        FaultPlan {
            k,
            speeds: vec![1.0; k],
            drift: Vec::new(),
            windows: Vec::new(),
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            policy: RecoveryPolicy::MasterRecompute,
            straggler_root: Rng::new(0),
        }
    }

    /// Draw a plan from `spec` for `k` workers over `horizon` iterations.
    ///
    /// Pure in `(spec, k, horizon, root)`: every speed and failure window
    /// comes from a per-worker `root.split(...)` child stream, so two
    /// calls with the same arguments — on any thread, in any order —
    /// produce identical plans.
    pub fn generate(spec: &FaultSpec, k: usize, horizon: u64, root: &Rng) -> FaultPlan {
        let mut speeds = Vec::with_capacity(k);
        for w in 0..k {
            let mut r = root.split(SPEED_STREAM | w as u64);
            speeds.push(r.jitter(spec.speed_sigma)); // exactly 1.0 at sigma 0
        }
        // Drift trends come from their own split stream so a zero-drift spec
        // performs no extra draws anywhere — the speed and failure streams
        // above stay bitwise identical to stationary plans.
        let mut drift = Vec::new();
        if spec.speed_drift != 0.0 {
            drift.reserve(k);
            for w in 0..k {
                let mut r = root.split(DRIFT_STREAM | w as u64);
                drift.push(spec.speed_drift * r.normal());
            }
        }
        let mut windows = Vec::new();
        if spec.fail_prob > 0.0 {
            let h = horizon.max(1) as f64;
            for w in 0..k {
                let mut r = root.split(FAIL_STREAM | w as u64);
                let mut i = 0u64;
                while i < horizon {
                    // Stationary hazard runs the exact PR-6 comparison; a
                    // non-zero drift scales the hazard with job age.
                    let p = if spec.hazard_drift != 0.0 {
                        spec.fail_prob * (spec.hazard_drift * i as f64 / h).exp()
                    } else {
                        spec.fail_prob
                    };
                    if r.uniform() < p {
                        let until = i.saturating_add(spec.downtime.max(1));
                        windows.push(FailureWindow { worker: w, from: i, until });
                        i = until;
                    } else {
                        i += 1;
                    }
                }
            }
        }
        FaultPlan {
            k,
            speeds,
            drift,
            windows,
            straggler_prob: spec.straggler_prob,
            straggler_factor: spec.straggler_factor,
            policy: spec.policy,
            straggler_root: root.split(STRAGGLER_STREAM),
        }
    }

    /// Explicit failure episode (test/experiment builder).
    #[must_use]
    pub fn with_failure(mut self, worker: usize, from: u64, downtime: u64) -> FaultPlan {
        assert!(worker < self.k, "worker {worker} out of range 0..{}", self.k);
        self.windows.push(FailureWindow { worker, from, until: from.saturating_add(downtime.max(1)) });
        self
    }

    /// Explicit per-worker speed multiplier (test/experiment builder).
    #[must_use]
    pub fn with_speed(mut self, worker: usize, mult: f64) -> FaultPlan {
        assert!(mult > 0.0, "speed multiplier must be positive");
        self.speeds[worker] = mult;
        self
    }

    /// Explicit per-worker drift trend slope (test/experiment builder):
    /// the worker's multiplier becomes `speed · exp(trend · iter)`.
    #[must_use]
    pub fn with_speed_drift(mut self, worker: usize, trend: f64) -> FaultPlan {
        assert!(worker < self.k, "worker {worker} out of range 0..{}", self.k);
        if self.drift.is_empty() {
            self.drift.resize(self.k, 0.0);
        }
        self.drift[worker] = trend;
        self
    }

    /// Straggler configuration (test/experiment builder). Draws come from
    /// pure child streams of `root`.
    #[must_use]
    pub fn with_stragglers(mut self, prob: f64, factor: f64, root: &Rng) -> FaultPlan {
        self.straggler_prob = prob;
        self.straggler_factor = factor;
        self.straggler_root = root.split(STRAGGLER_STREAM);
        self
    }

    /// Recovery policy for dead chunks (test/experiment builder).
    #[must_use]
    pub fn with_policy(mut self, policy: RecoveryPolicy) -> FaultPlan {
        self.policy = policy;
        self
    }

    /// Worker count the plan covers.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Recovery policy modeled for dead chunks.
    pub fn policy(&self) -> RecoveryPolicy {
        self.policy
    }

    /// Static per-worker speed multipliers.
    pub fn speeds(&self) -> &[f64] {
        &self.speeds
    }

    /// The failure schedule.
    pub fn windows(&self) -> &[FailureWindow] {
        &self.windows
    }

    /// True when the plan changes nothing: no failure windows, no
    /// stragglers, no drift, not checkpointing, every speed exactly 1.0.
    /// `run_faulty_into` then takes the untouched clean path (unless
    /// [`faults_audit`] forces the faulty machinery, which must still be
    /// bitwise identical).
    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
            && self.straggler_prob == 0.0
            && self.drift.is_empty()
            && !matches!(self.policy, RecoveryPolicy::Checkpoint { .. })
            && self.speeds.iter().all(|&s| s == 1.0)
    }

    /// True when per-iteration state never changes (no failure windows, no
    /// straggler draws, no drift trends, no periodic checkpoint tasks) —
    /// only static heterogeneous speeds, so the clean graph and the clean
    /// replication/lane batching machinery stay valid under the wrapped
    /// provider.
    pub fn is_static(&self) -> bool {
        self.windows.is_empty()
            && self.straggler_prob == 0.0
            && self.drift.is_empty()
            && !matches!(self.policy, RecoveryPolicy::Checkpoint { .. })
    }

    /// Map-time multiplier for `worker` at `iter`: static speed × drift
    /// trend × straggler draw. Pure in `(self, worker, iter)`.
    /// Out-of-range workers (the [`MASTER_WORKER`] recovery sentinel) run
    /// at nominal speed.
    pub fn mult(&self, worker: usize, iter: u64) -> f64 {
        if worker >= self.k {
            return 1.0;
        }
        let mut m = self.speeds[worker];
        if let Some(&trend) = self.drift.get(worker) {
            if trend != 0.0 {
                m *= (trend * iter as f64).exp();
            }
        }
        if self.straggler_prob > 0.0 {
            let mut r = self.straggler_root.split((iter << 32) | worker as u64);
            if r.uniform() < self.straggler_prob {
                m *= self.straggler_factor;
            }
        }
        m
    }

    /// Fill `out[w] = true` iff worker `w` is dead at `iter` (scratch is
    /// caller-owned so the replay loop allocates nothing once warm).
    pub fn dead_into(&self, iter: u64, out: &mut Vec<bool>) {
        out.clear();
        out.resize(self.k, false);
        for w in &self.windows {
            if w.from <= iter && iter < w.until {
                out[w.worker] = true;
            }
        }
    }
}

/// [`CostProvider`] adaptor applying a [`FaultPlan`]'s multiplier to
/// Map times. Passthrough is exact: a multiplier of 1.0 returns the inner
/// provider's value untouched (no `* 1.0` round trip), which is what makes
/// the audit-mode empty-plan path bitwise identical to the clean one.
pub struct FaultyCost<'a> {
    inner: &'a mut dyn CostProvider,
    plan: &'a FaultPlan,
    iter: u64,
}

impl<'a> FaultyCost<'a> {
    /// Wrap `inner` for iteration `iter` of `plan`.
    pub fn new(inner: &'a mut dyn CostProvider, plan: &'a FaultPlan, iter: u64) -> FaultyCost<'a> {
        FaultyCost { inner, plan, iter }
    }
}

impl CostProvider for FaultyCost<'_> {
    fn map_time(&mut self, worker: usize, chunk: usize) -> f64 {
        let t = self.inner.map_time(worker, chunk);
        let m = self.plan.mult(worker, self.iter);
        if m == 1.0 {
            t
        } else {
            t * m
        }
    }
    fn combine_time(&mut self) -> f64 {
        self.inner.combine_time()
    }
    fn post_time(&mut self) -> f64 {
        self.inner.post_time()
    }
    fn is_deterministic(&self) -> bool {
        self.inner.is_deterministic() && self.plan.is_static()
    }
}

/// Caller-owned scratch for [`run_faulty_into`]'s dead-set tracking (keeps
/// the replay loop allocation-free once warm, like the engine's buffers).
#[derive(Debug, Default)]
pub struct FaultScratch {
    cur: Vec<bool>,
    next: Vec<bool>,
}

/// Simulate `iters` iterations of `(plan.k(), l, params)` under `plan`,
/// appending timings to `out` (cleared first).
///
/// * Empty plan (and not [`faults_audit`]): delegates to the clean
///   [`IterationTemplate::run_into`] — bitwise identical to today's engine.
/// * Static plan (speeds only): clean graph + wrapped provider; the
///   replication / lane-batching machinery still applies because every
///   iteration's multipliers are identical.
/// * Failure windows, stragglers, drift, or checkpointing: per-iteration
///   scalar replays; the graph is rebuilt (via
///   [`IterationTemplate::reset_to_faulty_ckpt`]) only on iterations where
///   the dead set or the save-this-iteration flag actually changes, so
///   long failure windows replay through the engine's order cache like
///   any other template.
///
/// Under [`RecoveryPolicy::Checkpoint`], iterations at `i % interval == 0`
/// carry a state-save task (a fixed-duration append after `post`, so the
/// saved iteration's total is exactly `clean + save_cost`), and the first
/// iteration of each failure window additionally charges the rollback:
/// the `i % interval` iterations since the last checkpoint are re-executed
/// (extra replays under the post-death graph, folded into that
/// iteration's `total`). The extra replays consume jitter draws like any
/// real iteration — the run stays a pure function of `(plan, rng)`.
#[allow(clippy::too_many_arguments)]
pub fn run_faulty_into(
    tmpl: &mut IterationTemplate,
    plan: &FaultPlan,
    l: usize,
    params: &SimParams,
    iters: usize,
    provider: &mut dyn CostProvider,
    rng: &mut Rng,
    out: &mut Vec<IterationTiming>,
    scratch: &mut FaultScratch,
) {
    let k = plan.k();
    if plan.is_empty() && !faults_audit() {
        tmpl.reset_to(k, l, params);
        tmpl.run_into(iters, provider, rng, out);
        return;
    }
    if plan.is_static() {
        tmpl.reset_to(k, l, params);
        let mut fc = FaultyCost::new(provider, plan, 0);
        tmpl.run_into(iters, &mut fc, rng, out);
        return;
    }
    out.clear();
    let ckpt_interval = match plan.policy() {
        RecoveryPolicy::Checkpoint { interval } => Some(interval.max(1)),
        _ => None,
    };
    let mut built = false;
    let mut cur_save = false;
    for i in 0..iters {
        plan.dead_into(i as u64, &mut scratch.next);
        let save_now = ckpt_interval.is_some_and(|iv| i as u64 % iv == 0);
        // A rollback fires on the first iteration of a failure window:
        // some worker is dead now that was alive when the graph was last
        // current. (At i = 0 `cur` is still empty; `lost` is 0 there, so
        // the branch is harmless either way.)
        let new_death = ckpt_interval.is_some()
            && scratch
                .next
                .iter()
                .enumerate()
                .any(|(w, &d)| d && !scratch.cur.get(w).copied().unwrap_or(false));
        if !built || scratch.next != scratch.cur || save_now != cur_save {
            tmpl.reset_to_faulty_ckpt(k, l, params, &scratch.next, plan.policy(), save_now);
            std::mem::swap(&mut scratch.cur, &mut scratch.next);
            cur_save = save_now;
            built = true;
        }
        let mut fc = FaultyCost::new(provider, plan, i as u64);
        out.push(tmpl.replay(&mut fc, rng));
        if new_death {
            // Roll back to the last checkpoint: re-execute the iterations
            // lost since it, under the current (post-death) graph, and
            // charge them to this iteration's makespan.
            let lost = ckpt_interval.map_or(0, |iv| i as u64 % iv);
            for _ in 0..lost {
                let redo = tmpl.replay(&mut fc, rng);
                out.last_mut().expect("just pushed").total += redo.total;
            }
        }
    }
}

static ACTIVE_FAULTS: OnceLock<bool> = OnceLock::new();

/// Parse the `BSF_FAULTS` value: `audit` routes even empty plans through
/// the faulty build path + provider wrapper (which must stay bitwise
/// identical to the clean path — the CI matrix cell relies on it); unset
/// or `off` keeps the clean fast path. Unknown values panic loudly, like
/// `BSF_KERNEL`/`BSF_SCHED`/`BSF_LANES`.
fn select_faults(var: Option<&str>) -> bool {
    match var {
        None | Some("off") => false,
        Some("audit") => true,
        Some(other) => panic!("BSF_FAULTS must be `audit` or `off` (or unset), got `{other}`"),
    }
}

/// Process-wide audit switch, read once from `BSF_FAULTS` (see
/// [`select_faults`]).
pub fn faults_audit() -> bool {
    *ACTIVE_FAULTS.get_or_init(|| select_faults(std::env::var("BSF_FAULTS").ok().as_deref()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::cluster::AnalyticCost;

    fn analytic(l: usize) -> AnalyticCost {
        AnalyticCost { t_map_full: 1.0, l, t_a: 1e-4, t_p: 1e-3 }
    }

    #[test]
    fn select_faults_parses() {
        assert!(!select_faults(None));
        assert!(!select_faults(Some("off")));
        assert!(select_faults(Some("audit")));
    }

    #[test]
    #[should_panic(expected = "BSF_FAULTS")]
    fn select_faults_rejects_unknown() {
        select_faults(Some("sometimes"));
    }

    #[test]
    fn clean_spec_generates_empty_plan() {
        let root = Rng::new(42);
        let plan = FaultPlan::generate(&FaultSpec::clean(), 16, 100, &root);
        assert!(plan.is_empty());
        assert!(plan.is_static());
        assert!(plan.windows().is_empty());
        assert!(plan.speeds().iter().all(|&s| s == 1.0));
    }

    #[test]
    fn generate_is_pure_in_its_arguments() {
        let spec = FaultSpec {
            speed_sigma: 0.2,
            straggler_prob: 0.1,
            straggler_factor: 4.0,
            fail_prob: 0.05,
            downtime: 2,
            policy: RecoveryPolicy::Redistribute,
            speed_drift: 0.01,
            hazard_drift: 1.0,
        };
        let root = Rng::new(7);
        let a = FaultPlan::generate(&spec, 12, 50, &root);
        let b = FaultPlan::generate(&spec, 12, 50, &root);
        assert_eq!(a.windows(), b.windows());
        for (x, y) in a.speeds().iter().zip(b.speeds()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (w, i) in (0..12).flat_map(|w| (0..50u64).map(move |i| (w, i))) {
            assert_eq!(a.mult(w, i).to_bits(), b.mult(w, i).to_bits());
        }
        // and a fresh root with the same seed agrees too
        let c = FaultPlan::generate(&spec, 12, 50, &Rng::new(7));
        assert_eq!(a.windows(), c.windows());
    }

    #[test]
    fn zero_drift_generation_is_bitwise_stationary() {
        // Adding the drift knobs at zero must not perturb any existing
        // draw: speeds, windows, and mult all stay bitwise identical to a
        // spec that predates the fields.
        let base = FaultSpec {
            speed_sigma: 0.2,
            straggler_prob: 0.1,
            straggler_factor: 4.0,
            fail_prob: 0.05,
            downtime: 2,
            policy: RecoveryPolicy::MasterRecompute,
            speed_drift: 0.0,
            hazard_drift: 0.0,
        };
        let root = Rng::new(9);
        let plan = FaultPlan::generate(&base, 10, 60, &root);
        let drifted = FaultPlan::generate(
            &FaultSpec { speed_drift: 0.05, hazard_drift: 2.0, ..base },
            10,
            60,
            &root,
        );
        // The stationary plan's speeds are untouched by the drift stream.
        for (x, y) in plan.speeds().iter().zip(drifted.speeds()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Hazard drift only re-weights windows; same streams, same shape.
        assert_eq!(plan.k(), drifted.k());
        // Drifted mult actually varies with the iteration index.
        let varies = (0..10).any(|w| {
            drifted.mult(w, 0).to_bits() != drifted.mult(w, 40).to_bits()
        });
        assert!(varies, "non-zero drift must move multipliers over iterations");
        // Stationary mult does not drift (modulo straggler draws, disabled here).
        let still = FaultPlan::generate(
            &FaultSpec { straggler_prob: 0.0, ..base },
            10,
            60,
            &root,
        );
        for w in 0..10 {
            assert_eq!(still.mult(w, 0).to_bits(), still.mult(w, 40).to_bits());
        }
    }

    #[test]
    fn hazard_drift_raises_late_failure_density() {
        // With a strongly rising hazard, failures should cluster late.
        let spec = FaultSpec {
            fail_prob: 0.02,
            downtime: 1,
            hazard_drift: 4.0,
            ..FaultSpec::clean()
        };
        let plan = FaultPlan::generate(&spec, 64, 200, &Rng::new(12));
        let (mut early, mut late) = (0usize, 0usize);
        for w in plan.windows() {
            if w.from < 100 {
                early += 1;
            } else {
                late += 1;
            }
        }
        assert!(
            late > early,
            "rising hazard must concentrate failures late: early={early} late={late}"
        );
    }

    #[test]
    fn checkpoint_plan_is_neither_empty_nor_static() {
        let plan = FaultPlan::clean(8).with_policy(RecoveryPolicy::Checkpoint { interval: 4 });
        assert!(!plan.is_empty());
        assert!(!plan.is_static());
    }

    #[test]
    fn drifted_plan_is_not_static() {
        let plan = FaultPlan::clean(8).with_speed_drift(3, 0.01);
        assert!(!plan.is_empty());
        assert!(!plan.is_static());
        // drift compounds multiplicatively over iterations
        let m1 = plan.mult(3, 1);
        let m10 = plan.mult(3, 10);
        assert!(m10 > m1 && m1 > 1.0);
        // other workers stay nominal
        assert_eq!(plan.mult(2, 10), 1.0);
    }

    #[test]
    fn failure_windows_respect_downtime_and_horizon() {
        let spec = FaultSpec { fail_prob: 0.3, downtime: 3, ..FaultSpec::clean() };
        let plan = FaultPlan::generate(&spec, 8, 40, &Rng::new(3));
        assert!(!plan.windows().is_empty(), "p=0.3 over 8x40 draws should fire");
        for w in plan.windows() {
            assert!(w.from < 40, "window starts inside the horizon");
            assert_eq!(w.until, w.from + 3);
        }
        // per worker: windows are disjoint and ordered
        for worker in 0..8 {
            let mut last_until = 0;
            for w in plan.windows().iter().filter(|w| w.worker == worker) {
                assert!(w.from >= last_until, "overlapping windows for worker {worker}");
                last_until = w.until;
            }
        }
    }

    #[test]
    fn dead_set_tracks_windows() {
        let plan = FaultPlan::clean(4).with_failure(2, 3, 2);
        let mut dead = Vec::new();
        plan.dead_into(2, &mut dead);
        assert_eq!(dead, vec![false, false, false, false]);
        plan.dead_into(3, &mut dead);
        assert_eq!(dead, vec![false, false, true, false]);
        plan.dead_into(4, &mut dead);
        assert_eq!(dead, vec![false, false, true, false]);
        plan.dead_into(5, &mut dead);
        assert_eq!(dead, vec![false, false, false, false]);
    }

    #[test]
    fn straggler_mult_is_pure_and_master_sentinel_is_nominal() {
        let root = Rng::new(11);
        let plan = FaultPlan::clean(8).with_stragglers(0.5, 4.0, &root);
        for w in 0..8 {
            for i in 0..20u64 {
                let a = plan.mult(w, i);
                let b = plan.mult(w, i);
                assert_eq!(a.to_bits(), b.to_bits(), "mult must be pure in (w, iter)");
                assert!(a == 1.0 || a == 4.0);
            }
        }
        let fired = (0..8)
            .flat_map(|w| (0..20u64).map(move |i| (w, i)))
            .filter(|&(w, i)| plan.mult(w, i) != 1.0)
            .count();
        assert!(fired > 0, "p=0.5 over 160 draws should fire");
        assert!(fired < 160, "p=0.5 should not always fire");
        assert_eq!(plan.mult(MASTER_WORKER, 5), 1.0);
    }

    #[test]
    fn faulty_cost_guards_unit_multiplier() {
        let plan = FaultPlan::clean(4).with_speed(1, 3.0);
        let mut inner = analytic(1000);
        let t0 = inner.map_time(0, 250);
        let t1 = inner.map_time(1, 250);
        let mut fc = FaultyCost::new(&mut inner, &plan, 0);
        // worker 0 at nominal speed: bitwise passthrough
        assert_eq!(fc.map_time(0, 250).to_bits(), t0.to_bits());
        assert_eq!(fc.map_time(1, 250), t1 * 3.0);
        assert!(!plan.is_empty());
        assert!(plan.is_static());
    }

    #[test]
    fn empty_plan_run_matches_clean_run() {
        let l = 1024;
        let mut p = SimParams::new(l, l);
        p.jitter_comp = 0.06;
        let plan = FaultPlan::clean(12);
        let mut tmpl_a = IterationTemplate::new(12, l, &p);
        let mut want = Vec::new();
        tmpl_a.run_into(6, &mut analytic(l), &mut Rng::new(5), &mut want);
        let mut tmpl_b = IterationTemplate::new(12, l, &p);
        let mut got = Vec::new();
        let mut scratch = FaultScratch::default();
        run_faulty_into(
            &mut tmpl_b, &plan, l, &p, 6, &mut analytic(l), &mut Rng::new(5), &mut got,
            &mut scratch,
        );
        assert_eq!(want, got);
    }

    #[test]
    fn failure_costs_makespan() {
        let l = 4096;
        let p = SimParams::new(64, 64);
        let mut clean = Vec::new();
        IterationTemplate::new(8, l, &p).run_into(4, &mut analytic(l), &mut Rng::new(1), &mut clean);
        for policy in [RecoveryPolicy::MasterRecompute, RecoveryPolicy::Redistribute] {
            let plan = FaultPlan::clean(8).with_failure(3, 1, 2).with_policy(policy);
            let mut got = Vec::new();
            let mut scratch = FaultScratch::default();
            run_faulty_into(
                &mut IterationTemplate::new(8, l, &p),
                &plan,
                l,
                &p,
                4,
                &mut analytic(l),
                &mut Rng::new(1),
                &mut got,
                &mut scratch,
            );
            assert_eq!(got.len(), 4);
            // healthy iterations identical, failed iterations strictly slower
            assert_eq!(got[0], clean[0], "{policy:?}: pre-failure iteration must be clean");
            assert!(
                got[1].total > clean[1].total && got[2].total > clean[2].total,
                "{policy:?}: recovery must cost makespan"
            );
            assert_eq!(got[3], clean[3], "{policy:?}: post-recovery iteration must be clean");
        }
    }

    #[test]
    fn redistribute_beats_master_recompute_when_compute_bound() {
        // Compute-dominated chunk: overlapping the recovery across
        // survivors must beat a serial re-run on the master.
        let l = 8192;
        let p = SimParams::new(16, 16);
        let run = |policy| {
            let plan = FaultPlan::clean(8).with_failure(2, 0, 1).with_policy(policy);
            let mut out = Vec::new();
            let mut scratch = FaultScratch::default();
            run_faulty_into(
                &mut IterationTemplate::new(8, l, &p),
                &plan,
                l,
                &p,
                1,
                &mut analytic(l),
                &mut Rng::new(2),
                &mut out,
                &mut scratch,
            );
            out[0].total
        };
        let mr = run(RecoveryPolicy::MasterRecompute);
        let rd = run(RecoveryPolicy::Redistribute);
        assert!(rd < mr, "redistribute={rd} master-recompute={mr}");
    }

    #[test]
    fn slow_worker_stretches_map_phase() {
        let l = 4096;
        let p = SimParams::new(64, 64);
        let mut clean = Vec::new();
        IterationTemplate::new(8, l, &p).run_into(2, &mut analytic(l), &mut Rng::new(4), &mut clean);
        let plan = FaultPlan::clean(8).with_speed(5, 2.0);
        let mut got = Vec::new();
        let mut scratch = FaultScratch::default();
        run_faulty_into(
            &mut IterationTemplate::new(8, l, &p),
            &plan,
            l,
            &p,
            2,
            &mut analytic(l),
            &mut Rng::new(4),
            &mut got,
            &mut scratch,
        );
        assert!(got[0].total > clean[0].total, "a 2x-slow worker must stretch the iteration");
    }
}
