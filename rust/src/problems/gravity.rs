//! BSF-Gravity (paper §6, Algorithms 5–6): the simplified n-body problem.
//!
//! A probe of negligible mass moves among `n` motionless attractors. The
//! list is the bodies `[(Y_i, m_i)]`; the Map is the per-body acceleration
//! contribution (eq. 35, with G = 1):
//!
//! ```text
//! f_X(Y_i, m_i) = m_i / ‖Y_i − X‖² · (Y_i − X)
//! ```
//!
//! the fold is 3-vector addition, and the master integrates (eqs. 31–33)
//! with the adaptive time slot `Δt = η / (‖V‖²·‖α‖⁴)`.
//!
//! Downlink encoding: `[X₀ X₁ X₂ | V₀ V₁ V₂ | t]` (7 words — the paper's
//! analysis charges 3 down / 3 up, eq. `t_c = 6τ_tr + 2L`; the 4 extra
//! words are ≪ L on any real network and are noted in DESIGN.md).
//! Uplink: the partial `α` (3 words).
//!
//! Analytic costs (paper §6): `t_Map = 17·n·τ_op` (17 ops per body),
//! `t_a = 3·τ_op`, `Δt` costs 13 ops.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::{Arc, Mutex};

use crate::coordinator::{BsfProblem, CostSpec, Workspace};
use crate::linalg::generators::BodyWorkload;
use crate::runtime::{KernelRuntime, TensorView};

/// Guard matching the Pallas kernel's `_R2_FLOOR` (zero-mass padding makes
/// it irrelevant numerically; present for bit-equivalence with the kernel).
const R2_FLOOR: f64 = 1e-30;

/// The BSF-Gravity problem.
#[derive(Debug)]
pub struct GravityProblem {
    bodies: Vec<[f64; 3]>,
    masses: Vec<f64>,
    /// Time-slot constant η.
    pub eta: f64,
    /// Integration horizon T (Algorithm 5 stops when `t ≥ T`).
    pub t_end: f64,
    x0: [f64; 3],
    v0: [f64; 3],
    /// Packed `(B,3)` position + `(B,)` mass blocks for the kernel path,
    /// keyed by `(i0, i1, B)` — iteration-invariant, packed once per
    /// worker (see EXPERIMENTS.md §Perf).
    block_cache: Mutex<HashMap<(usize, usize, usize), (Arc<Vec<f64>>, Arc<Vec<f64>>)>>,
}

impl GravityProblem {
    /// Build from a generated workload.
    pub fn new(w: BodyWorkload, eta: f64, t_end: f64) -> GravityProblem {
        assert_eq!(w.bodies.len(), w.masses.len());
        GravityProblem {
            bodies: w.bodies,
            masses: w.masses,
            eta,
            t_end,
            x0: w.x0,
            v0: w.v0,
            block_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Packed `(y_blk, m_blk)` for bodies `i0..i1`, zero-padded to `b`
    /// slots, cached (the body set never changes between iterations).
    fn packed_block(&self, i0: usize, i1: usize, b: usize) -> (Arc<Vec<f64>>, Arc<Vec<f64>>) {
        let mut cache = self.block_cache.lock().expect("block cache poisoned");
        cache
            .entry((i0, i1, b))
            .or_insert_with(|| {
                let mut y_blk = vec![0.0; b * 3];
                let mut m_blk = vec![0.0; b];
                for (slot, i) in (i0..i1).enumerate() {
                    y_blk[slot * 3..slot * 3 + 3].copy_from_slice(&self.bodies[i]);
                    m_blk[slot] = self.masses[i];
                }
                (Arc::new(y_blk), Arc::new(m_blk))
            })
            .clone()
    }

    /// Number of attractors n.
    pub fn n(&self) -> usize {
        self.bodies.len()
    }

    /// Decode `[X|V|t]` from the downlink payload.
    fn decode(x: &[f64]) -> ([f64; 3], [f64; 3], f64) {
        ([x[0], x[1], x[2]], [x[3], x[4], x[5]], x[6])
    }

    fn native_block(&self, range: Range<usize>, pos: &[f64; 3]) -> [f64; 3] {
        let mut acc = [0.0f64; 3];
        for i in range {
            let y = &self.bodies[i];
            let d = [y[0] - pos[0], y[1] - pos[1], y[2] - pos[2]];
            let r2 = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).max(R2_FLOOR);
            let w = self.masses[i] / r2;
            acc[0] += w * d[0];
            acc[1] += w * d[1];
            acc[2] += w * d[2];
        }
        acc
    }
}

impl BsfProblem for GravityProblem {
    fn name(&self) -> &str {
        "bsf-gravity"
    }

    fn list_len(&self) -> usize {
        self.n()
    }

    fn initial_approx(&self) -> Vec<f64> {
        vec![
            self.x0[0], self.x0[1], self.x0[2], self.v0[0], self.v0[1], self.v0[2], 0.0,
        ]
    }

    fn map_fold_into(
        &self,
        range: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        ws: &mut Workspace,
        kernels: Option<&KernelRuntime>,
    ) {
        debug_assert_eq!(out.len(), 3, "fold buffer is the 3-vector α");
        let (pos, _v, _t) = Self::decode(x);
        out.fill(0.0);
        if range.is_empty() {
            return;
        }
        if let Some(rt) = kernels {
            if let Some(name) = rt.manifest().gravity_map() {
                let b = rt.block();
                // The probe position is a stack array borrowed directly;
                // only the 3-vector block result is workspace-staged.
                let (_, out_stage) = ws.staging(0, 3);
                let mut i0 = range.start;
                while i0 < range.end {
                    let i1 = (i0 + b).min(range.end);
                    let (y_blk, m_blk) = self.packed_block(i0, i1, b);
                    // Bound before the match: a scrutinee temporary would
                    // hold the staging borrow across the arms.
                    let res = rt.execute_into(
                        &name,
                        &[
                            TensorView::mat_cached(&y_blk, b, 3),
                            TensorView::vec_cached(&m_blk),
                            TensorView::vec_view(&pos),
                        ],
                        &mut [&mut *out_stage],
                    );
                    match res {
                        Ok(()) => {
                            out[0] += out_stage[0];
                            out[1] += out_stage[1];
                            out[2] += out_stage[2];
                        }
                        Err(_) => {
                            let a = self.native_block(i0..i1, &pos);
                            out[0] += a[0];
                            out[1] += a[1];
                            out[2] += a[2];
                        }
                    }
                    i0 = i1;
                }
                return;
            }
        }
        let a = self.native_block(range, &pos);
        out.copy_from_slice(&a);
    }

    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0; 3]
    }

    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        for (x, y) in acc.iter_mut().zip(b) {
            *x += y;
        }
    }

    fn post(&self, x: &[f64], s: &[f64], _iteration: usize) -> (Vec<f64>, bool) {
        let (pos, v, t) = Self::decode(x);
        let alpha = [s[0], s[1], s[2]];
        let v2 = v[0] * v[0] + v[1] * v[1] + v[2] * v[2];
        let a2 = alpha[0] * alpha[0] + alpha[1] * alpha[1] + alpha[2] * alpha[2];
        // Δt = η / (‖V‖²·‖α‖⁴); guard the degenerate rest state.
        let denom = (v2 * a2 * a2).max(R2_FLOOR);
        let dt = self.eta / denom;
        let v_new = [v[0] + alpha[0] * dt, v[1] + alpha[1] * dt, v[2] + alpha[2] * dt];
        let x_new = [pos[0] + v_new[0] * dt, pos[1] + v_new[1] * dt, pos[2] + v_new[2] * dt];
        let t_new = t + dt;
        let stop = t_new >= self.t_end;
        (
            vec![x_new[0], x_new[1], x_new[2], v_new[0], v_new[1], v_new[2], t_new],
            stop,
        )
    }

    fn cost_spec(&self) -> CostSpec {
        CostSpec {
            l: self.n(),
            // Actual payloads ([X|V|t] down, α up); the paper charges 3/3 —
            // the 4-word delta is ≪ L (see module docs).
            words_down: 7,
            words_up: 3,
            // paper §6: t_Map = 17·n·τ_op.
            ops_map_per_elem: 17.0,
            // t_a = 3·τ_op (3-vector add).
            ops_combine: 3.0,
            // Δt rule (13 ops) + V,X updates (12 ops) + compare.
            ops_post: 26.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_sequential, LiveRunner};
    use crate::linalg::generators::random_bodies;
    use std::sync::Arc;

    fn problem(n: usize) -> GravityProblem {
        // With ~n/10 effective |α| the Δt rule gives steps of ~1e-7 s here;
        // a 2e-6 horizon keeps the tests at tens of iterations.
        GravityProblem::new(random_bodies(n, 5.0, 42), 1e-3, 2e-6)
    }

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        p.join("manifest.json").exists().then(|| p.to_path_buf())
    }

    #[test]
    fn sequential_advances_time_to_horizon() {
        let p = problem(128);
        let r = run_sequential(&p, 10_000, None);
        assert!(r.converged, "did not reach T in {} iters", r.iterations);
        let t = r.final_approx[6];
        assert!(t >= 2e-6, "t={t}");
    }

    #[test]
    fn live_matches_sequential() {
        let seq = run_sequential(&problem(96), 10_000, None);
        for k in [1usize, 2, 5] {
            let p: Arc<dyn BsfProblem> = Arc::new(problem(96));
            let live = LiveRunner::new(k, 10_000).run(p).unwrap();
            assert_eq!(live.iterations, seq.iterations, "k={k}");
            let d: f64 = live
                .final_approx
                .iter()
                .zip(&seq.final_approx)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(d < 1e-9, "k={k}: dev {d}");
        }
    }

    #[test]
    fn acceleration_points_toward_single_attractor() {
        let w = BodyWorkload {
            bodies: vec![[10.0, 0.0, 0.0]],
            masses: vec![2.0],
            x0: [0.0; 3],
            v0: [1.0, 0.0, 0.0],
        };
        let p = GravityProblem::new(w, 1e-2, 1.0);
        let x = p.initial_approx();
        let a = p.map_fold(0..1, &x, None);
        // d = (10,0,0), r² = 100 → α = 2/100·(10,0,0) = (0.2, 0, 0)
        assert!((a[0] - 0.2).abs() < 1e-15);
        assert_eq!(&a[1..], &[0.0, 0.0]);
    }

    #[test]
    fn delta_t_rule() {
        let w = BodyWorkload {
            bodies: vec![[1.0, 0.0, 0.0]],
            masses: vec![1.0],
            x0: [0.0; 3],
            v0: [3.0, 0.0, 0.0], // ‖V‖² = 9
        };
        let p = GravityProblem::new(w, 9.0, 100.0);
        let x = p.initial_approx();
        // α = (1,0,0) → ‖α‖⁴ = 1 → Δt = 9/(9·1) = 1
        let (next, _stop) = p.post(&x, &[1.0, 0.0, 0.0], 0);
        let t_new = next[6];
        assert!((t_new - 1.0).abs() < 1e-12, "Δt={t_new}");
        // V' = (4,0,0); X' = (4,0,0)
        assert!((next[3] - 4.0).abs() < 1e-12);
        assert!((next[0] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn promotion_over_ranges() {
        let p = problem(100);
        let x = p.initial_approx();
        let full = p.map_fold(0..100, &x, None);
        let mut acc = p.fold_identity();
        for r in [0..29usize, 29..60, 60..100] {
            acc = p.combine(acc, p.map_fold(r, &x, None));
        }
        for (a, b) in acc.iter().zip(&full) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn cost_spec_matches_paper() {
        let cs = problem(300).cost_spec();
        assert_eq!(cs.l, 300);
        assert_eq!(cs.ops_map_per_elem, 17.0);
        assert_eq!(cs.ops_combine, 3.0);
        assert_eq!(cs.words_up, 3);
    }

    #[test]
    fn kernel_path_matches_native_when_artifacts_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = KernelRuntime::open(dir).unwrap();
        let p = problem(300); // forces a partial final block (300 = 256+44)
        let x = p.initial_approx();
        for r in [0..300usize, 0..256, 100..300, 10..50] {
            let native = p.map_fold(r.clone(), &x, None);
            let kernel = p.map_fold(r.clone(), &x, Some(&rt));
            for (a, b) in native.iter().zip(&kernel) {
                assert!((a - b).abs() < 1e-9, "range {r:?}");
            }
        }
    }
}
