//! BSF-Jacobi (paper §5, Algorithms 3–4).
//!
//! The Jacobi iteration `x' = Cx + d` specified on lists: the list is
//! `G = [1..n]`, the Map is `F_x(j) = x_j · c_j` (eq. 16), the fold is
//! vector addition, and the master's Compute/StopCond are `x' = s + d`
//! and `‖x' − x‖² < ε` (Algorithm 3 steps 5/7).
//!
//! A worker's sublist folding is the column-block matvec
//! `C[:, range] @ x[range]`, executed through the AOT Pallas kernel
//! (`jacobi_map_n{n}`, block width B) when an artifact for this `n`
//! exists, and through [`Matrix::col_block_matvec_acc`] natively
//! otherwise. Padding with zero columns is exact (tested in
//! `python/tests` and here).
//!
//! Analytic cost parameters (eqs. 17–23): `c_c = 2n`, `c_Map = n²`
//! (`n` ops per element), `c_a = n`.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::RwLock;

use crate::coordinator::{BsfProblem, CostSpec, Workspace};
use crate::linalg::generators::LinearSystem;
use crate::linalg::{sq_norm2, sub, Matrix};
use crate::runtime::{KernelRuntime, TensorView};

/// The BSF-Jacobi problem over a linear system.
#[derive(Debug)]
pub struct JacobiProblem {
    sys: LinearSystem,
    /// Termination threshold ε on `‖x' − x‖²`.
    pub epsilon: f64,
    /// Packed `(n, B)` column blocks for the kernel path, keyed by
    /// `(j0, j1, B)`. The blocks are iteration-invariant, so each worker
    /// packs its blocks once and replays them every iteration — without
    /// this cache the hot path spends more time copying the matrix than
    /// multiplying it (see EXPERIMENTS.md §Perf). `RwLock` so the
    /// steady-state path (every iteration after the first) is a shared
    /// read; packing happens *outside* any lock, so first-iteration
    /// workers pack their disjoint blocks concurrently instead of
    /// convoying on a global mutex.
    block_cache: RwLock<HashMap<(usize, usize, usize), std::sync::Arc<Vec<f64>>>>,
}

impl JacobiProblem {
    /// Wrap a linear system (see [`crate::linalg::generators`]).
    pub fn new(sys: LinearSystem, epsilon: f64) -> JacobiProblem {
        JacobiProblem { sys, epsilon, block_cache: RwLock::new(HashMap::new()) }
    }

    /// Packed column block `C[:, j0..j1]` padded to `b` columns, cached.
    ///
    /// Fast path: a shared read lock (concurrent across workers). On a
    /// miss the block is packed with *no* lock held — two workers racing
    /// on the same key pack it twice and the first insert wins, which is
    /// cheaper than serialising every worker's distinct first-iteration
    /// packing behind one global lock.
    ///
    /// Public so the allocation audit (`benches/coordinator_hotpath.rs`)
    /// can pin the cache-hit path: a warm call must be a read-lock +
    /// `Arc` clone, never a pack.
    pub fn packed_block(&self, j0: usize, j1: usize, b: usize) -> std::sync::Arc<Vec<f64>> {
        let key = (j0, j1, b);
        if let Some(hit) = self.block_cache.read().expect("block cache poisoned").get(&key) {
            return hit.clone();
        }
        let blk = std::sync::Arc::new(self.sys.c.col_block_padded(j0, j1, b));
        self.block_cache
            .write()
            .expect("block cache poisoned")
            .entry(key)
            .or_insert(blk)
            .clone()
    }

    /// Dimension n.
    pub fn n(&self) -> usize {
        self.sys.n()
    }

    /// The underlying system (residual checks in tests/examples).
    pub fn system(&self) -> &LinearSystem {
        &self.sys
    }

    /// Iteration matrix C (used by the fused sequential path).
    pub fn c(&self) -> &Matrix {
        &self.sys.c
    }
}

impl BsfProblem for JacobiProblem {
    fn name(&self) -> &str {
        "bsf-jacobi"
    }

    fn list_len(&self) -> usize {
        self.n()
    }

    fn initial_approx(&self) -> Vec<f64> {
        // Algorithm 3 step 2: x⁽⁰⁾ := d.
        self.sys.d.clone()
    }

    /// Kernel-backed column-block matvec over `range`, in blocks of the
    /// artifact's width B; falls back to native when no artifact matches n.
    /// Both paths write straight into `out` with zero steady-state
    /// allocations: the kernel path stages its padded x-blocks and block
    /// results in the caller's [`Workspace`] and hands the runtime
    /// borrowed [`TensorView`]s (the packed matrix blocks stay `Arc`-
    /// cached and device-buffer cacheable).
    fn map_fold_into(
        &self,
        range: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        ws: &mut Workspace,
        kernels: Option<&KernelRuntime>,
    ) {
        let n = self.n();
        debug_assert_eq!(out.len(), n, "fold buffer sized to n");
        out.fill(0.0);
        if range.is_empty() {
            return;
        }
        if let Some(rt) = kernels {
            if let Some(name) = rt.manifest().jacobi_map(n) {
                let b = rt.block();
                let (x_stage, out_stage) = ws.staging(b, n);
                let mut j0 = range.start;
                while j0 < range.end {
                    let j1 = (j0 + b).min(range.end);
                    let c_blk = self.packed_block(j0, j1, b);
                    x_stage[..j1 - j0].copy_from_slice(&x[j0..j1]);
                    x_stage[j1 - j0..].fill(0.0);
                    // Bound before the match: a scrutinee temporary would
                    // hold the staging borrow across the arms.
                    let res = rt.execute_into(
                        &name,
                        &[
                            TensorView::mat_cached(&c_blk, n, b),
                            TensorView::vec_view(x_stage),
                        ],
                        &mut [&mut *out_stage],
                    );
                    match res {
                        Ok(()) => {
                            for (a, v) in out.iter_mut().zip(out_stage.iter()) {
                                *a += v;
                            }
                        }
                        Err(_) => {
                            // Artifact mismatch mid-run: fall back natively
                            // for this block (keeps the iteration correct).
                            self.sys.c.col_block_matvec_acc(j0, j1, &x[j0..j1], out);
                        }
                    }
                    j0 = j1;
                }
                return;
            }
        }
        self.sys.c.col_block_matvec_acc(range.start, range.end, &x[range], out);
    }

    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0; self.n()]
    }

    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        for (x, y) in acc.iter_mut().zip(b) {
            *x += y;
        }
    }

    fn post(&self, x: &[f64], s: &[f64], _iteration: usize) -> (Vec<f64>, bool) {
        // x' = s + d; stop when ‖x' − x‖² < ε.
        let next: Vec<f64> = s.iter().zip(&self.sys.d).map(|(si, di)| si + di).collect();
        let stop = sq_norm2(&sub(&next, x)) < self.epsilon;
        (next, stop)
    }

    fn cost_spec(&self) -> CostSpec {
        let n = self.n();
        CostSpec {
            l: n,
            words_down: n,
            words_up: n,
            // eq. (18): c_Map = n² ⇒ n ops per list element.
            ops_map_per_elem: n as f64,
            // eq. (19): c_a = n.
            ops_combine: n as f64,
            // x' = s + d (n adds) + ‖x'−x‖² (3n ops) + compare.
            ops_post: 4.0 * n as f64 + 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_sequential, LiveRunner};
    use crate::linalg::generators::{dominant_system, paper_system};
    use std::sync::Arc;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        p.join("manifest.json").exists().then(|| p.to_path_buf())
    }

    #[test]
    fn sequential_converges_on_dominant_system() {
        let p = JacobiProblem::new(dominant_system(64), 1e-24);
        let r = run_sequential(&p, 500, None);
        assert!(r.converged);
        let err: f64 = r.final_approx.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10, "max err {err}");
        assert!(p.system().residual(&r.final_approx) < 1e-8);
    }

    #[test]
    fn live_matches_sequential_bitwise_shape() {
        let seq = run_sequential(&JacobiProblem::new(dominant_system(96), 1e-24), 500, None);
        for k in [1usize, 3, 8] {
            let p: Arc<dyn BsfProblem> = Arc::new(JacobiProblem::new(dominant_system(96), 1e-24));
            let live = LiveRunner::new(k, 500).run(p).unwrap();
            assert!(live.converged, "k={k}");
            assert_eq!(live.iterations, seq.iterations, "k={k}");
            let d: f64 = live
                .final_approx
                .iter()
                .zip(&seq.final_approx)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(d < 1e-12, "k={k}: dev {d}");
        }
    }

    #[test]
    fn map_fold_partials_satisfy_promotion() {
        let p = JacobiProblem::new(paper_system(50), 1e-12);
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.37).sin()).collect();
        let full = p.map_fold(0..50, &x, None);
        let mut acc = p.fold_identity();
        for r in [0..13usize, 13..37, 37..50] {
            acc = p.combine(acc, p.map_fold(r, &x, None));
        }
        for (a, b) in acc.iter().zip(&full) {
            assert!((a - b).abs() < 1e-12);
        }
        // full map-fold equals C x
        let cx = p.c().matvec(&x);
        for (a, b) in full.iter().zip(&cx) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_range_returns_identity() {
        let p = JacobiProblem::new(paper_system(10), 1e-12);
        let x = vec![1.0; 10];
        assert_eq!(p.map_fold(5..5, &x, None), vec![0.0; 10]);
    }

    #[test]
    fn cost_spec_matches_paper_eqs() {
        let p = JacobiProblem::new(paper_system(100), 1e-12);
        let cs = p.cost_spec();
        assert_eq!(cs.l, 100);
        assert_eq!(cs.words_down, 100); // c_c = 2n total
        assert_eq!(cs.words_up, 100);
        assert_eq!(cs.ops_map_per_elem, 100.0); // c_Map = n²
        assert_eq!(cs.ops_combine, 100.0); // c_a = n
    }

    /// eq. (24) reproduced through the generic machinery: plugging the
    /// Jacobi CostSpec into the closed form must equal the paper's
    /// specialised K_BSF-Jacobi equation.
    #[test]
    fn k_bsf_jacobi_closed_form_eq24() {
        let n = 10_000usize;
        let tau_op = 1e-9;
        let net = crate::net::NetworkParams {
            latency: 1.5e-5,
            tau_tr: 9.13e-8,
            link: crate::net::LinkMode::PerEdge,
        };
        let p = JacobiProblem::new(paper_system(64), 1e-12); // system size irrelevant here
        let mut cs = p.cost_spec();
        // rescale the spec to dimension n analytically
        cs.l = n;
        cs.words_down = n;
        cs.words_up = n;
        cs.ops_map_per_elem = n as f64;
        cs.ops_combine = n as f64;
        let params = cs.cost_params(tau_op, &net);
        let k_generic = crate::model::BsfModel::new(params).k_bsf();
        // Paper's specialised eq. (24) (exact-root form; see model::bsf):
        // K = 1/2 sqrt(c² + 4(n + n)) − c/2 with c = (nτ_tr + L)·2/(n τ_op ln2)
        let c = 2.0 * (n as f64 * net.tau_tr + net.latency)
            / (n as f64 * tau_op * std::f64::consts::LN_2);
        let k_eq24 = 0.5 * (c * c + 4.0 * (n as f64 + n as f64)).sqrt() - 0.5 * c;
        assert!(
            (k_generic - k_eq24).abs() < 1e-9,
            "generic={k_generic} eq24={k_eq24}"
        );
        // and the asymptotic law: K ≈ O(√n)
        assert!((k_eq24 / (n as f64).sqrt() - 1.0).abs() < 0.5);
    }

    #[test]
    fn kernel_path_matches_native_when_artifacts_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = KernelRuntime::open(dir).unwrap();
        let n = 256;
        let p = JacobiProblem::new(paper_system(n), 1e-12);
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).cos()).collect();
        // ranges that exercise partial blocks and multi-block spans
        for r in [0..n, 0..100usize, 100..256, 17..250] {
            let native = p.map_fold(r.clone(), &x, None);
            let kernel = p.map_fold(r.clone(), &x, Some(&rt));
            let d: f64 = native
                .iter()
                .zip(&kernel)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(d < 1e-9, "range {r:?}: dev {d}");
        }
    }
}
