//! The paper's BSF applications, each an implementation of
//! [`crate::coordinator::BsfProblem`]:
//!
//! * [`JacobiProblem`] — §5's BSF-Jacobi: `x' = Cx + d` with the Map
//!   `F_x(j) = x_j·c_j` over the column list, fold = vector addition
//!   (eqs. 16–24).
//! * [`GravityProblem`] — §6's BSF-Gravity: the simplified n-body problem,
//!   Map = per-body gravitational acceleration (eq. 35), fold = 3-vector
//!   addition (eq. 36).
//! * [`CimminoProblem`] — the non-stationary linear-inequalities solver of
//!   paper ref [31]: Map = per-row projection correction, fold = vector
//!   addition.
//! * [`MonteCarloPi`] — a Map-only algorithm (§7 Q2, ref [33]): `t_a ≈ 0`,
//!   exercising the model outside the closed-form's `t_a > 0` assumption.
//!
//! Every problem provides: a kernel-backed `map_fold_into` (PJRT artifacts
//! from the L1 Pallas kernels, with a bit-compatible native-Rust fallback
//! for sizes without artifacts) that writes into the caller's buffer with
//! zero steady-state allocations on **both** paths — the kernel path
//! stages its per-iteration blocks in the caller's
//! [`crate::coordinator::Workspace`] and hands the runtime borrowed
//! [`crate::runtime::TensorView`]s — plus the paper's analytic
//! [`CostSpec`] and a sequential reference implementation used by the
//! test suite.
//!
//! [`CostSpec`]: crate::coordinator::CostSpec

mod cimmino;
mod gravity;
mod jacobi;
mod montecarlo;

pub use cimmino::{CimminoProblem, NonStationaryCimmino};
pub use gravity::GravityProblem;
pub use jacobi::JacobiProblem;
pub use montecarlo::MonteCarloPi;
