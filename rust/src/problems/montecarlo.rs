//! Map-only Monte-Carlo π estimator (§7 Q2, paper ref [33]).
//!
//! Demonstrates the BSF model on an algorithm whose `⊕` is effectively
//! free: the list is `l` sample *strata*, the Map of stratum `j` at
//! iteration `i` draws `samples_per_item` quasi-random points and counts
//! hits inside the unit quarter-circle; the fold is scalar addition
//! (`t_a ≈ 0`, so the closed-form boundary does not apply and
//! [`crate::model::BsfModel::k_bsf_numeric`] must be used — exactly the
//! §7-Q2 discussion).
//!
//! The iteration refines a running estimate: `x' = (i·x + π̂_i)/(i+1)`
//! (streaming mean of per-iteration estimates), stopping when the update
//! changes the estimate by less than ε or at the iteration cap.
//!
//! Downlink encoding: `[estimate, iteration]`; uplink: `[hits]`.

use std::ops::Range;

use crate::coordinator::{BsfProblem, CostSpec, Workspace};
use crate::runtime::KernelRuntime;
use crate::util::Rng;

/// Map-only Monte-Carlo π estimation.
#[derive(Debug)]
pub struct MonteCarloPi {
    /// Number of strata (the list length `l`).
    pub strata: usize,
    /// Points drawn per stratum per iteration.
    pub samples_per_item: usize,
    /// Stop when `|x' − x| < ε`.
    pub epsilon: f64,
    /// Base seed (per-stratum streams are derived deterministically).
    pub seed: u64,
}

impl MonteCarloPi {
    /// Construct with the given sampling plan.
    pub fn new(strata: usize, samples_per_item: usize, epsilon: f64, seed: u64) -> MonteCarloPi {
        MonteCarloPi { strata, samples_per_item, epsilon, seed }
    }

    fn hits_for(&self, stratum: usize, iteration: u64) -> u64 {
        // Independent deterministic stream per (stratum, iteration).
        let mut rng = Rng::new(
            self.seed ^ (stratum as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ iteration.wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let mut hits = 0u64;
        for _ in 0..self.samples_per_item {
            let x = rng.uniform();
            let y = rng.uniform();
            if x * x + y * y <= 1.0 {
                hits += 1;
            }
        }
        hits
    }
}

impl BsfProblem for MonteCarloPi {
    fn name(&self) -> &str {
        "monte-carlo-pi"
    }

    fn list_len(&self) -> usize {
        self.strata
    }

    fn initial_approx(&self) -> Vec<f64> {
        vec![0.0, 0.0] // [estimate, iteration]
    }

    fn map_fold_into(
        &self,
        range: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        _ws: &mut Workspace,
        _kernels: Option<&KernelRuntime>,
    ) {
        debug_assert_eq!(out.len(), 1, "fold buffer is the scalar hit count");
        let iteration = x[1] as u64;
        let hits: u64 = range.map(|j| self.hits_for(j, iteration)).sum();
        out[0] = hits as f64;
    }

    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0]
    }

    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        acc[0] += b[0];
    }

    fn post(&self, x: &[f64], s: &[f64], iteration: usize) -> (Vec<f64>, bool) {
        let total = (self.strata * self.samples_per_item) as f64;
        let pi_i = 4.0 * s[0] / total;
        let i = iteration as f64;
        let next = (i * x[0] + pi_i) / (i + 1.0);
        let stop = iteration > 0 && (next - x[0]).abs() < self.epsilon;
        (vec![next, (iteration + 1) as f64], stop)
    }

    fn cost_spec(&self) -> CostSpec {
        CostSpec {
            l: self.strata,
            words_down: 2,
            words_up: 1,
            // per stratum: samples × (2 draws + 3 mults + compare) ≈ 6 ops
            ops_map_per_elem: 6.0 * self.samples_per_item as f64,
            // scalar add — the t_a ≈ 0 regime.
            ops_combine: 1.0,
            ops_post: 6.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_sequential, LiveRunner};
    use std::sync::Arc;

    fn problem() -> MonteCarloPi {
        MonteCarloPi::new(512, 64, 1e-5, 0xC0FFEE)
    }

    #[test]
    fn estimates_pi() {
        let p = problem();
        let r = run_sequential(&p, 200, None);
        let pi = r.final_approx[0];
        assert!((pi - std::f64::consts::PI).abs() < 0.02, "π̂ = {pi}");
    }

    #[test]
    fn live_matches_sequential_exactly() {
        // Deterministic per-(stratum, iteration) streams ⇒ the parallel
        // run must produce the *same* estimate bit-for-bit.
        let seq = run_sequential(&problem(), 50, None);
        for k in [2usize, 5] {
            let p: Arc<dyn BsfProblem> = Arc::new(problem());
            let live = LiveRunner::new(k, 50).run(p).unwrap();
            assert_eq!(live.iterations, seq.iterations);
            assert_eq!(live.final_approx[0].to_bits(), seq.final_approx[0].to_bits(), "k={k}");
        }
    }

    #[test]
    fn map_only_cost_spec_has_tiny_combine() {
        let cs = problem().cost_spec();
        assert_eq!(cs.ops_combine, 1.0);
        // the numeric boundary path must be used (closed form asserts t_a>0)
        let params = cs.cost_params(1e-9, &crate::net::NetworkParams::tornado_susu());
        let m = crate::model::BsfModel::new(params);
        let k = m.k_bsf_numeric(4_096);
        assert!(k >= 1);
    }

    #[test]
    fn stratum_streams_differ() {
        let p = problem();
        let a = p.hits_for(0, 0);
        let b = p.hits_for(1, 0);
        let c = p.hits_for(0, 1);
        // not all equal (independent streams)
        assert!(!(a == b && b == c), "streams look identical");
    }
}
