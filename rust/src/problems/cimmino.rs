//! BSF-Cimmino: iterative solver for systems of linear inequalities
//! `A x ≤ b` (paper ref [31] — the author's companion application of the
//! BSF model; the method is a Cimmino-style simultaneous-projection
//! iteration).
//!
//! The list is the constraint rows; the Map over row `i` is the projection
//! correction for violated rows:
//!
//! ```text
//! F_x(i) = −(max(0, aᵢ·x − bᵢ) / ‖aᵢ‖²) · aᵢ
//! ```
//!
//! the fold is n-vector addition, and the master applies the relaxed
//! update `x' = x + (λ/m)·s` (mean-projection form; Fejér-monotone for
//! `0 < λ < 2`), stopping when `‖x' − x‖² < ε` — by which point the
//! iterate satisfies every inequality to within the projection residual.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::{Arc, Mutex};

use crate::coordinator::{BsfProblem, CostSpec, Workspace};
use crate::linalg::generators::InequalitySystem;
use crate::linalg::{dot, sq_norm2, sub};
use crate::runtime::{KernelRuntime, TensorView};

/// The BSF-Cimmino problem.
#[derive(Debug)]
pub struct CimminoProblem {
    sys: InequalitySystem,
    /// Relaxation λ ∈ (0, 2).
    pub lambda: f64,
    /// Termination threshold ε on `‖x' − x‖²`.
    pub epsilon: f64,
    /// Packed `(B,n)` row blocks + rhs for the kernel path, keyed by
    /// `(i0, i1, B)` — iteration-invariant (see EXPERIMENTS.md §Perf).
    block_cache: Mutex<HashMap<(usize, usize, usize), (Arc<Vec<f64>>, Arc<Vec<f64>>)>>,
}

impl CimminoProblem {
    /// Wrap an inequality system.
    pub fn new(sys: InequalitySystem, lambda: f64, epsilon: f64) -> CimminoProblem {
        assert!(lambda > 0.0 && lambda < 2.0, "λ must be in (0,2)");
        CimminoProblem { sys, lambda, epsilon, block_cache: Mutex::new(HashMap::new()) }
    }

    /// Packed `(a_blk, b_blk)` for rows `i0..i1`, zero-padded to `b` rows,
    /// cached.
    fn packed_block(&self, i0: usize, i1: usize, b: usize) -> (Arc<Vec<f64>>, Arc<Vec<f64>>) {
        let n = self.n();
        let mut cache = self.block_cache.lock().expect("block cache poisoned");
        cache
            .entry((i0, i1, b))
            .or_insert_with(|| {
                let mut a_blk = vec![0.0; b * n];
                let mut b_blk = vec![0.0; b];
                for (slot, i) in (i0..i1).enumerate() {
                    a_blk[slot * n..(slot + 1) * n].copy_from_slice(self.sys.a.row(i));
                    b_blk[slot] = self.sys.b[i];
                }
                (Arc::new(a_blk), Arc::new(b_blk))
            })
            .clone()
    }

    /// Rows m.
    pub fn m(&self) -> usize {
        self.sys.b.len()
    }

    /// Columns n.
    pub fn n(&self) -> usize {
        self.sys.a.cols()
    }

    /// The underlying system.
    pub fn system(&self) -> &InequalitySystem {
        &self.sys
    }

    /// Count of violated rows at `x` (solution-quality check).
    pub fn violated(&self, x: &[f64], tol: f64) -> usize {
        (0..self.m())
            .filter(|&i| dot(self.sys.a.row(i), x) > self.sys.b[i] + tol)
            .count()
    }

    /// Accumulate the projection corrections for `range` into `acc`
    /// (caller zeroes; allocation-free).
    fn native_block_acc(&self, range: Range<usize>, x: &[f64], acc: &mut [f64]) {
        for i in range {
            let row = self.sys.a.row(i);
            let resid = dot(row, x) - self.sys.b[i];
            if resid > 0.0 {
                let nrm2 = sq_norm2(row);
                if nrm2 > 0.0 {
                    let w = resid / nrm2;
                    for (a, r) in acc.iter_mut().zip(row) {
                        *a -= w * r;
                    }
                }
            }
        }
    }
}

impl BsfProblem for CimminoProblem {
    fn name(&self) -> &str {
        "bsf-cimmino"
    }

    fn list_len(&self) -> usize {
        self.m()
    }

    fn initial_approx(&self) -> Vec<f64> {
        self.sys.x0.clone()
    }

    fn map_fold_into(
        &self,
        range: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        ws: &mut Workspace,
        kernels: Option<&KernelRuntime>,
    ) {
        let n = self.n();
        debug_assert_eq!(out.len(), n, "fold buffer sized to n");
        out.fill(0.0);
        if range.is_empty() {
            return;
        }
        if let Some(rt) = kernels {
            if let Some(name) = rt.manifest().cimmino_map(n) {
                let b = rt.block();
                // x is already the exact kernel input — borrowed directly;
                // only the block result needs a staging buffer.
                let (_, out_stage) = ws.staging(0, n);
                let mut i0 = range.start;
                while i0 < range.end {
                    let i1 = (i0 + b).min(range.end);
                    let (a_blk, b_blk) = self.packed_block(i0, i1, b);
                    // Bound before the match: a scrutinee temporary would
                    // hold the staging borrow across the arms.
                    let res = rt.execute_into(
                        &name,
                        &[
                            TensorView::mat_cached(&a_blk, b, n),
                            TensorView::vec_cached(&b_blk),
                            TensorView::vec_view(x),
                        ],
                        &mut [&mut *out_stage],
                    );
                    match res {
                        Ok(()) => {
                            for (a, v) in out.iter_mut().zip(out_stage.iter()) {
                                *a += v;
                            }
                        }
                        Err(_) => {
                            self.native_block_acc(i0..i1, x, out);
                        }
                    }
                    i0 = i1;
                }
                return;
            }
        }
        self.native_block_acc(range, x, out);
    }

    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0; self.n()]
    }

    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        for (x, y) in acc.iter_mut().zip(b) {
            *x += y;
        }
    }

    fn post(&self, x: &[f64], s: &[f64], _iteration: usize) -> (Vec<f64>, bool) {
        let scale = self.lambda / self.m() as f64;
        let next: Vec<f64> = x.iter().zip(s).map(|(xi, si)| xi + scale * si).collect();
        let stop = sq_norm2(&sub(&next, x)) < self.epsilon;
        (next, stop)
    }

    fn cost_spec(&self) -> CostSpec {
        let n = self.n();
        CostSpec {
            l: self.m(),
            words_down: n,
            words_up: n,
            // per row: dot (2n) + residual + norm (2n) + scale-add (2n).
            ops_map_per_elem: 6.0 * n as f64 + 2.0,
            ops_combine: n as f64,
            // x' = x + λ/m·s (2n) + ‖x'−x‖² (3n) + compare.
            ops_post: 5.0 * n as f64 + 2.0,
        }
    }
}

/// Non-stationary BSF-Cimmino (the actual subject of paper ref [31]):
/// the right-hand side drifts over iterations, `b(t) = b + t·δ`, and the
/// iterate must *track* the moving feasible region rather than converge.
///
/// Downlink encoding: `[x (n) | t]`. The Map for row `i` at time `t` is the
/// projection correction against `b_i + t·δ_i`; the fold is unchanged. The
/// kernel path reuses the `cimmino_map` artifact with an ephemeral shifted
/// `b`-block (the `A` blocks stay cached). There is no StopCond in the
/// stationary sense — the run ends at the horizon `t_end`, and solution
/// quality is the violation count against the *current* b(t).
#[derive(Debug)]
pub struct NonStationaryCimmino {
    inner: CimminoProblem,
    /// Per-row drift rate δ (b changes by δ each iteration).
    pub drift: Vec<f64>,
    /// Iterations to run (the tracking horizon).
    pub horizon: usize,
}

impl NonStationaryCimmino {
    /// Wrap a stationary problem with a drift vector.
    pub fn new(inner: CimminoProblem, drift: Vec<f64>, horizon: usize) -> NonStationaryCimmino {
        assert_eq!(drift.len(), inner.m(), "one drift rate per row");
        NonStationaryCimmino { inner, drift, horizon }
    }

    /// The shifted right-hand side at time `t`.
    pub fn b_at(&self, t: f64) -> Vec<f64> {
        self.inner.sys.b.iter().zip(&self.drift).map(|(b, d)| b + t * d).collect()
    }

    /// Violations of the *current* constraints at `[x|t]`.
    pub fn violated_now(&self, approx: &[f64], tol: f64) -> usize {
        let n = self.inner.n();
        let (x, t) = (&approx[..n], approx[n]);
        let b = self.b_at(t);
        (0..self.inner.m())
            .filter(|&i| dot(self.inner.sys.a.row(i), x) > b[i] + tol)
            .count()
    }
}

impl BsfProblem for NonStationaryCimmino {
    fn name(&self) -> &str {
        "bsf-cimmino-nonstationary"
    }

    fn list_len(&self) -> usize {
        self.inner.m()
    }

    fn initial_approx(&self) -> Vec<f64> {
        let mut x = self.inner.sys.x0.clone();
        x.push(0.0); // t
        x
    }

    fn map_fold_into(
        &self,
        range: Range<usize>,
        approx: &[f64],
        out: &mut [f64],
        ws: &mut Workspace,
        kernels: Option<&KernelRuntime>,
    ) {
        let n = self.inner.n();
        debug_assert_eq!(out.len(), n, "fold buffer sized to n");
        let (x, t) = (&approx[..n], approx[n]);
        out.fill(0.0);
        if range.is_empty() {
            return;
        }
        if let Some(rt) = kernels {
            if let Some(name) = rt.manifest().cimmino_map(n) {
                let bw = rt.block();
                // The drift-shifted b-block changes every iteration: it is
                // staged in the workspace and borrowed by the runtime (the
                // cached `A` blocks stay shared) — no per-block buffers.
                let (b_stage, out_stage) = ws.staging(bw, n);
                let mut i0 = range.start;
                while i0 < range.end {
                    let i1 = (i0 + bw).min(range.end);
                    let (a_blk, _) = self.inner.packed_block(i0, i1, bw);
                    for (slot, i) in (i0..i1).enumerate() {
                        b_stage[slot] = self.inner.sys.b[i] + t * self.drift[i];
                    }
                    b_stage[i1 - i0..].fill(0.0);
                    // Bound before the match: a scrutinee temporary would
                    // hold the staging borrow across the arms.
                    let res = rt.execute_into(
                        &name,
                        &[
                            TensorView::mat_cached(&a_blk, bw, n),
                            TensorView::vec_view(b_stage),
                            TensorView::vec_view(x),
                        ],
                        &mut [&mut *out_stage],
                    );
                    if res.is_ok() {
                        for (a, v) in out.iter_mut().zip(out_stage.iter()) {
                            *a += v;
                        }
                    } else {
                        self.native_shifted_acc(i0..i1, x, t, out);
                    }
                    i0 = i1;
                }
                return;
            }
        }
        self.native_shifted_acc(range, x, t, out);
    }

    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0; self.inner.n()]
    }

    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        for (x, y) in acc.iter_mut().zip(b) {
            *x += y;
        }
    }

    fn post(&self, approx: &[f64], s: &[f64], iteration: usize) -> (Vec<f64>, bool) {
        let n = self.inner.n();
        let scale = self.inner.lambda / self.inner.m() as f64;
        let mut next: Vec<f64> =
            approx[..n].iter().zip(s).map(|(xi, si)| xi + scale * si).collect();
        next.push((iteration + 1) as f64); // advance t
        let stop = iteration + 1 >= self.horizon;
        (next, stop)
    }

    fn cost_spec(&self) -> CostSpec {
        self.inner.cost_spec()
    }
}

impl NonStationaryCimmino {
    /// Accumulate the drift-shifted projection corrections for `range`
    /// into `acc` (caller zeroes; allocation-free).
    fn native_shifted_acc(&self, range: Range<usize>, x: &[f64], t: f64, acc: &mut [f64]) {
        for i in range {
            let row = self.inner.sys.a.row(i);
            let resid = dot(row, x) - (self.inner.sys.b[i] + t * self.drift[i]);
            if resid > 0.0 {
                let nrm2 = sq_norm2(row);
                if nrm2 > 0.0 {
                    let w = resid / nrm2;
                    for (a, r) in acc.iter_mut().zip(row) {
                        *a -= w * r;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_sequential, LiveRunner};
    use crate::linalg::generators::feasible_inequalities;
    use std::sync::Arc;

    fn problem(m: usize, n: usize) -> CimminoProblem {
        CimminoProblem::new(feasible_inequalities(m, n, 0.1, 7), 1.5, 1e-20)
    }

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        p.join("manifest.json").exists().then(|| p.to_path_buf())
    }

    #[test]
    fn sequential_reaches_feasibility() {
        let p = problem(200, 16);
        let start_viol = p.violated(&p.initial_approx(), 1e-9);
        assert!(start_viol > 0);
        let r = run_sequential(&p, 20_000, None);
        assert!(r.converged, "no convergence in {} iters", r.iterations);
        assert_eq!(p.violated(&r.final_approx, 1e-6), 0, "still infeasible");
    }

    #[test]
    fn live_matches_sequential() {
        let seq = run_sequential(&problem(120, 8), 20_000, None);
        for k in [1usize, 4] {
            let p: Arc<dyn BsfProblem> = Arc::new(problem(120, 8));
            let live = LiveRunner::new(k, 20_000).run(p).unwrap();
            assert_eq!(live.iterations, seq.iterations, "k={k}");
            let d: f64 = live
                .final_approx
                .iter()
                .zip(&seq.final_approx)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(d < 1e-10, "k={k}: dev {d}");
        }
    }

    #[test]
    fn satisfied_system_stops_immediately() {
        let mut sys = feasible_inequalities(50, 8, 0.1, 3);
        sys.x0 = sys.interior.clone(); // start feasible
        let p = CimminoProblem::new(sys, 1.0, 1e-20);
        let r = run_sequential(&p, 10, None);
        assert_eq!(r.iterations, 1);
        assert!(r.converged);
    }

    #[test]
    fn promotion_over_ranges() {
        let p = problem(100, 12);
        let x = p.initial_approx();
        let full = p.map_fold(0..100, &x, None);
        let mut acc = p.fold_identity();
        for r in [0..40usize, 40..77, 77..100] {
            acc = p.combine(acc, p.map_fold(r, &x, None));
        }
        for (a, b) in acc.iter().zip(&full) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "λ must be in (0,2)")]
    fn lambda_range_checked() {
        CimminoProblem::new(feasible_inequalities(10, 4, 0.1, 1), 2.5, 1e-12);
    }

    #[test]
    fn nonstationary_tracks_drifting_feasible_region() {
        // Slow drift: after an initial settling phase the iterate keeps the
        // violation count low against the *moving* constraints.
        let m = 150;
        let base = problem(m, 12);
        let drift = vec![1e-3; m]; // constraints loosen slowly
        let ns = NonStationaryCimmino::new(base, drift, 400);
        let r = crate::coordinator::run_sequential(&ns, 1_000, None);
        assert!(r.converged, "must stop at the horizon");
        assert_eq!(r.iterations, 400);
        assert_eq!(r.final_approx.len(), 13); // [x | t]
        assert_eq!(r.final_approx[12], 400.0);
        let viol = ns.violated_now(&r.final_approx, 1e-6);
        assert!(viol <= m / 20, "tracking lost: {viol} violations");
    }

    #[test]
    fn nonstationary_live_matches_sequential() {
        use std::sync::Arc;
        let mk = || {
            NonStationaryCimmino::new(problem(120, 8), vec![5e-4; 120], 100)
        };
        let seq = crate::coordinator::run_sequential(&mk(), 1_000, None);
        let live = crate::coordinator::LiveRunner::new(4, 1_000)
            .run(Arc::new(mk()) as Arc<dyn BsfProblem>)
            .unwrap();
        assert_eq!(live.iterations, seq.iterations);
        let d: f64 = live
            .final_approx
            .iter()
            .zip(&seq.final_approx)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(d < 1e-10, "dev {d}");
    }

    #[test]
    fn nonstationary_b_shifts_linearly() {
        let ns = NonStationaryCimmino::new(problem(10, 4), (0..10).map(|i| i as f64).collect(), 5);
        let b0 = ns.b_at(0.0);
        let b2 = ns.b_at(2.0);
        for i in 0..10 {
            assert!((b2[i] - b0[i] - 2.0 * i as f64).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "one drift rate per row")]
    fn nonstationary_drift_len_checked() {
        NonStationaryCimmino::new(problem(10, 4), vec![0.0; 3], 5);
    }

    #[test]
    fn kernel_path_matches_native_when_artifacts_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let rt = KernelRuntime::open(dir).unwrap();
        // n must have a cimmino artifact (256); m exercises partial blocks.
        let p = problem(300, 256);
        let x = p.initial_approx();
        for r in [0..300usize, 0..256, 100..300] {
            let native = p.map_fold(r.clone(), &x, None);
            let kernel = p.map_fold(r.clone(), &x, Some(&rt));
            let d: f64 = native
                .iter()
                .zip(&kernel)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(d < 1e-9, "range {r:?}: dev {d}");
        }
    }
}
