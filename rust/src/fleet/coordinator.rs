//! The fleet coordinator: owns the sweep grid, hands out leases on cell
//! buckets, watches heartbeats, and re-leases work whose owner went
//! silent or dropped its socket.
//!
//! ## Failure semantics (the short version; PERF.md has the contract)
//!
//! * Every cell is a pure function of `(job, K)` — the coordinator
//!   **never re-seeds**, so re-executing a cell anywhere yields the same
//!   bits. Duplicate completions are last-write-wins and harmless.
//! * A missed deadline *re-leases* the batch; it does not invalidate the
//!   original owner. A late/stale `Done` is still recorded — progress is
//!   monotone even under an expiry storm of false positives.
//! * Death (socket EOF/reset) and expiry (silent hang) converge on the
//!   same requeue path; only the counters differ.
//!
//! The final result table is therefore bitwise identical to the serial
//! sweep no matter how many workers died, re-joined, or raced — pinned by
//! `rust/tests/fleet.rs` and the CI fleet-smoke job.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::experiments::{cell_groups, flat_cells, SweepJob};

use super::lease::{est_cell_seconds, LeaseBook, WorkerStats};
use super::proto::{write_msg, Msg, MsgReader};
use super::FleetGrid;

/// Coordinator tuning knobs. Defaults are deliberately loose — false
/// expiries are bitwise-harmless but waste work, so production leans
/// patient; the chaos tests tighten these to force the failure paths.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Expected worker heartbeat interval.
    pub heartbeat: Duration,
    /// Heartbeats a worker may miss before its lease expires.
    pub grace: u32,
    /// Floor on every lease deadline (initial and refreshed) — absorbs
    /// debug-build and CI timing noise.
    pub min_deadline: Duration,
    /// Multiplier on the a-priori lease cost estimate when setting the
    /// initial deadline.
    pub safety: f64,
    /// Target wall time per lease; with throughput history the
    /// coordinator sizes batches to roughly this.
    pub lease_target: Duration,
    /// Hard cap on cells per lease.
    pub max_lease_cells: usize,
    /// Bail if *nothing* happens (no message from any worker) for this
    /// long while work is incomplete — a dead fleet should fail loudly,
    /// not hang CI.
    pub idle_timeout: Duration,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            heartbeat: Duration::from_millis(200),
            grace: 10,
            min_deadline: Duration::from_secs(5),
            safety: 20.0,
            lease_target: Duration::from_millis(500),
            max_lease_cells: 16,
            idle_timeout: Duration::from_secs(120),
        }
    }
}

/// What happened during a fleet run — the observability half of the
/// fault-tolerance contract (the chaos tests assert on these).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FleetReport {
    /// Workers that completed the hello handshake.
    pub workers_joined: usize,
    /// Leases handed out (including re-leases).
    pub leases_issued: usize,
    /// Batches put back on the queue (expiry + death combined).
    pub releases: usize,
    /// Re-leases triggered by a missed deadline specifically.
    pub leases_expired: usize,
    /// Workers lost to a dead socket mid-run.
    pub worker_deaths: usize,
    /// Cell results that overwrote an already-recorded result.
    pub duplicate_completions: usize,
    /// Duplicate completions whose bits disagreed with the recorded value
    /// — **must stay 0**; anything else means determinism is broken.
    pub duplicate_mismatches: usize,
    /// Total cells in the grid.
    pub cells: usize,
    /// Cells queued for re-execution by the releases above.
    pub re_executed_cells: usize,
}

/// Queue + results state for one grid. Pure bookkeeping (no I/O), so the
/// scheduling decisions are unit-testable without sockets.
struct GridState {
    groups: Vec<Vec<usize>>,
    cell_est: Vec<f64>,
    /// Result bits per flat cell (`None` = not yet computed).
    times: Vec<Option<u64>>,
    queue: VecDeque<usize>,
    queued: Vec<bool>,
}

impl GridState {
    fn from_parts(groups: Vec<Vec<usize>>, cell_est: Vec<f64>) -> GridState {
        let queue: VecDeque<usize> = (0..groups.len()).collect();
        let queued = vec![true; groups.len()];
        GridState { groups, times: vec![None; cell_est.len()], cell_est, queue, queued }
    }

    fn for_grid(jobs: &[SweepJob], flat: &[(usize, usize)], groups: Vec<Vec<usize>>) -> GridState {
        let cell_est = flat
            .iter()
            .map(|&(s, i)| est_cell_seconds(jobs[s].ks[i], jobs[s].iters))
            .collect();
        GridState::from_parts(groups, cell_est)
    }

    /// A bucket's members that still lack a result.
    fn incomplete_members(&self, bucket: usize) -> Vec<usize> {
        self.groups[bucket].iter().copied().filter(|&r| self.times[r].is_none()).collect()
    }

    /// Pop buckets off the queue for one lease: keep taking while the
    /// batch stays under both the cell budget and the time target (always
    /// at least one bucket; exactly one for suspect workers). Fully
    /// completed buckets are discarded on the way. Returns
    /// `(bucket ids, per-bucket incomplete members, estimated seconds)`.
    fn take_batch(
        &mut self,
        max_cells: usize,
        target_secs: f64,
        single_bucket: bool,
    ) -> Option<(Vec<usize>, Vec<Vec<usize>>, f64)> {
        let mut ids = Vec::new();
        let mut members = Vec::new();
        let mut est = 0.0;
        let mut cells = 0usize;
        while let Some(&b) = self.queue.front() {
            let inc = self.incomplete_members(b);
            if inc.is_empty() {
                self.queue.pop_front();
                self.queued[b] = false;
                continue;
            }
            if !ids.is_empty()
                && (single_bucket || cells + inc.len() > max_cells || est >= target_secs)
            {
                break;
            }
            self.queue.pop_front();
            self.queued[b] = false;
            cells += inc.len();
            est += inc.iter().map(|&r| self.cell_est[r]).sum::<f64>();
            ids.push(b);
            members.push(inc);
        }
        (!ids.is_empty()).then_some((ids, members, est))
    }

    /// Record one cell result (last-write-wins). Returns
    /// `(was duplicate, bits disagreed)`.
    fn record(&mut self, r: usize, bits: u64) -> (bool, bool) {
        let verdict = match self.times[r] {
            Some(prev) => (true, prev != bits),
            None => (false, false),
        };
        self.times[r] = Some(bits);
        verdict
    }

    /// Put a lease's buckets back at the front of the queue (recovery
    /// work preempts fresh work). Already-queued and fully-complete
    /// buckets are skipped. Returns how many cells will be re-executed.
    fn requeue(&mut self, buckets: &[usize]) -> usize {
        let mut cells = 0;
        for &b in buckets {
            if self.queued[b] {
                continue;
            }
            let inc = self.incomplete_members(b).len();
            if inc == 0 {
                continue;
            }
            self.queue.push_front(b);
            self.queued[b] = true;
            cells += inc;
        }
        cells
    }

    fn done(&self) -> bool {
        self.times.iter().all(Option::is_some)
    }
}

enum Event {
    Joined { conn: u64, name: String, writer: TcpStream },
    Incoming { conn: u64, msg: Msg },
    Gone { conn: u64 },
}

struct WorkerHandle {
    name: String,
    writer: TcpStream,
    stats: WorkerStats,
    /// Set when this worker's lease expired; suspects get single-bucket
    /// leases until they complete one again.
    suspect: bool,
}

/// Per-connection reader: handshake, then pump messages into the event
/// channel until EOF or error.
fn reader_thread(conn: u64, stream: TcpStream, tx: mpsc::Sender<Event>) {
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => {
            let _ = tx.send(Event::Gone { conn });
            return;
        }
    };
    let mut reader = MsgReader::new(stream);
    match reader.next() {
        Ok(Some(Msg::Hello { name })) => {
            if tx.send(Event::Joined { conn, name, writer }).is_err() {
                return;
            }
        }
        _ => {
            let _ = tx.send(Event::Gone { conn });
            return;
        }
    }
    loop {
        match reader.next() {
            Ok(Some(msg)) => {
                if tx.send(Event::Incoming { conn, msg }).is_err() {
                    return;
                }
            }
            _ => {
                let _ = tx.send(Event::Gone { conn });
                return;
            }
        }
    }
}

/// Run the coordinator on an already-bound listener until the grid is
/// complete. Returns the per-cell mean iteration times (bitwise identical
/// to [`super::serial_times`]) and the run report.
pub fn serve(
    grid: &FleetGrid,
    cfg: &FleetConfig,
    listener: TcpListener,
) -> Result<(Vec<f64>, FleetReport)> {
    let jobs = grid.jobs();
    let flat = flat_cells(&jobs);
    let groups = cell_groups(&jobs, &flat);
    let mut state = GridState::for_grid(&jobs, &flat, groups);
    let mut report = FleetReport { cells: flat.len(), ..Default::default() };

    let local = listener.local_addr()?;
    let (tx, rx) = mpsc::channel::<Event>();
    let keepalive = tx.clone(); // the channel must outlive every reader
    let stop = Arc::new(AtomicBool::new(false));
    let acceptor = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            let mut next_conn: u64 = 0;
            while let Ok((stream, _)) = listener.accept() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                next_conn += 1;
                let conn = next_conn;
                let tx = tx.clone();
                thread::spawn(move || reader_thread(conn, stream, tx));
            }
        })
    };

    let mut workers: HashMap<u64, WorkerHandle> = HashMap::new();
    let mut book = LeaseBook::default();
    let heartbeat_ms = cfg.heartbeat.as_millis().max(1) as u64;
    let tick = cfg.heartbeat.clamp(Duration::from_millis(10), Duration::from_millis(100));
    let refresh_by = cfg.min_deadline.max(cfg.heartbeat * cfg.grace);
    let mut last_event = Instant::now();

    // Issue (or decline) work to an idle worker; returns false if the
    // worker's socket is dead and it should be dropped.
    let try_issue = |state: &mut GridState,
                     book: &mut LeaseBook,
                     report: &mut FleetReport,
                     w: &mut WorkerHandle,
                     conn: u64|
     -> bool {
        let max_cells = w
            .stats
            .cells_for(cfg.lease_target, cfg.max_lease_cells)
            .min(cfg.max_lease_cells)
            .max(1);
        match state.take_batch(max_cells, cfg.lease_target.as_secs_f64(), w.suspect) {
            Some((ids, members, est)) => {
                let pad = Duration::from_secs_f64(cfg.safety * est) + cfg.heartbeat * cfg.grace;
                let deadline = Instant::now() + cfg.min_deadline.max(pad);
                let lease = book.issue(conn, ids, deadline);
                report.leases_issued += 1;
                if write_msg(&mut w.writer, &Msg::Lease { id: lease.id, buckets: members })
                    .is_err()
                {
                    return false;
                }
            }
            None => {
                if write_msg(&mut w.writer, &Msg::Wait).is_err() {
                    return false;
                }
            }
        }
        true
    };

    while !state.done() {
        let ev = match rx.recv_timeout(tick) {
            Ok(ev) => Some(ev),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => bail!("fleet event channel closed"),
        };
        if let Some(ev) = ev {
            last_event = Instant::now();
            match ev {
                Event::Joined { conn, name, writer } => {
                    report.workers_joined += 1;
                    eprintln!("bsf fleet: worker '{name}' joined (conn {conn})");
                    let mut w = WorkerHandle {
                        name,
                        writer,
                        stats: WorkerStats::default(),
                        suspect: false,
                    };
                    let spec = Msg::Spec { spec: grid.spec.clone(), heartbeat_ms };
                    let alive = write_msg(&mut w.writer, &spec).is_ok()
                        && try_issue(&mut state, &mut book, &mut report, &mut w, conn);
                    if alive {
                        workers.insert(conn, w);
                    } else {
                        // died during the handshake: reclaim anything the
                        // failed issue may have booked against it
                        for lease in book.drop_worker(conn) {
                            report.releases += 1;
                            report.re_executed_cells += state.requeue(&lease.buckets);
                        }
                    }
                }
                Event::Incoming { conn, msg } => match msg {
                    Msg::Heartbeat { lease: 0 } => {
                        // busy per our book but idle-pinging would be a
                        // protocol slip; only issue to genuinely idle ones
                        let alive = match workers.get_mut(&conn) {
                            Some(w) if book.worker_lease(conn).is_none() => {
                                try_issue(&mut state, &mut book, &mut report, w, conn)
                            }
                            _ => true,
                        };
                        if !alive {
                            drop_worker(conn, &mut workers, &mut book, &mut state, &mut report);
                        }
                    }
                    Msg::Heartbeat { lease } => {
                        // stale (expired/re-leased) heartbeats refresh
                        // nothing — the worker is draining; no reply owed
                        let _ = book.refresh(lease, Instant::now() + refresh_by);
                    }
                    Msg::Done { lease, wall, results } => {
                        let n = results.len();
                        for (r, bits) in results {
                            if r >= state.times.len() {
                                continue; // corrupt index; drop, don't panic
                            }
                            let (dup, mismatch) = state.record(r, bits);
                            report.duplicate_completions += dup as usize;
                            report.duplicate_mismatches += mismatch as usize;
                        }
                        if book.complete(lease).is_some() {
                            if let Some(w) = workers.get_mut(&conn) {
                                w.stats.observe(n, wall);
                                w.suspect = false;
                            }
                        }
                        // stale Done: results recorded above regardless —
                        // progress is monotone even under expiry storms
                        let alive = match workers.get_mut(&conn) {
                            Some(w) if !state.done() => {
                                try_issue(&mut state, &mut book, &mut report, w, conn)
                            }
                            _ => true,
                        };
                        if !alive {
                            drop_worker(conn, &mut workers, &mut book, &mut state, &mut report);
                        }
                    }
                    _ => {} // coordinator-bound streams carry nothing else
                },
                Event::Gone { conn } => {
                    drop_worker(conn, &mut workers, &mut book, &mut state, &mut report);
                }
            }
        }
        // expiry sweep (runs on the timer tick and after every event)
        let now = Instant::now();
        for lease in book.expired(now) {
            report.releases += 1;
            report.leases_expired += 1;
            report.re_executed_cells += state.requeue(&lease.buckets);
            if let Some(w) = workers.get_mut(&lease.worker) {
                w.suspect = true;
                eprintln!(
                    "bsf fleet: lease {} of worker '{}' expired; re-leasing {} bucket(s)",
                    lease.id,
                    w.name,
                    lease.buckets.len()
                );
            }
        }
        if last_event.elapsed() > cfg.idle_timeout {
            bail!(
                "fleet coordinator idle for {:?} with {} of {} cells incomplete (no workers?)",
                cfg.idle_timeout,
                state.times.iter().filter(|t| t.is_none()).count(),
                state.times.len()
            );
        }
    }

    // Grid complete: tell everyone to go home, then unblock the acceptor.
    for w in workers.values_mut() {
        let _ = write_msg(&mut w.writer, &Msg::Shutdown);
    }
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(local);
    let _ = acceptor.join();
    drop(keepalive);

    let times =
        state.times.iter().map(|t| f64::from_bits(t.expect("grid complete"))).collect();
    Ok((times, report))
}

/// Forget a dead worker and requeue everything it held.
fn drop_worker(
    conn: u64,
    workers: &mut HashMap<u64, WorkerHandle>,
    book: &mut LeaseBook,
    state: &mut GridState,
    report: &mut FleetReport,
) {
    if let Some(w) = workers.remove(&conn) {
        report.worker_deaths += 1;
        eprintln!("bsf fleet: worker '{}' lost (conn {conn})", w.name);
    }
    for lease in book.drop_worker(conn) {
        report.releases += 1;
        report.re_executed_cells += state.requeue(&lease.buckets);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4 buckets of 2 cells each, flat cells 0..8, unit estimates.
    fn state() -> GridState {
        let groups = vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
        GridState::from_parts(groups, vec![0.1; 8])
    }

    #[test]
    fn take_batch_respects_cell_budget() {
        let mut s = state();
        let (ids, members, est) = s.take_batch(4, f64::INFINITY, false).unwrap();
        assert_eq!(ids, vec![0, 1]);
        assert_eq!(members, vec![vec![0, 1], vec![2, 3]]);
        assert!((est - 0.4).abs() < 1e-12);
        // the next batch starts where the first stopped
        let (ids2, _, _) = s.take_batch(100, f64::INFINITY, false).unwrap();
        assert_eq!(ids2, vec![2, 3]);
        assert!(s.take_batch(100, f64::INFINITY, false).is_none(), "queue drained");
    }

    #[test]
    fn take_batch_single_bucket_for_suspects() {
        let mut s = state();
        let (ids, _, _) = s.take_batch(100, f64::INFINITY, true).unwrap();
        assert_eq!(ids.len(), 1);
    }

    #[test]
    fn take_batch_always_issues_at_least_one_bucket() {
        let mut s = state();
        // budget smaller than any bucket still yields one bucket
        let (ids, members, _) = s.take_batch(1, 0.0, false).unwrap();
        assert_eq!(ids.len(), 1);
        assert_eq!(members[0].len(), 2);
    }

    #[test]
    fn take_batch_skips_completed_buckets_and_cells() {
        let mut s = state();
        s.record(0, 1);
        s.record(1, 2); // bucket 0 fully done
        s.record(2, 3); // bucket 1 half done
        let (ids, members, _) = s.take_batch(1, f64::INFINITY, false).unwrap();
        assert_eq!(ids, vec![1]);
        assert_eq!(members, vec![vec![3]], "only the incomplete member is leased");
    }

    #[test]
    fn record_tracks_duplicates_and_mismatches() {
        let mut s = state();
        assert_eq!(s.record(0, 42), (false, false));
        assert_eq!(s.record(0, 42), (true, false), "same bits: benign duplicate");
        assert_eq!(s.record(0, 43), (true, true), "different bits: determinism broken");
        assert_eq!(s.times[0], Some(43), "last write wins");
    }

    #[test]
    fn requeue_dedups_and_prioritises() {
        let mut s = state();
        let (ids, _, _) = s.take_batch(4, f64::INFINITY, false).unwrap(); // buckets 0,1
        assert_eq!(s.requeue(&ids), 4);
        assert_eq!(s.requeue(&ids), 0, "already queued: no double-count");
        // requeued work preempts fresh work
        let (next, _, _) = s.take_batch(2, f64::INFINITY, false).unwrap();
        assert!(ids.contains(&next[0]));
    }

    #[test]
    fn requeue_skips_completed_buckets() {
        let mut s = state();
        let (ids, _, _) = s.take_batch(2, f64::INFINITY, false).unwrap(); // bucket 0
        s.record(0, 1);
        s.record(1, 2);
        assert_eq!(s.requeue(&ids), 0, "nothing left to re-execute");
        let (next, _, _) = s.take_batch(2, f64::INFINITY, false).unwrap();
        assert_ne!(next[0], ids[0]);
    }

    #[test]
    fn done_requires_every_cell() {
        let mut s = state();
        for r in 0..7 {
            s.record(r, r as u64);
            assert!(!s.done());
        }
        s.record(7, 7);
        assert!(s.done());
    }

    #[test]
    fn default_config_is_patient() {
        let cfg = FleetConfig::default();
        assert!(cfg.min_deadline >= Duration::from_secs(1));
        assert!(cfg.grace >= 2);
        assert!(cfg.safety >= 1.0);
    }
}
