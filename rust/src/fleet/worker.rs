//! A fleet worker: connects to the coordinator, rebuilds the sweep grid
//! locally from the wire spec, and executes leased cell buckets through
//! the same [`run_cell_bucket`] path the in-process pool uses — so a
//! fleet of separate OS processes produces bitwise-identical results to
//! one process.
//!
//! Failure posture: a lost coordinator connection is never fatal once the
//! worker has connected at least once — the worker drains whatever lease
//! it holds (the work is discarded; the coordinator will re-lease it),
//! then retries the connection under a bounded, jittered exponential
//! backoff ([`Backoff`]). Exhausting the budget after a successful run is
//! a clean exit 0: the likeliest cause is the coordinator finishing and
//! going away.
//!
//! The [`WorkerChaos`] knobs exist for the chaos harness
//! (`rust/tests/fleet.rs`): they inject kills, hangs, and delayed
//! completions at deterministic cell-count boundaries so every recovery
//! path in the coordinator is exercised by tests, not just by luck.

use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::experiments::{flat_cells, run_cell_bucket, SweepScratch};
use crate::util::{Backoff, Rng};

use super::proto::{write_msg, Msg, MsgReader};
use super::FleetGrid;

/// Deterministic fault injection for the chaos harness. All counts are
/// against the worker's **process-lifetime** executed-cell counter, so an
/// injection point survives reconnects and is reproducible run to run.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerChaos {
    /// Simulate a SIGKILL: once this many cells have been executed, drop
    /// the socket without a word (mid-lease) and exit.
    pub kill_after_cells: Option<usize>,
    /// Go silent: once this many cells have been executed, sleep
    /// `hang_hold` before the next bucket (long enough for the lease to
    /// expire), then carry on — the late `Done` exercises the stale-
    /// completion path.
    pub hang_after_cells: Option<usize>,
    /// How long a hang lasts.
    pub hang_hold: Duration,
    /// Delay the first `Done` by this long (forces a duplicate
    /// completion when longer than the coordinator's deadline).
    pub done_delay: Option<Duration>,
}

/// Worker runtime configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Coordinator address, e.g. `127.0.0.1:7500`.
    pub addr: String,
    /// Display name (logs + deterministic backoff jitter stream).
    pub name: String,
    /// Base delay of the connect backoff.
    pub connect_base: Duration,
    /// Connect attempts before giving up.
    pub connect_attempts: usize,
    /// Root seed for the jitter stream (any value; only decorrelates
    /// reconnect stampedes, never results).
    pub seed: u64,
    /// Fault injection (all-`None` in production).
    pub chaos: WorkerChaos,
}

impl WorkerConfig {
    /// Production defaults for `addr`, named `name`.
    pub fn new(addr: impl Into<String>, name: impl Into<String>) -> WorkerConfig {
        WorkerConfig {
            addr: addr.into(),
            name: name.into(),
            connect_base: Duration::from_millis(50),
            connect_attempts: 12,
            seed: 0xB5F,
            chaos: WorkerChaos::default(),
        }
    }
}

/// What one worker did over its lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Cells executed and reported.
    pub cells: usize,
    /// Leases completed.
    pub leases: usize,
    /// Times the coordinator connection was re-established.
    pub reconnects: usize,
    /// Cells executed whose results were discarded (connection lost
    /// mid-lease; the coordinator re-leases them elsewhere).
    pub drained_cells: usize,
    /// True when the chaos kill switch fired.
    pub killed: bool,
}

/// How one connected session ended.
enum SessionEnd {
    /// Coordinator said the grid is complete.
    Shutdown,
    /// Chaos kill fired; exit without reconnecting.
    Killed,
    /// Connection lost; reconnect and carry on.
    Lost,
}

/// Mutable chaos bookkeeping that must survive reconnects.
#[derive(Default)]
struct ChaosState {
    cells_executed: usize,
    hang_done: bool,
    done_delayed: bool,
}

/// FNV-1a of the worker name: a stable per-worker stream tag so every
/// worker jitters its reconnects differently but reproducibly.
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Run a worker to completion: connect (with backoff), execute leases,
/// survive coordinator loss, exit on shutdown or exhausted reconnects.
pub fn run_worker(cfg: &WorkerConfig) -> Result<WorkerSummary> {
    let mut summary = WorkerSummary::default();
    let mut chaos = ChaosState::default();
    let jitter = Rng::new(fnv64(&cfg.name) ^ cfg.seed).split(1);
    let mut backoff = Backoff::new(cfg.connect_base, cfg.connect_attempts).with_jitter(jitter);
    let mut connected_once = false;
    loop {
        let stream = match TcpStream::connect(&cfg.addr) {
            Ok(s) => {
                backoff.reset();
                s
            }
            Err(e) => match backoff.next_delay() {
                Some(delay) => {
                    thread::sleep(delay);
                    continue;
                }
                None if connected_once => {
                    // the coordinator most likely finished and went away
                    return Ok(summary);
                }
                None => {
                    return Err(e).with_context(|| {
                        format!(
                            "fleet worker '{}': coordinator at {} unreachable after {} attempts",
                            cfg.name, cfg.addr, cfg.connect_attempts
                        )
                    });
                }
            },
        };
        connected_once = true;
        match session(cfg, stream, &mut summary, &mut chaos)? {
            SessionEnd::Shutdown | SessionEnd::Killed => return Ok(summary),
            SessionEnd::Lost => {
                // a successful connect resets the backoff, so bound the
                // session count itself or an accept-then-drop coordinator
                // would keep us alive forever
                summary.reconnects += 1;
                if summary.reconnects > cfg.connect_attempts {
                    return Ok(summary);
                }
                thread::sleep(cfg.connect_base);
            }
        }
    }
}

/// One connected session: handshake, rebuild the grid, execute leases.
fn session(
    cfg: &WorkerConfig,
    stream: TcpStream,
    summary: &mut WorkerSummary,
    chaos: &mut ChaosState,
) -> Result<SessionEnd> {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return Ok(SessionEnd::Lost),
    };
    let mut reader = MsgReader::new(stream);
    if write_msg(&mut writer, &Msg::Hello { name: cfg.name.clone() }).is_err() {
        return Ok(SessionEnd::Lost);
    }
    let (spec, heartbeat) = match reader.next() {
        Ok(Some(Msg::Spec { spec, heartbeat_ms })) => {
            (spec, Duration::from_millis(heartbeat_ms.max(1)))
        }
        Ok(Some(other)) => bail!("fleet worker: expected spec, got {other:?}"),
        _ => return Ok(SessionEnd::Lost),
    };
    // Rebuild the grid locally: same spec ⇒ same jobs, same RNG streams,
    // same flat cell identities as the coordinator and every peer.
    let grid = FleetGrid::new(spec)?;
    let jobs = grid.jobs();
    let flat = flat_cells(&jobs);
    let mut scratch = SweepScratch::default();
    let mut out: Vec<f64> = Vec::new();

    loop {
        match reader.next() {
            Ok(Some(Msg::Lease { id, buckets })) => {
                let started = Instant::now();
                let mut results: Vec<(usize, u64)> = Vec::new();
                let mut lost = false;
                for (bi, bucket) in buckets.iter().enumerate() {
                    if let Some(n) = cfg.chaos.kill_after_cells {
                        if chaos.cells_executed >= n {
                            // simulated SIGKILL: vanish mid-lease without
                            // a goodbye; the real CI smoke job uses kill -9
                            summary.killed = true;
                            return Ok(SessionEnd::Killed);
                        }
                    }
                    if let Some(n) = cfg.chaos.hang_after_cells {
                        if chaos.cells_executed >= n && !chaos.hang_done {
                            chaos.hang_done = true;
                            thread::sleep(cfg.chaos.hang_hold);
                        }
                    }
                    out.clear();
                    run_cell_bucket(&mut scratch, &jobs, &flat, bucket, &mut out);
                    chaos.cells_executed += out.len();
                    if lost {
                        // draining: the coordinator can't hear us, but we
                        // finish the lease's work before reconnecting so a
                        // half-executed template never leaks state
                        summary.drained_cells += out.len();
                        continue;
                    }
                    for (j, &r) in bucket.iter().enumerate() {
                        results.push((r, out[j].to_bits()));
                    }
                    if bi + 1 < buckets.len()
                        && write_msg(&mut writer, &Msg::Heartbeat { lease: id }).is_err()
                    {
                        summary.drained_cells += results.len();
                        results.clear();
                        lost = true;
                    }
                }
                if lost {
                    return Ok(SessionEnd::Lost);
                }
                if let Some(delay) = cfg.chaos.done_delay {
                    if !chaos.done_delayed {
                        chaos.done_delayed = true;
                        thread::sleep(delay);
                    }
                }
                summary.cells += results.len();
                summary.leases += 1;
                let wall = started.elapsed().as_secs_f64();
                let done = Msg::Done { lease: id, wall, results };
                if write_msg(&mut writer, &done).is_err() {
                    return Ok(SessionEnd::Lost);
                }
            }
            Ok(Some(Msg::Wait)) => {
                thread::sleep(heartbeat);
                if write_msg(&mut writer, &Msg::Heartbeat { lease: 0 }).is_err() {
                    return Ok(SessionEnd::Lost);
                }
            }
            Ok(Some(Msg::Shutdown)) => return Ok(SessionEnd::Shutdown),
            Ok(Some(other)) => bail!("fleet worker: unexpected message {other:?}"),
            _ => return Ok(SessionEnd::Lost),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{serial_times, FleetSpec};
    use super::*;
    use crate::experiments::ProblemKind;
    use std::net::TcpListener;

    #[test]
    fn fnv64_is_stable_and_distinguishes_names() {
        assert_eq!(fnv64("w-1"), fnv64("w-1"));
        assert_ne!(fnv64("w-1"), fnv64("w-2"));
        assert_ne!(fnv64(""), 0);
    }

    #[test]
    fn unreachable_coordinator_errors_after_budget() {
        let mut cfg = WorkerConfig::new("127.0.0.1:1", "test-unreachable");
        cfg.connect_base = Duration::from_millis(1);
        cfg.connect_attempts = 2;
        let err = run_worker(&cfg).unwrap_err();
        assert!(err.to_string().contains("unreachable"), "{err}");
    }

    /// Script one coordinator session by hand: lease a single cell, check
    /// the result bits match the serial ground truth, shut down.
    #[test]
    fn executes_a_lease_and_reports_exact_bits() {
        let spec = FleetSpec {
            problem: ProblemKind::Jacobi,
            sizes: vec![1_500],
            iters: 1,
            seed: 7,
            quick: true,
            jitter: 0.05,
        };
        let truth = serial_times(&FleetGrid::new(spec.clone()).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = MsgReader::new(stream);
            assert!(matches!(reader.next().unwrap(), Some(Msg::Hello { .. })));
            write_msg(&mut writer, &Msg::Spec { spec, heartbeat_ms: 50 }).unwrap();
            write_msg(&mut writer, &Msg::Lease { id: 1, buckets: vec![vec![0], vec![2]] })
                .unwrap();
            // two buckets ⇒ one mid-lease heartbeat, then the completion
            assert_eq!(reader.next().unwrap(), Some(Msg::Heartbeat { lease: 1 }));
            let done = reader.next().unwrap().unwrap();
            write_msg(&mut writer, &Msg::Shutdown).unwrap();
            done
        });
        let cfg = WorkerConfig::new(addr, "test-exec");
        let summary = run_worker(&cfg).unwrap();
        let done = handle.join().unwrap();
        match done {
            Msg::Done { lease, results, .. } => {
                assert_eq!(lease, 1);
                assert_eq!(results.len(), 2);
                assert_eq!(results[0], (0, truth[0].to_bits()));
                assert_eq!(results[1], (2, truth[2].to_bits()));
            }
            other => panic!("expected done, got {other:?}"),
        }
        assert_eq!(summary.cells, 2);
        assert_eq!(summary.leases, 1);
        assert!(!summary.killed);
    }

    #[test]
    fn chaos_kill_fires_at_the_cell_boundary() {
        let spec = FleetSpec {
            problem: ProblemKind::Jacobi,
            sizes: vec![1_500],
            iters: 1,
            seed: 7,
            quick: true,
            jitter: 0.05,
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = MsgReader::new(stream);
            assert!(matches!(reader.next().unwrap(), Some(Msg::Hello { .. })));
            write_msg(&mut writer, &Msg::Spec { spec, heartbeat_ms: 50 }).unwrap();
            write_msg(&mut writer, &Msg::Lease { id: 1, buckets: vec![vec![0], vec![1]] })
                .unwrap();
            // bucket 1 executes, heartbeat arrives, then the kill fires
            // before bucket 2 and the socket just dies
            assert_eq!(reader.next().unwrap(), Some(Msg::Heartbeat { lease: 1 }));
            assert_eq!(reader.next().unwrap(), None, "socket dropped without a Done");
        });
        let mut cfg = WorkerConfig::new(addr, "test-kill");
        cfg.chaos.kill_after_cells = Some(1);
        let summary = run_worker(&cfg).unwrap();
        handle.join().unwrap();
        assert!(summary.killed);
        assert_eq!(summary.cells, 0, "killed before any Done");
    }
}
