//! Fleet plane: a lease-based coordinator/worker layer that shards the
//! pooled (experiment × size × K) sweep queue across OS processes with
//! end-to-end fault tolerance.
//!
//! The unit of work is a **shape bucket** from the same partition the
//! in-process pool uses ([`crate::experiments::cell_groups`]), so grouped
//! lane passes survive sharding. The coordinator hands out *leases* on
//! batches of buckets, tracks per-worker heartbeats against deadlines
//! derived from a DES cost estimate, and re-leases a batch when its owner
//! misses the deadline or drops its socket. Crucially it **never
//! re-seeds**: every cell's result is a pure function of `(job, K)` via
//! per-K [`crate::util::Rng::split`] streams, so re-executing a cell —
//! on any worker, any number of times — produces the identical bits, and
//! the final table is bitwise equal to the serial single-process sweep
//! regardless of how many workers died, joined late, or executed a cell
//! twice (last-write-wins is safe). The contract is pinned in
//! `rust/tests/fleet.rs` and the failure semantics are documented in
//! PERF.md ("Fleet protocol + failure semantics").
//!
//! Wire format: line-delimited JSON over localhost TCP ([`proto`]), with
//! every result f64 travelling as `to_bits` hex so the bitwise contract
//! survives text transport.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::config::ClusterConfig;
use crate::experiments::{
    analytic_provider, cell_groups, effective_net_with_latency, flat_cells, k_sweep,
    paper_gravity_params, paper_jacobi_params, run_cell_bucket, ProblemKind, SweepJob,
    SweepScratch,
};
use crate::model::{BsfModel, CostParams};
use crate::simulator::{AnalyticCost, SimParams};
use crate::util::{table::sci, Json, Rng, Table};

pub mod coordinator;
pub mod lease;
pub mod proto;
pub mod worker;

pub use coordinator::{serve, FleetConfig, FleetReport};
pub use worker::{run_worker, WorkerChaos, WorkerConfig, WorkerSummary};

/// The sweep grid a fleet executes, as it travels on the wire: everything
/// a worker needs to reconstruct the exact job list the coordinator
/// partitioned — same sizes, same K grids, same RNG forks — so both sides
/// agree on cell identities and every execution is bitwise reproducible.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// Which problem family (paper-params mode only: jacobi or gravity).
    pub problem: ProblemKind,
    /// Problem sizes, in grid order. Duplicates are allowed and meaningful
    /// — each occurrence forks its own sweep root, exactly like repeating
    /// a size in a figure grid.
    pub sizes: Vec<usize>,
    /// Simulated iterations averaged per K-point.
    pub iters: usize,
    /// Root seed (fixes every per-K stream).
    pub seed: u64,
    /// Quick K-grid resolution (mirrors `ExperimentCtx::quick`).
    pub quick: bool,
    /// Compute jitter sigma — makes the per-K RNG streams load-bearing,
    /// so the bitwise contract actually exercises stream placement.
    pub jitter: f64,
}

/// CLI/printable name of a problem kind.
pub fn problem_name(kind: ProblemKind) -> &'static str {
    match kind {
        ProblemKind::Jacobi => "jacobi",
        ProblemKind::Gravity => "gravity",
        ProblemKind::Cimmino => "cimmino",
    }
}

impl FleetSpec {
    /// Serialize for the wire. The jitter sigma travels as `to_bits` hex —
    /// it feeds the simulator directly, so it must survive transport
    /// exactly; the seed travels as a decimal string (JSON numbers are
    /// only exact to 2^53).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("problem".to_string(), Json::Str(problem_name(self.problem).to_string()));
        m.insert(
            "sizes".to_string(),
            Json::Arr(self.sizes.iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("seed".to_string(), Json::Str(self.seed.to_string()));
        m.insert("quick".to_string(), Json::Bool(self.quick));
        m.insert("jitter".to_string(), Json::Str(format!("{:016x}", self.jitter.to_bits())));
        Json::Obj(m)
    }

    /// Parse the wire form back (exact inverse of [`FleetSpec::to_json`]).
    pub fn from_json(v: &Json) -> Result<FleetSpec> {
        let field = |k: &str| v.get(k).ok_or_else(|| anyhow!("fleet spec missing '{k}'"));
        let problem = field("problem")?
            .as_str()
            .and_then(ProblemKind::parse)
            .ok_or_else(|| anyhow!("fleet spec: bad problem"))?;
        let sizes = field("sizes")?
            .as_arr()
            .ok_or_else(|| anyhow!("fleet spec: sizes must be an array"))?
            .iter()
            .map(|e| e.as_usize().ok_or_else(|| anyhow!("fleet spec: bad size")))
            .collect::<Result<Vec<usize>>>()?;
        let iters = field("iters")?.as_usize().ok_or_else(|| anyhow!("fleet spec: bad iters"))?;
        let seed = field("seed")?
            .as_str()
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| anyhow!("fleet spec: bad seed"))?;
        let quick = matches!(field("quick")?, Json::Bool(true));
        let jitter = field("jitter")?
            .as_str()
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .map(f64::from_bits)
            .ok_or_else(|| anyhow!("fleet spec: bad jitter"))?;
        Ok(FleetSpec { problem, sizes, iters, seed, quick, jitter })
    }
}

/// A materialized fleet grid: the spec plus per-size cost parameters and
/// providers. Built identically (and independently) by the coordinator
/// and every worker from the same [`FleetSpec`].
pub struct FleetGrid {
    /// The spec this grid was built from.
    pub spec: FleetSpec,
    /// Per-size `(n, params)` in grid order.
    metas: Vec<(usize, CostParams)>,
    provs: Vec<AnalyticCost>,
}

impl FleetGrid {
    /// Validate a spec and build its grid. Rejects problems without
    /// published cost parameters and sizes outside the published tables —
    /// the fleet runs paper-params mode only (calibrated/measured grids
    /// would need per-host calibration, which breaks cross-process
    /// bitwise identity by construction).
    pub fn new(spec: FleetSpec) -> Result<FleetGrid> {
        let (lookup, valid): (fn(usize) -> Option<CostParams>, &str) = match spec.problem {
            ProblemKind::Jacobi => (paper_jacobi_params, "1500|5000|10000|16000"),
            ProblemKind::Gravity => (paper_gravity_params, "300|600|900|1200"),
            ProblemKind::Cimmino => {
                bail!("fleet sweeps run on published cost parameters; cimmino has none (use jacobi or gravity)")
            }
        };
        if spec.sizes.is_empty() {
            bail!("fleet spec has no sizes");
        }
        if spec.iters == 0 {
            bail!("fleet spec needs iters >= 1");
        }
        let mut metas = Vec::with_capacity(spec.sizes.len());
        let mut provs = Vec::with_capacity(spec.sizes.len());
        for &n in &spec.sizes {
            let params = lookup(n).ok_or_else(|| {
                anyhow!(
                    "no published {} parameters for n={n} (valid sizes: {valid})",
                    problem_name(spec.problem)
                )
            })?;
            provs.push(analytic_provider(&params));
            metas.push((n, params));
        }
        Ok(FleetGrid { spec, metas, provs })
    }

    /// Build the job list — the same construction order (and therefore
    /// the same RNG fork sequence) as the figure harnesses: one
    /// [`SweepJob`] per size, sweep roots forked from `Rng::new(seed)` in
    /// grid order.
    pub fn jobs(&self) -> Vec<SweepJob<'_>> {
        let cluster = ClusterConfig::default();
        let mut rng = Rng::new(self.spec.seed);
        let mut jobs = Vec::with_capacity(self.metas.len());
        for ((n, params), prov) in self.metas.iter().zip(&self.provs) {
            let model = BsfModel::new(*params);
            let ks = k_sweep(model.k_bsf(), self.spec.quick);
            let (wd, wu) = match self.spec.problem {
                ProblemKind::Gravity => (7usize, 3usize),
                _ => (*n, *n),
            };
            let sim = SimParams {
                net: effective_net_with_latency(params.t_c, wd, wu, cluster.net.latency),
                algo: cluster.algo,
                reduce_mode: cluster.reduce_mode,
                words_down: wd,
                words_up: wu,
                jitter_comp: self.spec.jitter,
                jitter_comm: 0.0,
                masters: cluster.masters,
            };
            jobs.push(SweepJob::new(sim, *n, prov, ks, self.spec.iters, &mut rng));
        }
        jobs
    }

    /// Total cell count of the grid.
    pub fn cells(&self) -> usize {
        flat_cells(&self.jobs()).len()
    }
}

/// Execute the whole grid serially in one process — the ground truth the
/// fleet must match bitwise. Returns mean iteration time per flat cell.
pub fn serial_times(grid: &FleetGrid) -> Vec<f64> {
    let jobs = grid.jobs();
    let flat = flat_cells(&jobs);
    let groups = cell_groups(&jobs, &flat);
    let mut times = vec![0.0f64; flat.len()];
    let mut scratch = SweepScratch::default();
    let mut out = Vec::new();
    for g in &groups {
        out.clear();
        run_cell_bucket(&mut scratch, &jobs, &flat, g, &mut out);
        for (j, &r) in g.iter().enumerate() {
            times[r] = out[j];
        }
    }
    times
}

/// Render per-cell times as the fleet's result table: one row per (size,
/// K) with the exact bits alongside the human-readable figures. Both the
/// coordinator and `fleet-serial` produce this table from their `times`
/// vector, so a byte-compare of the two CSVs is the end-to-end
/// determinism check.
pub fn fleet_table(grid: &FleetGrid, times: &[f64]) -> Table {
    let jobs = grid.jobs();
    let mut t = Table::new(
        format!(
            "Fleet sweep: {} sizes {:?} (seed {}, iters {})",
            problem_name(grid.spec.problem),
            grid.spec.sizes,
            grid.spec.seed,
            grid.spec.iters
        ),
        &["n", "K", "T_K sim", "speedup", "T_K bits"],
    );
    let mut off = 0;
    for (job, (n, _)) in jobs.iter().zip(&grid.metas) {
        let tks = &times[off..off + job.ks.len()];
        off += job.ks.len();
        // k_sweep always starts at 1, so tks[0] is the T_1 reference.
        let t1 = tks[0];
        for (&k, &tk) in job.ks.iter().zip(tks) {
            t.row(&[
                n.to_string(),
                k.to_string(),
                sci(tk),
                format!("{:.2}", t1 / tk),
                format!("{:016x}", tk.to_bits()),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> FleetSpec {
        FleetSpec {
            problem: ProblemKind::Jacobi,
            sizes: vec![1_500, 5_000],
            iters: 2,
            seed: 0xB5F,
            quick: true,
            jitter: 0.05,
        }
    }

    #[test]
    fn spec_json_round_trips_exactly() {
        let s = spec();
        let v = s.to_json();
        let text = v.to_string();
        let back = FleetSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        // jitter bits are exact, not approximately-parsed
        assert_eq!(back.jitter.to_bits(), s.jitter.to_bits());
    }

    #[test]
    fn grid_rejects_bad_specs() {
        let mut s = spec();
        s.sizes = vec![1_500, 123];
        assert!(FleetGrid::new(s).is_err());
        let mut s = spec();
        s.problem = ProblemKind::Cimmino;
        assert!(FleetGrid::new(s).is_err());
        let mut s = spec();
        s.sizes.clear();
        assert!(FleetGrid::new(s).is_err());
        let mut s = spec();
        s.iters = 0;
        assert!(FleetGrid::new(s).is_err());
    }

    #[test]
    fn grid_construction_is_deterministic() {
        let g1 = FleetGrid::new(spec()).unwrap();
        let g2 = FleetGrid::new(spec()).unwrap();
        assert_eq!(serial_times(&g1), serial_times(&g2));
        assert_eq!(g1.cells(), g2.cells());
        assert!(g1.cells() > 10);
    }

    #[test]
    fn serial_times_match_simulated_curves() {
        // The fleet's ground-truth path is the same pooled executor the
        // figure harnesses use — cell times must agree bitwise.
        let grid = FleetGrid::new(spec()).unwrap();
        let times = serial_times(&grid);
        let jobs = grid.jobs();
        let curves = crate::experiments::simulated_curves(&jobs, 1);
        let mut off = 0;
        for (job, curve) in jobs.iter().zip(&curves) {
            for (i, p) in curve.iter().enumerate() {
                assert_eq!(p.t_k.to_bits(), times[off + i].to_bits(), "cell {i} of size {}", job.l);
            }
            off += job.ks.len();
        }
    }

    #[test]
    fn table_carries_exact_bits() {
        let grid = FleetGrid::new(spec()).unwrap();
        let times = serial_times(&grid);
        let t = fleet_table(&grid, &times);
        assert_eq!(t.len(), times.len());
        let csv = t.to_csv();
        assert!(csv.contains(&format!("{:016x}", times[0].to_bits())));
    }
}
