//! Lease bookkeeping: which worker holds which cell buckets, when each
//! lease expires, and how fast each worker has been going.
//!
//! A lease is a *soft* ownership claim: the coordinator re-leases a batch
//! when the deadline passes, but a late completion from the original
//! owner is still accepted (last-write-wins) — re-execution is bitwise
//! harmless because every cell is a pure function of `(job, K)`. The
//! deadline math therefore only affects *latency* under faults, never
//! correctness, which is what lets the defaults stay loose enough for
//! debug-build CI.

use std::time::{Duration, Instant};

/// Crude a-priori estimate of one cell's simulation wall time in seconds:
/// the DES hot path is O((5K + 16) × iters) node visits (see PERF.md),
/// scaled by an empirical per-visit constant. Only used to size leases and
/// deadlines before a worker has throughput history — an estimate off by
/// 10x merely changes batch sizes, not results.
pub fn est_cell_seconds(k: usize, iters: usize) -> f64 {
    (5.0 * k as f64 + 16.0) * iters as f64 * 1e-7
}

/// One outstanding lease.
#[derive(Debug, Clone)]
pub struct Lease {
    /// Lease id (nonzero).
    pub id: u64,
    /// Connection id of the owning worker.
    pub worker: u64,
    /// Bucket ids (indices into the coordinator's partition) on lease.
    pub buckets: Vec<usize>,
    /// Expiry: miss this and the batch goes back on the queue.
    pub deadline: Instant,
}

/// The coordinator's table of outstanding leases.
#[derive(Debug, Default)]
pub struct LeaseBook {
    active: Vec<Lease>,
    next_id: u64,
}

impl LeaseBook {
    /// Issue a new lease to `worker` and return it (cloned for sending).
    pub fn issue(&mut self, worker: u64, buckets: Vec<usize>, deadline: Instant) -> Lease {
        self.next_id += 1;
        let lease = Lease { id: self.next_id, worker, buckets, deadline };
        self.active.push(lease.clone());
        lease
    }

    /// Push a lease's deadline out (heartbeat received).
    pub fn refresh(&mut self, id: u64, deadline: Instant) -> bool {
        match self.active.iter_mut().find(|l| l.id == id) {
            Some(l) => {
                l.deadline = deadline;
                true
            }
            None => false,
        }
    }

    /// Remove a completed lease, returning it if it was still active
    /// (`None` ⇒ the lease had already expired and been re-leased — the
    /// completion is *stale* but its results are still good).
    pub fn complete(&mut self, id: u64) -> Option<Lease> {
        let at = self.active.iter().position(|l| l.id == id)?;
        Some(self.active.swap_remove(at))
    }

    /// Remove and return every lease whose deadline has passed.
    pub fn expired(&mut self, now: Instant) -> Vec<Lease> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].deadline <= now {
                out.push(self.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Remove and return every lease held by `worker` (socket died).
    pub fn drop_worker(&mut self, worker: u64) -> Vec<Lease> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].worker == worker {
                out.push(self.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        out
    }

    /// The lease currently held by `worker`, if any.
    pub fn worker_lease(&self, worker: u64) -> Option<&Lease> {
        self.active.iter().find(|l| l.worker == worker)
    }

    /// Outstanding lease count.
    pub fn len(&self) -> usize {
        self.active.len()
    }

    /// True when no leases are outstanding.
    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }
}

/// EWMA smoothing factor for worker throughput: heavy enough that one
/// slow lease (page cache miss, CI noise) doesn't crater the estimate,
/// light enough to adapt within a few leases.
const EWMA_ALPHA: f64 = 0.3;

/// Per-worker throughput history, steering lease sizes: fast workers get
/// bigger batches, slow (or suspect) workers smaller ones.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerStats {
    ewma: Option<f64>, // cells per second
}

impl WorkerStats {
    /// Fold one completed lease into the estimate.
    pub fn observe(&mut self, cells: usize, wall_seconds: f64) {
        if cells == 0 || !wall_seconds.is_finite() || wall_seconds <= 0.0 {
            return;
        }
        let rate = cells as f64 / wall_seconds;
        self.ewma = Some(match self.ewma {
            None => rate,
            Some(prev) => EWMA_ALPHA * rate + (1.0 - EWMA_ALPHA) * prev,
        });
    }

    /// Smoothed throughput in cells/second, if any history exists.
    pub fn rate(&self) -> Option<f64> {
        self.ewma
    }

    /// How many cells this worker should get for a lease targeting
    /// `target` wall time; `fallback` when no history exists yet.
    pub fn cells_for(&self, target: Duration, fallback: usize) -> usize {
        match self.ewma {
            None => fallback,
            Some(rate) => ((rate * target.as_secs_f64()).floor() as usize).max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64) -> Instant {
        // A fixed origin keeps the tests independent of real elapsed time.
        static ORIGIN: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
        *ORIGIN.get_or_init(Instant::now) + Duration::from_millis(ms)
    }

    #[test]
    fn issue_complete_lifecycle() {
        let mut book = LeaseBook::default();
        let a = book.issue(1, vec![0, 1], t(100));
        let b = book.issue(2, vec![2], t(100));
        assert_ne!(a.id, b.id);
        assert_ne!(a.id, 0);
        assert_eq!(book.len(), 2);
        assert_eq!(book.worker_lease(1).unwrap().id, a.id);
        let done = book.complete(a.id).unwrap();
        assert_eq!(done.buckets, vec![0, 1]);
        assert!(book.complete(a.id).is_none(), "double-complete is stale");
        assert_eq!(book.len(), 1);
    }

    #[test]
    fn expiry_returns_overdue_leases_only() {
        let mut book = LeaseBook::default();
        let a = book.issue(1, vec![0], t(50));
        let _b = book.issue(2, vec![1], t(500));
        let exp = book.expired(t(100));
        assert_eq!(exp.len(), 1);
        assert_eq!(exp[0].id, a.id);
        assert_eq!(book.len(), 1);
        // expired lease is gone: a late completion is stale
        assert!(book.complete(a.id).is_none());
    }

    #[test]
    fn refresh_extends_deadline() {
        let mut book = LeaseBook::default();
        let a = book.issue(1, vec![0], t(50));
        assert!(book.refresh(a.id, t(1_000)));
        assert!(book.expired(t(100)).is_empty());
        assert!(!book.refresh(999, t(1_000)), "unknown lease not refreshable");
    }

    #[test]
    fn drop_worker_reclaims_all_its_leases() {
        let mut book = LeaseBook::default();
        book.issue(1, vec![0], t(100));
        book.issue(1, vec![1], t(100));
        book.issue(2, vec![2], t(100));
        let dropped = book.drop_worker(1);
        assert_eq!(dropped.len(), 2);
        assert_eq!(book.len(), 1);
        assert!(book.worker_lease(1).is_none());
        assert!(book.worker_lease(2).is_some());
    }

    #[test]
    fn stats_converge_and_size_leases() {
        let mut s = WorkerStats::default();
        assert_eq!(s.cells_for(Duration::from_millis(500), 4), 4, "no history → fallback");
        s.observe(100, 1.0); // 100 cells/s
        assert_eq!(s.cells_for(Duration::from_millis(500), 4), 50);
        s.observe(0, 1.0); // ignored
        s.observe(100, 0.0); // ignored
        assert_eq!(s.rate(), Some(100.0));
        s.observe(200, 1.0); // EWMA moves toward 200
        let r = s.rate().unwrap();
        assert!(r > 100.0 && r < 200.0, "rate {r}");
        // a glacial worker still gets at least one cell
        let mut slow = WorkerStats::default();
        slow.observe(1, 1_000.0);
        assert_eq!(slow.cells_for(Duration::from_millis(500), 4), 1);
    }

    #[test]
    fn cell_estimate_scales_with_k_and_iters() {
        assert!(est_cell_seconds(100, 7) > est_cell_seconds(10, 7));
        assert!(est_cell_seconds(10, 7) > est_cell_seconds(10, 3));
        assert!(est_cell_seconds(1, 1) > 0.0);
    }
}
