//! Fleet wire protocol: line-delimited JSON over a TCP stream.
//!
//! One [`Msg`] enum covers both directions; each message is a single JSON
//! object on one line, tagged by its `"t"` field. Floats that must survive
//! transport exactly (cell results) travel as `f64::to_bits` hex strings —
//! the whole fleet contract is *bitwise* identity with the serial sweep,
//! so the wire cannot be allowed to round anything.
//!
//! | tag        | direction      | meaning                                   |
//! |------------|----------------|-------------------------------------------|
//! | `hello`    | worker → coord | join; carries the worker's display name   |
//! | `spec`     | coord → worker | the [`FleetSpec`] + heartbeat interval    |
//! | `lease`    | coord → worker | a batch of cell buckets to execute        |
//! | `wait`     | coord → worker | no work right now; idle-ping and stand by |
//! | `hb`       | worker → coord | heartbeat (`lease` = 0 means idle)        |
//! | `done`     | worker → coord | lease finished; per-cell result bits      |
//! | `shutdown` | coord → worker | grid complete; drain and exit            |

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

use super::FleetSpec;

/// One protocol message (either direction).
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker → coordinator: join the fleet.
    Hello {
        /// Worker display name (used in logs and the coordinator report).
        name: String,
    },
    /// Coordinator → worker: the sweep grid and the heartbeat interval the
    /// coordinator expects (milliseconds).
    Spec {
        /// The grid to reconstruct locally.
        spec: FleetSpec,
        /// Expected heartbeat interval in milliseconds.
        heartbeat_ms: u64,
    },
    /// Coordinator → worker: execute these cell buckets. Each inner list
    /// holds **flat cell indices** of one (possibly partial) shape bucket;
    /// the worker runs each through one grouped pass.
    Lease {
        /// Lease id (nonzero; echoed in heartbeats and completion).
        id: u64,
        /// Buckets of flat cell indices.
        buckets: Vec<Vec<usize>>,
    },
    /// Coordinator → worker: no work available right now.
    Wait,
    /// Worker → coordinator: still alive. `lease` echoes the lease being
    /// executed, or 0 when idle.
    Heartbeat {
        /// Lease currently held (0 = idle ping).
        lease: u64,
    },
    /// Worker → coordinator: lease complete.
    Done {
        /// The finished lease id.
        lease: u64,
        /// Wall-clock seconds spent executing the lease (feeds the
        /// coordinator's per-worker throughput EWMA; not part of any
        /// result, so plain JSON number precision is fine).
        wall: f64,
        /// Per-cell results as `(flat index, f64 bits)`.
        results: Vec<(usize, u64)>,
    },
    /// Coordinator → worker: grid complete; exit cleanly.
    Shutdown,
}

impl Msg {
    /// Serialize to one JSON object (the line body; no trailing newline).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        match self {
            Msg::Hello { name } => {
                m.insert("t".into(), Json::Str("hello".into()));
                m.insert("name".into(), Json::Str(name.clone()));
            }
            Msg::Spec { spec, heartbeat_ms } => {
                m.insert("t".into(), Json::Str("spec".into()));
                m.insert("spec".into(), spec.to_json());
                m.insert("heartbeat_ms".into(), Json::Num(*heartbeat_ms as f64));
            }
            Msg::Lease { id, buckets } => {
                m.insert("t".into(), Json::Str("lease".into()));
                m.insert("id".into(), Json::Num(*id as f64));
                m.insert(
                    "buckets".into(),
                    Json::Arr(
                        buckets
                            .iter()
                            .map(|b| Json::Arr(b.iter().map(|&r| Json::Num(r as f64)).collect()))
                            .collect(),
                    ),
                );
            }
            Msg::Wait => {
                m.insert("t".into(), Json::Str("wait".into()));
            }
            Msg::Heartbeat { lease } => {
                m.insert("t".into(), Json::Str("hb".into()));
                m.insert("lease".into(), Json::Num(*lease as f64));
            }
            Msg::Done { lease, wall, results } => {
                m.insert("t".into(), Json::Str("done".into()));
                m.insert("lease".into(), Json::Num(*lease as f64));
                m.insert("wall".into(), Json::Num(*wall));
                m.insert(
                    "results".into(),
                    Json::Arr(
                        results
                            .iter()
                            .map(|&(r, bits)| {
                                Json::Arr(vec![
                                    Json::Num(r as f64),
                                    Json::Str(format!("{bits:016x}")),
                                ])
                            })
                            .collect(),
                    ),
                );
            }
            Msg::Shutdown => {
                m.insert("t".into(), Json::Str("shutdown".into()));
            }
        }
        Json::Obj(m)
    }

    /// Parse one message (inverse of [`Msg::to_json`]).
    pub fn from_json(v: &Json) -> Result<Msg> {
        let tag = v
            .get("t")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("fleet message missing tag"))?;
        let num =
            |k: &str| v.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("bad '{k}' field"));
        match tag {
            "hello" => {
                let name = v
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("hello missing name"))?;
                Ok(Msg::Hello { name: name.to_string() })
            }
            "spec" => {
                let spec = FleetSpec::from_json(
                    v.get("spec").ok_or_else(|| anyhow!("spec message missing spec"))?,
                )?;
                Ok(Msg::Spec { spec, heartbeat_ms: num("heartbeat_ms")? as u64 })
            }
            "lease" => {
                let buckets = v
                    .get("buckets")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("lease missing buckets"))?
                    .iter()
                    .map(|b| {
                        b.as_arr()
                            .ok_or_else(|| anyhow!("lease bucket must be an array"))?
                            .iter()
                            .map(|e| e.as_usize().ok_or_else(|| anyhow!("bad cell index")))
                            .collect::<Result<Vec<usize>>>()
                    })
                    .collect::<Result<Vec<Vec<usize>>>>()?;
                Ok(Msg::Lease { id: num("id")? as u64, buckets })
            }
            "wait" => Ok(Msg::Wait),
            "hb" => Ok(Msg::Heartbeat { lease: num("lease")? as u64 }),
            "done" => {
                let wall = v
                    .get("wall")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("done missing wall"))?;
                let results = v
                    .get("results")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("done missing results"))?
                    .iter()
                    .map(|pair| {
                        let p = pair
                            .as_arr()
                            .filter(|p| p.len() == 2)
                            .ok_or_else(|| anyhow!("done result must be [idx, bits]"))?;
                        let r = p[0].as_usize().ok_or_else(|| anyhow!("bad result index"))?;
                        let bits = p[1]
                            .as_str()
                            .and_then(|s| u64::from_str_radix(s, 16).ok())
                            .ok_or_else(|| anyhow!("bad result bits"))?;
                        Ok((r, bits))
                    })
                    .collect::<Result<Vec<(usize, u64)>>>()?;
                Ok(Msg::Done { lease: num("lease")? as u64, wall, results })
            }
            "shutdown" => Ok(Msg::Shutdown),
            other => Err(anyhow!("unknown fleet message tag '{other}'")),
        }
    }
}

/// Write one message as a line and flush (a heartbeat sitting in a buffer
/// is a missed heartbeat).
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    writeln!(w, "{}", msg.to_json()).context("fleet send")?;
    w.flush().context("fleet flush")
}

/// Buffered line-at-a-time message reader over a stream.
pub struct MsgReader<R: Read> {
    inner: BufReader<R>,
    line: String,
}

impl<R: Read> MsgReader<R> {
    /// Wrap a stream.
    pub fn new(stream: R) -> MsgReader<R> {
        MsgReader { inner: BufReader::new(stream), line: String::new() }
    }

    /// Read the next message. `Ok(None)` on clean EOF (peer closed the
    /// stream); errors on I/O failure or a malformed line.
    pub fn next(&mut self) -> Result<Option<Msg>> {
        self.line.clear();
        let n = self.inner.read_line(&mut self.line).context("fleet recv")?;
        if n == 0 {
            return Ok(None);
        }
        let text = self.line.trim_end();
        let v = Json::parse(text).map_err(|e| anyhow!("fleet recv: bad JSON: {e}"))?;
        Msg::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ProblemKind;

    fn round_trip(m: &Msg) -> Msg {
        let line = m.to_json().to_string();
        assert!(!line.contains('\n'));
        Msg::from_json(&Json::parse(&line).unwrap()).unwrap()
    }

    #[test]
    fn all_messages_round_trip() {
        let spec = FleetSpec {
            problem: ProblemKind::Gravity,
            sizes: vec![300, 600],
            iters: 3,
            seed: u64::MAX - 1, // exercises > 2^53 (string transport)
            quick: true,
            jitter: 0.05,
        };
        let msgs = [
            Msg::Hello { name: "w-1".into() },
            Msg::Spec { spec, heartbeat_ms: 200 },
            Msg::Lease { id: 7, buckets: vec![vec![0, 4, 9], vec![2]] },
            Msg::Wait,
            Msg::Heartbeat { lease: 0 },
            Msg::Heartbeat { lease: 7 },
            Msg::Done {
                lease: 7,
                wall: 0.125,
                results: vec![(0, 1.5f64.to_bits()), (4, f64::NAN.to_bits())],
            },
            Msg::Shutdown,
        ];
        for m in &msgs {
            assert_eq!(&round_trip(m), m, "{m:?}");
        }
    }

    #[test]
    fn result_bits_survive_exactly() {
        // The load-bearing property: a result that JSON numbers would
        // mangle (full 64-bit pattern) survives the hex-string transport.
        let exotic = f64::from_bits(0x3FF0_0000_0000_0001); // 1.0 + 1 ulp
        let m = Msg::Done { lease: 1, wall: 0.0, results: vec![(3, exotic.to_bits())] };
        match round_trip(&m) {
            Msg::Done { results, .. } => assert_eq!(results[0].1, exotic.to_bits()),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn reader_handles_stream_of_lines_and_eof() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Hello { name: "a".into() }).unwrap();
        write_msg(&mut buf, &Msg::Wait).unwrap();
        let mut r = MsgReader::new(&buf[..]);
        assert_eq!(r.next().unwrap(), Some(Msg::Hello { name: "a".into() }));
        assert_eq!(r.next().unwrap(), Some(Msg::Wait));
        assert_eq!(r.next().unwrap(), None);
    }

    #[test]
    fn malformed_lines_error() {
        let mut r = MsgReader::new(&b"not json\n"[..]);
        assert!(r.next().is_err());
        let mut r = MsgReader::new(&b"{\"t\":\"nope\"}\n"[..]);
        assert!(r.next().is_err());
    }
}
