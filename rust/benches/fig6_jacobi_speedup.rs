//! Bench: regenerate Fig. 6 / Table 3 (BSF-Jacobi speedup curves, paper
//! parameters) and time the whole pipeline per size.
//!
//! ```text
//! cargo bench --bench fig6_jacobi_speedup
//! ```

use bsf::experiments::{
    analytic_provider, boundary_row, paper_jacobi_params, ExperimentCtx,
};
use bsf::util::bench::bench;
use bsf::util::Rng;

fn main() {
    let ctx = ExperimentCtx { quick: true, ..Default::default() };
    println!("== fig6_jacobi_speedup: per-size curve regeneration ==");
    let mut rows = Vec::new();
    for n in [1_500usize, 5_000, 10_000, 16_000] {
        let params = paper_jacobi_params(n).expect("published");
        bench(&format!("fig6 curve n={n}"), 1, 5, || {
            let prov = analytic_provider(&params);
            let mut rng = Rng::new(1);
            let row = boundary_row(&ctx, n, &params, n, n, &prov, &mut rng);
            std::hint::black_box(&row);
        });
        let prov = analytic_provider(&params);
        let mut rng = Rng::new(1);
        rows.push(boundary_row(&ctx, n, &params, n, n, &prov, &mut rng));
    }
    println!("\nregenerated Table 3 (paper K_test: 40/60/120/160):");
    for r in rows {
        println!(
            "  n={:<6} K_BSF={:<6.0} K_test={:<6.0} err={:.3}",
            r.n, r.k_bsf, r.k_test, r.error
        );
    }
}
