//! Bench: the live skeleton's per-iteration overhead, plus the
//! zero-allocation contract of the whole live data plane.
//!
//! The coordinator must not be the bottleneck (DESIGN.md §9): its per-
//! iteration cost (broadcast + gather + fold + bookkeeping) is measured
//! with a near-zero-compute problem, so everything measured here is
//! skeleton overhead. Compare against the per-iteration `t_Map` of real
//! problems (milliseconds) — overhead should be ≪ that.
//!
//! Three allocation audits run under a counting allocator and **assert**
//! zero steady-state allocations per call/iteration:
//!
//! 1. `BsfProblem::map_fold_into` + `combine_into`, native path, all four
//!    shipped problems;
//! 2. the PJRT **staging layer** (workspace staging buffers, borrowed
//!    `TensorView`s, the `Arc`-cached packed blocks) that the kernel path
//!    threads per block;
//! 3. the live-runner **uplink**: the worker's steady-state iteration
//!    (downlink receive → map_fold_into → slot send) and the master's
//!    gather + fold + buffer recycle, driven through the real transport
//!    with the double-buffer swap protocol.
//!
//! Headline figures land in `BENCH_ci.json` (see `bsf::util::bench::CiReport`).
//!
//! ```text
//! cargo bench --bench coordinator_hotpath
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bsf::coordinator::{BsfProblem, CostSpec, LiveRunner, Workspace};
use bsf::linalg::{generators, kernels};
use bsf::net::transport::{fabric, Downlink, Uplink};
use bsf::problems::{CimminoProblem, GravityProblem, JacobiProblem, MonteCarloPi};
use bsf::runtime::{KernelRuntime, TensorView};
use bsf::simulator::{lanes_enabled, sched_mode, SchedMode};
use bsf::util::bench::{bench, human_time, CiReport};

/// Counts every allocation so the zero-allocation claims are measured,
/// not assumed.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// A problem whose compute is a single multiply — pure skeleton overhead.
#[derive(Debug)]
struct Noop {
    l: usize,
    payload: usize,
}

impl BsfProblem for Noop {
    fn name(&self) -> &str {
        "noop"
    }
    fn list_len(&self) -> usize {
        self.l
    }
    fn initial_approx(&self) -> Vec<f64> {
        vec![1.0; self.payload]
    }
    fn map_fold_into(
        &self,
        _r: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        _ws: &mut Workspace,
        _k: Option<&KernelRuntime>,
    ) {
        out.fill(0.0);
        out[0] = x[0] * 2.0;
    }
    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0; self.payload]
    }
    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        acc[0] += b[0];
    }
    fn post(&self, _x: &[f64], s: &[f64], _i: usize) -> (Vec<f64>, bool) {
        let mut next = vec![1.0; self.payload];
        next[0] = s[0] * 0.5;
        (next, false)
    }
    fn cost_spec(&self) -> CostSpec {
        CostSpec {
            l: self.l,
            words_down: self.payload,
            words_up: self.payload,
            ops_map_per_elem: 1.0,
            ops_combine: 1.0,
            ops_post: 1.0,
        }
    }
}

/// Steady-state allocations per `map_fold_into` call over the whole list,
/// native path. Warm call first (grows buffers), then `reps` measured
/// calls: the count must be exactly zero.
fn assert_zero_alloc_map_fold(name: &str, p: &dyn BsfProblem, ci: &mut CiReport) {
    let x = p.initial_approx();
    let l = p.list_len();
    let mut out = p.fold_identity();
    let mut ws = Workspace::new();
    p.map_fold_into(0..l, &x, &mut out, &mut ws, None); // warm buffers
    let reps = 64u64;
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        p.map_fold_into(0..l, &x, &mut out, &mut ws, None);
        std::hint::black_box(&out);
    }
    let per_call = (ALLOCS.load(Ordering::Relaxed) - before) as f64 / reps as f64;
    println!("    -> allocations per map_fold_into [{name}]: {per_call}");
    ci.metric(format!("allocs_per_map_fold [{name}]"), per_call);
    assert_eq!(per_call, 0.0, "{name}: map_fold_into allocates in steady state");
    // combine_into is in-place by construction; pin it too.
    let b = out.clone();
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        p.combine_into(&mut out, &b);
    }
    assert_eq!(
        ALLOCS.load(Ordering::Relaxed),
        before,
        "{name}: combine_into allocates in steady state"
    );
    // Workspace scratch reuse: once grown, `zeroed` must hand back
    // capacity without touching the allocator.
    std::hint::black_box(ws.zeroed(l.min(1_024)));
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        std::hint::black_box(ws.zeroed(l.min(1_024)));
    }
    assert_eq!(
        ALLOCS.load(Ordering::Relaxed),
        before,
        "{name}: Workspace::zeroed allocates in steady state"
    );
}

/// The PJRT staging layer in steady state: per "block" the kernel path
/// packs the padded x-block into the workspace's staging buffer, pulls
/// the `Arc`-cached packed matrix block, and wraps everything in borrowed
/// `TensorView`s. All of it must be allocation-free once warm (the actual
/// device execution is exercised on hosts with `--features pjrt` +
/// artifacts; the staging contract holds regardless).
fn assert_zero_alloc_pjrt_staging(ci: &mut CiReport) {
    let n = 512usize;
    let b = 256usize;
    let jacobi = JacobiProblem::new(generators::paper_system(n), 1e-12);
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
    let mut ws = Workspace::new();
    // Warm: grows the staging buffers and packs both blocks into the cache.
    {
        let (x_stage, out_stage) = ws.staging(b, n);
        let blk = jacobi.packed_block(0, b, b);
        x_stage[..b].copy_from_slice(&x[..b]);
        std::hint::black_box((TensorView::mat_cached(&blk, n, b), &out_stage));
        let blk2 = jacobi.packed_block(b, n, b);
        std::hint::black_box(TensorView::mat_cached(&blk2, n, b));
    }
    let reps = 64u64;
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        // One map_fold_into's worth of staging: workspace buffers + both
        // cached blocks + borrowed views over x-block and output.
        let (x_stage, out_stage) = ws.staging(b, n);
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + b).min(n);
            let c_blk = jacobi.packed_block(j0, j1, b);
            x_stage[..j1 - j0].copy_from_slice(&x[j0..j1]);
            x_stage[j1 - j0..].fill(0.0);
            let views =
                [TensorView::mat_cached(&c_blk, n, b), TensorView::vec_view(x_stage)];
            std::hint::black_box(&views);
            std::hint::black_box(&out_stage);
            j0 = j1;
        }
    }
    let per_call = (ALLOCS.load(Ordering::Relaxed) - before) as f64 / reps as f64;
    println!("    -> allocations per kernel-path staging pass (n=512, B=256): {per_call}");
    ci.metric("allocs_per_pjrt_staging_pass", per_call);
    assert_eq!(per_call, 0.0, "PJRT staging layer allocates in steady state");
}

/// The live uplink in steady state, through the real transport: the
/// worker side (downlink receive → map_fold_into → slot send) and the
/// master side (gather into the reused inbox → in-place fold → buffer
/// recycle) must allocate nothing once the double-buffer rotation is
/// primed. Driven single-threaded so master-side setup (Arc wrap + mpsc
/// downlink node) stays outside the measured region deterministically.
fn assert_zero_alloc_live_uplink(ci: &mut CiReport) {
    let problem = Noop { l: 64, payload: 256 };
    let (master, mut workers) = fabric(1);
    let w = workers.pop().expect("one worker");
    let mut ws = Workspace::new();
    let mut spare = Some(problem.fold_identity());
    let mut recycle: Option<Vec<f64>> = None;
    let identity = problem.fold_identity();
    let mut acc = problem.fold_identity();
    let mut got: Vec<Option<Uplink>> = Vec::new();
    let x = Arc::new(problem.initial_approx());
    let warm = 2u64;
    let reps = 64u64;
    let mut measured = 0u64;
    for epoch in 0..(warm + reps) {
        // Master downlink (allocations allowed here: the mpsc node).
        master
            .send_to(
                1,
                Downlink::Approximation {
                    x: x.clone(),
                    epoch,
                    reuse: recycle.take(),
                    extra: Vec::new(),
                },
            )
            .expect("worker alive");
        let before = ALLOCS.load(Ordering::Relaxed);
        // Worker iteration: receive, compute into the rotated buffer, send
        // by move through the uplink slot.
        match w.recv().expect("master alive") {
            Downlink::Approximation { x, epoch, reuse, extra: _ } => {
                let mut partial =
                    reuse.or_else(|| spare.take()).expect("rotation primed");
                problem.map_fold_into(0..64, &x, &mut partial, &mut ws, None);
                w.send(epoch, partial, 0.0).expect("master alive");
            }
            Downlink::Stop { .. } => unreachable!("no stop sent"),
        }
        // Master gather + fold + recycle.
        let received =
            master.gather_into(&[true], epoch, Duration::from_secs(5), &mut got);
        assert_eq!(received, 1);
        acc.copy_from_slice(&identity);
        let u = got[0].take().expect("gathered");
        problem.combine_into(&mut acc, &u.partial);
        recycle = Some(u.partial);
        if epoch >= warm {
            measured += ALLOCS.load(Ordering::Relaxed) - before;
        }
    }
    let per_iter = measured as f64 / reps as f64;
    println!("    -> allocations per live-uplink iteration (worker + gather + fold): {per_iter}");
    ci.metric("allocs_per_uplink_iteration", per_iter);
    assert_eq!(per_iter, 0.0, "live uplink allocates in steady state");
    master.broadcast_best_effort(&Downlink::Stop { iterations: (warm + reps) as usize });
}

fn main() {
    let mut ci = CiReport::new("coordinator_hotpath");
    println!("== coordinator_hotpath: skeleton overhead per iteration ==");
    println!(
        "active kernel: {}, scheduler: {}, lanes: {}",
        kernels::active().name(),
        sched_mode().name(),
        if lanes_enabled() { "on" } else { "off" }
    );
    // Self-describe the configuration that produced these figures, so a
    // BENCH_ci.json artifact is attributable to its
    // BSF_KERNEL/BSF_SCHED/BSF_LANES cell without consulting the CI log.
    let flag = |b: bool| if b { 1.0 } else { 0.0 };
    ci.metric("config_kernel_avx2", flag(kernels::active() == kernels::KernelKind::Avx2));
    ci.metric("config_sched_cached", flag(sched_mode() == SchedMode::Cached));
    ci.metric("config_lanes_on", flag(lanes_enabled()));
    let iters = 400;
    for k in [1usize, 2, 4, 8] {
        for payload in [8usize, 4_096] {
            let r = bench(
                &format!("live K={k}, payload={payload} f64 ({iters} iters)"),
                1,
                5,
                || {
                    let p: Arc<dyn BsfProblem> = Arc::new(Noop { l: 1_024, payload });
                    let report = LiveRunner::new(k, iters).run(p).unwrap();
                    std::hint::black_box(report.iterations);
                },
            );
            let per_iter = r.summary.median / iters as f64;
            println!("    -> per-iteration overhead: {}", human_time(per_iter));
            ci.metric(
                format!("live_overhead_sec [K={k} payload={payload}]"),
                per_iter,
            );
        }
    }

    println!("== coordinator_hotpath: map_fold_into allocation audit (native path) ==");
    let jacobi = JacobiProblem::new(generators::paper_system(512), 1e-12);
    assert_zero_alloc_map_fold("bsf-jacobi n=512", &jacobi, &mut ci);
    let gravity = GravityProblem::new(generators::random_bodies(2_048, 5.0, 7), 1e-3, f64::MAX);
    assert_zero_alloc_map_fold("bsf-gravity n=2048", &gravity, &mut ci);
    let cimmino =
        CimminoProblem::new(generators::feasible_inequalities(1_024, 64, 0.1, 7), 1.5, 1e-20);
    assert_zero_alloc_map_fold("bsf-cimmino m=1024", &cimmino, &mut ci);
    let pi = MonteCarloPi::new(1_024, 16, 1e-6, 0xC0FFEE);
    assert_zero_alloc_map_fold("monte-carlo-pi l=1024", &pi, &mut ci);
    println!("all four problems: 0 steady-state allocations per map_fold_into call");

    println!("== coordinator_hotpath: PJRT staging-layer allocation audit ==");
    assert_zero_alloc_pjrt_staging(&mut ci);

    println!("== coordinator_hotpath: live-uplink allocation audit ==");
    assert_zero_alloc_live_uplink(&mut ci);

    if let Err(e) = ci.save("BENCH_ci.json") {
        eprintln!("warning: could not write BENCH_ci.json: {e}");
    } else {
        println!("machine-readable figures merged into BENCH_ci.json");
    }
}
