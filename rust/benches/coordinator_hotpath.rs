//! Bench: the live skeleton's per-iteration overhead, plus the
//! zero-allocation contract of the workspace-threaded problem API.
//!
//! The coordinator must not be the bottleneck (DESIGN.md §9): its per-
//! iteration cost (broadcast + gather + fold + bookkeeping) is measured
//! with a near-zero-compute problem, so everything measured here is
//! skeleton overhead. Compare against the per-iteration `t_Map` of real
//! problems (milliseconds) — overhead should be ≪ that.
//!
//! The second section drives `BsfProblem::map_fold_into` (native path) for
//! all four shipped problems under a counting allocator and **asserts**
//! zero steady-state allocations per call — the kernel-side analogue of
//! the engine's zero-allocation replay.
//!
//! ```text
//! cargo bench --bench coordinator_hotpath
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use bsf::coordinator::{BsfProblem, CostSpec, LiveRunner, Workspace};
use bsf::linalg::generators;
use bsf::problems::{CimminoProblem, GravityProblem, JacobiProblem, MonteCarloPi};
use bsf::runtime::KernelRuntime;
use bsf::util::bench::{bench, human_time};

/// Counts every allocation so the zero-allocation `map_fold_into` claim is
/// measured, not assumed.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// A problem whose compute is a single multiply — pure skeleton overhead.
#[derive(Debug)]
struct Noop {
    l: usize,
    payload: usize,
}

impl BsfProblem for Noop {
    fn name(&self) -> &str {
        "noop"
    }
    fn list_len(&self) -> usize {
        self.l
    }
    fn initial_approx(&self) -> Vec<f64> {
        vec![1.0; self.payload]
    }
    fn map_fold_into(
        &self,
        _r: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        _ws: &mut Workspace,
        _k: Option<&KernelRuntime>,
    ) {
        out.fill(0.0);
        out[0] = x[0] * 2.0;
    }
    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0; self.payload]
    }
    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        acc[0] += b[0];
    }
    fn post(&self, _x: &[f64], s: &[f64], _i: usize) -> (Vec<f64>, bool) {
        let mut next = vec![1.0; self.payload];
        next[0] = s[0] * 0.5;
        (next, false)
    }
    fn cost_spec(&self) -> CostSpec {
        CostSpec {
            l: self.l,
            words_down: self.payload,
            words_up: self.payload,
            ops_map_per_elem: 1.0,
            ops_combine: 1.0,
            ops_post: 1.0,
        }
    }
}

/// Steady-state allocations per `map_fold_into` call over the whole list,
/// native path. Warm call first (grows buffers), then `reps` measured
/// calls: the count must be exactly zero.
fn assert_zero_alloc_map_fold(name: &str, p: &dyn BsfProblem) {
    let x = p.initial_approx();
    let l = p.list_len();
    let mut out = p.fold_identity();
    let mut ws = Workspace::new();
    p.map_fold_into(0..l, &x, &mut out, &mut ws, None); // warm buffers
    let reps = 64u64;
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        p.map_fold_into(0..l, &x, &mut out, &mut ws, None);
        std::hint::black_box(&out);
    }
    let per_call = (ALLOCS.load(Ordering::Relaxed) - before) as f64 / reps as f64;
    println!("    -> allocations per map_fold_into [{name}]: {per_call}");
    assert_eq!(per_call, 0.0, "{name}: map_fold_into allocates in steady state");
    // combine_into is in-place by construction; pin it too.
    let b = out.clone();
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        p.combine_into(&mut out, &b);
    }
    assert_eq!(
        ALLOCS.load(Ordering::Relaxed),
        before,
        "{name}: combine_into allocates in steady state"
    );
    // Workspace scratch reuse: once grown, `zeroed` must hand back
    // capacity without touching the allocator.
    std::hint::black_box(ws.zeroed(l.min(1_024)));
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        std::hint::black_box(ws.zeroed(l.min(1_024)));
    }
    assert_eq!(
        ALLOCS.load(Ordering::Relaxed),
        before,
        "{name}: Workspace::zeroed allocates in steady state"
    );
}

fn main() {
    println!("== coordinator_hotpath: skeleton overhead per iteration ==");
    let iters = 400;
    for k in [1usize, 2, 4, 8] {
        for payload in [8usize, 4_096] {
            let r = bench(
                &format!("live K={k}, payload={payload} f64 ({iters} iters)"),
                1,
                5,
                || {
                    let p: Arc<dyn BsfProblem> = Arc::new(Noop { l: 1_024, payload });
                    let report = LiveRunner::new(k, iters).run(p).unwrap();
                    std::hint::black_box(report.iterations);
                },
            );
            println!(
                "    -> per-iteration overhead: {}",
                human_time(r.summary.median / iters as f64)
            );
        }
    }

    println!("== coordinator_hotpath: map_fold_into allocation audit (native path) ==");
    let jacobi = JacobiProblem::new(generators::paper_system(512), 1e-12);
    assert_zero_alloc_map_fold("bsf-jacobi n=512", &jacobi);
    let gravity = GravityProblem::new(generators::random_bodies(2_048, 5.0, 7), 1e-3, f64::MAX);
    assert_zero_alloc_map_fold("bsf-gravity n=2048", &gravity);
    let cimmino =
        CimminoProblem::new(generators::feasible_inequalities(1_024, 64, 0.1, 7), 1.5, 1e-20);
    assert_zero_alloc_map_fold("bsf-cimmino m=1024", &cimmino);
    let pi = MonteCarloPi::new(1_024, 16, 1e-6, 0xC0FFEE);
    assert_zero_alloc_map_fold("monte-carlo-pi l=1024", &pi);
    println!("all four problems: 0 steady-state allocations per map_fold_into call");
}
