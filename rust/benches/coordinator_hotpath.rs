//! Bench: the live skeleton's per-iteration overhead.
//!
//! The coordinator must not be the bottleneck (DESIGN.md §9): its per-
//! iteration cost (broadcast + gather + fold + bookkeeping) is measured
//! with a near-zero-compute problem, so everything measured here is
//! skeleton overhead. Compare against the per-iteration `t_Map` of real
//! problems (milliseconds) — overhead should be ≪ that.
//!
//! ```text
//! cargo bench --bench coordinator_hotpath
//! ```

use std::ops::Range;
use std::sync::Arc;

use bsf::coordinator::{BsfProblem, CostSpec, LiveRunner};
use bsf::runtime::KernelRuntime;
use bsf::util::bench::{bench, human_time};

/// A problem whose compute is a single multiply — pure skeleton overhead.
#[derive(Debug)]
struct Noop {
    l: usize,
    payload: usize,
}

impl BsfProblem for Noop {
    fn name(&self) -> &str {
        "noop"
    }
    fn list_len(&self) -> usize {
        self.l
    }
    fn initial_approx(&self) -> Vec<f64> {
        vec![1.0; self.payload]
    }
    fn map_fold(&self, _r: Range<usize>, x: &[f64], _k: Option<&KernelRuntime>) -> Vec<f64> {
        let mut out = vec![0.0; self.payload];
        out[0] = x[0] * 2.0;
        out
    }
    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0; self.payload]
    }
    fn combine(&self, mut a: Vec<f64>, b: Vec<f64>) -> Vec<f64> {
        a[0] += b[0];
        a
    }
    fn post(&self, _x: &[f64], s: &[f64], _i: usize) -> (Vec<f64>, bool) {
        let mut next = vec![1.0; self.payload];
        next[0] = s[0] * 0.5;
        (next, false)
    }
    fn cost_spec(&self) -> CostSpec {
        CostSpec {
            l: self.l,
            words_down: self.payload,
            words_up: self.payload,
            ops_map_per_elem: 1.0,
            ops_combine: 1.0,
            ops_post: 1.0,
        }
    }
}

fn main() {
    println!("== coordinator_hotpath: skeleton overhead per iteration ==");
    let iters = 400;
    for k in [1usize, 2, 4, 8] {
        for payload in [8usize, 4_096] {
            let r = bench(
                &format!("live K={k}, payload={payload} f64 ({iters} iters)"),
                1,
                5,
                || {
                    let p: Arc<dyn BsfProblem> = Arc::new(Noop { l: 1_024, payload });
                    let report = LiveRunner::new(k, iters).run(p).unwrap();
                    std::hint::black_box(report.iterations);
                },
            );
            println!(
                "    -> per-iteration overhead: {}",
                human_time(r.summary.median / iters as f64)
            );
        }
    }
}
