//! Bench: what fault tolerance costs — fleet vs serial on the same grid.
//!
//! Three cells, one grid (paper-params Jacobi, pooled sweep queue):
//!
//! 1. **serial** — the single-process ground truth (`serial_times`);
//! 2. **clean fleet** — coordinator + 3 workers over localhost TCP, no
//!    faults: protocol + scheduling overhead only;
//! 3. **chaos fleet** — same, with one worker killed mid-lease:
//!    measures the re-lease recovery cost.
//!
//! Every fleet run **asserts** its result table is bitwise identical to
//! the serial baseline — this bench is also an end-to-end determinism
//! gate. Headline figures land in `BENCH_ci.json`:
//! `fleet_re_lease_overhead` (re-executed cells / total cells) and
//! `fleet_duplicate_completions`.
//!
//! ```text
//! cargo bench --bench fleet_overhead
//! ```

use std::net::TcpListener;
use std::thread;
use std::time::{Duration, Instant};

use bsf::experiments::ProblemKind;
use bsf::fleet::{
    run_worker, serial_times, serve, FleetConfig, FleetGrid, FleetReport, FleetSpec, WorkerChaos,
    WorkerConfig,
};
use bsf::util::bench::{human_time, CiReport};

fn spec() -> FleetSpec {
    FleetSpec {
        problem: ProblemKind::Jacobi,
        sizes: vec![1_500, 5_000],
        iters: 3,
        seed: 0xB5F,
        quick: true,
        jitter: 0.05,
    }
}

fn cfg() -> FleetConfig {
    FleetConfig {
        heartbeat: Duration::from_millis(50),
        grace: 100,
        min_deadline: Duration::from_secs(20),
        safety: 50.0,
        lease_target: Duration::from_millis(200),
        max_lease_cells: 16,
        idle_timeout: Duration::from_secs(60),
    }
}

fn run_fleet(chaos: &[WorkerChaos]) -> (Vec<f64>, FleetReport) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let grid = FleetGrid::new(spec()).expect("grid");
    let cfg = cfg();
    let coord = thread::spawn(move || serve(&grid, &cfg, listener).expect("serve"));
    let workers: Vec<_> = chaos
        .iter()
        .enumerate()
        .map(|(i, &ch)| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut wc = WorkerConfig::new(addr, format!("bench-w{i}"));
                wc.connect_base = Duration::from_millis(1);
                wc.connect_attempts = 8;
                wc.chaos = ch;
                run_worker(&wc).expect("worker")
            })
        })
        .collect();
    let out = coord.join().expect("coordinator thread");
    for w in workers {
        w.join().expect("worker thread");
    }
    out
}

fn assert_bitwise(times: &[f64], truth: &[f64], label: &str) {
    assert_eq!(times.len(), truth.len(), "{label}: cell count");
    for (r, (a, b)) in times.iter().zip(truth).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: cell {r} diverged");
    }
}

fn main() {
    let mut ci = CiReport::new("fleet_overhead");
    println!("== fleet_overhead: fault tolerance cost vs serial ==");

    let grid = FleetGrid::new(spec()).expect("grid");
    let t0 = Instant::now();
    let truth = serial_times(&grid);
    let serial_wall = t0.elapsed().as_secs_f64();
    println!("serial: {} cells in {}", truth.len(), human_time(serial_wall));
    ci.metric("fleet_serial_wall_sec", serial_wall);

    let t0 = Instant::now();
    let (times, report) = run_fleet(&[WorkerChaos::default(); 3]);
    let clean_wall = t0.elapsed().as_secs_f64();
    assert_bitwise(&times, &truth, "clean fleet");
    assert_eq!(report.duplicate_mismatches, 0, "{report:?}");
    let overhead = report.re_executed_cells as f64 / report.cells.max(1) as f64;
    println!(
        "clean fleet (3 workers): {} ({} leases, {} re-leases) — bitwise == serial",
        human_time(clean_wall),
        report.leases_issued,
        report.releases
    );
    ci.metric("fleet_clean_wall_sec", clean_wall);
    ci.metric("fleet_clean_vs_serial", clean_wall / serial_wall.max(1e-9));
    ci.metric("fleet_re_lease_overhead", overhead);
    ci.metric("fleet_duplicate_completions", report.duplicate_completions as f64);

    let t0 = Instant::now();
    let chaos = [
        WorkerChaos::default(),
        WorkerChaos::default(),
        WorkerChaos { kill_after_cells: Some(4), ..Default::default() },
    ];
    let (times, report) = run_fleet(&chaos);
    let chaos_wall = t0.elapsed().as_secs_f64();
    assert_bitwise(&times, &truth, "chaos fleet");
    assert!(report.releases >= 1, "killed worker must force a re-lease: {report:?}");
    assert_eq!(report.duplicate_mismatches, 0, "{report:?}");
    let chaos_overhead = report.re_executed_cells as f64 / report.cells.max(1) as f64;
    println!(
        "chaos fleet (1 worker killed mid-lease): {} ({} cells re-executed, {:.1}% overhead) \
         — bitwise == serial",
        human_time(chaos_wall),
        report.re_executed_cells,
        100.0 * chaos_overhead
    );
    ci.metric("fleet_chaos_wall_sec", chaos_wall);
    ci.metric("fleet_chaos_re_lease_overhead", chaos_overhead);
    ci.metric("fleet_chaos_duplicate_completions", report.duplicate_completions as f64);

    if let Err(e) = ci.save("BENCH_ci.json") {
        eprintln!("warning: could not write BENCH_ci.json: {e}");
    } else {
        println!("machine-readable figures merged into BENCH_ci.json");
    }
}
