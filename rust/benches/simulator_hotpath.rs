//! Bench: the discrete-event engine + cluster-timeline hot path.
//!
//! A full Fig.-6 sweep simulates ~10⁵ Algorithm-2 iterations; each
//! iteration at K workers is ~4K tasks, so the engine must sustain
//! millions of tasks/second (DESIGN.md §9 target: ≥ 1 M events/s).
//!
//! Besides raw throughput this harness measures every layer of the
//! allocation-free rework (see PERF.md):
//!
//! * rebuild-per-iteration (the old path, kept as the baseline) vs
//!   template **replay** (graph built once, scratch reused);
//! * `simulate_run`'s deterministic **replication** fast path;
//! * the **parallel sweep** at 1 thread vs all cores;
//! * steady-state heap **allocations per replay**, counted by a global
//!   counting allocator (must be 0);
//! * the **calendar event queue vs the retired binary heap** on the
//!   identical K=270 iteration graph (schedules asserted bitwise equal;
//!   calendar must be no slower);
//! * the **order-cached linear replay vs the calendar queue** on that
//!   same K=270 graph, deterministic and jittered (schedule equality
//!   hard-asserted both ways; the deterministic replay must hit the
//!   cache 100% of the time — no bucket scan after the first run — and
//!   perform **zero** heap allocations once warm; hit-rate and fallback
//!   counts land in `BENCH_ci.json`);
//! * the **lane-batched jittered replay vs the scalar loop** on that
//!   same K=270 graph, at **every dispatch width** (4-lane and 8-lane,
//!   pinned per engine via `set_lane_width`): independent jittered
//!   duration sets ride one pass through the order cache (per-lane
//!   equality hard-asserted against the one-at-a-time loop, zero heap
//!   allocations once warm asserted at every width), plus a **padded
//!   remainder** audit (batches narrower than the width ride the same
//!   pass with discarded pad lanes); per-width hit rates, pad counts
//!   (`lane_pad_replays`) and lane-vs-scalar throughput pairs land in
//!   `BENCH_ci.json`;
//! * the **end-to-end jittered sweep** (K=1..270 × 7 jittered
//!   iterations through the pooled queue — no replication shortcut) as
//!   `jittered_sweep_throughput` in tasks/sec, the ROADMAP's
//!   order-of-magnitude target row;
//! * the **shape-class grouped multi-sweep** (4 sizes sharing one K
//!   grid, so every K forms a 4-cell shape bucket): grouped vs per-cell
//!   throughput pair (`jittered_sweep_throughput_grouped` /
//!   `_percell`), grouped results hard-asserted bitwise equal to the
//!   per-cell loop at 1 thread and all cores, plus a template-level
//!   audit per lane width — `group_batches` / `group_spanned_cells` /
//!   `shape_rebinds` counters (multi-cell batches asserted to occur)
//!   and zero heap allocations per warm `run_group_into` pass.
//!
//! ```text
//! cargo bench --bench simulator_hotpath
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use bsf::experiments::{
    analytic_provider, simulated_curve_threads, simulated_curves, ExperimentCtx, SweepJob,
};
use bsf::linalg::kernels;
use bsf::model::scalability::peak_knee;
use bsf::simulator::{
    faults_audit, group_enabled, lane_width, lanes_enabled, run_faulty_into, sched_mode,
    simulate_iteration, simulate_iteration_full, AnalyticCost, CostFactory, Engine, FaultPlan,
    FaultScratch, FaultSpec, GroupCell, IterationTemplate, IterationTiming, RecoveryPolicy,
    ReferenceScheduler, SchedMode, SimParams, TaskId,
};
use bsf::util::bench::{bench_throughput, human_time, CiReport};
use bsf::util::Rng;

/// Counts every allocation so the zero-allocation replay claim is
/// measured, not assumed.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    let mut ci = CiReport::new("simulator_hotpath");
    println!("== simulator_hotpath ==");
    println!(
        "active kernel: {}, scheduler: {}, lanes: {} (dispatch width {}), grouping: {}",
        kernels::active().name(),
        sched_mode().name(),
        if lanes_enabled() { "on" } else { "off" },
        lane_width(),
        if group_enabled() { "on" } else { "off" }
    );
    // Self-describe the configuration that produced these figures.
    let flag = |b: bool| if b { 1.0 } else { 0.0 };
    ci.metric("config_kernel_avx2", flag(kernels::active() == kernels::KernelKind::Avx2));
    ci.metric("config_sched_cached", flag(sched_mode() == SchedMode::Cached));
    ci.metric("config_lanes_on", flag(lanes_enabled()));
    ci.metric("config_lane_width", lane_width() as f64);
    ci.metric("config_faults_audit", flag(faults_audit()));
    ci.metric("config_group", flag(group_enabled()));

    // Raw engine: chain graphs, rebuild vs replay.
    for tasks in [1_000usize, 100_000] {
        bench_throughput(&format!("engine chain rebuild, {tasks} tasks"), 2, 10, tasks as u64, || {
            let mut e = Engine::new();
            let mut prev = e.task(0, 1e-9);
            for i in 1..tasks {
                let t = e.task((i % 64) as u32, 1e-9);
                e.dep(prev, t);
                prev = t;
            }
            std::hint::black_box(e.run());
        });
        let mut e = Engine::new();
        let mut prev = e.task(0, 1e-9);
        for i in 1..tasks {
            let t = e.task((i % 64) as u32, 1e-9);
            e.dep(prev, t);
            prev = t;
        }
        e.run_reuse(); // warm scratch + CSR
        let r = bench_throughput(
            &format!("engine chain replay,  {tasks} tasks"),
            2,
            10,
            tasks as u64,
            || {
                std::hint::black_box(Engine::makespan(e.run_reuse()));
            },
        );
        ci.rate(&r);
    }

    // Full Algorithm-2 iterations at representative scales:
    // rebuild-per-iteration (old path) vs template replay (new path).
    let l = 16_000;
    for k in [16usize, 128, 512] {
        let mut prov = AnalyticCost { t_map_full: 0.77, l, t_a: 2.1e-5, t_p: 5.6e-5 };
        let params = SimParams::new(l, l);
        let tasks_per_iter = IterationTemplate::new(k, l, &params).task_count() as u64;
        let mut rng = Rng::new(7);
        bench_throughput(
            &format!("iteration rebuild K={k} (l={l})"),
            5,
            30,
            tasks_per_iter,
            || {
                std::hint::black_box(simulate_iteration(k, l, &params, &mut prov, &mut rng));
            },
        );
        let mut tmpl = IterationTemplate::new(k, l, &params);
        tmpl.replay(&mut prov, &mut rng); // warm scratch + CSR
        let r = bench_throughput(
            &format!("iteration replay  K={k} (l={l})"),
            5,
            30,
            tasks_per_iter,
            || {
                std::hint::black_box(tmpl.replay(&mut prov, &mut rng));
            },
        );
        ci.rate(&r);
        // Steady-state allocation count: must be zero per replay.
        let reps = 100u64;
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..reps {
            std::hint::black_box(tmpl.replay(&mut prov, &mut rng));
        }
        let allocs = ALLOCS.load(Ordering::Relaxed) - before;
        println!("    -> allocations per replay at K={k}: {}", allocs as f64 / reps as f64);
        ci.metric(format!("allocs_per_replay [K={k}]"), allocs as f64 / reps as f64);
    }

    // A whole deterministic Fig-6-style sweep (one size): the old
    // rebuild-everything loop vs simulate_run's replicate fast path vs the
    // multi-core sweep. Iteration counts mirror a real Fig.-6 point (7
    // averaged iterations per K).
    let n = 10_000usize;
    let prov = AnalyticCost { t_map_full: 0.373, l: n, t_a: 9.31e-6, t_p: 3.7e-5 };
    let params = SimParams::new(n, n);
    let iters = 7usize;
    let ks: Vec<usize> = (1..=270).collect();
    let sweep_iters = (ks.len() * iters) as u64;

    bench_throughput(
        &format!("sweep n={n} K=1..270 x{iters}: rebuild loop (old path)"),
        1,
        3,
        sweep_iters,
        || {
            let mut p = prov.clone();
            let mut rng = Rng::new(8);
            for &k in &ks {
                for _ in 0..iters {
                    std::hint::black_box(simulate_iteration(k, n, &params, &mut p, &mut rng));
                }
            }
        },
    );

    let ctx = ExperimentCtx::default();
    let factory = analytic_provider(&bsf::model::CostParams {
        l: n,
        t_c: params.net.t_c(n, n),
        t_p: 3.7e-5,
        t_map: 0.373,
        t_a: 9.31e-6,
    });
    bench_throughput(
        &format!("sweep n={n} K=1..270 x{iters}: replicate, 1 thread"),
        1,
        3,
        sweep_iters,
        || {
            let mut rng = Rng::new(8);
            std::hint::black_box(simulated_curve_threads(
                &ctx, &params, n, &factory, &ks, iters, &mut rng, 1,
            ));
        },
    );
    let threads = bsf::util::parallel::default_threads();
    let r = bench_throughput(
        &format!("sweep n={n} K=1..270 x{iters}: replicate, {threads} threads"),
        1,
        3,
        sweep_iters,
        || {
            let mut rng = Rng::new(8);
            std::hint::black_box(simulated_curve_threads(
                &ctx, &params, n, &factory, &ks, iters, &mut rng, threads,
            ));
        },
    );
    println!(
        "    -> full-sweep wall time (all cores): {}",
        human_time(r.summary.median)
    );
    ci.rate(&r);
    ci.metric("sweep_wall_sec_all_cores", r.summary.median);

    // End-to-end jittered sweep: the ROADMAP's order-of-magnitude target
    // row. Same grid (K=1..270, 7 iterations per point) but with jitter
    // on, so no replication shortcut applies — every iteration replays
    // through the lane-batched path, padded remainders included (7 iters
    // = 4+3 at width 4, one 7-lane padded batch at width 8). Tasks/sec
    // over the *actual* task graphs, so the figure is an end-to-end
    // metric, not an inference from micro-pairs.
    let mut params_jit = params.clone();
    params_jit.jitter_comp = 0.05;
    params_jit.jitter_comm = 0.03;
    let jit_tasks: u64 = ks
        .iter()
        .map(|&k| IterationTemplate::new(k, n, &params_jit).task_count() as u64)
        .sum::<u64>()
        * iters as u64;
    let r = bench_throughput(
        &format!("sweep n={n} K=1..270 x{iters}: jittered,  {threads} threads"),
        1,
        3,
        jit_tasks,
        || {
            let mut rng = Rng::new(8);
            std::hint::black_box(simulated_curve_threads(
                &ctx,
                &params_jit,
                n,
                &factory,
                &ks,
                iters,
                &mut rng,
                threads,
            ));
        },
    );
    ci.rate(&r);
    ci.metric("jittered_sweep_throughput", jit_tasks as f64 / r.summary.mean);

    // Calendar queue vs the retired binary-heap event loop, same graph:
    // the Fig.-6 iteration at K=270 (the paper's largest Jacobi sweep
    // point). The acceptance bar is "calendar no slower than heap".
    let mut prov_cmp = AnalyticCost { t_map_full: 0.373, l: n, t_a: 9.31e-6, t_p: 3.7e-5 };
    let (_, mut eng, _) =
        simulate_iteration_full(270, n, &params, &mut prov_cmp, &mut Rng::new(14));
    // Pin this engine to the pure calendar path so the line below measures
    // the event queue, not the order cache, whatever BSF_SCHED says.
    eng.set_sched_mode(Some(SchedMode::Calendar));
    let mut heap_ref = ReferenceScheduler::from_engine(&eng);
    let want = heap_ref.run().to_vec();
    let got = eng.run_reuse();
    assert_eq!(want.len(), got.len());
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.to_bits(), g.to_bits(), "heap vs calendar diverge at task {i}");
    }
    let tasks = eng.len() as u64;
    let r = bench_throughput("event loop: heap reference, K=270 graph", 3, 20, tasks, || {
        std::hint::black_box(ReferenceScheduler::run(&mut heap_ref));
    });
    ci.rate(&r);
    let r = bench_throughput("event loop: calendar queue,  K=270 graph", 3, 20, tasks, || {
        std::hint::black_box(Engine::makespan(eng.run_reuse()));
    });
    ci.rate(&r);

    // Order-cached linear replay vs the calendar queue, same K=270 graph
    // (two engines holding the identical graph, explicitly pinned to one
    // scheduler each — the `_with`-style race, independent of BSF_SCHED).
    let (_, mut eng_cal, _) =
        simulate_iteration_full(270, n, &params, &mut prov_cmp, &mut Rng::new(14));
    let (_, mut eng_oc, _) =
        simulate_iteration_full(270, n, &params, &mut prov_cmp, &mut Rng::new(14));
    eng_cal.set_sched_mode(Some(SchedMode::Calendar));
    eng_oc.set_sched_mode(Some(SchedMode::Cached));
    eng_oc.run_reuse(); // record the pop order once

    // (a) deterministic durations: every replay must be a cache hit —
    // after the first run, no calendar bucket scan ever executes again.
    let before = eng_oc.sched_counters();
    let r = bench_throughput("replay det: calendar queue,  K=270 graph", 3, 20, tasks, || {
        std::hint::black_box(Engine::makespan(eng_cal.run_reuse()));
    });
    ci.rate(&r);
    let r = bench_throughput("replay det: order-cached,    K=270 graph", 3, 20, tasks, || {
        std::hint::black_box(Engine::makespan(eng_oc.run_reuse()));
    });
    ci.rate(&r);
    {
        let want = eng_cal.run_reuse().to_vec();
        let got = eng_oc.run_reuse();
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "calendar vs order-cached diverge at task {i}");
        }
    }
    let after = eng_oc.sched_counters();
    assert_eq!(
        after.calendar_runs,
        before.calendar_runs,
        "deterministic replay fell back to the calendar"
    );
    assert_eq!(after.fallbacks, before.fallbacks);
    let det_replays = after.cached_hits - before.cached_hits;
    println!("    -> deterministic cache hit-rate: 100% ({det_replays} replays, 0 fallbacks)");
    ci.metric("cached_hit_rate_deterministic", 1.0);

    // Zero heap allocations once warm (hard assert, like the template
    // replay audit above).
    let before_allocs = ALLOCS.load(Ordering::Relaxed);
    let reps = 100u64;
    for _ in 0..reps {
        std::hint::black_box(Engine::makespan(eng_oc.run_reuse()));
    }
    let allocs = ALLOCS.load(Ordering::Relaxed) - before_allocs;
    assert_eq!(allocs, 0, "order-cached replay must be zero-alloc once warm");
    println!("    -> allocations per order-cached replay: {}", allocs as f64 / reps as f64);
    ci.metric("allocs_per_cached_replay", allocs as f64 / reps as f64);

    // (b) jittered durations (small lognormal, the Fig.-6 ablation
    // regime): equality hard-asserted per replay, hit-rate recorded.
    let base: Vec<f64> = eng_oc.durations().to_vec();
    let sigma = 0.01;
    let mut rj_cal = Rng::new(21);
    let mut rj_oc = Rng::new(21);
    let before = eng_oc.sched_counters();
    let audit_reps = 40u64;
    for _ in 0..audit_reps {
        for (id, &b) in base.iter().enumerate() {
            eng_cal.set_duration(id as TaskId, b * rj_cal.jitter(sigma));
            eng_oc.set_duration(id as TaskId, b * rj_oc.jitter(sigma));
        }
        let want = eng_cal.run_reuse().to_vec();
        let got = eng_oc.run_reuse();
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "jittered schedules diverge at task {i}");
        }
    }
    let after = eng_oc.sched_counters();
    let hits = after.cached_hits - before.cached_hits;
    let falls = after.fallbacks - before.fallbacks;
    let hit_rate = hits as f64 / audit_reps as f64;
    println!(
        "    -> jittered (sigma={sigma}) cache hit-rate: {:.1}% ({hits} hits, {falls} fallbacks)",
        hit_rate * 100.0
    );
    ci.metric("cached_hit_rate_jittered", hit_rate);
    ci.metric("cached_fallbacks_jittered", falls as f64);
    let r = bench_throughput("replay jit: calendar queue,  K=270 graph", 3, 20, tasks, || {
        for (id, &b) in base.iter().enumerate() {
            eng_cal.set_duration(id as TaskId, b * rj_cal.jitter(sigma));
        }
        std::hint::black_box(Engine::makespan(eng_cal.run_reuse()));
    });
    ci.rate(&r);
    let r = bench_throughput("replay jit: order-cached,    K=270 graph", 3, 20, tasks, || {
        for (id, &b) in base.iter().enumerate() {
            eng_oc.set_duration(id as TaskId, b * rj_oc.jitter(sigma));
        }
        std::hint::black_box(Engine::makespan(eng_oc.run_reuse()));
    });
    ci.rate(&r);

    // (c) lane-batched jittered replay vs the scalar one-at-a-time loop,
    // same K=270 graph, once per dispatch width: independent jittered
    // duration sets per pass through the order cache. Both engines
    // pinned to the cached scheduler; the lane engine forces the vector
    // pass on and pins its width (the `set_lane_mode`/`set_lane_width`
    // analogue of the `_with` races above) so this section measures both
    // widths whatever BSF_LANES / BSF_LANE_WIDTH say, under the
    // process's BSF_KERNEL implementation family (width 8 without
    // avx512f runs the width-generic scalar twin — the row is still
    // recorded, labeled by width, so the CI compare sees which hardware
    // produced it; `config_lane_width` above says what a real sweep
    // would dispatch).
    let mut total_pads = 0u64;
    for width in [4usize, 8] {
        println!("\n-- lane-batched replay, width {width} --");
        let (_, mut eng_sc, _) =
            simulate_iteration_full(270, n, &params, &mut prov_cmp, &mut Rng::new(14));
        let (_, mut eng_ln, _) =
            simulate_iteration_full(270, n, &params, &mut prov_cmp, &mut Rng::new(14));
        eng_sc.set_sched_mode(Some(SchedMode::Cached));
        eng_ln.set_sched_mode(Some(SchedMode::Cached));
        eng_ln.set_lane_mode(Some(true));
        eng_ln.set_lane_width(Some(width));
        eng_sc.run_reuse();
        eng_ln.run_reuse(); // record the pop order once each
        assert_eq!(eng_ln.len() as u64, tasks, "lane engine graph drifted from the reference");
        let mut rl_sc = Rng::new(23);
        let mut rl_ln = Rng::new(23);

        // Correctness audit: every lane of every batch must equal the
        // scalar loop replaying the identical duration sets, bit for bit.
        let before = eng_ln.sched_counters();
        let lane_batches = 40u64;
        for _ in 0..lane_batches {
            let mat = eng_ln.lane_durations_mut(width);
            for m in 0..width {
                for (i, &b) in base.iter().enumerate() {
                    mat[i * width + m] = b * rl_ln.jitter(sigma);
                }
            }
            eng_ln.run_lanes(width);
            for m in 0..width {
                for (i, &b) in base.iter().enumerate() {
                    eng_sc.set_duration(i as TaskId, b * rl_sc.jitter(sigma));
                }
                let want = eng_sc.run_reuse();
                let got = eng_ln.lane_finish();
                for (i, w) in want.iter().enumerate() {
                    assert_eq!(
                        w.to_bits(),
                        got[i * width + m].to_bits(),
                        "width {width} lane {m} diverges from the scalar loop at task {i}"
                    );
                }
                assert_eq!(
                    eng_sc.last_makespan().to_bits(),
                    eng_ln.lane_makespans()[m].to_bits(),
                    "width {width} lane {m} makespan diverges"
                );
            }
        }
        let after = eng_ln.sched_counters();
        assert_eq!(after.lane_width, width as u64, "dispatched width drifted");
        let lhits = after.lane_hits - before.lane_hits;
        let lfalls = after.lane_fallbacks - before.lane_fallbacks;
        let lane_rate = lhits as f64 / (lane_batches * width as u64) as f64;
        println!(
            "    -> lane (sigma={sigma}) hit-rate: {:.1}% ({lhits} hits, {lfalls} batch fallbacks)",
            lane_rate * 100.0
        );
        ci.metric(format!("lane_hit_rate_jittered [w={width}]"), lane_rate);
        ci.metric(format!("lane_fallbacks_jittered [w={width}]"), lfalls as f64);

        // Padded remainder audit: a batch of 3 real lanes rides the same
        // width-wide pass with (width - 3) discarded pad lanes — the real
        // lanes must still equal the scalar loop bitwise, and the pad
        // economics must land in the counters.
        let before = eng_ln.sched_counters();
        let rem = 3usize;
        let pad_batches = 10u64;
        for _ in 0..pad_batches {
            let mat = eng_ln.lane_durations_mut(rem);
            for m in 0..rem {
                for (i, &b) in base.iter().enumerate() {
                    mat[i * rem + m] = b * rl_ln.jitter(sigma);
                }
            }
            eng_ln.run_lanes(rem);
            for m in 0..rem {
                for (i, &b) in base.iter().enumerate() {
                    eng_sc.set_duration(i as TaskId, b * rl_sc.jitter(sigma));
                }
                let want = eng_sc.run_reuse();
                let got = eng_ln.lane_finish();
                for (i, w) in want.iter().enumerate() {
                    assert_eq!(
                        w.to_bits(),
                        got[i * rem + m].to_bits(),
                        "width {width} padded lane {m} diverges at task {i}"
                    );
                }
            }
        }
        let after = eng_ln.sched_counters();
        let pads = after.lane_pad_replays - before.lane_pad_replays;
        let pad_hits = after.lane_hits - before.lane_hits;
        println!(
            "    -> padded remainder (3 of {width}): {pad_hits} real-lane hits, {pads} pad replays"
        );
        total_pads += pads;

        // Zero heap allocations once warm — matrix fill + lane pass (and
        // any per-lane fallback it takes) must never touch the allocator,
        // full and padded batches alike.
        let before_allocs = ALLOCS.load(Ordering::Relaxed);
        let lane_reps = 25u64;
        for _ in 0..lane_reps {
            for lanes in [width, rem] {
                let mat = eng_ln.lane_durations_mut(lanes);
                for m in 0..lanes {
                    for (i, &b) in base.iter().enumerate() {
                        mat[i * lanes + m] = b * rl_ln.jitter(sigma);
                    }
                }
                std::hint::black_box(eng_ln.run_lanes(lanes).len());
            }
        }
        let allocs = ALLOCS.load(Ordering::Relaxed) - before_allocs;
        assert_eq!(allocs, 0, "lane-batched replay must be zero-alloc once warm (width {width})");
        println!("    -> allocations per lane batch: {}", allocs as f64 / (2 * lane_reps) as f64);
        ci.metric(format!("allocs_per_lane_batch [w={width}]"), allocs as f64 / (2 * lane_reps) as f64);

        // Throughput: `width` jittered replays per timed unit on both
        // paths. Re-sync the jitter streams (the audits advanced them
        // unevenly) so both timed loops replay identical duration sets.
        rl_sc = Rng::new(29);
        rl_ln = Rng::new(29);
        let r = bench_throughput(
            &format!("replay jit: scalar loop x{width},  K=270 graph"),
            3,
            20,
            tasks * width as u64,
            || {
                for _ in 0..width {
                    for (i, &b) in base.iter().enumerate() {
                        eng_sc.set_duration(i as TaskId, b * rl_sc.jitter(sigma));
                    }
                    std::hint::black_box(Engine::makespan(eng_sc.run_reuse()));
                }
            },
        );
        ci.rate(&r);
        let r = bench_throughput(
            &format!("replay jit: lane-batched x{width}, K=270 graph"),
            3,
            20,
            tasks * width as u64,
            || {
                let mat = eng_ln.lane_durations_mut(width);
                for m in 0..width {
                    for (i, &b) in base.iter().enumerate() {
                        mat[i * width + m] = b * rl_ln.jitter(sigma);
                    }
                }
                eng_ln.run_lanes(width);
                std::hint::black_box(eng_ln.lane_makespans()[width - 1]);
            },
        );
        ci.rate(&r);
        // Padded-remainder throughput: 3 replays through one padded pass
        // (this PR) vs the same 3 through the scalar loop (the old
        // scalar-remainder path) — the padded batch must win.
        let r = bench_throughput(
            &format!("replay jit: scalar rem x3 (w={width}), K=270 graph"),
            3,
            20,
            tasks * 3,
            || {
                for _ in 0..3 {
                    for (i, &b) in base.iter().enumerate() {
                        eng_sc.set_duration(i as TaskId, b * rl_sc.jitter(sigma));
                    }
                    std::hint::black_box(Engine::makespan(eng_sc.run_reuse()));
                }
            },
        );
        ci.rate(&r);
        let r = bench_throughput(
            &format!("replay jit: padded rem x3 (w={width}), K=270 graph"),
            3,
            20,
            tasks * 3,
            || {
                let mat = eng_ln.lane_durations_mut(3);
                for m in 0..3 {
                    for (i, &b) in base.iter().enumerate() {
                        mat[i * 3 + m] = b * rl_ln.jitter(sigma);
                    }
                }
                eng_ln.run_lanes(3);
                std::hint::black_box(eng_ln.lane_makespans()[2]);
            },
        );
        ci.rate(&r);
    }
    ci.metric("lane_pad_replays", total_pads as f64);

    // Shape-class grouped multi-sweep (this PR): a Fig.-6-style jittered
    // sweep over FOUR list sizes sharing one K grid. All four cells at a
    // given K have equal ShapeClass (same graph, different duration
    // payload), so the shape-bucketed partition routes them through one
    // shared template whose lane batches span cell boundaries — the
    // remainder iterations that used to pad with duplicates now carry
    // the next cell's real durations.
    {
        println!("\n-- shape-class grouped sweep (4 sizes, jittered) --");
        let sizes = [2_500usize, 5_000, 10_000, 16_000];
        let gks: Vec<usize> = (1..=96).collect();
        let giters = 7usize;
        let provs: Vec<AnalyticCost> = sizes
            .iter()
            .map(|&s| AnalyticCost { t_map_full: 0.373, l: s, t_a: 9.31e-6, t_p: 3.7e-5 })
            .collect();
        let gsims: Vec<SimParams> = sizes
            .iter()
            .map(|&s| {
                let mut p = SimParams::new(s, s);
                p.jitter_comp = 0.05;
                p.jitter_comm = 0.03;
                p
            })
            .collect();
        let build_jobs = |group: Option<bool>| {
            let mut rng = Rng::new(0x6E0);
            sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    SweepJob::new(gsims[i].clone(), s, &provs[i], gks.clone(), giters, &mut rng)
                        .set_group_mode(group)
                })
                .collect::<Vec<_>>()
        };
        // Grouping must be invisible in the numbers: the grouped sweep
        // equals the per-cell loop bitwise, serial and pooled alike.
        let want = simulated_curves(&build_jobs(Some(false)), 1);
        for t in [1usize, threads] {
            let got = simulated_curves(&build_jobs(Some(true)), t);
            for (s, (wc, gc)) in want.iter().zip(&got).enumerate() {
                for (w, g) in wc.iter().zip(gc) {
                    assert_eq!(
                        w.t_k.to_bits(),
                        g.t_k.to_bits(),
                        "grouped sweep diverges from per-cell: size {} K={} ({t} threads)",
                        sizes[s],
                        w.k
                    );
                }
            }
        }
        // The graph structure is size-independent (that is the point of
        // the shape key), so one template per K prices the task grid for
        // all four sizes.
        let gtasks: u64 = gks
            .iter()
            .map(|&k| IterationTemplate::new(k, sizes[0], &gsims[0]).task_count() as u64)
            .sum::<u64>()
            * (giters * sizes.len()) as u64;
        let r = bench_throughput(
            &format!("msweep 4 sizes K=1..96 x{giters}: per-cell, {threads} threads"),
            1,
            3,
            gtasks,
            || {
                std::hint::black_box(simulated_curves(&build_jobs(Some(false)), threads));
            },
        );
        ci.rate(&r);
        ci.metric("jittered_sweep_throughput_percell", gtasks as f64 / r.summary.mean);
        let r = bench_throughput(
            &format!("msweep 4 sizes K=1..96 x{giters}: grouped,  {threads} threads"),
            1,
            3,
            gtasks,
            || {
                std::hint::black_box(simulated_curves(&build_jobs(Some(true)), threads));
            },
        );
        ci.rate(&r);
        ci.metric("jittered_sweep_throughput_grouped", gtasks as f64 / r.summary.mean);

        // Template-level audit at K=64, once per lane width: one shared
        // template rides the 4-cell bucket through run_group_into; the
        // reference binds and replays each cell alone through run_into.
        // Bitwise equal, multi-cell batches must actually occur, and the
        // warm grouped pass must never touch the allocator.
        let gk = 64usize;
        for width in [4usize, 8] {
            let mk_cells = || -> Vec<GroupCell> {
                let root = Rng::new(0x6E1);
                sizes
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| {
                        GroupCell::new(
                            Box::new(provs[i].clone()),
                            root.split(i as u64),
                            s,
                            &gsims[i],
                        )
                    })
                    .collect()
            };
            let mut tmpl = IterationTemplate::new(gk, sizes[0], &gsims[0]);
            tmpl.set_lane_mode(Some(true));
            tmpl.set_lane_width(Some(width));
            let mut want: Vec<IterationTiming> = Vec::new();
            let mut tmp = Vec::new();
            for c in &mut mk_cells() {
                tmpl.reset_shape(gk, c.l, &c.params);
                tmpl.run_into(giters, c.provider.as_mut(), &mut c.rng, &mut tmp);
                want.extend_from_slice(&tmp);
            }
            let before = tmpl.sched_counters();
            let mut got: Vec<IterationTiming> = Vec::new();
            let mut cells = mk_cells();
            tmpl.run_group_into(&mut cells, giters, &mut got);
            let after = tmpl.sched_counters();
            assert_eq!(want.len(), got.len(), "width {width}: grouped replay count");
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(w, g, "width {width}: grouped flat replay {i} diverges from per-cell");
            }
            let batches = after.group_batches - before.group_batches;
            let spanned = after.group_spanned_cells - before.group_spanned_cells;
            let rebinds = after.shape_rebinds - before.shape_rebinds;
            assert!(
                spanned > 0,
                "width {width}: no lane batch ever spanned a cell boundary"
            );
            println!(
                "    -> width {width}: {batches} group batches, {spanned} spanned cell \
                 boundaries, {rebinds} payload rebinds"
            );
            ci.metric(format!("group_batches [w={width}]"), batches as f64);
            ci.metric(format!("group_spanned_cells [w={width}]"), spanned as f64);
            ci.metric(format!("group_shape_rebinds [w={width}]"), rebinds as f64);

            // Zero heap allocations once warm: payload rebinds (closed-form
            // chunk sizes + comm re-pricing), lane-matrix refreshes and the
            // timing pushes all reuse capacity from the first pass.
            tmpl.run_group_into(&mut cells, giters, &mut got); // warm out + matrix
            let reps = 25u64;
            let before_allocs = ALLOCS.load(Ordering::Relaxed);
            for _ in 0..reps {
                tmpl.run_group_into(&mut cells, giters, &mut got);
                std::hint::black_box(got.len());
            }
            let allocs = ALLOCS.load(Ordering::Relaxed) - before_allocs;
            assert_eq!(
                allocs, 0,
                "grouped lane batches must be zero-alloc once warm (width {width})"
            );
            println!("    -> allocations per grouped pass: {}", allocs as f64 / reps as f64);
            ci.metric(format!("allocs_per_group_pass [w={width}]"), allocs as f64 / reps as f64);
        }
    }

    // Faulty-sweep smoke: run a clean and a fault-injected sweep over the
    // same per-K split streams and track (a) how much recovery work
    // inflates the mean iteration time and (b) how far the speedup peak
    // K* retreats. Both ride BENCH_ci.json so the bench-compare step
    // flags drift in the fault plane's cost model.
    {
        println!("\n-- faulty-sweep smoke (failure rate 5%, stragglers 3x) --");
        let l = 1_500;
        let mut params = SimParams::new(l, l);
        params.jitter_comp = 0.05;
        let prov = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
        let ks: Vec<usize> = (1..=48).collect();
        let spec = FaultSpec {
            speed_sigma: 0.05,
            straggler_prob: 0.1,
            straggler_factor: 3.0,
            fail_prob: 0.05,
            downtime: 2,
            policy: RecoveryPolicy::Redistribute,
            speed_drift: 0.0,
            hazard_drift: 0.0,
        };
        let mut rng = Rng::new(0xFA11);
        let jobs = vec![
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 6, &mut rng),
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 6, &mut rng).with_fault(spec),
        ];
        let curves = simulated_curves(&jobs, 4);
        let (clean, faulty) = (&curves[0], &curves[1]);
        let mean = |c: &[_]| {
            c.iter().map(|p: &bsf::model::scalability::SpeedupPoint| p.t_k).sum::<f64>()
                / c.len() as f64
        };
        let overhead = mean(faulty) / mean(clean);
        let w = (ks.len() / 10).max(3);
        let peak = |c: &[bsf::model::scalability::SpeedupPoint]| {
            peak_knee(c, w, 0.99).map(|p| p.k).unwrap_or(0)
        };
        let shift = peak(clean) as f64 - peak(faulty) as f64;
        println!(
            "    recovery overhead: {:.3}x mean T(K); boundary shift: {:+} nodes (K*={} -> {})",
            overhead,
            shift,
            peak(clean),
            peak(faulty)
        );
        ci.metric("fault_recovery_overhead", overhead);
        ci.metric("boundary_shift_k", shift);
    }

    // Non-stationary smoke: checkpoint/restart overhead with zero
    // failures, the cost-optimal interval's shift with the failure rate,
    // and the K* retreat a contended shared link costs. All three land in
    // BENCH_ci.json so drift in the new planes is flagged by bench-compare.
    {
        println!("\n-- non-stationary smoke (checkpointing + shared link) --");
        let l = 1_500;
        let k = 16;
        let iters = 40;
        let params = SimParams::new(l, l);
        let prov = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };

        // (a) Pure checkpoint overhead: no failures, so the only extra
        // cost is the periodic save task — the ratio must sit just above 1.
        let mut tmpl = IterationTemplate::new(k, l, &params);
        let mut scratch = FaultScratch::default();
        let mut runs = Vec::new();
        let mean_with = |tmpl: &mut IterationTemplate,
                         runs: &mut Vec<IterationTiming>,
                         scratch: &mut FaultScratch,
                         plan: &FaultPlan| {
            let mut provider = prov.instance(k as u64);
            let mut rng = Rng::new(0xC4E0);
            run_faulty_into(tmpl, plan, l, &params, iters, provider.as_mut(), &mut rng, runs, scratch);
            runs.iter().map(|t| t.total).sum::<f64>() / runs.len() as f64
        };
        let clean_mean = mean_with(&mut tmpl, &mut runs, &mut scratch, &FaultPlan::clean(k));
        let ckpt_plan =
            FaultPlan::clean(k).with_policy(RecoveryPolicy::Checkpoint { interval: 4 });
        let ckpt_mean = mean_with(&mut tmpl, &mut runs, &mut scratch, &ckpt_plan);
        let ckpt_overhead = ckpt_mean / clean_mean;
        println!("    checkpoint overhead (interval 4, zero failures): {ckpt_overhead:.4}x");
        ci.metric("checkpoint_overhead", ckpt_overhead);

        // (b) The cost-optimal interval tightens as failures grow: argmin
        // interval at 2% minus argmin at 8% over a small grid.
        let argmin_iv = |fail: f64| {
            let ivs = [1u64, 2, 4, 8, 16];
            let mut best = (f64::INFINITY, ivs[0]);
            for &iv in &ivs {
                let spec = FaultSpec {
                    fail_prob: fail,
                    downtime: 2,
                    policy: RecoveryPolicy::Checkpoint { interval: iv },
                    ..FaultSpec::clean()
                };
                let root = Rng::new(0xC4E1).split((fail.to_bits() >> 8) ^ iv);
                let plan = FaultPlan::generate(&spec, k, iters as u64, &root);
                let mut tmpl = IterationTemplate::new(k, l, &params);
                let mut scratch = FaultScratch::default();
                let mut runs = Vec::new();
                let mut provider = prov.instance(k as u64);
                let mut rng = root.split(7);
                run_faulty_into(
                    &mut tmpl,
                    &plan,
                    l,
                    &params,
                    iters,
                    provider.as_mut(),
                    &mut rng,
                    &mut runs,
                    &mut scratch,
                );
                let mean = runs.iter().map(|t| t.total).sum::<f64>() / runs.len() as f64;
                if mean < best.0 {
                    best = (mean, iv);
                }
            }
            best.1
        };
        let (iv_lo, iv_hi) = (argmin_iv(0.02), argmin_iv(0.08));
        let iv_shift = iv_lo as f64 - iv_hi as f64;
        println!("    optimal interval: {iv_lo} @ 2% -> {iv_hi} @ 8% (shift {iv_shift:+})");
        ci.metric("optimal_interval_shift", iv_shift);

        // (c) Contended-link boundary retreat: the same sweep per-edge vs
        // shared; bandwidth splitting can only push K* down.
        let ks: Vec<usize> = (1..=48).collect();
        let mut shared = params.clone();
        shared.net.link = bsf::net::LinkMode::Shared;
        let mut rng = Rng::new(0xC4E2);
        let jobs = vec![
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 6, &mut rng),
            SweepJob::new(shared, l, &prov, ks.clone(), 6, &mut rng),
        ];
        let curves = simulated_curves(&jobs, 4);
        let w = (ks.len() / 10).max(3);
        let peak = |c: &[bsf::model::scalability::SpeedupPoint]| {
            peak_knee(c, w, 0.99).map(|p| p.k).unwrap_or(0)
        };
        let shift = peak(&curves[0]) as f64 - peak(&curves[1]) as f64;
        println!(
            "    contended boundary shift: {:+} nodes (K*={} -> {})",
            shift,
            peak(&curves[0]),
            peak(&curves[1])
        );
        ci.metric("contended_boundary_shift_k", shift);
    }

    if let Err(e) = ci.save("BENCH_ci.json") {
        eprintln!("warning: could not write BENCH_ci.json: {e}");
    } else {
        println!("machine-readable figures merged into BENCH_ci.json");
    }
}
