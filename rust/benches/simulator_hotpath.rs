//! Bench: the discrete-event engine + cluster-timeline hot path.
//!
//! A full Fig.-6 sweep simulates ~10⁵ Algorithm-2 iterations; each
//! iteration at K workers is ~4K tasks, so the engine must sustain
//! millions of tasks/second (DESIGN.md §9 target: ≥ 1 M events/s).
//!
//! ```text
//! cargo bench --bench simulator_hotpath
//! ```

use bsf::simulator::{simulate_iteration, AnalyticCost, Engine, SimParams};
use bsf::util::bench::bench_throughput;
use bsf::util::Rng;

fn main() {
    println!("== simulator_hotpath ==");

    // Raw engine: chain + fan-out graphs.
    for tasks in [1_000usize, 100_000] {
        bench_throughput(&format!("engine chain, {tasks} tasks"), 2, 10, tasks as u64, || {
            let mut e = Engine::new();
            let mut prev = e.task(0, 1e-9);
            for i in 1..tasks {
                let t = e.task((i % 64) as u32, 1e-9);
                e.dep(prev, t);
                prev = t;
            }
            std::hint::black_box(e.run());
        });
    }

    // Full Algorithm-2 iterations at representative scales.
    let l = 16_000;
    for k in [16usize, 128, 512] {
        let tasks_per_iter = 4 * k as u64; // bcast + compute + reduce + folds
        let mut prov = AnalyticCost { t_map_full: 0.77, l, t_a: 2.1e-5, t_p: 5.6e-5 };
        let params = SimParams::new(l, l);
        let mut rng = Rng::new(7);
        bench_throughput(
            &format!("simulate_iteration K={k} (l={l})"),
            5,
            30,
            tasks_per_iter,
            || {
                std::hint::black_box(simulate_iteration(k, l, &params, &mut prov, &mut rng));
            },
        );
    }

    // A whole quick Fig-6-style sweep (one size).
    let mut prov = AnalyticCost { t_map_full: 0.373, l: 10_000, t_a: 9.31e-6, t_p: 3.7e-5 };
    let params = SimParams::new(10_000, 10_000);
    let mut rng = Rng::new(8);
    bench_throughput("sweep n=10000, K=1..270 x3 iters", 1, 5, 270 * 3, || {
        for k in 1..=270usize {
            for _ in 0..3 {
                std::hint::black_box(simulate_iteration(k, 10_000, &params, &mut prov, &mut rng));
            }
        }
    });
}
