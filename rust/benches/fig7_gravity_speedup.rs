//! Bench: regenerate Fig. 7 / Table 4 (BSF-Gravity speedup curves, paper
//! parameters) and time the pipeline per size.
//!
//! ```text
//! cargo bench --bench fig7_gravity_speedup
//! ```

use bsf::experiments::{
    analytic_provider, boundary_row, paper_gravity_params, ExperimentCtx,
};
use bsf::util::bench::bench;
use bsf::util::Rng;

fn main() {
    let ctx = ExperimentCtx { quick: true, ..Default::default() };
    println!("== fig7_gravity_speedup: per-size curve regeneration ==");
    let mut rows = Vec::new();
    for n in [300usize, 600, 900, 1_200] {
        let params = paper_gravity_params(n).expect("published");
        bench(&format!("fig7 curve n={n}"), 1, 5, || {
            let prov = analytic_provider(&params);
            let mut rng = Rng::new(1);
            let row = boundary_row(&ctx, n, &params, 7, 3, &prov, &mut rng);
            std::hint::black_box(&row);
        });
        let prov = analytic_provider(&params);
        let mut rng = Rng::new(1);
        rows.push(boundary_row(&ctx, n, &params, 7, 3, &prov, &mut rng));
    }
    println!("\nregenerated Table 4 (paper K_test: 60/140/200/280):");
    for r in rows {
        println!(
            "  n={:<6} K_BSF={:<6.0} K_test={:<6.0} err={:.3}",
            r.n, r.k_bsf, r.k_test, r.error
        );
    }
}
