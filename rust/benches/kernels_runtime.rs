//! Bench: PJRT artifact execution vs native Rust on the worker hot path.
//!
//! Measures per-call latency of the AOT Pallas kernels (`jacobi_map`,
//! `gravity_map`, `cimmino_map`) through the runtime, against the
//! bit-equivalent native implementations — quantifying the PJRT call
//! overhead and the crossover block size. Requires `make artifacts`.
//!
//! ```text
//! cargo bench --bench kernels_runtime
//! ```

use bsf::linalg::generators::paper_system;
use bsf::problems::{GravityProblem, JacobiProblem};
use bsf::coordinator::BsfProblem;
use bsf::runtime::{KernelRuntime, Tensor};
use bsf::util::bench::bench_throughput;
use bsf::util::Rng;

fn main() {
    println!("== kernels_runtime ==");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let rt = KernelRuntime::open(dir).expect("open runtime");
    let mut rng = Rng::new(42);

    // Raw artifact call: jacobi_map_n{N} (one block of B columns).
    for n in [256usize, 1024, 2048] {
        let Some(name) = rt.manifest().jacobi_map(n) else { continue };
        rt.warm(&name).unwrap();
        let b = rt.block();
        let c: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
        let flops = (2 * n * b) as u64;
        bench_throughput(&format!("pjrt jacobi_map n={n} B={b}"), 3, 30, flops, || {
            let out = rt
                .execute(&name, &[Tensor::mat(c.clone(), n, b), Tensor::vec(x.clone())])
                .unwrap();
            std::hint::black_box(&out);
        });
    }

    // Whole-problem map_fold: kernel path vs native path.
    for n in [1024usize, 2048] {
        let p = JacobiProblem::new(paper_system(n), 1e-12);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let flops = (2 * n * n) as u64;
        bench_throughput(&format!("jacobi map_fold n={n} [pjrt]"), 2, 15, flops, || {
            std::hint::black_box(p.map_fold(0..n, &x, Some(&rt)));
        });
        bench_throughput(&format!("jacobi map_fold n={n} [native]"), 2, 15, flops, || {
            std::hint::black_box(p.map_fold(0..n, &x, None));
        });
    }

    // Gravity block kernel.
    let g = GravityProblem::new(bsf::linalg::generators::random_bodies(1024, 5.0, 7), 1e-3, 1.0);
    let xg = g.initial_approx();
    bench_throughput("gravity map_fold n=1024 [pjrt]", 2, 15, 17 * 1024, || {
        std::hint::black_box(g.map_fold(0..1024, &xg, Some(&rt)));
    });
    bench_throughput("gravity map_fold n=1024 [native]", 2, 15, 17 * 1024, || {
        std::hint::black_box(g.map_fold(0..1024, &xg, None));
    });
}
