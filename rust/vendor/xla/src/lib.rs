//! Offline **API stub** of the vendored XLA/PJRT FFI crate.
//!
//! The `bsf` crate's `pjrt` feature compiles its kernel-execution path
//! against this surface (`PjRtClient`, `PjRtLoadedExecutable`,
//! `PjRtBuffer`, `Literal`, `HloModuleProto`, `XlaComputation`), so the
//! runtime code is type-checked in CI even though the build is fully
//! offline. Every entry point that would touch XLA returns an [`Error`]
//! — in particular [`PjRtClient::cpu`] fails, so `KernelRuntime::open`
//! degrades exactly like a missing artifact directory and callers take
//! the native compute path.
//!
//! Hosts provisioned with the XLA toolchain swap this path dependency
//! for the real vendored crate (same API) to execute AOT artifacts.

use std::rc::Rc;

/// Error type mirroring the FFI crate's (stringly, `Display`-able).
#[derive(Debug)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err() -> Error {
    Error(
        "offline xla stub: swap rust/vendor/xla for the real vendored XLA \
         crate to execute PJRT artifacts"
            .to_string(),
    )
}

/// PJRT client handle. `Rc`-based like the real crate — deliberately
/// **not** `Send`, which is what forces `bsf` to keep one runtime per
/// worker thread.
#[derive(Debug)]
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    /// CPU client constructor — always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(stub_err())
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(stub_err())
    }

    /// Upload a host buffer to the device (row-major `dims`).
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _layout: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(stub_err())
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    /// Execute on device buffers; returns per-device, per-output buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(stub_err())
    }
}

/// A device buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _not_send: Rc<()>,
}

impl PjRtBuffer {
    /// Synchronously copy the buffer back into a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(stub_err())
    }
}

/// A host-side literal (tensor or tuple of tensors).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(stub_err())
    }

    /// Copy the literal's elements out as a flat vector.
    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>, Error> {
        Err(stub_err())
    }
}

/// Parsed HLO module text.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file (the artifact interchange format).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(stub_err())
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_loudly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn hlo_parse_fails_in_stub() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
