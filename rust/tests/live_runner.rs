//! Integration: the live skeleton against sequential ground truth, through
//! the public API only — every shipped problem, multiple worker counts,
//! failure paths.

use std::sync::Arc;

use bsf::coordinator::{run_sequential, BsfProblem, LiveRunner};
use bsf::linalg::generators;
use bsf::problems::{CimminoProblem, GravityProblem, JacobiProblem, MonteCarloPi};

fn max_dev(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn jacobi_live_equals_sequential_across_k() {
    let seq = run_sequential(&JacobiProblem::new(generators::dominant_system(128), 1e-24), 500, None);
    assert!(seq.converged);
    for k in [1usize, 2, 4, 7, 16] {
        let p: Arc<dyn BsfProblem> =
            Arc::new(JacobiProblem::new(generators::dominant_system(128), 1e-24));
        let live = LiveRunner::new(k, 500).run(p).unwrap();
        assert_eq!(live.iterations, seq.iterations, "k={k}");
        assert!(max_dev(&live.final_approx, &seq.final_approx) < 1e-12, "k={k}");
    }
}

#[test]
fn gravity_live_equals_sequential_across_k() {
    let mk = || GravityProblem::new(generators::random_bodies(150, 5.0, 99), 1e-3, 1e-6);
    let seq = run_sequential(&mk(), 10_000, None);
    assert!(seq.converged);
    for k in [2usize, 5, 9] {
        let live = LiveRunner::new(k, 10_000).run(Arc::new(mk()) as Arc<dyn BsfProblem>).unwrap();
        assert_eq!(live.iterations, seq.iterations, "k={k}");
        assert!(max_dev(&live.final_approx, &seq.final_approx) < 1e-9, "k={k}");
    }
}

#[test]
fn cimmino_live_reaches_feasible_point() {
    let sys = generators::feasible_inequalities(400, 24, 0.1, 5);
    let p = CimminoProblem::new(sys, 1.5, 1e-20);
    let checker = CimminoProblem::new(generators::feasible_inequalities(400, 24, 0.1, 5), 1.5, 1e-20);
    let live = LiveRunner::new(6, 50_000).run(Arc::new(p) as Arc<dyn BsfProblem>).unwrap();
    assert!(live.converged);
    assert_eq!(checker.violated(&live.final_approx, 1e-6), 0);
}

#[test]
fn montecarlo_parallel_deterministic() {
    let mk = || MonteCarloPi::new(256, 32, 1e-6, 7);
    let seq = run_sequential(&mk(), 80, None);
    let live = LiveRunner::new(8, 80).run(Arc::new(mk()) as Arc<dyn BsfProblem>).unwrap();
    assert_eq!(seq.final_approx[0].to_bits(), live.final_approx[0].to_bits());
    assert!((seq.final_approx[0] - std::f64::consts::PI).abs() < 0.1);
}

#[test]
fn metrics_are_complete_and_positive() {
    let p: Arc<dyn BsfProblem> =
        Arc::new(JacobiProblem::new(generators::dominant_system(96), 1e-24));
    let r = LiveRunner::new(3, 20).run(p).unwrap();
    assert_eq!(r.metrics.len(), r.iterations);
    for it in &r.metrics.iterations {
        assert_eq!(it.map_fold.len(), 3);
        assert!(it.total > 0.0);
        assert!(it.post >= 0.0);
        assert!(it.comm >= 0.0);
    }
}

#[test]
fn many_workers_small_list() {
    // K > l: the skeleton must still be correct with empty sublists.
    let seq = run_sequential(&JacobiProblem::new(generators::dominant_system(5), 1e-24), 200, None);
    let p: Arc<dyn BsfProblem> =
        Arc::new(JacobiProblem::new(generators::dominant_system(5), 1e-24));
    let live = LiveRunner::new(12, 200).run(p).unwrap();
    assert_eq!(live.iterations, seq.iterations);
    assert!(max_dev(&live.final_approx, &seq.final_approx) < 1e-12);
}
